#!/usr/bin/env python
"""Docs checks, run by the CI ``docs`` job:

1. every intra-repo markdown link in README.md / ROADMAP.md / docs/*.md
   resolves to an existing file (http/mailto/anchor links are skipped,
   fenced code blocks and inline code spans are ignored);
2. every fenced ```python block in docs/*.md that contains doctest
   prompts (``>>>``) runs clean under doctest — blocks within one file
   share a namespace, so examples can build on each other.

    python tools/check_docs.py          # exits nonzero on any failure
"""

from __future__ import annotations

import doctest
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def _md_files() -> list[pathlib.Path]:
    files = [ROOT / "README.md", ROOT / "ROADMAP.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def _strip_code(text: str) -> str:
    """Remove fenced blocks and inline code spans so bracket/paren
    patterns inside code never read as markdown links."""
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return re.sub(r"`[^`]*`", "", text)


def check_links(files) -> list[str]:
    errors = []
    for md in files:
        for target in LINK_RE.findall(_strip_code(md.read_text())):
            if target.startswith(_SKIP_SCHEMES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (md.parent / rel).resolve().exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link -> "
                              f"{target}")
    return errors


def run_doctests(files) -> tuple[list[str], int]:
    errors, n_examples = [], 0
    parser = doctest.DocTestParser()
    runner = doctest.DocTestRunner(verbose=False,
                                   optionflags=doctest.ELLIPSIS)
    for md in files:
        blocks = [b for b in FENCE_RE.findall(md.read_text()) if ">>>" in b]
        if not blocks:
            continue
        # one shared namespace per file: later blocks may use earlier names
        test = parser.get_doctest("\n".join(blocks), {},
                                  str(md.relative_to(ROOT)), str(md), 0)
        n_examples += len(test.examples)
        out: list[str] = []
        result = runner.run(test, out=out.append)
        if result.failed:
            errors.append(f"{md.relative_to(ROOT)}: {result.failed} doctest "
                          f"failure(s)\n" + "".join(out))
    return errors, n_examples


def main() -> int:
    files = _md_files()
    link_errors = check_links(files)
    doc_errors, n_examples = run_doctests(
        [f for f in files if f.parent.name == "docs"])
    for e in link_errors + doc_errors:
        print(f"FAIL {e}", file=sys.stderr)
    n_links = sum(len(LINK_RE.findall(_strip_code(f.read_text())))
                  for f in files)
    print(f"checked {len(files)} markdown files: {n_links} links, "
          f"{n_examples} doctest examples; "
          f"{len(link_errors) + len(doc_errors)} failure(s)")
    return 1 if link_errors or doc_errors else 0


if __name__ == "__main__":
    sys.exit(main())
