#!/usr/bin/env python
"""Docs checks, run by the CI ``docs`` job:

1. every intra-repo markdown link in README.md / ROADMAP.md / docs/*.md
   resolves to an existing file (http/mailto/anchor links are skipped,
   fenced code blocks and inline code spans are ignored);
2. every fenced ```python block in docs/*.md that contains doctest
   prompts (``>>>``) runs clean under doctest — blocks within one file
   share a namespace, so examples can build on each other;
3. stale-reference check: every `module.py` / `function()` inline-code
   reference in docs/*.md resolves to a real file / a real ``def`` or
   ``class`` somewhere in the repo's python sources, so renames can't
   silently strand the documentation.

    python tools/check_docs.py          # exits nonzero on any failure
"""

from __future__ import annotations

import builtins
import doctest
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")

# stale-reference patterns over inline code spans (see check_code_refs):
# a `path/to/module.py` file reference, or a `name(...)` call reference
# (no nested parens — those are full expressions, not references).
INLINE_CODE_RE = re.compile(r"`([^`\n]+)`")
FILE_REF_RE = re.compile(r"^[\w./-]+\.py$")
CALL_REF_RE = re.compile(r"^[A-Za-z_][\w.]*\([^()]*\)$")
_PY_DIRS = ("src", "benchmarks", "tools", "examples", "tests")


def _md_files() -> list[pathlib.Path]:
    files = [ROOT / "README.md", ROOT / "ROADMAP.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def _strip_code(text: str) -> str:
    """Remove fenced blocks and inline code spans so bracket/paren
    patterns inside code never read as markdown links."""
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return re.sub(r"`[^`]*`", "", text)


def check_links(files) -> list[str]:
    errors = []
    for md in files:
        for target in LINK_RE.findall(_strip_code(md.read_text())):
            if target.startswith(_SKIP_SCHEMES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (md.parent / rel).resolve().exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link -> "
                              f"{target}")
    return errors


def _py_files() -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for sub in _PY_DIRS:
        out += sorted((ROOT / sub).rglob("*.py"))
    return out


def check_code_refs(files) -> tuple[list[str], int]:
    """Stale-reference check over docs/*.md: a `module.py` span must name
    a file that exists in the repo (matched by path suffix, so both
    `core/obs.py` and `src/repro/core/obs.py` work), and a `name(...)`
    span must name a ``def``/``class`` defined somewhere in the python
    sources (dotted spans check the last component, so
    `CounterTimeline.load()` checks ``load``).  Returns
    ``(errors, refs_checked)``."""
    py = _py_files()
    paths = {str(p.relative_to(ROOT)) for p in py}
    source = "\n".join(p.read_text() for p in py)
    errors: list[str] = []
    checked = 0
    for md in files:
        text = re.sub(r"```.*?```", "", md.read_text(), flags=re.DOTALL)
        for span in INLINE_CODE_RE.findall(text):
            span = span.strip()
            if FILE_REF_RE.match(span):
                checked += 1
                if not any(p == span or p.endswith("/" + span)
                           for p in paths):
                    errors.append(f"{md.relative_to(ROOT)}: stale file "
                                  f"reference `{span}`")
            elif CALL_REF_RE.match(span):
                name = span.split("(", 1)[0].rsplit(".", 1)[-1]
                if hasattr(builtins, name):
                    continue       # `len(samples)` isn't a repo reference
                checked += 1
                if not re.search(rf"^\s*(?:def|class)\s+{re.escape(name)}\b",
                                 source, re.MULTILINE):
                    errors.append(f"{md.relative_to(ROOT)}: stale function "
                                  f"reference `{span}`")
    return errors, checked


def run_doctests(files) -> tuple[list[str], int]:
    errors, n_examples = [], 0
    parser = doctest.DocTestParser()
    runner = doctest.DocTestRunner(verbose=False,
                                   optionflags=doctest.ELLIPSIS)
    for md in files:
        blocks = [b for b in FENCE_RE.findall(md.read_text()) if ">>>" in b]
        if not blocks:
            continue
        # one shared namespace per file: later blocks may use earlier names
        test = parser.get_doctest("\n".join(blocks), {},
                                  str(md.relative_to(ROOT)), str(md), 0)
        n_examples += len(test.examples)
        out: list[str] = []
        result = runner.run(test, out=out.append)
        if result.failed:
            errors.append(f"{md.relative_to(ROOT)}: {result.failed} doctest "
                          f"failure(s)\n" + "".join(out))
    return errors, n_examples


def main() -> int:
    files = _md_files()
    docs = [f for f in files if f.parent.name == "docs"]
    link_errors = check_links(files)
    doc_errors, n_examples = run_doctests(docs)
    ref_errors, n_refs = check_code_refs(docs)
    for e in link_errors + doc_errors + ref_errors:
        print(f"FAIL {e}", file=sys.stderr)
    n_links = sum(len(LINK_RE.findall(_strip_code(f.read_text())))
                  for f in files)
    n_fail = len(link_errors) + len(doc_errors) + len(ref_errors)
    print(f"checked {len(files)} markdown files: {n_links} links, "
          f"{n_examples} doctest examples, {n_refs} code references; "
          f"{n_fail} failure(s)")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
