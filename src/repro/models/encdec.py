"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB (per assignment): ``input_specs`` provides
precomputed frame features (B, T_enc, n_mels) which a linear projection
lifts to d_model.  Encoder layers are bidirectional; decoder layers are
causal self-attention + cross-attention over the encoder output.
Positions are sinusoidal (whisper uses learned/sinusoidal, no RoPE).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.attention import (attend, attend_naive, attention_init,
                                    output_project, qkv_project)
from repro.layers.common import constrain, dense_init, dtype_of, rmsnorm, rmsnorm_init, stacked_init
from repro.layers.embedding import embed, embedding_init, logits as logits_fn
from repro.layers.kvcache import (kv_cache_init, kv_update, kv_update_slots,
                                  slot_validity)
from repro.layers.mlp import mlp, mlp_init
from repro.layers.rope import sinusoidal_positions
from repro.models.losses import ce_metrics, chunked_ce_loss


def encdec_init(rng, cfg: ModelConfig) -> dict:
    a = cfg.attention
    r = jax.random.split(rng, 5)

    def enc_layer(lr):
        ks = jax.random.split(lr, 2)
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": attention_init(ks[0], cfg.d_model, a.num_heads,
                                   a.num_kv_heads, cfg.head_dim),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=False),
        }

    def dec_layer(lr):
        ks = jax.random.split(lr, 3)
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "self_attn": attention_init(ks[0], cfg.d_model, a.num_heads,
                                        a.num_kv_heads, cfg.head_dim),
            "norm_x": rmsnorm_init(cfg.d_model),
            "cross_attn": attention_init(ks[1], cfg.d_model, a.num_heads,
                                         a.num_kv_heads, cfg.head_dim),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, gated=False),
        }

    return {
        "frontend": dense_init(r[0], cfg.frontend_dim, cfg.d_model),
        "enc_layers": stacked_init(r[1], cfg.encoder_layers, enc_layer),
        "enc_norm": rmsnorm_init(cfg.d_model),
        "embed": embedding_init(r[2], cfg.vocab_size, cfg.d_model,
                                tied=cfg.tie_embeddings),
        "layers": stacked_init(r[3], cfg.num_layers, dec_layer),
        "final_norm": rmsnorm_init(cfg.d_model),
    }


def encode(params, cfg: ModelConfig, frames: jax.Array, *, dp=None,
           impl="flash"):
    """frames: (B, T, n_mels) -> (B, T, D)."""
    dtype = dtype_of(cfg.dtype)
    a = cfg.attention
    x = jnp.einsum("btf,fd->btd", frames.astype(dtype),
                   params["frontend"].astype(dtype))
    t = x.shape[1]
    x = x + sinusoidal_positions(t, cfg.d_model).astype(dtype)
    x = constrain(dp, x, ("batch", "seq", "embed"), tag="enc/in")
    positions = jnp.arange(t, dtype=jnp.int32)

    def body(x, lp):
        h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
        q, k, v = qkv_project(lp["attn"], h, num_kv_heads=a.num_kv_heads,
                              positions=positions, theta=None,
                              qk_norm=False, eps=cfg.norm_eps, dp=dp)
        o = attend(q, k, v, q_pos=positions, k_pos=positions,
                   causal=False, window=None, impl=impl)
        x = x + output_project(lp["attn"], o, dp=dp)
        h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
        x = x + mlp(lp["mlp"], h, act=cfg.act_fn, dp=dp)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(lp, x, enc, *, cfg, dp, positions, enc_positions, mode,
               cache_k=None, cache_v=None, cross_k=None, cross_v=None,
               cache_pos=None, impl="flash"):
    a = cfg.attention
    # self attention
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    q, k, v = qkv_project(lp["self_attn"], h, num_kv_heads=a.num_kv_heads,
                          positions=positions, theta=None, qk_norm=False,
                          eps=cfg.norm_eps, dp=dp)
    if mode == "decode":
        cache_k, cache_v = kv_update(cache_k, cache_v, k, v, cache_pos)
        s_max = cache_k.shape[1]
        k_pos = jnp.arange(s_max, dtype=jnp.int32)
        o = attend(q, cache_k, cache_v, q_pos=positions, k_pos=k_pos,
                   causal=True, window=None, k_valid=k_pos <= cache_pos,
                   impl="flash", q_block=1)
    elif mode == "decode_slots":
        # fixed-shape slot decode: per-slot write positions (B,), batched
        # validity mask, naive attend at q=1 (transformer idiom).  The
        # cross-attention cache below is a per-slot *snapshot* of the
        # encoder's k/v — inserted whole by state_slot_insert, never
        # advanced — so slots only differ in their self-attention state.
        cache_k, cache_v = kv_update_slots(cache_k, cache_v, k, v, cache_pos)
        s_max = cache_k.shape[1]
        valid = slot_validity(s_max, cache_pos)               # (B, S_max)
        o = attend_naive(q, cache_k, cache_v, valid[:, None, :])
    else:
        if cache_k is not None:
            cache_k, cache_v = kv_update(cache_k, cache_v, k, v, 0)
        o = attend(q, k, v, q_pos=positions, k_pos=positions, causal=True,
                   window=None, impl=impl)
    x = x + output_project(lp["self_attn"], o, dp=dp)

    # cross attention
    h = rmsnorm(lp["norm_x"], x, cfg.norm_eps)
    if mode in ("decode", "decode_slots"):
        qc = jnp.einsum("bsd,dhe->bshe", h,
                        lp["cross_attn"]["wq"].astype(h.dtype))
        kc, vc = cross_k, cross_v
    else:
        qc, kc, vc = qkv_project(lp["cross_attn"], h,
                                 num_kv_heads=a.num_kv_heads,
                                 positions=positions, theta=None,
                                 qk_norm=False, eps=cfg.norm_eps, dp=dp,
                                 kv_input=enc)
        cross_k, cross_v = kc, vc
    if mode == "decode_slots":
        # every encoder position is valid for every slot (the snapshot is
        # full-length); positions here are per-slot (B, 1), which the
        # shared make_mask path can't express — the all-true naive mask is
        # the exact equivalent of the unmasked causal=False attend.
        all_enc = jnp.ones((qc.shape[0], 1, kc.shape[1]), bool)
        o = attend_naive(qc, kc, vc, all_enc)
    else:
        o = attend(qc, kc, vc, q_pos=positions, k_pos=enc_positions,
                   causal=False, window=None, impl=impl)
    x = x + output_project(lp["cross_attn"], o, dp=dp)

    # mlp
    h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    x = x + mlp(lp["mlp"], h, act=cfg.act_fn, dp=dp)
    x = constrain(dp, x, ("batch", "seq_resid", "embed"), tag="layer/out")
    return x, cache_k, cache_v, cross_k, cross_v


def encdec_apply(params, cfg: ModelConfig, batch: dict, *, dp=None,
                 cache=None, train=False, remat="none", impl="flash"):
    dtype = dtype_of(cfg.dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    enc = encode(params, cfg, batch["frames"], dp=dp, impl=impl)
    t = enc.shape[1]
    enc_positions = jnp.arange(t, dtype=jnp.int32)

    x = embed(params["embed"], tokens, dtype, scale=False, dp=dp)
    x = x + sinusoidal_positions(s, cfg.d_model).astype(dtype)
    positions = jnp.arange(s, dtype=jnp.int32)
    mode = "prefill" if cache is not None else "train"

    def body(x, xs):
        if cache is not None:
            lp, ck, cv = xs
        else:
            lp = xs
            ck = cv = None
        x, ck, cv, xk, xv = _dec_layer(
            lp, x, enc, cfg=cfg, dp=dp, positions=positions,
            enc_positions=enc_positions, mode=mode, cache_k=ck, cache_v=cv,
            impl=impl)
        ys = (ck, cv, xk, xv) if cache is not None else None
        return x, ys

    if remat in ("full", "dots"):
        pol = None if remat == "full" else jax.checkpoint_policies.checkpoint_dots
        body = jax.checkpoint(body, policy=pol, prevent_cse=False)

    xs = (params["layers"], cache["k"], cache["v"]) if cache is not None \
        else params["layers"]
    x, ys = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_cache = None
    if cache is not None:
        new_cache = {"k": ys[0], "v": ys[1], "cross_k": ys[2],
                     "cross_v": ys[3]}
    return x, jnp.zeros((), jnp.float32), new_cache, 0


def encdec_loss(params, cfg, batch, *, dp=None, rng=None, remat="none",
                impl="flash"):
    x, aux, _, _ = encdec_apply(params, cfg, batch, dp=dp, train=True,
                                remat=remat, impl=impl)
    table = params["embed"].get("head", params["embed"]["tok"])
    loss, correct, count = chunked_ce_loss(x, table, batch["labels"], dp=dp)
    m = ce_metrics(loss, correct, count, aux)
    return m["loss"], m


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    a = cfg.attention
    kv = kv_cache_init(cfg.num_layers, batch, max_len, a.num_kv_heads,
                       cfg.head_dim, dtype=dtype_of(cfg.dtype))
    # cross k/v get filled at prefill (encoder length)
    t = cfg.encoder_max_len
    kv["cross_k"] = jnp.zeros((cfg.num_layers, batch, t, a.num_kv_heads,
                               cfg.head_dim), dtype_of(cfg.dtype))
    kv["cross_v"] = jnp.zeros_like(kv["cross_k"])
    return kv


def encdec_prefill(params, cfg, batch, cache, *, dp=None, impl="flash",
                   last_pos=None):
    """Decoder prefill: fills the self-attention cache AND snapshots the
    encoder's projected k/v into the per-slot cross_k/cross_v cache.

    The serve engine submits token-only batches; the conv/mel frontend is
    a stub, so when ``frames`` is absent a zero frame window of the
    configured encoder geometry is synthesized — deterministic, identical
    across gang and continuous paths.  ``last_pos`` (B,) picks the hidden
    position whose logits are returned (right padding after the prompt is
    causally inert for the decoder, so bucketed prefill stays exact)."""
    if "frames" not in batch:
        b = batch["tokens"].shape[0]
        batch = dict(batch, frames=jnp.zeros(
            (b, cfg.encoder_max_len, cfg.frontend_dim), jnp.float32))
    x, _aux, cache, _ = encdec_apply(params, cfg, batch, dp=dp, cache=cache,
                                     impl=impl)
    if last_pos is None:
        last = x[:, -1:, :]
    else:
        idx = jnp.asarray(last_pos, jnp.int32)
        last = x[jnp.arange(x.shape[0]), idx][:, None, :]
    return logits_fn(params["embed"], last, dp=dp), cache


def encdec_decode_step(params, cfg, token, cache, pos, *, dp=None, **_):
    dtype = dtype_of(cfg.dtype)
    b = token.shape[0]
    x = embed(params["embed"], token, dtype, scale=False, dp=dp)
    # sinusoidal position for the current step
    tbl = sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
    x = x + jax.lax.dynamic_slice_in_dim(tbl, pos, 1, 0)[None].astype(dtype)
    positions = jnp.full((1,), pos, jnp.int32)
    t = cache["cross_k"].shape[2]
    enc_positions = jnp.arange(t, dtype=jnp.int32)

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        x, ck, cv, _, _ = _dec_layer(
            lp, x, None, cfg=cfg, dp=dp, positions=positions,
            enc_positions=enc_positions, mode="decode", cache_k=ck,
            cache_v=cv, cross_k=xk, cross_v=xv, cache_pos=pos)
        return x, (ck, cv, xk, xv)

    xs = (params["layers"], cache["k"], cache["v"], cache["cross_k"],
          cache["cross_v"])
    x, ys = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_cache = {"k": ys[0], "v": ys[1], "cross_k": ys[2], "cross_v": ys[3]}
    return logits_fn(params["embed"], x, dp=dp), new_cache


def encdec_decode_step_slots(params, cfg, token, cache, pos, *, dp=None, **_):
    """Fixed-shape slot decode: every slot advances one token at its own
    position ``pos`` (B,).  Sinusoidal positions are gathered per slot
    (``tbl[pos]``) instead of the gang path's scalar slice; self-attention
    masks per slot; cross-attention reads each slot's full encoder
    snapshot (cross_k/cross_v rows inserted by ``state_slot_insert``)."""
    dtype = dtype_of(cfg.dtype)
    x = embed(params["embed"], token, dtype, scale=False, dp=dp)
    pos = jnp.asarray(pos, jnp.int32)
    tbl = sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
    x = x + tbl[pos][:, None, :].astype(dtype)            # (B, 1, D)
    positions = pos[:, None]
    t = cache["cross_k"].shape[2]
    enc_positions = jnp.arange(t, dtype=jnp.int32)

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        x, ck, cv, _, _ = _dec_layer(
            lp, x, None, cfg=cfg, dp=dp, positions=positions,
            enc_positions=enc_positions, mode="decode_slots", cache_k=ck,
            cache_v=cv, cross_k=xk, cross_v=xv, cache_pos=pos)
        return x, (ck, cv, xk, xv)

    xs = (params["layers"], cache["k"], cache["v"], cache["cross_k"],
          cache["cross_v"])
    x, ys = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_cache = {"k": ys[0], "v": ys[1], "cross_k": ys[2], "cross_v": ys[3]}
    return logits_fn(params["embed"], x, dp=dp), new_cache


__all__ = ["encdec_init", "encdec_apply", "encdec_loss", "encdec_init_cache",
           "encdec_prefill", "encdec_decode_step", "encdec_decode_step_slots",
           "encode"]
