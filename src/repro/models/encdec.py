"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB (per assignment): ``input_specs`` provides
precomputed frame features (B, T_enc, n_mels) which a linear projection
lifts to d_model.  Encoder layers are bidirectional; decoder layers are
causal self-attention + cross-attention over the encoder output.
Positions are sinusoidal (whisper uses learned/sinusoidal, no RoPE).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.attention import attend, attention_init, output_project, qkv_project
from repro.layers.common import constrain, dense_init, dtype_of, rmsnorm, rmsnorm_init, stacked_init
from repro.layers.embedding import embed, embedding_init, logits as logits_fn
from repro.layers.kvcache import kv_cache_init, kv_update
from repro.layers.mlp import mlp, mlp_init
from repro.layers.rope import sinusoidal_positions
from repro.models.losses import ce_metrics, chunked_ce_loss


def encdec_init(rng, cfg: ModelConfig) -> dict:
    a = cfg.attention
    r = jax.random.split(rng, 5)

    def enc_layer(lr):
        ks = jax.random.split(lr, 2)
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": attention_init(ks[0], cfg.d_model, a.num_heads,
                                   a.num_kv_heads, cfg.head_dim),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=False),
        }

    def dec_layer(lr):
        ks = jax.random.split(lr, 3)
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "self_attn": attention_init(ks[0], cfg.d_model, a.num_heads,
                                        a.num_kv_heads, cfg.head_dim),
            "norm_x": rmsnorm_init(cfg.d_model),
            "cross_attn": attention_init(ks[1], cfg.d_model, a.num_heads,
                                         a.num_kv_heads, cfg.head_dim),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, gated=False),
        }

    return {
        "frontend": dense_init(r[0], cfg.frontend_dim, cfg.d_model),
        "enc_layers": stacked_init(r[1], cfg.encoder_layers, enc_layer),
        "enc_norm": rmsnorm_init(cfg.d_model),
        "embed": embedding_init(r[2], cfg.vocab_size, cfg.d_model,
                                tied=cfg.tie_embeddings),
        "layers": stacked_init(r[3], cfg.num_layers, dec_layer),
        "final_norm": rmsnorm_init(cfg.d_model),
    }


def encode(params, cfg: ModelConfig, frames: jax.Array, *, dp=None,
           impl="flash"):
    """frames: (B, T, n_mels) -> (B, T, D)."""
    dtype = dtype_of(cfg.dtype)
    a = cfg.attention
    x = jnp.einsum("btf,fd->btd", frames.astype(dtype),
                   params["frontend"].astype(dtype))
    t = x.shape[1]
    x = x + sinusoidal_positions(t, cfg.d_model).astype(dtype)
    x = constrain(dp, x, ("batch", "seq", "embed"), tag="enc/in")
    positions = jnp.arange(t, dtype=jnp.int32)

    def body(x, lp):
        h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
        q, k, v = qkv_project(lp["attn"], h, num_kv_heads=a.num_kv_heads,
                              positions=positions, theta=None,
                              qk_norm=False, eps=cfg.norm_eps, dp=dp)
        o = attend(q, k, v, q_pos=positions, k_pos=positions,
                   causal=False, window=None, impl=impl)
        x = x + output_project(lp["attn"], o, dp=dp)
        h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
        x = x + mlp(lp["mlp"], h, act=cfg.act_fn, dp=dp)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(lp, x, enc, *, cfg, dp, positions, enc_positions, mode,
               cache_k=None, cache_v=None, cross_k=None, cross_v=None,
               cache_pos=None, impl="flash"):
    a = cfg.attention
    # self attention
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    q, k, v = qkv_project(lp["self_attn"], h, num_kv_heads=a.num_kv_heads,
                          positions=positions, theta=None, qk_norm=False,
                          eps=cfg.norm_eps, dp=dp)
    if mode == "decode":
        cache_k, cache_v = kv_update(cache_k, cache_v, k, v, cache_pos)
        s_max = cache_k.shape[1]
        k_pos = jnp.arange(s_max, dtype=jnp.int32)
        o = attend(q, cache_k, cache_v, q_pos=positions, k_pos=k_pos,
                   causal=True, window=None, k_valid=k_pos <= cache_pos,
                   impl="flash", q_block=1)
    else:
        if cache_k is not None:
            cache_k, cache_v = kv_update(cache_k, cache_v, k, v, 0)
        o = attend(q, k, v, q_pos=positions, k_pos=positions, causal=True,
                   window=None, impl=impl)
    x = x + output_project(lp["self_attn"], o, dp=dp)

    # cross attention
    h = rmsnorm(lp["norm_x"], x, cfg.norm_eps)
    if mode == "decode":
        qc = jnp.einsum("bsd,dhe->bshe", h,
                        lp["cross_attn"]["wq"].astype(h.dtype))
        kc, vc = cross_k, cross_v
    else:
        qc, kc, vc = qkv_project(lp["cross_attn"], h,
                                 num_kv_heads=a.num_kv_heads,
                                 positions=positions, theta=None,
                                 qk_norm=False, eps=cfg.norm_eps, dp=dp,
                                 kv_input=enc)
        cross_k, cross_v = kc, vc
    o = attend(qc, kc, vc, q_pos=positions, k_pos=enc_positions,
               causal=False, window=None, impl=impl)
    x = x + output_project(lp["cross_attn"], o, dp=dp)

    # mlp
    h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    x = x + mlp(lp["mlp"], h, act=cfg.act_fn, dp=dp)
    x = constrain(dp, x, ("batch", "seq_resid", "embed"), tag="layer/out")
    return x, cache_k, cache_v, cross_k, cross_v


def encdec_apply(params, cfg: ModelConfig, batch: dict, *, dp=None,
                 cache=None, train=False, remat="none", impl="flash"):
    dtype = dtype_of(cfg.dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    enc = encode(params, cfg, batch["frames"], dp=dp, impl=impl)
    t = enc.shape[1]
    enc_positions = jnp.arange(t, dtype=jnp.int32)

    x = embed(params["embed"], tokens, dtype, scale=False, dp=dp)
    x = x + sinusoidal_positions(s, cfg.d_model).astype(dtype)
    positions = jnp.arange(s, dtype=jnp.int32)
    mode = "prefill" if cache is not None else "train"

    def body(x, xs):
        if cache is not None:
            lp, ck, cv = xs
        else:
            lp = xs
            ck = cv = None
        x, ck, cv, xk, xv = _dec_layer(
            lp, x, enc, cfg=cfg, dp=dp, positions=positions,
            enc_positions=enc_positions, mode=mode, cache_k=ck, cache_v=cv,
            impl=impl)
        ys = (ck, cv, xk, xv) if cache is not None else None
        return x, ys

    if remat in ("full", "dots"):
        pol = None if remat == "full" else jax.checkpoint_policies.checkpoint_dots
        body = jax.checkpoint(body, policy=pol, prevent_cse=False)

    xs = (params["layers"], cache["k"], cache["v"]) if cache is not None \
        else params["layers"]
    x, ys = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_cache = None
    if cache is not None:
        new_cache = {"k": ys[0], "v": ys[1], "cross_k": ys[2],
                     "cross_v": ys[3]}
    return x, jnp.zeros((), jnp.float32), new_cache, 0


def encdec_loss(params, cfg, batch, *, dp=None, rng=None, remat="none",
                impl="flash"):
    x, aux, _, _ = encdec_apply(params, cfg, batch, dp=dp, train=True,
                                remat=remat, impl=impl)
    table = params["embed"].get("head", params["embed"]["tok"])
    loss, correct, count = chunked_ce_loss(x, table, batch["labels"], dp=dp)
    m = ce_metrics(loss, correct, count, aux)
    return m["loss"], m


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    a = cfg.attention
    kv = kv_cache_init(cfg.num_layers, batch, max_len, a.num_kv_heads,
                       cfg.head_dim, dtype=dtype_of(cfg.dtype))
    # cross k/v get filled at prefill (encoder length)
    t = cfg.encoder_max_len
    kv["cross_k"] = jnp.zeros((cfg.num_layers, batch, t, a.num_kv_heads,
                               cfg.head_dim), dtype_of(cfg.dtype))
    kv["cross_v"] = jnp.zeros_like(kv["cross_k"])
    return kv


def encdec_prefill(params, cfg, batch, cache, *, dp=None, impl="flash"):
    x, _aux, cache, _ = encdec_apply(params, cfg, batch, dp=dp, cache=cache,
                                     impl=impl)
    return logits_fn(params["embed"], x[:, -1:, :], dp=dp), cache


def encdec_decode_step(params, cfg, token, cache, pos, *, dp=None, **_):
    dtype = dtype_of(cfg.dtype)
    b = token.shape[0]
    x = embed(params["embed"], token, dtype, scale=False, dp=dp)
    # sinusoidal position for the current step
    tbl = sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
    x = x + jax.lax.dynamic_slice_in_dim(tbl, pos, 1, 0)[None].astype(dtype)
    positions = jnp.full((1,), pos, jnp.int32)
    t = cache["cross_k"].shape[2]
    enc_positions = jnp.arange(t, dtype=jnp.int32)

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        x, ck, cv, _, _ = _dec_layer(
            lp, x, None, cfg=cfg, dp=dp, positions=positions,
            enc_positions=enc_positions, mode="decode", cache_k=ck,
            cache_v=cv, cross_k=xk, cross_v=xv, cache_pos=pos)
        return x, (ck, cv, xk, xv)

    xs = (params["layers"], cache["k"], cache["v"], cache["cross_k"],
          cache["cross_v"])
    x, ys = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_cache = {"k": ys[0], "v": ys[1], "cross_k": ys[2], "cross_v": ys[3]}
    return logits_fn(params["embed"], x, dp=dp), new_cache


__all__ = ["encdec_init", "encdec_apply", "encdec_loss", "encdec_init_cache",
           "encdec_prefill", "encdec_decode_step", "encode"]
