"""Hymba-style hybrid LM: every block runs attention heads and a mamba
SSM in parallel on the same (normed) input, combining the two branch
outputs (each RMS-normed) by averaging.  Sliding-window attention on all
but the first / middle / last layers; the SSM state plus windowed KV is
what makes the 500k decode cell feasible.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.attention import (attention_init, attend, attend_naive,
                                    output_project, qkv_project)
from repro.layers.common import constrain, dtype_of, rmsnorm, rmsnorm_init, stacked_init
from repro.layers.embedding import embed, embedding_init, logits as logits_fn
from repro.layers.kvcache import (kv_cache_init, kv_update, kv_update_slots,
                                  slot_validity)
from repro.layers.mamba import mamba, mamba_init, mamba_state_init
from repro.layers.mlp import mlp, mlp_init
from repro.models.losses import ce_metrics, chunked_ce_loss
from repro.models.transformer import layer_flags


def hybrid_init(rng, cfg: ModelConfig) -> dict:
    a = cfg.attention
    r = jax.random.split(rng, 3)

    def one_layer(lr):
        ks = jax.random.split(lr, 3)
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "norm2": rmsnorm_init(cfg.d_model),
            "attn": attention_init(ks[0], cfg.d_model, a.num_heads,
                                   a.num_kv_heads, cfg.head_dim),
            "attn_norm": rmsnorm_init(cfg.d_model),
            "mamba": mamba_init(ks[1], cfg.d_model, cfg.ssm),
            "mamba_norm": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp),
        }

    return {
        "embed": embedding_init(r[0], cfg.vocab_size, cfg.d_model,
                                tied=cfg.tie_embeddings),
        "layers": stacked_init(r[1], cfg.num_layers, one_layer),
        "final_norm": rmsnorm_init(cfg.d_model),
    }


def _block(lp, x, *, cfg, dp, positions, window, theta, mode,
           cache=None, cache_pos=None, impl="flash", q_block=512,
           kv_block=1024):
    a = cfg.attention
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)

    # --- attention branch ---
    q, k, v = qkv_project(lp["attn"], h, num_kv_heads=a.num_kv_heads,
                          positions=positions, theta=theta, qk_norm=False,
                          eps=cfg.norm_eps, dp=dp)
    new_cache = dict(cache) if cache is not None else None
    if mode == "decode":
        ck, cv = kv_update(cache["k"], cache["v"], k, v, cache_pos)
        s_max = ck.shape[1]
        k_pos = jnp.arange(s_max, dtype=jnp.int32)
        o = attend(q, ck, cv, q_pos=positions, k_pos=k_pos, causal=True,
                   window=window, k_valid=k_pos <= cache_pos,
                   impl="flash", q_block=1, kv_block=kv_block)
        new_cache["k"], new_cache["v"] = ck, cv
    elif mode == "decode_slots":
        # fixed-shape slot decode (serve/engine.py): q len 1 per slot,
        # per-slot write positions ``cache_pos`` (B,).  Same batched-mask
        # naive attend as the transformer's decode_slots — exact and tiny
        # at q=1.  The mamba branch below is already per-row recurrent, so
        # only the attention mask changes between gang and slot decode.
        ck, cv = kv_update_slots(cache["k"], cache["v"], k, v, cache_pos)
        s_max = ck.shape[1]
        k_pos = jnp.arange(s_max, dtype=jnp.int32)
        valid = slot_validity(s_max, cache_pos)               # (B, S_max)
        w = jnp.asarray(window)
        valid &= jnp.where(w > 0,
                           cache_pos[:, None] - k_pos[None, :] < w, True)
        o = attend_naive(q, ck, cv, valid[:, None, :])
        new_cache["k"], new_cache["v"] = ck, cv
    else:
        if cache is not None:  # prefill
            new_cache["k"], new_cache["v"] = kv_update(cache["k"], cache["v"],
                                                       k, v, 0)
        o = attend(q, k, v, q_pos=positions, k_pos=positions,
                   causal=True, window=window, impl=impl,
                   q_block=q_block, kv_block=kv_block)
    attn_out = output_project(lp["attn"], o, dp=dp)

    # --- mamba branch (parallel, same input) ---
    st = {"conv": cache["conv"], "h": cache["h"]} if cache is not None else None
    m_out, m_state = mamba(lp["mamba"], h, cfg.ssm, state=st, dp=dp)
    if new_cache is not None:
        new_cache["conv"], new_cache["h"] = m_state["conv"], m_state["h"]

    x = x + 0.5 * (rmsnorm(lp["attn_norm"], attn_out, cfg.norm_eps)
                   + rmsnorm(lp["mamba_norm"], m_out, cfg.norm_eps))

    h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    x = x + mlp(lp["mlp"], h, act=cfg.act_fn, dp=dp)
    x = constrain(dp, x, ("batch", "seq_resid", "embed"), tag="layer/out")
    return x, new_cache


def hybrid_apply(params, cfg: ModelConfig, batch: dict, *, dp=None,
                 cache=None, train=False, remat="none", impl="flash",
                 q_block=512, kv_block=1024):
    dtype = dtype_of(cfg.dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed(params["embed"], tokens, dtype, dp=dp)
    positions = jnp.arange(s, dtype=jnp.int32)
    window_arr, theta_arr = layer_flags(cfg)
    mode = "prefill" if cache is not None else "train"

    def body(carry, xs):
        x = carry
        if cache is not None:
            lp, w, th, c = xs
        else:
            lp, w, th = xs
            c = None
        x, c = _block(lp, x, cfg=cfg, dp=dp, positions=positions, window=w,
                      theta=th, mode=mode, cache=c, impl=impl,
                      q_block=q_block, kv_block=kv_block)
        return x, c

    if remat in ("full", "dots"):
        pol = (None if remat == "full"
               else jax.checkpoint_policies.checkpoint_dots)
        body = jax.checkpoint(body, policy=pol, prevent_cse=False)

    xs = (params["layers"], jnp.asarray(window_arr), jnp.asarray(theta_arr))
    if cache is not None:
        xs = xs + (cache,)
    x, new_cache = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32), new_cache, 0


def hybrid_loss(params, cfg, batch, *, dp=None, rng=None, remat="none",
                impl="flash"):
    x, aux, _, _ = hybrid_apply(params, cfg, batch, dp=dp, train=True,
                                remat=remat, impl=impl)
    table = params["embed"].get("head", params["embed"]["tok"])
    loss, correct, count = chunked_ce_loss(x, table, batch["labels"], dp=dp)
    m = ce_metrics(loss, correct, count, aux)
    return m["loss"], m


def hybrid_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    a = cfg.attention
    kv = kv_cache_init(cfg.num_layers, batch, max_len, a.num_kv_heads,
                       cfg.head_dim, dtype=dtype_of(cfg.dtype))
    st = mamba_state_init(batch, cfg.d_model, cfg.ssm, dtype_of(cfg.dtype))
    L = cfg.num_layers
    return {
        "k": kv["k"], "v": kv["v"],
        "conv": jnp.broadcast_to(st["conv"][None], (L,) + st["conv"].shape).astype(jnp.float32),
        "h": jnp.broadcast_to(st["h"][None], (L,) + st["h"].shape),
    }


def hybrid_prefill(params, cfg, batch, cache, *, dp=None, impl="flash",
                   last_pos=None):
    """Fill attention cache + mamba state with the prompt.

    ``last_pos`` (B,) selects which hidden position feeds the logits.
    Unlike the transformer, right padding is NOT harmless here — padding
    tokens advance the mamba recurrence — so the serve engine prefills
    recurrent families at exact prompt length (``Model.recurrent``)."""
    x, _aux, cache, _ = hybrid_apply(params, cfg, batch, dp=dp, cache=cache,
                                     impl=impl)
    if last_pos is None:
        last = x[:, -1:, :]
    else:
        idx = jnp.asarray(last_pos, jnp.int32)
        last = x[jnp.arange(x.shape[0]), idx][:, None, :]
    return logits_fn(params["embed"], last, dp=dp), cache


def hybrid_decode_step(params, cfg, token, cache, pos, *, dp=None,
                       kv_block=1024):
    dtype = dtype_of(cfg.dtype)
    b = token.shape[0]
    x = embed(params["embed"], token, dtype, dp=dp)
    positions = jnp.full((1,), pos, jnp.int32)
    window_arr, theta_arr = layer_flags(cfg)

    def body(x, xs):
        lp, w, th, c = xs
        x, c = _block(lp, x, cfg=cfg, dp=dp, positions=positions, window=w,
                      theta=th, mode="decode", cache=c, cache_pos=pos,
                      kv_block=kv_block)
        return x, c

    xs = (params["layers"], jnp.asarray(window_arr), jnp.asarray(theta_arr),
          cache)
    x, new_cache = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params["embed"], x, dp=dp), new_cache


def hybrid_decode_step_slots(params, cfg, token, cache, pos, *, dp=None,
                             kv_block=1024):
    """Fixed-shape slot decode: advance every slot one token at its own
    position ``pos`` (B,).  The attention branch masks per slot; the mamba
    branch is per-row recurrent state and needs no masking — a freed
    slot's state evolves harmlessly until ``state_slot_insert`` replaces
    the whole row."""
    dtype = dtype_of(cfg.dtype)
    x = embed(params["embed"], token, dtype, dp=dp)
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None]                              # (B, 1)
    window_arr, theta_arr = layer_flags(cfg)

    def body(x, xs):
        lp, w, th, c = xs
        x, c = _block(lp, x, cfg=cfg, dp=dp, positions=positions, window=w,
                      theta=th, mode="decode_slots", cache=c, cache_pos=pos,
                      kv_block=kv_block)
        return x, c

    xs = (params["layers"], jnp.asarray(window_arr), jnp.asarray(theta_arr),
          cache)
    x, new_cache = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params["embed"], x, dp=dp), new_cache


__all__ = ["hybrid_init", "hybrid_apply", "hybrid_loss", "hybrid_init_cache",
           "hybrid_prefill", "hybrid_decode_step", "hybrid_decode_step_slots"]
