"""Decoder-only transformer LM covering the dense, MoE and VLM families
(gemma3-4b/1b, granite-34b/3-2b, llava-next-34b, arctic-480b, grok-1-314b).

The layer stack is a single ``lax.scan`` over stacked per-layer params;
per-layer heterogeneity (gemma3's 5:1 local:global pattern, per-layer RoPE
theta) rides along as scanned flag arrays, so the traced HLO contains ONE
layer body regardless of depth — which is what keeps 88-layer granite
compilable at 512-way SPMD.

All communication edges are issued through the CoRD dataplane (``dp``).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.layers.attention import (
    attend,
    attend_naive,
    attention_init,
    output_project,
    qkv_project,
)
from repro.layers.common import constrain, dense_init, dtype_of, rmsnorm, rmsnorm_init, stacked_init
from repro.layers.embedding import embed, embedding_init
from repro.layers.kvcache import (
    kv_cache_init,
    kv_update,
    kv_update_slots,
    slot_validity,
)
from repro.layers.mlp import mlp, mlp_init
from repro.layers.moe import moe, moe_init
from repro.models.losses import ce_metrics, chunked_ce_loss

BIG_WINDOW = 0  # window value meaning "no window" in make_mask


# ---------------------------------------------------------------------------
# per-layer flags (local/global pattern, per-layer rope theta)
# ---------------------------------------------------------------------------

def layer_flags(cfg: ModelConfig) -> tuple[np.ndarray, np.ndarray]:
    a = cfg.attention
    L = cfg.num_layers
    if a.local_global_ratio > 0 and a.sliding_window > 0:
        # pattern: r local layers then 1 global, repeating (gemma3)
        r = a.local_global_ratio
        is_global = np.array([(i % (r + 1)) == r for i in range(L)])
    elif cfg.family == "hybrid" and a.sliding_window > 0:
        # hymba: first / middle / last layers are global
        is_global = np.zeros(L, bool)
        is_global[[0, L // 2, L - 1]] = True
    elif a.sliding_window > 0:
        is_global = np.zeros(L, bool)
    else:
        is_global = np.ones(L, bool)
    theta_g = a.rope_theta_global or a.rope_theta
    theta = np.where(is_global, theta_g, a.rope_theta).astype(np.float32)
    window = np.where(is_global, 0, a.sliding_window).astype(np.int32)
    return window, theta


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def transformer_init(rng, cfg: ModelConfig) -> dict:
    a = cfg.attention
    r = jax.random.split(rng, 4)

    def one_layer(lr):
        ks = jax.random.split(lr, 2)
        p = {
            "norm1": rmsnorm_init(cfg.d_model),
            "norm2": rmsnorm_init(cfg.d_model),
            "attn": attention_init(ks[0], cfg.d_model, a.num_heads,
                                   a.num_kv_heads, cfg.head_dim,
                                   qk_norm=a.qk_norm),
        }
        if cfg.family == "moe":
            p["moe"] = moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.moe,
                                gated=cfg.gated_mlp)
        else:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                gated=cfg.gated_mlp)
        return p

    params = {
        "embed": embedding_init(r[0], cfg.vocab_size, cfg.d_model,
                                tied=cfg.tie_embeddings),
        "layers": stacked_init(r[1], cfg.num_layers, one_layer),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if cfg.family == "vlm":
        params["vision_proj"] = dense_init(r[2], cfg.frontend_dim, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# layer body (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _layer(lp, x, *, cfg, dp, positions, window, theta, mode,
           cache_k=None, cache_v=None, cache_pos=None, kv_len=None,
           train=False, impl="flash", q_block=512, kv_block=1024):
    a = cfg.attention
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    q, k, v = qkv_project(lp["attn"], h, num_kv_heads=a.num_kv_heads,
                          positions=positions, theta=theta,
                          qk_norm=a.qk_norm, eps=cfg.norm_eps, dp=dp)
    aux = jnp.zeros((), jnp.float32)
    if mode == "train":
        o = attend(q, k, v, q_pos=positions, k_pos=positions,
                   causal=True, window=window, logit_cap=a.logit_softcap,
                   impl=impl, q_block=q_block, kv_block=kv_block)
        new_ck = new_cv = None
    elif mode == "prefill":
        cache_k, cache_v = kv_update(cache_k, cache_v, k, v, 0)
        o = attend(q, k, v, q_pos=positions, k_pos=positions,
                   causal=True, window=window, logit_cap=a.logit_softcap,
                   impl=impl, q_block=q_block, kv_block=kv_block)
        new_ck, new_cv = cache_k, cache_v
    elif mode == "decode_slots":
        # fixed-shape slot decode: q len 1 per slot, per-slot write
        # positions (B,). The (B, 1, S_max) mask is tiny at q=1, so the
        # batched-mask naive path is exact and memory-safe here (the
        # make_mask hoisting hazard only bites the flash scans).
        cache_k, cache_v = kv_update_slots(cache_k, cache_v, k, v, cache_pos)
        s_max = cache_k.shape[1]
        k_pos = jnp.arange(s_max, dtype=jnp.int32)
        ck = constrain(dp, cache_k,
                       ("batch", "kv_seq", "kv_heads", "cache_head_dim"),
                       tag="attn/cache_k")
        cv = constrain(dp, cache_v,
                       ("batch", "kv_seq", "kv_heads", "cache_head_dim"),
                       tag="attn/cache_v")
        valid = slot_validity(s_max, cache_pos)               # (B, S_max)
        w = jnp.asarray(window)
        valid &= jnp.where(w > 0,
                           cache_pos[:, None] - k_pos[None, :] < w, True)
        o = attend_naive(q, ck, cv, valid[:, None, :],
                         logit_cap=a.logit_softcap)
        new_ck, new_cv = cache_k, cache_v
    elif mode == "chunk":
        # chunked prefill: q len C written into the cache at a *traced*
        # offset (cache_pos), attending to everything filled so far.  The
        # cache constrain is the same mediation edge decode pays, so every
        # chunk is accounted through the fused pipeline like a decode tick.
        cache_k, cache_v = kv_update(cache_k, cache_v, k, v, cache_pos)
        s_max = cache_k.shape[1]
        k_pos = jnp.arange(s_max, dtype=jnp.int32)
        k_valid = k_pos < cache_pos + q.shape[1]
        ck = constrain(dp, cache_k,
                       ("batch", "kv_seq", "kv_heads", "cache_head_dim"),
                       tag="attn/cache_k")
        cv = constrain(dp, cache_v,
                       ("batch", "kv_seq", "kv_heads", "cache_head_dim"),
                       tag="attn/cache_v")
        o = attend(q, ck, cv, q_pos=positions, k_pos=k_pos, causal=True,
                   window=window, logit_cap=a.logit_softcap, k_valid=k_valid,
                   impl="flash", q_block=q_block, kv_block=kv_block)
        new_ck, new_cv = cache_k, cache_v
    else:  # decode: q len 1 against the cache
        cache_k, cache_v = kv_update(cache_k, cache_v, k, v, cache_pos)
        s_max = cache_k.shape[1]
        k_pos = jnp.arange(s_max, dtype=jnp.int32)
        k_valid = k_pos <= cache_pos
        ck = constrain(dp, cache_k,
                       ("batch", "kv_seq", "kv_heads", "cache_head_dim"),
                       tag="attn/cache_k")
        cv = constrain(dp, cache_v,
                       ("batch", "kv_seq", "kv_heads", "cache_head_dim"),
                       tag="attn/cache_v")
        o = attend(q, ck, cv, q_pos=positions, k_pos=k_pos, causal=True,
                   window=window, logit_cap=a.logit_softcap, k_valid=k_valid,
                   impl="flash", q_block=1, kv_block=kv_block)
        new_ck, new_cv = cache_k, cache_v
    x = x + output_project(lp["attn"], o, dp=dp)

    h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        f, aux = moe(lp["moe"], h, cfg.moe, act=cfg.act_fn, train=train,
                     dp=dp)
    else:
        f = mlp(lp["mlp"], h, act=cfg.act_fn, dp=dp)
    x = x + f
    x = constrain(dp, x, ("batch", "seq_resid", "embed"), tag="layer/out")
    return x, aux, new_ck, new_cv


# ---------------------------------------------------------------------------
# full-sequence apply (train / prefill)
# ---------------------------------------------------------------------------

def transformer_apply(params, cfg: ModelConfig, batch: dict, *, dp=None,
                      cache=None, train=False, remat="none", impl="flash",
                      q_block=512, kv_block=1024):
    """Returns (final_hiddens, aux_loss, new_cache, prefix_len)."""
    dtype = dtype_of(cfg.dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed(params["embed"], tokens, dtype, dp=dp)
    prefix = 0
    if cfg.family == "vlm" and "patches" in batch:
        pe = jnp.einsum("bpf,fd->bpd", batch["patches"].astype(dtype),
                        params["vision_proj"].astype(dtype))
        pe = constrain(dp, pe, ("batch", "seq", "embed"), tag="vision/proj")
        x = jnp.concatenate([pe, x], axis=1)
        prefix = pe.shape[1]
        s = s + prefix
    positions = jnp.arange(s, dtype=jnp.int32)

    window_arr, theta_arr = layer_flags(cfg)
    mode = "prefill" if cache is not None else "train"

    def body(carry, xs):
        x, aux = carry
        if cache is not None:
            lp, w, th, ck, cv = xs
        else:
            lp, w, th = xs
            ck = cv = None
        x, a, ck, cv = _layer(lp, x, cfg=cfg, dp=dp, positions=positions,
                              window=w, theta=th, mode=mode, cache_k=ck,
                              cache_v=cv, train=train, impl=impl,
                              q_block=q_block, kv_block=kv_block)
        out = (ck, cv) if cache is not None else None
        return (x, aux + a), out

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots,
            prevent_cse=False)

    xs = (params["layers"], jnp.asarray(window_arr), jnp.asarray(theta_arr))
    if cache is not None:
        xs = xs + (cache["k"], cache["v"])
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_cache = None
    if cache is not None:
        new_cache = {"k": caches[0], "v": caches[1]}
    return x, aux, new_cache, prefix


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def transformer_loss(params, cfg: ModelConfig, batch: dict, *, dp=None,
                     rng=None, remat="none", impl="flash"):
    x, aux, _, prefix = transformer_apply(params, cfg, batch, dp=dp,
                                          train=True, remat=remat, impl=impl)
    if prefix:
        x = x[:, prefix:]
    table = params["embed"].get("head", params["embed"]["tok"])
    loss, correct, count = chunked_ce_loss(x, table, batch["labels"], dp=dp)
    m = ce_metrics(loss, correct, count, aux)
    return m["loss"], m


def transformer_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    a = cfg.attention
    return kv_cache_init(cfg.num_layers, batch, max_len, a.num_kv_heads,
                         cfg.head_dim, dtype=dtype_of(cfg.dtype))


def transformer_prefill(params, cfg: ModelConfig, batch: dict, cache, *,
                        dp=None, impl="flash", last_pos=None):
    """Fill the cache with the prompt; returns (last_hidden_logits, cache).

    ``last_pos`` (B,) int32 selects the per-request position whose hidden
    state feeds the logits — the last *real* prompt token when prompts are
    right-padded to a bucket capacity.  Right padding sits causally after
    every real token, so bucketing never perturbs the returned logits.
    Default (None) keeps the legacy behaviour: logits at the final
    sequence position."""
    # caches sized >= prompt length; positions start at 0
    x, _aux, cache, prefix = transformer_apply(params, cfg, batch, dp=dp,
                                               cache=cache, impl=impl)
    from repro.layers.embedding import logits as logits_fn
    if last_pos is None:
        last = x[:, -1:, :]
    else:
        idx = jnp.asarray(last_pos, jnp.int32) + prefix
        last = x[jnp.arange(x.shape[0]), idx][:, None, :]
    return logits_fn(params["embed"], last, dp=dp), cache


def transformer_prefill_chunk(params, cfg: ModelConfig, batch: dict, cache,
                              offset, *, dp=None, last_pos=None,
                              kv_block=1024):
    """One prefill *chunk*: write ``batch["tokens"]`` (B, C) into the cache
    at traced position ``offset`` and attend causally to everything filled
    so far.  Returns (logits, cache) like :func:`transformer_prefill`;
    the logits only matter on the chunk containing ``last_pos`` (the last
    real prompt token) — earlier chunks' logits are discarded by the
    caller.

    ``offset`` is a traced scalar, so ONE jitted chunk step serves every
    chunk of every prompt of a given chunk length — the chunked analogue
    of the fixed-shape slot decode.  Token-only batches (no vision
    prefix); the engine falls back to whole prefill otherwise."""
    dtype = dtype_of(cfg.dtype)
    tokens = batch["tokens"]
    b, c = tokens.shape
    offset = jnp.asarray(offset, jnp.int32)
    x = embed(params["embed"], tokens, dtype, dp=dp)
    positions = offset + jnp.arange(c, dtype=jnp.int32)
    window_arr, theta_arr = layer_flags(cfg)

    def body(x, xs):
        lp, w, th, ck, cv = xs
        x, _aux, ck, cv = _layer(lp, x, cfg=cfg, dp=dp, positions=positions,
                                 window=w, theta=th, mode="chunk",
                                 cache_k=ck, cache_v=cv, cache_pos=offset,
                                 kv_block=kv_block)
        return x, (ck, cv)

    xs = (params["layers"], jnp.asarray(window_arr), jnp.asarray(theta_arr),
          cache["k"], cache["v"])
    x, caches = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    from repro.layers.embedding import logits as logits_fn
    if last_pos is None:
        last = x[:, -1:, :]
    else:
        idx = jnp.clip(jnp.asarray(last_pos, jnp.int32) - offset, 0, c - 1)
        last = x[jnp.arange(b), idx][:, None, :]
    return logits_fn(params["embed"], last, dp=dp), {"k": caches[0],
                                                     "v": caches[1]}


def transformer_decode_step(params, cfg: ModelConfig, token, cache, pos, *,
                            dp=None, kv_block=1024):
    """One decode step. token: (B,1) int32; pos: scalar int32 (current
    write position = number of tokens already in cache)."""
    dtype = dtype_of(cfg.dtype)
    b = token.shape[0]
    x = embed(params["embed"], token, dtype, dp=dp)
    positions = jnp.full((1,), pos, jnp.int32)
    window_arr, theta_arr = layer_flags(cfg)

    def body(x, xs):
        lp, w, th, ck, cv = xs
        x, _aux, ck, cv = _layer(lp, x, cfg=cfg, dp=dp, positions=positions,
                                 window=w, theta=th, mode="decode",
                                 cache_k=ck, cache_v=cv, cache_pos=pos,
                                 kv_block=kv_block)
        return x, (ck, cv)

    xs = (params["layers"], jnp.asarray(window_arr), jnp.asarray(theta_arr),
          cache["k"], cache["v"])
    x, caches = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    from repro.layers.embedding import logits as logits_fn
    return logits_fn(params["embed"], x, dp=dp), {"k": caches[0], "v": caches[1]}


def transformer_decode_step_slots(params, cfg: ModelConfig, token, cache,
                                  pos, *, dp=None):
    """One fixed-shape decode step over persistent slots.

    token: (B, 1) int32 — each slot's last sampled token; pos: (B,) int32
    per-slot write position.  Every shape is a function of the engine's
    slot geometry (max_batch, max_cache_len), never of the request mix, so
    this traces and compiles exactly once per engine.  Free slots still
    compute — their writes land at their stale position and are replaced
    on slot refill; the per-slot validity mask keeps stale cache entries
    unreachable."""
    dtype = dtype_of(cfg.dtype)
    pos = jnp.asarray(pos, jnp.int32)
    x = embed(params["embed"], token, dtype, dp=dp)
    positions = pos[:, None]                       # (B, 1) per-slot RoPE
    window_arr, theta_arr = layer_flags(cfg)

    def body(x, xs):
        lp, w, th, ck, cv = xs
        x, _aux, ck, cv = _layer(lp, x, cfg=cfg, dp=dp, positions=positions,
                                 window=w, theta=th, mode="decode_slots",
                                 cache_k=ck, cache_v=cv, cache_pos=pos)
        return x, (ck, cv)

    xs = (params["layers"], jnp.asarray(window_arr), jnp.asarray(theta_arr),
          cache["k"], cache["v"])
    x, caches = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    from repro.layers.embedding import logits as logits_fn
    return logits_fn(params["embed"], x, dp=dp), {"k": caches[0], "v": caches[1]}


__all__ = [
    "transformer_init", "transformer_apply", "transformer_loss",
    "transformer_init_cache", "transformer_prefill",
    "transformer_prefill_chunk", "transformer_decode_step",
    "transformer_decode_step_slots", "layer_flags",
]
