"""xLSTM LM (xlstm-350m): a stack of mLSTM and sLSTM blocks following the
configured block pattern (e.g. "mmms" = 3 mLSTM : 1 sLSTM), scanned over
*pattern units* so the traced program contains one unit regardless of
depth.  Decode is pure recurrent state — O(1) memory per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.common import constrain, dtype_of, rmsnorm, rmsnorm_init, stacked_init
from repro.layers.embedding import embed, embedding_init, logits as logits_fn
from repro.layers.xlstm import (
    mlstm, mlstm_init, mlstm_state_init,
    slstm, slstm_init, slstm_state_init,
)
from repro.models.losses import ce_metrics, chunked_ce_loss


def _pattern(cfg: ModelConfig) -> str:
    pat = cfg.ssm.block_pattern
    L = cfg.num_layers
    if L % len(pat):
        # cycle the pattern and cut: fall back to unit = full depth
        pat = (pat * L)[:L]
    return pat


def xlstm_init(rng, cfg: ModelConfig) -> dict:
    pat = _pattern(cfg)
    reps = cfg.num_layers // len(pat)
    r = jax.random.split(rng, 2 + len(pat))

    unit = {}
    for j, kind in enumerate(pat):
        def one(lr, kind=kind):
            ks = jax.random.split(lr, 2)
            blk = {"norm": rmsnorm_init(cfg.d_model)}
            if kind == "m":
                blk["core"] = mlstm_init(ks[0], cfg.d_model, cfg.ssm)
            else:
                blk["core"] = slstm_init(ks[0], cfg.d_model, cfg.ssm)
            return blk
        unit[f"blk{j}"] = stacked_init(r[2 + j], reps, one)

    return {
        "embed": embedding_init(r[0], cfg.vocab_size, cfg.d_model,
                                tied=cfg.tie_embeddings),
        "units": unit,
        "final_norm": rmsnorm_init(cfg.d_model),
    }


def _unit_states(cfg: ModelConfig, batch: int) -> dict:
    pat = _pattern(cfg)
    reps = cfg.num_layers // len(pat)

    def stack(st):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), st)

    states = {}
    for j, kind in enumerate(pat):
        if kind == "m":
            states[f"blk{j}"] = stack(mlstm_state_init(batch, cfg.d_model,
                                                       cfg.ssm))
        else:
            states[f"blk{j}"] = stack(slstm_state_init(batch, cfg.d_model,
                                                       cfg.ssm))
    return states


def xlstm_apply(params, cfg: ModelConfig, batch: dict, *, dp=None,
                cache=None, train=False, remat="none", chunk: int = 128):
    dtype = dtype_of(cfg.dtype)
    tokens = batch["tokens"]
    pat = _pattern(cfg)
    x = embed(params["embed"], tokens, dtype, dp=dp)

    def body(x, xs):
        new_states = {}
        for j, kind in enumerate(pat):
            blk = xs[f"blk{j}"]
            st = xs.get(f"st{j}")
            h = rmsnorm(blk["norm"], x, cfg.norm_eps)
            if kind == "m":
                out, ns = mlstm(blk["core"], h, cfg.ssm, state=st, dp=dp,
                                chunk=chunk)
            else:
                out, ns = slstm(blk["core"], h, cfg.ssm, state=st, dp=dp)
            x = x + out
            new_states[f"blk{j}"] = ns
        from repro.layers.common import constrain
        x = constrain(dp, x, ("batch", "seq_resid", "embed"), tag="layer/out")
        return x, new_states if cache is not None else None

    if remat in ("full", "dots"):
        pol = (None if remat == "full"
               else jax.checkpoint_policies.checkpoint_dots)
        body = jax.checkpoint(body, policy=pol, prevent_cse=False)

    xs = dict(params["units"])
    if cache is not None:
        for j in range(len(pat)):
            xs[f"st{j}"] = cache[f"blk{j}"]
    x, new_cache = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32), new_cache, 0


def xlstm_loss(params, cfg, batch, *, dp=None, rng=None, remat="none",
               impl="flash"):
    x, aux, _, _ = xlstm_apply(params, cfg, batch, dp=dp, train=True,
                               remat=remat)
    table = params["embed"].get("head", params["embed"]["tok"])
    loss, correct, count = chunked_ce_loss(x, table, batch["labels"], dp=dp)
    m = ce_metrics(loss, correct, count, aux)
    return m["loss"], m


def xlstm_init_cache(cfg: ModelConfig, batch: int, max_len: int = 0):
    return _unit_states(cfg, batch)


def xlstm_prefill(params, cfg, batch, cache, *, dp=None, impl="flash",
                  last_pos=None):
    """Run the prompt through the recurrence, returning (logits, states).

    ``last_pos`` (B,) selects the hidden position feeding the logits.
    Padding is NOT inert for a recurrence (every token, real or pad,
    advances the mLSTM/sLSTM memories), so the serve engine prefills this
    family at exact prompt length (``Model.recurrent``)."""
    x, _aux, cache, _ = xlstm_apply(params, cfg, batch, dp=dp, cache=cache)
    if last_pos is None:
        last = x[:, -1:, :]
    else:
        idx = jnp.asarray(last_pos, jnp.int32)
        last = x[jnp.arange(x.shape[0]), idx][:, None, :]
    return logits_fn(params["embed"], last, dp=dp), cache


def xlstm_decode_step(params, cfg, token, cache, pos, *, dp=None, **_):
    dtype = dtype_of(cfg.dtype)
    x = embed(params["embed"], token, dtype, dp=dp)
    pat = _pattern(cfg)

    def body(x, xs):
        new_states = {}
        for j, kind in enumerate(pat):
            blk = xs[f"blk{j}"]
            st = xs[f"st{j}"]
            h = rmsnorm(blk["norm"], x, cfg.norm_eps)
            if kind == "m":
                out, ns = mlstm(blk["core"], h, cfg.ssm, state=st, chunk=1,
                                dp=dp)
            else:
                out, ns = slstm(blk["core"], h, cfg.ssm, state=st, dp=dp)
            x = x + out
            new_states[f"blk{j}"] = ns
        return x, new_states

    xs = dict(params["units"])
    for j in range(len(pat)):
        xs[f"st{j}"] = cache[f"blk{j}"]
    x, new_cache = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params["embed"], x, dp=dp), new_cache


def xlstm_decode_step_slots(params, cfg, token, cache, pos, *, dp=None, **_):
    """Fixed-shape slot decode for the pure-recurrent family.

    Decode here is position-free — the recurrence carries all sequence
    context in the (reps, B, ...) unit states, and every batch row
    advances independently — so the per-slot ``pos`` vector the engine
    feeds is simply unused and the gang decode step IS the slot decode
    step.  A freed slot's state keeps evolving on stale tokens until
    ``state_slot_insert`` overwrites the whole row at the next insert."""
    del pos
    return xlstm_decode_step(params, cfg, token, cache, 0, dp=dp)


__all__ = ["xlstm_init", "xlstm_apply", "xlstm_loss", "xlstm_init_cache",
           "xlstm_prefill", "xlstm_decode_step", "xlstm_decode_step_slots"]
