"""Loss functions. Cross-entropy is computed in sequence chunks so the
(B, S, vocab) logits tensor is never materialized — at vocab 262k /
seq 4k this is the difference between fitting and not fitting HBM."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.common import constrain


def chunked_ce_loss(x: jax.Array, table: jax.Array, labels: jax.Array, *,
                    dp=None, chunk: int = 512, softcap_val: float = 0.0):
    """Cross entropy of final hiddens ``x`` (B,S,D) against ``labels``
    (B,S; -1 = ignore) with tied/untied vocab ``table`` (V,D).

    Returns (sum_loss, sum_correct, sum_count)."""
    b, s, d = x.shape
    ck = min(chunk, s)
    while s % ck:
        ck -= 1
    nc = s // ck
    xc = x.reshape(b, nc, ck, d).swapaxes(0, 1)
    lc = labels.reshape(b, nc, ck).swapaxes(0, 1)
    table = constrain(dp, table, ("vocab", "embed"), tag="loss/table")

    @jax.checkpoint
    def step(carry, args):
        # rematted: the (b, chunk, vocab) logits are recomputed in backward
        # instead of saved — the difference between fitting HBM and not at
        # vocab 262k.
        loss, correct, count = carry
        xi, li = args
        logits = jnp.einsum("bsd,vd->bsv", xi, table.astype(xi.dtype),
                            preferred_element_type=jnp.float32)
        if softcap_val > 0:
            logits = softcap_val * jnp.tanh(logits / softcap_val)
        logits = constrain(dp, logits, ("batch", "seq", "vocab"),
                           tag="loss/logits")
        mask = li >= 0
        safe = jnp.where(mask, li, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = jnp.where(mask, lse - gold, 0.0)
        pred = logits.argmax(axis=-1)
        return (loss + nll.sum(),
                correct + jnp.where(mask, pred == safe, False).sum(),
                count + mask.sum()), None

    init = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32))
    (loss, correct, count), _ = jax.lax.scan(step, init, (xc, lc))
    return loss, correct, count


def ce_metrics(loss, correct, count, aux=0.0):
    n = jnp.maximum(count, 1)
    return {"loss": loss / n + aux, "nll": loss / n,
            "acc": correct / n, "tokens": count, "aux": aux}


__all__ = ["chunked_ce_loss", "ce_metrics"]
