"""Unified model interface + input specs for every (arch × shape) cell.

``build_model(cfg)`` returns a :class:`Model` whose methods have identical
signatures across families, so the launcher / dry-run / serving engine are
architecture-agnostic.

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input of that shape cell (weak-type-correct, shardable, no
device allocation) — the dry-run contract.  Modality frontends are stubs:
VLM cells get precomputed patch embeddings, audio cells get precomputed
mel frames.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, transformer, xlstm_model


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    apply: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable
    # Fixed-shape decode over persistent slots (per-slot positions).  None
    # for families without a slot-aware decode path; the serving engine
    # falls back to gang scheduling when absent.
    decode_step_slots: Callable | None = None
    # Chunked prefill: write one (B, C) chunk at a traced offset.  None for
    # families without it; the engine prefills whole prompts when absent.
    prefill_chunk: Callable | None = None
    # True when the decode cache holds recurrent state that every token —
    # real or padding — advances (mamba/xLSTM).  The serving engine then
    # prefills at exact prompt length instead of bucketed capacity: right
    # padding is causally inert for attention but corrupts a recurrence.
    recurrent: bool = False


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        m = transformer
        return Model(
            cfg=cfg,
            init=lambda rng: m.transformer_init(rng, cfg),
            loss=lambda params, batch, **kw: m.transformer_loss(
                params, cfg, batch, **kw),
            apply=lambda params, batch, **kw: m.transformer_apply(
                params, cfg, batch, **kw),
            init_cache=lambda batch, max_len: m.transformer_init_cache(
                cfg, batch, max_len),
            prefill=lambda params, batch, cache, **kw: m.transformer_prefill(
                params, cfg, batch, cache, **kw),
            decode_step=lambda params, token, cache, pos, **kw:
                m.transformer_decode_step(params, cfg, token, cache, pos, **kw),
            decode_step_slots=lambda params, token, cache, pos, **kw:
                m.transformer_decode_step_slots(params, cfg, token, cache,
                                                pos, **kw),
            prefill_chunk=lambda params, batch, cache, offset, **kw:
                m.transformer_prefill_chunk(params, cfg, batch, cache,
                                            offset, **kw),
        )
    if fam == "hybrid":
        m = hybrid
        return Model(
            cfg=cfg,
            init=lambda rng: m.hybrid_init(rng, cfg),
            loss=lambda params, batch, **kw: m.hybrid_loss(params, cfg, batch, **kw),
            apply=lambda params, batch, **kw: m.hybrid_apply(params, cfg, batch, **kw),
            init_cache=lambda batch, max_len: m.hybrid_init_cache(cfg, batch, max_len),
            prefill=lambda params, batch, cache, **kw: m.hybrid_prefill(
                params, cfg, batch, cache, **kw),
            decode_step=lambda params, token, cache, pos, **kw:
                m.hybrid_decode_step(params, cfg, token, cache, pos, **kw),
            decode_step_slots=lambda params, token, cache, pos, **kw:
                m.hybrid_decode_step_slots(params, cfg, token, cache, pos,
                                           **kw),
            recurrent=True,
        )
    if fam == "ssm":
        m = xlstm_model
        return Model(
            cfg=cfg,
            init=lambda rng: m.xlstm_init(rng, cfg),
            loss=lambda params, batch, **kw: m.xlstm_loss(params, cfg, batch, **kw),
            apply=lambda params, batch, **kw: m.xlstm_apply(params, cfg, batch, **kw),
            init_cache=lambda batch, max_len=0: m.xlstm_init_cache(cfg, batch, max_len),
            prefill=lambda params, batch, cache, **kw: m.xlstm_prefill(
                params, cfg, batch, cache, **kw),
            decode_step=lambda params, token, cache, pos, **kw:
                m.xlstm_decode_step(params, cfg, token, cache, pos, **kw),
            decode_step_slots=lambda params, token, cache, pos, **kw:
                m.xlstm_decode_step_slots(params, cfg, token, cache, pos,
                                          **kw),
            recurrent=True,
        )
    if fam == "encdec":
        m = encdec
        return Model(
            cfg=cfg,
            init=lambda rng: m.encdec_init(rng, cfg),
            loss=lambda params, batch, **kw: m.encdec_loss(params, cfg, batch, **kw),
            apply=lambda params, batch, **kw: m.encdec_apply(params, cfg, batch, **kw),
            init_cache=lambda batch, max_len: m.encdec_init_cache(cfg, batch, max_len),
            prefill=lambda params, batch, cache, **kw: m.encdec_prefill(
                params, cfg, batch, cache, **kw),
            decode_step=lambda params, token, cache, pos, **kw:
                m.encdec_decode_step(params, cfg, token, cache, pos, **kw),
            decode_step_slots=lambda params, token, cache, pos, **kw:
                m.encdec_decode_step_slots(params, cfg, token, cache, pos,
                                           **kw),
        )
    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# input specs (dry-run contract)
# ---------------------------------------------------------------------------

def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    """VLM cells budget the patch prefix inside the cell's seq_len."""
    if cfg.family == "vlm" and cfg.num_patches:
        return max(seq_len - cfg.num_patches, 16)
    return seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step function of this cell."""
    b = shape.global_batch
    i32 = jnp.int32

    if shape.kind in ("train", "prefill"):
        s = _text_len(cfg, shape.seq_len)
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.num_patches, cfg.frontend_dim), jnp.float32)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_max_len, cfg.frontend_dim), jnp.float32)
        if shape.kind == "prefill":
            specs.pop("labels")
        return specs

    # decode: one new token against a cache of seq_len
    return {"token": jax.ShapeDtypeStruct((b, 1), i32)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract cache pytree for decode cells (no allocation)."""
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(shape.global_batch,
                                                   shape.seq_len))


def make_batch(cfg: ModelConfig, shape: ShapeConfig, rng,
               vocab_cap: int | None = None):
    """Concrete random batch matching input_specs (smoke tests, examples)."""
    specs = input_specs(cfg, shape)
    out = {}
    v = vocab_cap or cfg.vocab_size
    for name, sd in specs.items():
        rng, k = jax.random.split(rng)
        if sd.dtype == jnp.int32:
            out[name] = jax.random.randint(k, sd.shape, 0, v, jnp.int32)
        else:
            out[name] = jax.random.normal(k, sd.shape, sd.dtype)
    return out


__all__ = ["Model", "build_model", "input_specs", "cache_specs", "make_batch"]
