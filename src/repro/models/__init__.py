"""Model zoo: dense/MoE/VLM transformer, hymba hybrid, xLSTM, whisper
enc-dec — all behind one Model interface."""

from repro.models.api import Model, build_model, cache_specs, input_specs, make_batch

__all__ = ["Model", "build_model", "cache_specs", "input_specs", "make_batch"]
