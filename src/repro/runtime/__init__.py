from repro.runtime.elastic import (
    ElasticController,
    ServeElasticController,
    remesh,
    shrink_mesh,
    state_shardings,
)
from repro.runtime.fault import FaultInjector, RunReport, SimulatedFailure, run_loop

__all__ = ["run_loop", "FaultInjector", "SimulatedFailure", "RunReport",
           "remesh", "state_shardings", "shrink_mesh", "ElasticController",
           "ServeElasticController"]
