"""Elastic scaling: re-shard a training state onto a different mesh, and
the closed control loop that decides *when* (docs/elasticity.md).

Checkpoints are mesh-agnostic (full arrays + manifest), and sharding specs
are *logical* (parallel/sharding.py), so growing or shrinking the mesh is:
restore → derive specs for the new mesh → device_put.  ``remesh`` does the
same for live states (device-loss recovery without a disk round-trip when
the state still fits).

The OS-control story on top (CoRD keeps the OS on the dataplane, so the
OS keeps control over live workloads — what kernel bypass gives up):

* :func:`shrink_mesh` carves a smaller slice out of a mesh (same axis
  names, fewer devices) — the elastic response's target.
* :class:`ElasticController` closes the loop: a
  :class:`~repro.core.obs.ThresholdWatcher` over a
  :class:`~repro.core.obs.CounterTimeline`'s rate series (``denied_pct``,
  ``throttled_pct``, ``stalls_pct``) trips on sustained over-threshold
  windows, and the controller migrates the state onto a shrunken slice
  with :func:`remesh` mid-run, recording ``trigger``/``remesh`` events
  into the timeline artifact.  In-flight verbs connections survive the
  move via live QP migration (``qp_quiesce``/``qp_snapshot``/
  ``qp_restore`` in core/verbs.py).
"""

from __future__ import annotations

import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import compat
from repro.core.obs import CounterTimeline, ThresholdWatcher
from repro.parallel.sharding import param_specs


def state_shardings(state, mesh: Mesh, *, fsdp: bool = False):
    """NamedSharding pytree for a TrainState on ``mesh``."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspec = param_specs(state.params, fsdp=fsdp, mesh_sizes=sizes)

    def to_sh(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    return type(state)(
        params=to_sh(pspec),
        opt=type(state.opt)(step=NamedSharding(mesh, P()), mu=to_sh(pspec),
                            nu=to_sh(pspec)),
        step=NamedSharding(mesh, P()),
        err=None if state.err is None else to_sh(
            param_specs(state.err, fsdp=fsdp, mesh_sizes=sizes)),
    )


def remesh(state, new_mesh: Mesh, *, fsdp: bool = False):
    """Re-shard a live state onto ``new_mesh`` (elastic grow/shrink)."""
    sh = state_shardings(state, new_mesh, fsdp=fsdp)
    flat_s, tdef = jax.tree.flatten(state)
    flat_sh = jax.tree.leaves(sh)
    moved = [jax.device_put(jax.device_get(x), s)
             for x, s in zip(flat_s, flat_sh)]
    return jax.tree.unflatten(tdef, moved)


def shrink_mesh(mesh: Mesh, factor: int = 2, *,
                min_devices: int = 1) -> Mesh | None:
    """A shrunken slice of ``mesh``: same axis names, the leading
    ``1/factor`` of the devices, taken off the largest axis.  Returns
    ``None`` when no smaller mesh exists (the largest axis cannot absorb
    the factor, or the result would fall under ``min_devices``) — the
    caller keeps the current mesh."""
    shape = list(mesh.devices.shape)
    axis = max(range(len(shape)), key=lambda i: shape[i])
    if factor < 2 or shape[axis] < factor:
        return None
    new_shape = list(shape)
    new_shape[axis] = shape[axis] // factor
    n = math.prod(new_shape)
    if n < max(min_devices, 1):
        return None
    devices = list(mesh.devices.reshape(-1)[:n])
    return compat.make_mesh(new_shape, mesh.axis_names, devices=devices)


class ElasticController:
    """The closed OS-control loop: timeline rates → threshold watcher →
    remesh onto a shrunken mesh slice (docs/elasticity.md).

    Built from an :class:`~repro.configs.base.ElasticConfig`; call
    :meth:`drive` after each timeline snapshot.  It consumes any new
    windows through the watcher (logging ``trigger``/``recover`` events
    into the timeline); when a tenant trips and the remesh budget
    (``cfg.max_remesh``, shrinks only) allows, it shrinks the current
    mesh by ``cfg.shrink_factor``, migrates ``state`` onto it with
    :func:`remesh` and records a ``remesh`` event
    (``detail["direction"] == "shrink"``).  With the watcher's release
    arm configured (``cfg.release_thresholds``), a later ``recover``
    event drives :meth:`grow_mesh` — the state migrates *back* onto the
    pre-shrink mesh (popped off a shrink-history stack), recorded as a
    ``remesh`` with ``direction == "grow"``, closing the cycle.  The
    caller rebuilds anything compiled against the old mesh (the
    Dataplane, the jitted step) whenever ``drive`` reports a move — the
    rebuild path is direction-agnostic, see ``launch/train.py
    --elastic``."""

    def __init__(self, cfg, timeline: CounterTimeline, mesh: Mesh, *,
                 fsdp: bool = False):
        self.cfg = cfg
        self.timeline = timeline
        self.mesh = mesh
        self.fsdp = fsdp
        self.watcher = ThresholdWatcher.from_config(cfg)
        self.remeshes = 0              # shrink count (the budgeted kind)
        self.grows = 0
        self._mesh_stack: list[Mesh] = []   # pre-shrink meshes, LIFO

    def _skip(self, ev, step: int, reason: str) -> None:
        """A trigger the controller cannot answer is recorded, not
        swallowed: the artifact (and the end-of-run event print) explains
        why the advertised remesh never happened — e.g. a single-device
        local run with nowhere to shrink to."""
        self.timeline.record_event("remesh-skipped", step,
                                   tenant=(ev or {}).get("tenant"),
                                   detail={"reason": reason})

    def drive(self, state, step: int):
        """Observe → record → respond.  Returns ``(state, moved)``; when
        ``moved`` the state now lives on the updated ``self.mesh``
        (shrunken on a trigger, restored on a recover).  A trigger or
        recover that cannot be answered records a ``remesh-skipped``
        event instead of silently doing nothing."""
        events = self.watcher.observe(self.timeline)
        for ev in events:
            self.timeline.record_event(ev["kind"], ev["step"],
                                       tenant=ev["tenant"], t=ev["t"],
                                       detail=ev["detail"])
        return self.respond(state, step, events)

    def respond(self, state, step: int, events):
        """Apply already-recorded watcher events — the entry point for a
        :class:`~repro.core.obs.WatcherGroup`, which records events
        itself and hands each controller its own member's slice."""
        moved = False
        for ev in events:
            if ev["kind"] == "trigger":
                state, m = self._shrink(state, step, ev)
            elif ev["kind"] == "recover":
                state, m = self.grow_mesh(state, step, ev)
            else:
                continue
            moved = moved or m
        return state, moved

    def _shrink(self, state, step: int, ev):
        if self.cfg.max_remesh and self.remeshes >= self.cfg.max_remesh:
            self._skip(ev, step, "max_remesh budget exhausted")
            return state, False
        new_mesh = shrink_mesh(self.mesh, self.cfg.shrink_factor,
                               min_devices=self.cfg.min_devices)
        if new_mesh is None:
            self._skip(ev, step,
                       f"no smaller mesh: shape "
                       f"{tuple(self.mesh.devices.shape)} cannot shrink by "
                       f"{self.cfg.shrink_factor} above min_devices="
                       f"{self.cfg.min_devices}")
            return state, False
        state = remesh(state, new_mesh, fsdp=self.fsdp)
        old_mesh, self.mesh = self.mesh, new_mesh
        self._mesh_stack.append(old_mesh)
        self.remeshes += 1
        self.timeline.record_event(
            "remesh", step, tenant=ev["tenant"],
            detail={"direction": "shrink",
                    "devices_before": int(old_mesh.devices.size),
                    "devices_after": int(new_mesh.devices.size),
                    "mesh_shape": list(new_mesh.devices.shape)})
        return state, True

    def grow_mesh(self, state, step: int, ev=None):
        """Grow-back: migrate ``state`` onto the most recently shrunken-
        from mesh (LIFO, so nested shrinks unwind in order) with the same
        :func:`remesh` move the shrink used — and therefore the same
        ``qp_snapshot``/``qp_restore`` live-migration guarantees for
        in-flight verbs connections.  Returns ``(state, moved)``; a
        recover with no shrink on record logs a ``remesh-skipped``."""
        if not self._mesh_stack:
            self._skip(ev, step, "nothing to grow back to: no shrink on "
                                 "record for this controller")
            return state, False
        new_mesh = self._mesh_stack.pop()
        state = remesh(state, new_mesh, fsdp=self.fsdp)
        old_mesh, self.mesh = self.mesh, new_mesh
        self.grows += 1
        self.timeline.record_event(
            "remesh", step, tenant=(ev or {}).get("tenant"),
            detail={"direction": "grow",
                    "devices_before": int(old_mesh.devices.size),
                    "devices_after": int(new_mesh.devices.size),
                    "mesh_shape": list(new_mesh.devices.shape)})
        return state, True


class ServeElasticController:
    """Serve-side elasticity: the same watcher signal, a far cheaper
    response (docs/elasticity.md).  Instead of remeshing — pointless for
    decode traffic, which is slot-bound, not device-bound — a trigger
    shrinks the engine's per-tenant slot budget
    (:meth:`~repro.serve.engine.Engine.set_slot_budget`, enforced by
    preemption with exact temp-0 resume) and a ``recover`` restores the
    pre-shrink budget.  Attach to a running engine via
    ``Engine(..., obs=timeline)`` + ``engine.on_tick = ctl.tick`` (what
    ``launch/serve.py --elastic`` wires), or hand a
    :class:`~repro.core.obs.WatcherGroup`'s serve slice to
    :meth:`respond` when a pod-level hierarchy owns the observing."""

    def __init__(self, cfg, timeline: CounterTimeline, engine):
        self.cfg = cfg
        self.timeline = timeline
        self.engine = engine
        self.watcher = ThresholdWatcher.from_config(cfg)
        self.shrinks = 0
        self.grows = 0
        self._saved_cap: int | None = None  # raw pre-shrink budget override

    def tick(self, engine=None) -> None:
        """Engine ``on_tick`` hook: observe any new timeline windows,
        record the fired events, apply the budget response."""
        events = self.watcher.observe(self.timeline)
        for ev in events:
            self.timeline.record_event(ev["kind"], ev["step"],
                                       tenant=ev["tenant"], t=ev["t"],
                                       detail=ev["detail"])
        self.respond(events)

    def respond(self, events) -> None:
        """Apply already-recorded watcher events (the
        :class:`~repro.core.obs.WatcherGroup` entry point)."""
        for ev in events:
            if ev["kind"] == "trigger":
                self._shrink_budget(ev)
            elif ev["kind"] == "recover":
                self._grow_budget(ev)

    def _skip(self, ev, reason: str) -> None:
        self.timeline.record_event("budget-skipped", ev["step"],
                                   tenant=ev.get("tenant"),
                                   detail={"reason": reason})

    def _shrink_budget(self, ev) -> None:
        if self._saved_cap is not None:
            self._skip(ev, "slot budget already shrunk; awaiting recover")
            return
        if self.cfg.max_remesh and self.shrinks >= self.cfg.max_remesh:
            self._skip(ev, "max_remesh budget exhausted")
            return
        before = self.engine.slot_budget()
        after = max(before // self.cfg.shrink_factor, 1)
        if after >= before:
            self._skip(ev, f"slot budget already at the floor ({before})")
            return
        self._saved_cap = self.engine.set_slot_budget(after)
        self.shrinks += 1
        self.timeline.record_event(
            "budget", ev["step"], tenant=ev.get("tenant"),
            detail={"direction": "shrink",
                    "slots_before": int(before), "slots_after": int(after)})

    def _grow_budget(self, ev) -> None:
        if self._saved_cap is None:
            self._skip(ev, "nothing to grow back to: no budget shrink on "
                           "record for this controller")
            return
        before = self.engine.slot_budget()
        self.engine.set_slot_budget(self._saved_cap)
        self._saved_cap = None
        self.grows += 1
        self.timeline.record_event(
            "budget", ev["step"], tenant=ev.get("tenant"),
            detail={"direction": "grow", "slots_before": int(before),
                    "slots_after": int(self.engine.slot_budget())})


__all__ = ["state_shardings", "remesh", "shrink_mesh", "ElasticController",
           "ServeElasticController"]
