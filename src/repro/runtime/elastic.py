"""Elastic scaling: re-shard a training state onto a different mesh.

Checkpoints are mesh-agnostic (full arrays + manifest), and sharding specs
are *logical* (parallel/sharding.py), so growing or shrinking the mesh is:
restore → derive specs for the new mesh → device_put.  ``remesh`` does the
same for live states (device-loss recovery without a disk round-trip when
the state still fits).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import param_specs


def state_shardings(state, mesh: Mesh, *, fsdp: bool = False):
    """NamedSharding pytree for a TrainState on ``mesh``."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspec = param_specs(state.params, fsdp=fsdp, mesh_sizes=sizes)

    def to_sh(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    return type(state)(
        params=to_sh(pspec),
        opt=type(state.opt)(step=NamedSharding(mesh, P()), mu=to_sh(pspec),
                            nu=to_sh(pspec)),
        step=NamedSharding(mesh, P()),
        err=None if state.err is None else to_sh(
            param_specs(state.err, fsdp=fsdp, mesh_sizes=sizes)),
    )


def remesh(state, new_mesh: Mesh, *, fsdp: bool = False):
    """Re-shard a live state onto ``new_mesh`` (elastic grow/shrink)."""
    sh = state_shardings(state, new_mesh, fsdp=fsdp)
    flat_s, tdef = jax.tree.flatten(state)
    flat_sh = jax.tree.leaves(sh)
    moved = [jax.device_put(jax.device_get(x), s)
             for x, s in zip(flat_s, flat_sh)]
    return jax.tree.unflatten(tdef, moved)


__all__ = ["state_shardings", "remesh"]
