"""Fault-tolerant runtime: step-level failures and wire-level loss.

At thousands of nodes the question is not *if* something fails but
*when*.  Two injection planes live here:

* **Step plane** (``FaultInjector`` + ``run_loop``): whole-step failures
  — device/node loss — handled host-side with periodic (optionally
  async) checkpointing, auto-resume from the latest valid checkpoint,
  bounded retry, and a straggler watchdog (steps slower than
  ``straggler_factor`` × the trailing median get logged and counted).

* **Wire plane** (:class:`WireFault`): per-work-request loss and
  corruption injected *inside traced code* into the verbs transport
  (``core/verbs.py``): ``windowed_send``/``conn_send`` consult the
  injector per wire transmission, a dropped WR produces no CQE (the
  sender's RTO fires), a corrupted one completes with ``CQE_ERR_RETRY``
  (a NAK), and the go-back-N retransmission machine re-posts — paying
  mediation cost per retry — until the transfer is bit-identical to a
  lossless run or ``QPConfig.retry_limit`` is exhausted
  (docs/transport.md).  Predicates are pure integer hashes of
  ``(wr, attempt, seed)`` computed identically on every rank, so queue
  counters stay SPMD-uniform; explicit ``drops``/``corrupts`` schedules
  give tests deterministic single-event control.
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.checkpoint import store


class SimulatedFailure(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# wire-level fault injection (traced — consumed by core/verbs.py)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class WireFault:
    """Deterministic wire loss/corruption for the verbs transport.

    ``drop_rate`` / ``corrupt_rate`` are per-transmission probabilities
    realized by a pure integer hash of ``(wr, attempt, seed)`` — no RNG
    state, identical on every rank, and a *retry of the same WR rolls a
    fresh outcome* (the attempt number salts the hash), so any rate < 1
    eventually delivers.  ``drops`` / ``corrupts`` are explicit
    ``(wr, attempt)`` schedules for tests that need exactly one loss at
    a known point.  A drop beats a corrupt when both fire for the same
    transmission (the packet never arrived to be corrupted).

    ``wr`` is the transfer-relative work-request identity the transport
    passes in (message index for ``windowed_send``;
    ``qp_id * n_msgs + msg`` for ``conn_send``), so schedules address
    "QP 3's second message" directly."""

    drop_rate: float = 0.0
    corrupt_rate: float = 0.0
    seed: int = 0
    drops: tuple = ()     # explicit (wr, attempt) pairs, always dropped
    corrupts: tuple = ()  # explicit (wr, attempt) pairs, always corrupted

    def __post_init__(self):
        for r in (self.drop_rate, self.corrupt_rate):
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"wire fault rate {r} outside [0, 1]")

    @property
    def active(self) -> bool:
        """True if any fault can ever fire — verbs compiles the plain
        (no-retry-machinery) loop when inactive."""
        return bool(self.drop_rate or self.corrupt_rate
                    or self.drops or self.corrupts)

    def _roll(self, wr, attempt, salt: int):
        """16-bit hash of (wr, attempt, seed, salt): a Knuth mix through
        a murmur-style avalanche finalizer, so consecutive attempts of
        the same WR land independently across the 16-bit range (a weak
        mix here makes a dropped WR stay dropped for many retries)."""
        w = jnp.asarray(wr, jnp.uint32)
        a = jnp.asarray(attempt, jnp.uint32)
        h = (w * jnp.uint32(2654435761)
             + a * jnp.uint32(2246822519)
             + jnp.uint32((self.seed * 2 + salt) & 0xffffffff)
             * jnp.uint32(69069))
        h = h ^ (h >> 16)
        h = h * jnp.uint32(0x85ebca6b)
        h = h ^ (h >> 13)
        h = h * jnp.uint32(0xc2b2ae35)
        h = h ^ (h >> 16)
        return h & jnp.uint32(0xffff)

    def _scheduled(self, pairs, wr, attempt):
        hit = jnp.bool_(False)
        for w, a in pairs:
            hit = hit | ((jnp.asarray(wr, jnp.int32) == int(w))
                         & (jnp.asarray(attempt, jnp.int32) == int(a)))
        return hit

    def drops_wr(self, wr, attempt):
        """Traced bool: this (wr, attempt) transmission is lost on the
        wire — no delivery, no CQE (silent loss; the RTO catches it)."""
        hit = self._scheduled(self.drops, wr, attempt)
        if self.drop_rate > 0:
            thresh = jnp.uint32(int(self.drop_rate * 0x10000))
            hit = hit | (self._roll(wr, attempt, salt=1) < thresh)
        return hit

    def corrupts_wr(self, wr, attempt):
        """Traced bool: this transmission arrives damaged — delivery is
        discarded and the CQE carries ``CQE_ERR_RETRY`` (a NAK)."""
        hit = self._scheduled(self.corrupts, wr, attempt)
        if self.corrupt_rate > 0:
            thresh = jnp.uint32(int(self.corrupt_rate * 0x10000))
            hit = hit | (self._roll(wr, attempt, salt=2) < thresh)
        return hit


@dataclass
class FaultInjector:
    """Deterministically fail specific steps (for tests/examples)."""
    fail_steps: tuple[int, ...] = ()
    max_failures_per_step: int = 1
    _counts: dict = field(default_factory=dict)

    def check(self, step: int) -> None:
        if step in self.fail_steps:
            n = self._counts.get(step, 0)
            if n < self.max_failures_per_step:
                self._counts[step] = n + 1
                raise SimulatedFailure(f"injected failure at step {step}")


@dataclass
class RunReport:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0
    straggler_steps: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    metrics: list = field(default_factory=list)


def run_loop(step_fn, state, loader, *, steps: int, ckpt_dir: str | None = None,
             checkpoint_every: int = 0, keep_last: int = 3,
             async_ckpt: bool = True, injector: FaultInjector | None = None,
             straggler_factor: float = 3.0, max_retries: int = 2,
             log_every: int = 0, start_step: int = 0) -> tuple:
    """Run ``steps`` steps with checkpoint/restart and straggler tracking.

    Returns (state, RunReport)."""
    report = RunReport()
    pending: list = []

    # auto-resume
    step = start_step
    if ckpt_dir:
        latest = store.latest_step(ckpt_dir)
        if latest is not None and latest > step:
            state = store.restore(ckpt_dir, latest, state)
            step = latest
            report.restores += 1

    while step < steps:
        batch = loader.get(step)
        retries = 0
        while True:
            try:
                if injector is not None:
                    injector.check(step)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(jax.tree.leaves(metrics))
                dt = time.perf_counter() - t0
                break
            except SimulatedFailure:
                report.failures += 1
                retries += 1
                if retries > max_retries:
                    # full restart path: restore from checkpoint
                    if ckpt_dir and store.latest_step(ckpt_dir) is not None:
                        latest = store.latest_step(ckpt_dir)
                        state = store.restore(ckpt_dir, latest, state)
                        step = latest
                        report.restores += 1
                        batch = loader.get(step)
                        retries = 0
                    else:
                        raise

        report.step_times.append(dt)
        trailing = report.step_times[-20:]
        if len(trailing) >= 5:
            med = statistics.median(trailing)
            if dt > straggler_factor * med:
                report.straggler_steps.append(step)

        report.metrics.append({k: float(v) for k, v in metrics.items()})
        report.steps_run += 1
        step += 1

        if ckpt_dir and checkpoint_every and step % checkpoint_every == 0:
            th = store.save(ckpt_dir, step, state, keep_last=keep_last,
                            blocking=not async_ckpt)
            if th is not None:
                pending.append(th)
        if log_every and step % log_every == 0:
            m = report.metrics[-1]
            print(f"step {step:5d} loss={m.get('loss', float('nan')):.4f} "
                  f"dt={dt*1e3:.1f}ms")

    for th in pending:
        th.join()
    return state, report


__all__ = ["run_loop", "FaultInjector", "SimulatedFailure", "RunReport",
           "WireFault"]
