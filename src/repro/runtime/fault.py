"""Fault-tolerant training runtime.

At thousands of nodes the question is not *if* a step fails but *when*:
this runner wraps the train loop with

  * periodic (optionally async) checkpointing,
  * auto-resume from the latest valid checkpoint,
  * bounded retry on step failure (``FaultInjector`` simulates device/node
    loss in tests),
  * a step watchdog flagging stragglers (steps slower than
    ``straggler_factor`` × the trailing median get logged and counted —
    the mitigation at scale is re-issue/skip, which the data pipeline's
    deterministic ``batch_at(step)`` makes safe).
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field

import jax

from repro.checkpoint import store


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FaultInjector:
    """Deterministically fail specific steps (for tests/examples)."""
    fail_steps: tuple[int, ...] = ()
    max_failures_per_step: int = 1
    _counts: dict = field(default_factory=dict)

    def check(self, step: int) -> None:
        if step in self.fail_steps:
            n = self._counts.get(step, 0)
            if n < self.max_failures_per_step:
                self._counts[step] = n + 1
                raise SimulatedFailure(f"injected failure at step {step}")


@dataclass
class RunReport:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0
    straggler_steps: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    metrics: list = field(default_factory=list)


def run_loop(step_fn, state, loader, *, steps: int, ckpt_dir: str | None = None,
             checkpoint_every: int = 0, keep_last: int = 3,
             async_ckpt: bool = True, injector: FaultInjector | None = None,
             straggler_factor: float = 3.0, max_retries: int = 2,
             log_every: int = 0, start_step: int = 0) -> tuple:
    """Run ``steps`` steps with checkpoint/restart and straggler tracking.

    Returns (state, RunReport)."""
    report = RunReport()
    pending: list = []

    # auto-resume
    step = start_step
    if ckpt_dir:
        latest = store.latest_step(ckpt_dir)
        if latest is not None and latest > step:
            state = store.restore(ckpt_dir, latest, state)
            step = latest
            report.restores += 1

    while step < steps:
        batch = loader.get(step)
        retries = 0
        while True:
            try:
                if injector is not None:
                    injector.check(step)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(jax.tree.leaves(metrics))
                dt = time.perf_counter() - t0
                break
            except SimulatedFailure:
                report.failures += 1
                retries += 1
                if retries > max_retries:
                    # full restart path: restore from checkpoint
                    if ckpt_dir and store.latest_step(ckpt_dir) is not None:
                        latest = store.latest_step(ckpt_dir)
                        state = store.restore(ckpt_dir, latest, state)
                        step = latest
                        report.restores += 1
                        batch = loader.get(step)
                        retries = 0
                    else:
                        raise

        report.step_times.append(dt)
        trailing = report.step_times[-20:]
        if len(trailing) >= 5:
            med = statistics.median(trailing)
            if dt > straggler_factor * med:
                report.straggler_steps.append(step)

        report.metrics.append({k: float(v) for k, v in metrics.items()})
        report.steps_run += 1
        step += 1

        if ckpt_dir and checkpoint_every and step % checkpoint_every == 0:
            th = store.save(ckpt_dir, step, state, keep_last=keep_last,
                            blocking=not async_ckpt)
            if th is not None:
                pending.append(th)
        if log_every and step % log_every == 0:
            m = report.metrics[-1]
            print(f"step {step:5d} loss={m.get('loss', float('nan')):.4f} "
                  f"dt={dt*1e3:.1f}ms")

    for th in pending:
        th.join()
    return state, report


__all__ = ["run_loop", "FaultInjector", "SimulatedFailure", "RunReport"]
