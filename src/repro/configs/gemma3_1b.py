"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.

5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    d_ff=6912,
    vocab_size=262_144,
    attention=AttentionConfig(
        num_heads=4,
        num_kv_heads=1,
        head_dim=256,
        sliding_window=512,
        local_global_ratio=5,
        rope_theta=10_000.0,
        rope_theta_global=1_000_000.0,
        qk_norm=True,
    ),
    max_seq_len=131_072,
    tie_embeddings=True,
    act_fn="gelu",
)
