"""whisper-small [audio]: 12L d_model=768 12H d_ff=3072 vocab=51865 — enc-dec.

Conv frontend is a STUB: input_specs() provides precomputed frame embeddings
(1500 frames after the conv downsampling). [arXiv:2212.04356; unverified]
"""

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,                  # decoder layers
    encoder_layers=12,
    encoder_max_len=1500,
    d_model=768,
    d_ff=3072,
    vocab_size=51_865,
    attention=AttentionConfig(
        num_heads=12,
        num_kv_heads=12,
        rope_theta=0.0,             # whisper uses learned/sinusoidal positions
    ),
    frontend="audio_frames",
    frontend_dim=80,                # mel bins delivered by the (stub) frontend
    max_seq_len=448,
    tie_embeddings=True,
    act_fn="gelu",
)
