"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]
"""

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    d_ff=10240,
    vocab_size=262_144,
    attention=AttentionConfig(
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,               # gemma3 uses explicit head_dim=256
        sliding_window=1024,
        local_global_ratio=5,       # 5 local : 1 global
        rope_theta=10_000.0,
        rope_theta_global=1_000_000.0,
        qk_norm=True,
    ),
    max_seq_len=131_072,
    tie_embeddings=True,
    act_fn="gelu",
)
