"""Architecture/config registry.

``get_model_config("gemma3-4b")`` returns the exact assigned config;
``get_model_config("gemma3-4b", smoke=True)`` returns the reduced
same-family smoke variant. ``ARCHS`` lists all assigned architectures.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (
    SHAPES,
    AttentionConfig,
    DataplaneConfig,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    RunConfig,
    ServeConfig,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
    apply_overrides,
    reduced,
)

_ARCH_MODULES = {
    "gemma3-4b": "repro.configs.gemma3_4b",
    "granite-34b": "repro.configs.granite_34b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "whisper-small": "repro.configs.whisper_small",
    "arctic-480b": "repro.configs.arctic_480b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "xlstm-350m": "repro.configs.xlstm_350m",
}

ARCHS = tuple(_ARCH_MODULES)

# long_500k applicability: run for sub-quadratic archs only
# (ModelConfig.is_subquadratic).
LONG_CONTEXT_ARCHS = ("gemma3-4b", "gemma3-1b", "hymba-1.5b", "xlstm-350m")


def get_model_config(name: str, *, smoke: bool = False) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    cfg = importlib.import_module(_ARCH_MODULES[name]).CONFIG
    return reduced(cfg) if smoke else cfg


def cells(include_skipped: bool = False):
    """Yield every (arch, shape) dry-run cell; skips long_500k for pure
    full-attention archs unless ``include_skipped``."""
    for arch in ARCHS:
        for shape in SHAPES.values():
            if (shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS
                    and not include_skipped):
                continue
            yield arch, shape


__all__ = [
    "ARCHS",
    "LONG_CONTEXT_ARCHS",
    "SHAPES",
    "AttentionConfig",
    "DataplaneConfig",
    "MeshConfig",
    "ModelConfig",
    "MoEConfig",
    "RunConfig",
    "ServeConfig",
    "ShapeConfig",
    "SSMConfig",
    "TrainConfig",
    "apply_overrides",
    "cells",
    "get_model_config",
    "reduced",
]
