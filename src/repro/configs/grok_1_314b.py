"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]
"""

from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    d_ff=32768,
    vocab_size=131_072,
    attention=AttentionConfig(
        num_heads=48,
        num_kv_heads=8,
        rope_theta=10_000.0,
        logit_softcap=30.0,         # grok attention logit soft-capping
    ),
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        capacity_factor=1.25,
    ),
    max_seq_len=8_192,
    tie_embeddings=True,
    act_fn="gelu",
)
