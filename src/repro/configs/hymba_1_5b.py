"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attention + mamba heads in every block.
[arXiv:2411.13676; hf]
"""

from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    d_ff=5504,
    vocab_size=32_001,
    attention=AttentionConfig(
        num_heads=25,
        num_kv_heads=5,
        sliding_window=1024,        # hymba uses SWA on most layers
        local_global_ratio=0,       # handled as all-local + hybrid global state
        rope_theta=10_000.0,
    ),
    ssm=SSMConfig(state_size=16, conv_width=4, expand=2),
    hybrid_parallel=True,
    max_seq_len=8_192,
    tie_embeddings=True,
    act_fn="silu",
)
