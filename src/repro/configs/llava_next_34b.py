"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Anyres tiling; the vision frontend is a STUB — input_specs() provides
precomputed patch embeddings.
[hf:llava-hf/llava-v1.6-mistral-7b-hf family; unverified]
"""

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    d_ff=20480,
    vocab_size=64_000,
    attention=AttentionConfig(
        num_heads=56,
        num_kv_heads=8,
        rope_theta=1_000_000.0,
    ),
    frontend="image_patches",
    frontend_dim=1024,              # CLIP-large patch embedding dim (stub)
    num_patches=2880,               # anyres: base 576 + 4 tiles * 576
    max_seq_len=32_768,
    tie_embeddings=False,
    act_fn="silu",
)
