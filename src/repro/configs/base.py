"""Config system for the CoRD-JAX framework.

Plain dataclasses (no external deps), with:
  * ``ModelConfig``   — architecture description covering every assigned family
  * ``ShapeConfig``   — (seq_len, global_batch, kind) input-shape cells
  * ``MeshConfig``    — mesh shape/axis names (single-pod / multi-pod)
  * ``DataplaneConfig`` — CoRD dataplane mode + policies + technique toggles
  * ``TrainConfig`` / ``ServeConfig`` / ``RunConfig``
  * ``apply_overrides`` — ``key.subkey=value`` CLI override support
  * ``reduced``       — shrink any ModelConfig to a CPU-smoke-test size
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, fields, replace
from typing import Any


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 2
    # Arctic-style: a dense residual MLP runs in parallel with the expert MLPs.
    dense_residual: bool = False
    dense_residual_ff: int = 0
    # capacity factor for fixed-capacity dispatch (EP all-to-all friendly)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    """State-space / recurrent block parameters (mamba, mLSTM, sLSTM)."""
    state_size: int = 16          # N in mamba; per-head state for mLSTM
    conv_width: int = 4           # depthwise conv width in mamba
    expand: int = 2               # inner expansion factor
    dt_rank: int = 0              # 0 -> ceil(d_model/16)
    num_heads: int = 4            # heads for mLSTM/sLSTM
    block_pattern: str = "m"      # xlstm: string over {"m","s"} cycled across layers


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0             # 0 -> d_model // num_heads
    # sliding-window pattern: window>0 enables local attention;
    # local_global_ratio = k means layers cycle [k local, 1 global].
    sliding_window: int = 0
    local_global_ratio: int = 0   # 0 -> all layers global (or all local if window>0)
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # gemma3 uses a larger theta on global layers
    logit_softcap: float = 0.0
    qk_norm: bool = False


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int = 4
    d_model: int = 256
    d_ff: int = 1024
    vocab_size: int = 32_000
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    max_seq_len: int = 131_072
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    act_fn: str = "silu"          # silu | gelu
    gated_mlp: bool = True        # SwiGLU/GeGLU (3 mats) vs classic MLP (2 mats)
    # enc-dec (whisper): encoder layer count; decoder uses num_layers.
    encoder_layers: int = 0
    encoder_max_len: int = 1500   # whisper: 1500 frames after conv frontend
    # modality frontend stub: "none" | "audio_frames" | "image_patches"
    frontend: str = "none"
    frontend_dim: int = 0         # embedding dim delivered by the (stub) frontend
    num_patches: int = 0          # vlm: patches per image (anyres tiling stub)
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # hybrid (hymba): attention and mamba run in parallel in every block
    hybrid_parallel: bool = True

    @property
    def head_dim(self) -> int:
        a = self.attention
        return a.head_dim if a.head_dim else self.d_model // max(a.num_heads, 1)

    @property
    def is_subquadratic(self) -> bool:
        """True if decode state does not grow ~ O(seq) for *all* layers.

        Used to decide long_500k applicability (see configs/__init__.py
        LONG_CONTEXT_ARCHS)."""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True
        a = self.attention
        # sliding-window archs with a local:global pattern: local layers have
        # O(window) KV; we treat them as runnable for long_500k.
        return a.sliding_window > 0 and a.local_global_ratio > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        a = self.attention
        hd = self.head_dim
        emb = self.vocab_size * self.d_model
        out = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        att = self.d_model * hd * (a.num_heads + 2 * a.num_kv_heads) \
            + a.num_heads * hd * self.d_model
        nmat = 3 if self.gated_mlp else 2
        if self.family == "moe":
            m = self.moe
            ff_exp = nmat * self.d_model * self.d_ff * m.num_experts
            ff_dense = (nmat * self.d_model * m.dense_residual_ff
                        if m.dense_residual else 0)
            router = self.d_model * m.num_experts
            ff = ff_exp + ff_dense + router
        elif self.family == "ssm":
            # xlstm: inner projections replace FFN; approximate with expand factor
            inner = self.ssm.expand * self.d_model
            ff = 2 * self.d_model * inner + inner * self.d_model \
                + 4 * inner * self.ssm.state_size
        else:
            ff = nmat * self.d_model * self.d_ff
        if self.family == "hybrid":
            inner = self.ssm.expand * self.d_model
            ff += 2 * self.d_model * inner + inner * self.d_model
        layers = self.num_layers + self.encoder_layers
        return emb + out + layers * (att + ff + 2 * self.d_model) + self.d_model

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        full = self.param_count()
        nmat = 3 if self.gated_mlp else 2
        ff_all = nmat * self.d_model * self.d_ff * m.num_experts * self.num_layers
        ff_act = nmat * self.d_model * self.d_ff * m.top_k * self.num_layers
        return full - ff_all + ff_act


# ---------------------------------------------------------------------------
# Input shapes (the four assigned cells)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh / dataplane / run configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    # Axis sizes for the production meshes (see launch/mesh.py). For local CPU
    # runs, ``local_devices`` overrides with a (data, model) mesh of that many
    # host devices.
    local_devices: int = 0
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: str = "pod"


@dataclass(frozen=True)
class DataplaneConfig:
    """CoRD dataplane configuration — the paper's knobs."""
    mode: str = "cord"            # bypass | cord | socket
    # Technique toggles (paper Fig. 1). True = technique active (fast path).
    # Effective value = mode preset AND toggle, so setting one False
    # "removes" that technique from any mode (cord/socket presets already
    # remove kernel_bypass / zero_copy+polling respectively).
    zero_copy: bool = True
    polling: bool = True
    kernel_bypass: bool = True
    # Fuse the pipeline's pure-cost stages into one delay chain + one
    # staged-copy pass per side (bit-identical, smaller per-op HLO).
    # False keeps one chain/copy per stage (the pre-fusion shape, kept
    # for ablation and the fusion-equivalence tests).
    fuse_mediation: bool = True
    # Pallas dataplane kernels (kernels/dataplane): "auto" runs the real
    # bounce-copy / in-kernel-cost kernels on TPU and the XLA emulation
    # elsewhere; "on" forces the kernels everywhere (interpret mode
    # off-TPU — the bit-equivalence test path); "off" keeps the XLA
    # emulation.  Value-identical in all three settings.
    pallas_dataplane: str = "auto"
    # Policy set enforced in cord mode.
    policies: tuple[str, ...] = ("telemetry",)
    # Tenants sharing this dataplane (per-tenant runtime accounting/QoS).
    # The Dataplane's own tenant is always included.
    tenants: tuple[str, ...] = ()
    # Chunked-collective scheduling (QoS + compute/comm overlap).
    chunk_bytes: int = 0          # 0 = no chunking
    # Cost emulation (perftest/NPB measured paths only; off for model paths
    # so dry-run cost analysis stays clean).
    emulate_costs: bool = False
    # Emulated interrupt cost in microseconds when polling is disabled
    # (the paper's wait-for-event path).
    interrupt_cost_us: float = 8.0
    # Per-op mediation cost emulation: the user->kernel crossing.
    syscall_cost_ns: float = 400.0
    # Extra per-op cost of the full socket/IPoIB kernel network stack.
    socket_stack_ns: float = 3000.0
    # IPoIB bandwidth degradation: extra ns per payload byte on the
    # socket path (calibrated against the measured bypass bandwidth).
    socket_ns_per_byte: float = 1.0


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    seq_len: int = 1024
    global_batch: int = 8
    microbatch: int = 0           # 0 = no grad accumulation
    learning_rate: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    opt_dtype: str = "float32"    # adam mu/nu dtype ("bfloat16" halves opt mem)
    seed: int = 0
    remat: str = "none"           # none | full | dots
    grad_compression: str = "none"  # none | int8
    checkpoint_every: int = 0     # 0 = disabled
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    log_every: int = 10


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    prefill_chunk: int = 512
    max_new_tokens: int = 32
    temperature: float = 0.0
    kv_cache_len: int = 4096
    # Slot scheduler: "continuous" = persistent decode slots with
    # mid-decode WFQ refill (fixed-shape decode step, compiled once);
    # "gang" = legacy batch-to-completion scheduling (convoy effect,
    # shape-derived recompiles) — kept as the benchmark baseline.
    scheduler: str = "continuous"
    # Host-bucket admission charges len(prompt) tokens per request; rate
    # and burst from QoSPolicy.rates (defined in ops) scale by this many
    # tokens per traced-rate unit.
    admission_token_scale: float = 4.0
    # Per-tenant cap on concurrently held decode slots (0 = uncapped) —
    # the hard ceiling on a tenant's decode-step budget per engine step.
    max_slots_per_tenant: int = 0
    # Paged KV cache (docs/serving.md).  block_size > 0 switches the
    # continuous engine from per-slot fixed stripes to a shared block
    # pool with per-slot block tables; 0 keeps the legacy stripe layout.
    block_size: int = 0
    # Usable pool blocks (0 = auto: max_batch * kv_cache_len/block_size,
    # i.e. the same token capacity the stripe layout preallocates).
    n_blocks: int = 0

    def __post_init__(self):
        if self.block_size < 0:
            raise ValueError(f"block_size must be >= 0, got {self.block_size}")
        if self.block_size > 0 and self.kv_cache_len % self.block_size:
            raise ValueError(
                f"block_size={self.block_size} must divide "
                f"kv_cache_len={self.kv_cache_len} — partial trailing "
                f"blocks would silently truncate a slot's cache")
        if self.n_blocks < 0:
            raise ValueError(f"n_blocks must be >= 0, got {self.n_blocks}")
        if self.n_blocks > 0 and self.block_size == 0:
            raise ValueError(
                f"n_blocks={self.n_blocks} requires block_size > 0 — the "
                f"pool is only allocated under the paged layout")
        if self.prefill_chunk < 0:
            raise ValueError(
                f"prefill_chunk must be >= 0, got {self.prefill_chunk}")
        if self.prefill_chunk > 0:
            if self.prefill_chunk < 8 or (
                    self.prefill_chunk & (self.prefill_chunk - 1)):
                raise ValueError(
                    f"prefill_chunk={self.prefill_chunk} must be 0 (off) or "
                    f"a power of two >= 8 (the minimum prompt bucket) so "
                    f"chunk covers nest inside prompt buckets")
            if self.block_size > 0 and self.prefill_chunk % self.block_size:
                raise ValueError(
                    f"prefill_chunk={self.prefill_chunk} must be a multiple "
                    f"of block_size={self.block_size} so chunk scatters stay "
                    f"block-aligned")


@dataclass(frozen=True)
class ObsConfig:
    """Per-tenant observability timelines (core/obs.py).

    Opt-in and provably free when off: snapshots read host/device arrays
    only *between* steps, never inside traced code, so traced results are
    bit-identical with the toggle on or off (tests/test_obs.py)."""
    timeline: bool = False        # snapshot per-tenant counters each step
    every: int = 1                # snapshot every N steps / engine ticks
    out_dir: str = "runs"         # where *_timeline.json artifacts land
    spark_width: int = 48         # console sparkline panel width
    panel: bool = True            # print per-tenant panels at end of run


@dataclass(frozen=True)
class ElasticConfig:
    """Observability-triggered elastic remesh — the closed control loop
    (docs/elasticity.md): a ThresholdWatcher (core/obs.py) over the
    timeline's rate series drives runtime/elastic.py remesh, with live
    QP migration for in-flight verbs connections (core/verbs.py).

    ``thresholds`` are CLI-friendly ``"rate_field=level"`` strings over
    the derived rate series (``obs.RATE_FIELDS``); ``release_thresholds``
    (same format, levels strictly below their trigger counterparts) arm
    the grow-back half of the cycle — sustained quiet under every release
    level restores a shrunken tenant to its pre-shrink slice (or, on the
    serve side, its pre-shrink slot budget).  Empty = shrink-only, the
    pre-pod-control-plane behaviour."""
    enabled: bool = False
    thresholds: tuple[str, ...] = ("denied_pct=50",)
    sustain: int = 3              # consecutive over-threshold windows to trip
    cooldown: int = 8             # windows a tripped tenant cannot re-trip
    shrink_factor: int = 2        # device shrink per remesh (largest axis)
    min_devices: int = 2          # never shrink below this many devices
    max_remesh: int = 1           # shrink remeshes per run (0 = unlimited);
    # grow-backs close the cycle and are not counted against the budget
    tenants: tuple[str, ...] = ()  # watched tenants; empty = all
    release_thresholds: tuple[str, ...] = ()  # grow-back arm; empty = off
    release_sustain: int = 3      # consecutive under-release windows to grow
    release_cooldown: int = 8     # windows before a grown tenant re-grows
    # Observe-only byte budget wired by ``launch/train.py --elastic``: a
    # QuotaPolicy(hard=False) marks runtime traffic over this budget in
    # the tenant's `denied` counter — the default trigger signal.
    meter_quota_bytes: int = 0    # 0 = no metering policy added


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    shape: ShapeConfig = SHAPES["train_4k"]
    mesh: MeshConfig = field(default_factory=MeshConfig)
    dataplane: DataplaneConfig = field(default_factory=DataplaneConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)
    obs: ObsConfig = field(default_factory=ObsConfig)
    elastic: ElasticConfig = field(default_factory=ElasticConfig)


# ---------------------------------------------------------------------------
# Reduced (smoke-test) configs
# ---------------------------------------------------------------------------

def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink an architecture to CPU smoke-test size, preserving its family
    and structural quirks (GQA ratio, local:global pattern, MoE top-k, dense
    residual, hybrid parallelism, enc-dec split...)."""
    a = cfg.attention
    heads = max(2, min(4, a.num_heads))
    kv = max(1, min(heads, max(1, round(heads * a.num_kv_heads / max(a.num_heads, 1)))))
    # keep the head-grouping divisible
    while heads % kv:
        kv -= 1
    att = replace(
        a,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        sliding_window=min(a.sliding_window, 8) if a.sliding_window else 0,
    )
    moe = cfg.moe
    if moe.num_experts:
        moe = replace(moe, num_experts=4, top_k=min(2, moe.top_k),
                      dense_residual_ff=64 if moe.dense_residual else 0)
    ssm = replace(cfg.ssm, state_size=min(cfg.ssm.state_size, 8), num_heads=2)
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 4 if not cfg.encoder_layers else 2),
        encoder_layers=min(cfg.encoder_layers, 2),
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        attention=att,
        moe=moe,
        ssm=ssm,
        max_seq_len=512,
        encoder_max_len=32,
        num_patches=8 if cfg.num_patches else 0,
        frontend_dim=32 if cfg.frontend_dim else 0,
        dtype="float32",
    )


# ---------------------------------------------------------------------------
# CLI overrides: "train.steps=10" / "dataplane.mode=bypass" / "model.d_model=128"
# ---------------------------------------------------------------------------

def _coerce(val: str, typ: Any) -> Any:
    if typ is bool or isinstance(typ, bool):
        return val.lower() in ("1", "true", "yes", "on")
    if typ is int:
        return int(val)
    if typ is float:
        return float(val)
    if typ is tuple or (hasattr(typ, "__origin__") and typ.__origin__ is tuple):
        return tuple(v for v in val.split(",") if v)
    return val


def apply_overrides(cfg: Any, overrides: list[str]) -> Any:
    """Apply ``a.b.c=value`` overrides to a (possibly nested) frozen dataclass."""
    for ov in overrides:
        if "=" not in ov:
            raise ValueError(f"override must be key=value, got {ov!r}")
        key, val = ov.split("=", 1)
        cfg = _set_path(cfg, key.split("."), val)
    return cfg


def _set_path(obj: Any, path: list[str], val: str) -> Any:
    name, rest = path[0], path[1:]
    if not dataclasses.is_dataclass(obj):
        raise TypeError(f"cannot descend into non-dataclass at {name!r}")
    fld = {f.name: f for f in fields(obj)}.get(name)
    if fld is None:
        raise KeyError(f"unknown config field {name!r} on {type(obj).__name__}")
    cur = getattr(obj, name)
    if rest:
        new = _set_path(cur, rest, val)
    else:
        typ = fld.type if isinstance(fld.type, type) else type(cur)
        new = _coerce(val, typ if cur is None else type(cur))
    return replace(obj, **{name: new})
