"""granite-34b [dense]: 88L d_model=6144 48H (GQA kv=1 / MQA) d_ff=24576
vocab=49152 — llama-architecture code model. [arXiv:2405.04324; hf]
"""

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    d_ff=24576,
    vocab_size=49_152,
    attention=AttentionConfig(
        num_heads=48,
        num_kv_heads=1,             # MQA
        rope_theta=10_000.0,
    ),
    max_seq_len=8_192,
    gated_mlp=False,            # GPT-BigCode-style 2-matrix MLP (hits ~34B)
    tie_embeddings=True,
    act_fn="silu",
)
