"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks (no separate FFN; blocks carry their own up/down projections).
[arXiv:2405.04517; unverified]
"""

from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    d_ff=0,                         # xLSTM blocks have internal projections
    vocab_size=50_304,
    attention=AttentionConfig(      # nominal GQA spec (used for head grouping)
        num_heads=4,
        num_kv_heads=4,
    ),
    ssm=SSMConfig(
        state_size=64,              # mLSTM per-head matrix-memory dim
        expand=2,
        num_heads=4,
        block_pattern="mmms",       # 3 mLSTM : 1 sLSTM, cycled over 24 layers
    ),
    max_seq_len=131_072,
    tie_embeddings=True,
    act_fn="gelu",
)
