"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 PLUS a dense residual MLP in parallel (arctic's
dense-MoE hybrid). [hf:Snowflake/snowflake-arctic-base; hf]
"""

from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    d_ff=4864,                      # per-expert FFN width
    vocab_size=32_000,
    attention=AttentionConfig(
        num_heads=56,
        num_kv_heads=8,
        rope_theta=10_000.0,
    ),
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        dense_residual=True,
        dense_residual_ff=7168,     # arctic residual dense MLP (assumption)
        capacity_factor=1.25,
    ),
    max_seq_len=4_096,
    tie_embeddings=False,
    act_fn="silu",
)
