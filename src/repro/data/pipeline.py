"""Data pipeline: deterministic synthetic token streams (learnable
structure, so example trainings visibly reduce loss), sharded loading,
packing, and straggler-mitigation hooks.

The synthetic task mixes affine token chains ``x_{t+1} = (a·x_t + b) mod V``
(with (a, b) drawn per sequence from a small pool) with noise tokens — a
language a ~100M transformer learns quickly, giving the end-to-end example
a visibly decreasing loss curve.

The loader is *stateless*: ``batch_at(step)`` is a pure function of
(seed, step, shard), so restarts and elastic re-sharding replay the exact
stream — the property checkpoint/restart correctness depends on.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 256
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    n_rules: int = 8          # size of the (a, b) pool
    noise: float = 0.02       # probability of a random token


class SyntheticLM:
    """Deterministic, shardable synthetic LM dataset."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # odd multipliers are invertible mod 2^k vocab sizes; keep it simple
        self.rules_a = rng.choice(np.arange(1, v, 2), cfg.n_rules)
        self.rules_b = rng.integers(0, v, cfg.n_rules)

    def _sequence(self, idx: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, idx))
        rule = rng.integers(0, cfg.n_rules)
        a, b = self.rules_a[rule], self.rules_b[rule]
        x = np.empty(cfg.seq_len + 1, np.int64)
        x[0] = rng.integers(0, cfg.vocab_size)
        for t in range(cfg.seq_len):
            x[t + 1] = (a * x[t] + b) % cfg.vocab_size
        noise = rng.random(cfg.seq_len + 1) < cfg.noise
        x[noise] = rng.integers(0, cfg.vocab_size, noise.sum())
        return x

    def batch_at(self, step: int, *, shard: int = 0, num_shards: int = 1):
        """Global batch for ``step``, optionally this shard's slice."""
        cfg = self.cfg
        per = cfg.global_batch // num_shards
        base = step * cfg.global_batch + shard * per
        seqs = np.stack([self._sequence(base + i) for i in range(per)])
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32)}


class ShardedLoader:
    """Per-host loader with prefetch-style iteration and a straggler
    watchdog: if producing a batch exceeds ``deadline_s`` the loader
    substitutes the previous batch and records the event (at scale, a slow
    input shard must never stall the step barrier)."""

    def __init__(self, dataset: SyntheticLM, shard: int = 0,
                 num_shards: int = 1, deadline_s: float = 5.0):
        self.ds = dataset
        self.shard = shard
        self.num_shards = num_shards
        self.deadline_s = deadline_s
        self.straggler_events: list[int] = []
        self._last = None

    def get(self, step: int):
        t0 = time.perf_counter()
        batch = self.ds.batch_at(step, shard=self.shard,
                                 num_shards=self.num_shards)
        if time.perf_counter() - t0 > self.deadline_s and self._last is not None:
            self.straggler_events.append(step)
            return self._last
        self._last = batch
        return batch


__all__ = ["DataConfig", "SyntheticLM", "ShardedLoader"]
