from repro.data.pipeline import DataConfig, ShardedLoader, SyntheticLM

__all__ = ["DataConfig", "SyntheticLM", "ShardedLoader"]
