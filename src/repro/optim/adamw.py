"""AdamW with warmup-cosine schedule and global-norm clipping.

Self-contained (no optax): init/update pure functions over pytrees, so
optimizer state shards exactly like the parameters.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def warmup_cosine(cfg: TrainConfig, total_steps: int | None = None):
    total = total_steps or max(cfg.steps, 1)

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(total - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return cfg.learning_rate * warm * (0.1 + 0.9 * cos)

    return schedule


def clip_by_global_norm(grads, max_norm: float):
    if max_norm <= 0:
        return grads, jnp.asarray(0.0)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def adamw_init(params, opt_dtype: str = "float32") -> AdamWState:
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[opt_dtype]
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, dt), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def adamw_update(grads, state: AdamWState, params, cfg: TrainConfig,
                 schedule=None):
    """Returns (new_params, new_state, stats)."""
    schedule = schedule or warmup_cosine(cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(step)
    b1, b2, eps, wd = cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        mdt = m.dtype
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), stats


__all__ = ["AdamWState", "adamw_init", "adamw_update", "warmup_cosine",
           "clip_by_global_norm"]
