from repro.train.gradsync import err_state_init, sync_grads
from repro.train.step import TrainState, init_state, make_explicit_dp_step, make_train_step

__all__ = ["TrainState", "init_state", "make_train_step",
           "make_explicit_dp_step", "sync_grads", "err_state_init"]
