"""Train-step builders.

Two distribution styles (docs/architecture.md maps both onto the
dataplane):

* :func:`make_train_step` — pjit/GSPMD: the step is jitted with
  in/out shardings derived from parallel/sharding.py; all communication
  edges inside the model flow through the dataplane as constraints.  Used
  by the production launcher and the multi-pod dry-run.

* :func:`make_explicit_dp_step` — shard_map over the data axis with the
  gradient all-reduce issued *explicitly* through the dataplane
  (bucketing / QoS / int8 compression) — the measured CoRD path; also the
  vehicle for the bypass/cord/socket end-to-end comparison (paper Fig. 6).

Both support gradient accumulation (microbatching) and donate the train
state.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig
from repro.core import compat
from repro.core.dataplane import Dataplane
from repro.optim.adamw import adamw_init, adamw_update, warmup_cosine
from repro.parallel.sharding import batch_specs, param_specs
from repro.train.gradsync import err_state_init, sync_grads


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array
    err: Any = None      # compression error feedback


def init_state(model, rng, compression: str = "none",
               opt_dtype: str = "float32") -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=adamw_init(params, opt_dtype),
                      step=jnp.zeros((), jnp.int32),
                      err=err_state_init(params, compression))


def _accumulate(loss_fn, params, batch, microbatch: int):
    """Gradient accumulation over microbatches via lax.scan."""
    b = jax.tree.leaves(batch)[0].shape[0]
    if microbatch <= 0 or microbatch >= b:
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    n = b // microbatch
    micro = jax.tree.map(
        lambda x: x.reshape((n, microbatch) + x.shape[1:]), batch)

    def mb_step(carry, mb):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb)
        acc_loss, acc_metrics, acc_grads = carry
        acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
        acc_metrics = jax.tree.map(jnp.add, acc_metrics, metrics)
        return (acc_loss + loss, acc_metrics, acc_grads), None

    (loss0, metrics0), grads0 = jax.value_and_grad(loss_fn, has_aux=True)(
        params, jax.tree.map(lambda x: x[0], micro))
    rest = jax.tree.map(lambda x: x[1:], micro)
    (loss, metrics, grads), _ = jax.lax.scan(
        mb_step, (loss0, metrics0, grads0), rest)
    inv = 1.0 / n
    return (loss * inv, jax.tree.map(lambda m: m * inv, metrics)), \
        jax.tree.map(lambda g: g * inv, grads)


# ---------------------------------------------------------------------------
# pjit/GSPMD step
# ---------------------------------------------------------------------------

def make_train_step(model, run: RunConfig, dp: Dataplane, *,
                    total_steps: int | None = None, fsdp: bool = False,
                    jit: bool = True):
    """Returns (step_fn, state_sharding_fn). ``step_fn(state, batch)``."""
    tcfg = run.train
    schedule = warmup_cosine(tcfg, total_steps)

    def loss_fn(params, batch):
        return model.loss(params, batch, dp=dp, remat=tcfg.remat)

    def step_fn(state: TrainState, batch):
        (loss, metrics), grads = _accumulate(loss_fn, state.params, batch,
                                             tcfg.microbatch)
        new_params, new_opt, stats = adamw_update(
            grads, state.opt, state.params, tcfg, schedule)
        metrics = {**metrics, **stats}
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1, err=state.err), metrics

    if not jit:
        return step_fn

    mesh = dp.mesh
    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0,))

    def shard_state(state_shape):
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        pspec = param_specs(state_shape.params, fsdp=fsdp, mesh_sizes=sizes)
        return TrainState(
            params=pspec,
            opt=type(state_shape.opt)(step=P(), mu=pspec, nu=pspec),
            step=P(),
            err=None if state_shape.err is None else param_specs(
                state_shape.err, fsdp=fsdp, mesh_sizes=sizes),
        )

    def sharded_jit(state_shape, batch_shape):
        st_spec = shard_state(state_shape)
        b_spec = batch_specs(batch_shape, dp.rules)
        to_sh = lambda spec: jax.tree.map(
            lambda s: NamedSharding(mesh, s), spec,
            is_leaf=lambda x: isinstance(x, P))
        return jax.jit(step_fn,
                       in_shardings=(to_sh(st_spec), to_sh(b_spec)),
                       out_shardings=(to_sh(st_spec), None),
                       donate_argnums=(0,))

    return step_fn, sharded_jit


# ---------------------------------------------------------------------------
# explicit shard_map DP step (the measured CoRD path)
# ---------------------------------------------------------------------------

def make_explicit_dp_step(model, run: RunConfig, dp: Dataplane, *,
                          axis: str = "data",
                          total_steps: int | None = None,
                          runtime_accounting: bool = False):
    """DP over ``axis``: per-shard grads + dataplane all-reduce.

    The returned function must be called under jit; batch leading dim is
    sharded over ``axis``, params replicated.

    With ``runtime_accounting=True`` the step threads the dataplane's
    per-tenant runtime state (``dp.runtime_init()``) through the gradient
    sync with the uniform ``(x, state)`` convention: the step becomes
    ``step(state, batch, rt) -> (state, metrics, rt)``, and QoS/quota act
    at run time on the measured path."""
    tcfg = run.train
    schedule = warmup_cosine(tcfg, total_steps)
    mesh = dp.mesh

    def loss_fn(params, batch):
        return model.loss(params, batch, dp=None, remat=tcfg.remat)

    def local_step(state: TrainState, batch, rt):
        (loss, metrics), grads = _accumulate(loss_fn, state.params, batch,
                                             tcfg.microbatch)
        grads, new_err, rt = sync_grads(
            dp, grads, axis, compression=tcfg.grad_compression,
            err_state=state.err, state=rt)
        loss = jax.lax.pmean(loss, axis)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(
            jnp.asarray(m, jnp.float32), axis), metrics)
        new_params, new_opt, stats = adamw_update(
            grads, state.opt, state.params, tcfg, schedule)
        metrics = {**metrics, **stats}
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1, err=new_err), metrics, rt

    state_specs = TrainState(params=P(), opt=P(), step=P(), err=P())
    if runtime_accounting:
        shard = compat.shard_map(
            local_step, mesh=mesh,
            in_specs=(state_specs, P(axis), P()),
            out_specs=(state_specs, P(), P()))
        return jax.jit(shard, donate_argnums=(0,))

    def stateless_step(state: TrainState, batch):
        new_state, metrics, _ = local_step(state, batch, None)
        return new_state, metrics

    shard = compat.shard_map(
        stateless_step, mesh=mesh,
        in_specs=(state_specs, P(axis)),
        out_specs=(state_specs, P()))
    return jax.jit(shard, donate_argnums=(0,))


__all__ = ["TrainState", "init_state", "make_train_step",
           "make_explicit_dp_step"]
