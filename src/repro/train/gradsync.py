"""Gradient synchronization through the CoRD dataplane.

This is the framework's highest-volume communication path, and the one the
paper's architecture pays off on: every gradient all-reduce is a dataplane
op, so the OS-side (framework-side) policies see, account, schedule and
may compress it.

Features (distributed-optimization tricks):
  * **bucketing** — leaves are grouped into ~bucket_bytes buckets, issued
    in reverse layer order so the first buckets to sync are the last
    layers' grads (overlap with the rest of backward on real hardware).
  * **QoS classes** — small (latency-sensitive) buckets go out first under
    the "grads-small" class when a QoSPolicy is configured.
  * **int8 compression with error feedback** — per-leaf symmetric
    quantization before the all-reduce, dequantize + residual accumulation
    after; halves→quarters the collective bytes on the DP axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.chunking import bucket_pytree
from repro.core.dataplane import Dataplane


# ---------------------------------------------------------------------------
# int8 compression with error feedback
# ---------------------------------------------------------------------------

def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_error_feedback(g: jax.Array, err: jax.Array):
    """Returns (quantized, scale, new_error)."""
    total = g.astype(jnp.float32) + err
    q, scale = quantize_int8(total)
    recon = dequantize_int8(q, scale)
    return q, scale, total - recon


# ---------------------------------------------------------------------------
# dataplane-mediated sync
# ---------------------------------------------------------------------------

def sync_grads(dp: Dataplane, grads, axis: str, *, bucket_bytes: int = 1 << 22,
               compression: str = "none", err_state=None, state=None):
    """All-reduce a gradient pytree over mesh axis ``axis`` through the
    dataplane (call inside shard_map over that axis).

    Returns ``(mean_grads, new_err_state, state)`` — the uniform dataplane
    state convention (``state`` is None when not threaded)."""
    leaves, tdef = jax.tree.flatten(grads)
    err_leaves = (jax.tree.leaves(err_state) if err_state is not None
                  else [jnp.zeros((), jnp.float32)] * len(leaves))
    n = jax.lax.psum(1, axis)

    buckets = bucket_pytree(grads, bucket_bytes)
    # reverse order: last layers' buckets (produced first in backward) sync
    # first → compute/comm overlap on hardware with async collectives
    order = list(range(len(buckets)))[::-1]

    flat_out: dict[int, jax.Array] = {}
    flat_err: dict[int, jax.Array] = {}
    idx = 0
    bucket_leaf_ids = []
    for bucket in buckets:
        ids = list(range(idx, idx + len(bucket)))
        bucket_leaf_ids.append(ids)
        idx += len(bucket)

    for bi in order:
        ids = bucket_leaf_ids[bi]
        for li in ids:
            g = leaves[li]
            if compression == "int8" and g.size >= 1024:
                q, scale, new_err = compress_error_feedback(
                    g, err_leaves[li] if err_leaves[li].shape == g.shape
                    else jnp.zeros_like(g, jnp.float32))
                r, state = dp.psum(q.astype(jnp.int32), axis,
                                   tag=f"grads/bucket{bi}", qos="grads",
                                   state=state)
                s, state = dp.psum(scale, axis, tag=f"grads/scale{bi}",
                                   qos="grads-small", state=state)
                # mean of dequantized sums (scales averaged is an
                # approximation; error feedback absorbs the residual)
                out = (r.astype(jnp.float32) * (s / n)) / n
                flat_err[li] = new_err
            else:
                r, state = dp.psum(g, axis, tag=f"grads/bucket{bi}",
                                   qos="grads", state=state)
                out = r / n
                flat_err[li] = jnp.zeros_like(g, jnp.float32) \
                    if compression == "int8" else jnp.zeros((), jnp.float32)
            flat_out[li] = out.astype(leaves[li].dtype)

    mean = jax.tree.unflatten(tdef, [flat_out[i] for i in range(len(leaves))])
    new_err = jax.tree.unflatten(tdef, [flat_err[i] for i in range(len(leaves))])
    return mean, new_err, state


def err_state_init(params, compression: str = "none"):
    if compression != "int8":
        return None
    return jax.tree.map(
        lambda p: (jnp.zeros(p.shape, jnp.float32) if p.size >= 1024
                   else jnp.zeros((), jnp.float32)), params)


__all__ = ["sync_grads", "err_state_init", "quantize_int8",
           "dequantize_int8", "compress_error_feedback"]
