"""Sharded checkpointing: atomic, async-capable, mesh-agnostic.

Checkpoints store each pytree leaf as a full (unsharded) ``.npy`` plus a
JSON manifest — so a checkpoint written on one mesh restores onto any
other (elastic re-shard on load = runtime/elastic.py).  Writes go to a
temp dir renamed into place (atomic), an async thread can own the write,
and ``keep_last`` prunes history.  ``latest_step`` + ``restore`` give the
auto-resume path used by the fault-tolerant runner.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_leaves_with_path(tree)]
    return leaves, paths, treedef


def save(ckpt_dir: str, step: int, tree, *, keep_last: int = 3,
         blocking: bool = True) -> threading.Thread | None:
    """Write checkpoint ``step``. Returns the writer thread if async."""
    leaves, paths, _ = _flatten(tree)
    # materialize on host first (cheap vs. the write; keeps jax arrays out
    # of the writer thread)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

    def write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for i, (arr, path) in enumerate(zip(host_leaves, paths)):
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append({"path": path, "file": fn,
                                       "shape": list(arr.shape),
                                       "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _prune(ckpt_dir, keep_last)

    if blocking:
        write()
        return None
    th = threading.Thread(target=write, daemon=True)
    th.start()
    return th


def _prune(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            full = os.path.join(ckpt_dir, name, "manifest.json")
            if os.path.exists(full):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore checkpoint into the structure of ``like``.

    ``shardings``: optional pytree of NamedSharding — leaves are placed
    with those shardings (elastic re-mesh on load)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, _, treedef = _flatten(like)
    if len(manifest["leaves"]) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"expected {len(leaves)}")
    arrays = []
    sh_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None) if shardings is not None
        else [None] * len(leaves))
    for rec, ref, sh in zip(manifest["leaves"], leaves, sh_leaves):
        arr = np.load(os.path.join(d, rec["file"]))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {rec['path']}: checkpoint shape "
                             f"{arr.shape} != expected {tuple(ref.shape)}")
        arrays.append(jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, arrays)


__all__ = ["save", "restore", "latest_step", "all_steps"]
