from repro.parallel.sharding import (
    DATA,
    MODEL,
    POD,
    activation_rules,
    batch_specs,
    cache_spec_tree,
    param_specs,
    spec_for_param,
)

__all__ = [
    "DATA", "MODEL", "POD", "activation_rules", "batch_specs",
    "cache_spec_tree", "param_specs", "spec_for_param",
]
