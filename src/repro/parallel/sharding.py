"""Logical-axis sharding rules (DP/TP/EP/SP over pod/data/model) and
per-parameter PartitionSpec derivation.

Activations are constrained through the dataplane using *logical* names
("batch", "heads", "mlp", ...); these rule tables map them to mesh axes.
Parameters get specs from path-pattern rules (``param_specs``), TP-sharding
attention heads / MLP hidden / vocab / experts over the ``model`` axis,
with optional FSDP sharding of the remaining large dimension over
``data``.

Shape-cell specialisations:
  * train / prefill / decode: batch → (pod, data)
  * long-context decode (batch=1): KV sequence → (data, model) —
    sequence-parallel attention, GSPMD inserts the reduction collectives.
"""

from __future__ import annotations

import re

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

DATA = "data"
MODEL = "model"
POD = "pod"


def activation_rules(cfg: ModelConfig, shape: ShapeConfig, *,
                     multi_pod: bool = False,
                     seq_shard_prefill: bool = True,
                     model_size: int = 16) -> dict:
    """Logical-name -> mesh-axis rules for activation constraints.

    Head axes are only mapped to ``model`` when the head count is at least
    the axis size (GSPMD pads the remainder, ≤2× waste); below that the
    padding blow-up is worse than replicating the attention activations
    (measured: KVH=1 padded to 16 materializes a 16× K buffer)."""
    batch_axes = (POD, DATA) if multi_pod else (DATA,)
    long_ctx = shape.kind == "decode" and shape.global_batch == 1
    a = cfg.attention
    rules = {
        "batch": batch_axes if not long_ctx else None,
        "seq": None,
        "embed": None,
        # heads shard only when they divide-ish the axis (≥ axis size):
        # padding 8→16 was MEASURED to double collective time (padded q/k
        # reshards) for a smaller compute win — see EXPERIMENTS.md §Perf
        # gemma3-4b iteration 1 (refuted).
        "heads": MODEL if a.num_heads >= model_size else None,
        "kv_heads": MODEL if a.num_kv_heads >= model_size else None,
        "mlp": MODEL,
        "expert_mlp": None,
        "vocab": MODEL,
        "experts": MODEL,
        "exp_groups": batch_axes,
        "kv_seq": None,
        "head_dim": None,
        # sequence-parallel residual stream (Megatron-SP): the residual /
        # norm segments and the remat-saved layer inputs shard over model,
        # re-gathered inside attention/MLP by GSPMD (reduce-scatter +
        # all-gather replaces the post-projection psum).
        "seq_resid": MODEL if shape.kind in ("train", "prefill") else None,
    }
    if shape.kind == "decode":
        # decode activations are (B, 1, H, hd) — tiny; constraining them on
        # heads only forces weight-side resharding/padding (measured 7.7 GiB
        # padded wq stacks on arctic). Let GSPMD place them.
        rules["heads"] = None
        rules["kv_heads"] = None
    rules["cache_head_dim"] = None
    if rules["kv_heads"] is None and not long_ctx and \
            shape.kind in ("decode", "prefill"):
        # KV heads don't divide the model axis: shard the KV *cache* over
        # head_dim instead — dynamic cache updates stay local, GSPMD adds a
        # small psum on decode logits.  (Without this, arctic's 300 GB
        # decode cache and llava's 16 GB/device prefill cache replicate.)
        rules["cache_head_dim"] = MODEL
        if shape.kind == "decode":
            rules["head_dim"] = MODEL
    if long_ctx:
        # batch=1: shard the KV cache sequence across the whole mesh (SP)
        rules["kv_seq"] = (batch_axes + (MODEL,)) if multi_pod \
            else (DATA, MODEL)
        rules["heads"] = None
        rules["kv_heads"] = None
        rules["mlp"] = MODEL
    if shape.kind == "prefill" and seq_shard_prefill:
        # sequence parallelism only when the batch cannot fill the data
        # axis — sharding seq while replicating batch is a memory disaster
        # (measured: llava prefill_32k at 481 GiB/device).
        data_size = 16
        if shape.global_batch < data_size:
            rules["seq"] = DATA
            rules["batch"] = (POD,) if multi_pod else None
            rules["exp_groups"] = (POD,) if multi_pod else None
    return rules


# ---------------------------------------------------------------------------
# parameter specs by path pattern
# ---------------------------------------------------------------------------

# (regex over the param path, spec for the LAST ndims of the leaf)
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/(tok|head)$", (MODEL, None)),             # vocab-sharded tables
    (r"attn.*/(wq|wk|wv)$", (None, MODEL, None)),      # heads sharded
    (r"attn.*/wo$", (MODEL, None)),
    (r"(q_norm|k_norm)/scale$", (None,)),
    (r"moe/router$", (None, MODEL)),
    (r"moe/(wi|wg|wo)$", (MODEL, None, None)),         # experts sharded
    (r"moe/dense/(wi|wg)$", (None, MODEL)),
    (r"moe/dense/wo$", (MODEL, None)),
    (r"mlp/(wi|wg)$", (None, MODEL)),
    (r"mlp/wo$", (MODEL, None)),
    (r"ffn/(wi|wg)$", (None, MODEL)),
    (r"ffn/wo$", (MODEL, None)),
    (r"mamba/in_proj$", (None, MODEL)),
    (r"mamba/(out_proj|x_proj)$", (MODEL, None)),
    (r"mamba/dt_proj$", (None, MODEL)),
    (r"mamba/(conv|A_log)$", (None, MODEL) ),
    (r"mamba/(conv_bias|dt_bias|D)$", (MODEL,)),
    (r"core/up$", (None, MODEL)),
    (r"core/(down)$", (MODEL, None)),
    (r"core/(wq|wk|wv)$", (None, MODEL)),
    (r"core/conv$", (None, MODEL)),
    (r"core/(conv_bias)$", (MODEL,)),
    (r"core/w$", (None, MODEL)),
    (r"vision_proj$", (None, MODEL)),
    (r"frontend$", (None, None)),
]

_FSDP_BLOCKLIST = re.compile(r"(norm|bias|scale|b[if]?$|/D$|A_log|conv)")


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _axis_size(axis, mesh_sizes: dict) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh_sizes.get(a, 1)
        return n
    return mesh_sizes.get(axis, 1)


# Serving (decode/prefill) 2D expert sharding: experts over model AND the
# FFN dim over data, statically resident — no ZeRO-style regathers on the
# latency path.  Contractions over the data-sharded dim become psums.
_SERVE_MOE_RULES: list[tuple[str, tuple]] = [
    (r"moe/(wi|wg)$", (MODEL, None, DATA)),
    (r"moe/wo$", (MODEL, DATA, None)),
]


def spec_for_param(path: str, ndim: int, shape: tuple, *,
                   fsdp: bool = False, mesh_sizes: dict | None = None,
                   serve_moe_2d: bool = False) -> P:
    """Derive the PartitionSpec for a parameter leaf.

    ``mesh_sizes`` (axis name -> size): axes that do not divide the dim are
    dropped (in/out shardings must divide exactly, unlike constraints)."""
    mesh_sizes = mesh_sizes or {}

    def fits(i, axis):
        return shape[i] % _axis_size(axis, mesh_sizes) == 0

    rules = (_SERVE_MOE_RULES + _PARAM_RULES) if serve_moe_2d else _PARAM_RULES
    for pat, tail in rules:
        if re.search(pat, path):
            if len(tail) > ndim:
                return P()
            spec = [None] * (ndim - len(tail)) + list(tail)
            spec = [s if fits(i, s) else None for i, s in enumerate(spec)]
            if fsdp and not _FSDP_BLOCKLIST.search(path):
                # shard the largest remaining unsharded dim over data
                free = [i for i, s in enumerate(spec) if s is None]
                if free:
                    big = max(free, key=lambda i: shape[i])
                    if shape[big] >= 64 and fits(big, DATA):
                        spec[big] = DATA
            return P(*spec)
    return P()  # replicate by default (norms, biases, small tensors)


def param_specs(params_tree, *, fsdp: bool = False,
                mesh_sizes: dict | None = None, serve_moe_2d: bool = False):
    """PartitionSpec pytree matching ``params_tree`` (shapes or arrays)."""
    def leaf_spec(path, leaf):
        return spec_for_param(_path_str(path), leaf.ndim, tuple(leaf.shape),
                              fsdp=fsdp, mesh_sizes=mesh_sizes,
                              serve_moe_2d=serve_moe_2d)
    return jax.tree_util.tree_map_with_path(leaf_spec, params_tree)


def filter_spec(spec: P, shape: tuple, mesh_sizes: dict | None) -> P:
    """Drop spec axes that do not divide the corresponding dim exactly
    (required for jit in/out shardings, unlike constraints)."""
    if mesh_sizes is None:
        return spec
    out = []
    for i, s in enumerate(tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))):
        out.append(s if shape[i] % _axis_size(s, mesh_sizes) == 0 else None)
    return P(*out)


def cache_spec_tree(cache_tree, rules: dict, mesh_sizes: dict | None = None):
    """Specs for decode caches: (L, B, S, KVH, hd) KV tensors get
    (None, batch, kv_seq, kv_heads, None); recurrent states get batch."""
    def leaf_spec(path, leaf):
        p = _path_str(path)
        if re.search(r"(^|/)(k|v|cross_k|cross_v)$", p) and leaf.ndim == 5:
            spec = P(None, rules.get("batch"), rules.get("kv_seq"),
                     rules.get("kv_heads"), rules.get("cache_head_dim"))
        elif leaf.ndim >= 2:
            spec = P(None, rules.get("batch"))
        else:
            spec = P()
        return filter_spec(spec, tuple(leaf.shape), mesh_sizes)
    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)


def batch_specs(batch_tree, rules: dict, mesh_sizes: dict | None = None):
    """Specs for input batches: leading dim = batch, text dims replicated."""
    def leaf_spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.ndim >= 2 and rules.get("seq") is not None:
            spec = P(rules.get("batch"), rules.get("seq"))
        else:
            spec = P(rules.get("batch"), *([None] * (leaf.ndim - 1)))
        return filter_spec(spec, tuple(leaf.shape), mesh_sizes)
    return jax.tree_util.tree_map_with_path(leaf_spec, batch_tree)


__all__ = [
    "DATA", "MODEL", "POD", "activation_rules", "param_specs",
    "spec_for_param", "cache_spec_tree", "batch_specs",
]
