"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM
(scalar memory with recurrent mixing, inherently sequential).

mLSTM uses the stabilized chunkwise-parallel form (linear attention with
per-head exponential gating): within a chunk the decay matrix is built in
log space; across chunks a (C, n, m) state is carried.  Decode is O(1)
per token — xlstm-350m runs the 500k cell on recurrent state alone.

sLSTM has recurrent weights (h_{t-1} feeds the gates), so it is evaluated
with ``lax.scan`` over time, block-diagonal per head.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.layers.common import act_fn, constrain, dense_init, rmsnorm, rmsnorm_init


# ===========================================================================
# mLSTM
# ===========================================================================

def mlstm_init(rng, d_model: int, cfg: SSMConfig) -> dict:
    di = cfg.expand * d_model
    h = cfg.num_heads
    r = jax.random.split(rng, 8)
    return {
        "up": dense_init(r[0], d_model, 2 * di),
        "conv": jax.random.normal(r[1], (cfg.conv_width, di), jnp.float32)
                 / math.sqrt(cfg.conv_width),
        "conv_bias": jnp.zeros((di,), jnp.float32),
        "wq": dense_init(r[2], di, di),
        "wk": dense_init(r[3], di, di),
        "wv": dense_init(r[4], di, di),
        "wi": dense_init(r[5], di, h, scale=1e-2),
        "bi": jnp.zeros((h,), jnp.float32),
        "wf": dense_init(r[6], di, h, scale=1e-2),
        "bf": jnp.linspace(3.0, 6.0, h),       # forget-gate bias init (open)
        "out_norm": rmsnorm_init(di),
        "down": dense_init(r[7], di, d_model),
    }


def mlstm_state_init(batch: int, d_model: int, cfg: SSMConfig) -> dict:
    di = cfg.expand * d_model
    h = cfg.num_heads
    hd = di // h
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), jnp.float32),
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def _mlstm_chunk(q, k, v, log_i, log_f, state, eps=1e-6):
    """One chunk of the stabilized chunkwise mLSTM.

    q,k,v: (B,H,c,hd); log_i/log_f: (B,H,c); state: dict(C,n,m).
    Returns (y, new_state)."""
    b, h, c, hd = q.shape
    C0, n0, m0 = state["C"], state["n"], state["m"]

    F = jnp.cumsum(log_f, axis=-1)                       # (B,H,c)
    # log weights for source position s at target t: F_t - F_s + log_i_s
    lw = F[..., :, None] - F[..., None, :] + log_i[..., None, :]  # (B,H,t,s)
    causal = jnp.tril(jnp.ones((c, c), bool))
    lw = jnp.where(causal, lw, -jnp.inf)
    inter_l = F + m0[..., None]                          # (B,H,t) carry weight
    m_t = jnp.maximum(lw.max(axis=-1), inter_l)          # stabilizer per t
    D = jnp.exp(lw - m_t[..., None])                     # (B,H,t,s)
    w_inter = jnp.exp(inter_l - m_t)                     # (B,H,t)

    scale = 1.0 / math.sqrt(hd)
    qk = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    intra = jnp.einsum("bhts,bhsd->bhtd", qk * D, v)
    inter = jnp.einsum("bhtd,bhde->bhte", q * scale, C0) * w_inter[..., None]
    num = intra + inter

    n_t = (jnp.einsum("bhts,bhsd->bhtd", D, k)
           + n0[..., None, :] * w_inter[..., None])      # (B,H,t,hd)
    denom = jnp.abs(jnp.einsum("bhtd,bhtd->bht", q * scale, n_t))
    denom = jnp.maximum(denom, jnp.exp(-m_t)) + eps
    y = num / denom[..., None]

    # carry to next chunk (state at position c)
    wc = jnp.exp(F[..., -1:] - F + log_i - m_t[..., -1:])    # (B,H,s)
    C_new = (C0 * jnp.exp(F[..., -1] + m0 - m_t[..., -1])[..., None, None]
             + jnp.einsum("bhs,bhsd,bhse->bhde", wc, k, v))
    n_new = (n0 * jnp.exp(F[..., -1] + m0 - m_t[..., -1])[..., None]
             + jnp.einsum("bhs,bhsd->bhd", wc, k))
    return y, {"C": C_new, "n": n_new, "m": m_t[..., -1], "conv": state["conv"]}


def mlstm(params: dict, x: jax.Array, cfg: SSMConfig, *,
          state: dict | None = None, dp=None, chunk: int = 128):
    """mLSTM block. x: (B,S,D). Returns (out, new_state)."""
    from repro.layers.mamba import _causal_conv
    b, s, d = x.shape
    di = cfg.expand * d
    h = cfg.num_heads
    hd = di // h

    xz = jnp.einsum("bsd,de->bse", x, params["up"].astype(x.dtype))
    xm, z = jnp.split(xz, 2, axis=-1)
    xm = constrain(dp, xm, ("batch", "seq", "mlp"), tag="mlstm/inner")

    if state is None:
        state = mlstm_state_init(b, d, cfg)
    conv_in_tail = state["conv"].astype(xm.dtype)
    xc, new_tail = _causal_conv(xm, params["conv"], params["conv_bias"],
                                conv_in_tail)
    xc = jax.nn.silu(xc)

    def heads(t):  # (B,S,di) -> (B,H,S,hd)
        return t.reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    q = heads(jnp.einsum("bse,ef->bsf", xc, params["wq"].astype(x.dtype)))
    k = heads(jnp.einsum("bse,ef->bsf", xc, params["wk"].astype(x.dtype)))
    v = heads(jnp.einsum("bse,ef->bsf", xm, params["wv"].astype(x.dtype)))
    log_i = (jnp.einsum("bse,eh->bsh", xc, params["wi"].astype(x.dtype))
             .astype(jnp.float32) + params["bi"]).transpose(0, 2, 1)
    log_f_raw = (jnp.einsum("bse,eh->bsh", xc, params["wf"].astype(x.dtype))
                 .astype(jnp.float32) + params["bf"]).transpose(0, 2, 1)
    log_f = -jax.nn.softplus(-log_f_raw)                 # log sigmoid

    ck = min(chunk, s)
    while s % ck:
        ck -= 1
    nc = s // ck

    def chunk_step(st, args):
        qc, kc, vc, lic, lfc = args
        y, st = _mlstm_chunk(qc.astype(jnp.float32), kc.astype(jnp.float32),
                             vc.astype(jnp.float32), lic, lfc, st)
        return st, y

    resh = lambda t: t.reshape(b, h, nc, ck, -1).transpose(2, 0, 1, 3, 4)
    reshg = lambda t: t.reshape(b, h, nc, ck).transpose(2, 0, 1, 3)
    st = dict(state)
    st, ys = jax.lax.scan(chunk_step, st,
                          (resh(q), resh(k), resh(v), reshg(log_i), reshg(log_f)))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(b, h, s, hd)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, di).astype(x.dtype)
    y = rmsnorm(params["out_norm"], y)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["down"].astype(x.dtype))
    out = constrain(dp, out, ("batch", "seq", "embed"), tag="mlstm/out")
    st["conv"] = new_tail.astype(jnp.float32)
    return out, st


# ===========================================================================
# sLSTM
# ===========================================================================

def slstm_init(rng, d_model: int, cfg: SSMConfig) -> dict:
    h = cfg.num_heads
    hd = d_model // h
    r = jax.random.split(rng, 4)
    dff = int(d_model * 4 / 3)
    return {
        "w": dense_init(r[0], d_model, 4 * d_model),     # i,f,z,o
        "r": (jax.random.normal(r[1], (h, hd, 4 * hd), jnp.float32)
              / math.sqrt(hd)),
        "b": jnp.concatenate([jnp.zeros((d_model,)),
                              jnp.full((d_model,), 3.0),   # forget bias open
                              jnp.zeros((2 * d_model,))]),
        "ffn": {
            "wi": dense_init(r[2], d_model, dff),
            "wg": dense_init(r[2], d_model, dff),
            "wo": dense_init(r[3], dff, d_model),
        },
        "ffn_norm": rmsnorm_init(d_model),
    }


def slstm_state_init(batch: int, d_model: int, cfg: SSMConfig) -> dict:
    return {"h": jnp.zeros((batch, d_model), jnp.float32),
            "c": jnp.zeros((batch, d_model), jnp.float32),
            "n": jnp.ones((batch, d_model), jnp.float32),
            "m": jnp.zeros((batch, d_model), jnp.float32)}


def slstm(params: dict, x: jax.Array, cfg: SSMConfig, *,
          state: dict | None = None, dp=None):
    """sLSTM layer + gated FFN. x: (B,S,D). Returns (out, new_state)."""
    b, s, d = x.shape
    h = cfg.num_heads
    hd = d // h
    if state is None:
        state = slstm_state_init(b, d, cfg)

    wx = (jnp.einsum("bsd,de->bse", x, params["w"].astype(x.dtype))
          .astype(jnp.float32) + params["b"])             # (B,S,4d)

    R = params["r"]                                       # (H,hd,4hd)

    def step(st, wx_t):
        hp = st["h"].reshape(b, h, hd)
        rec = jnp.einsum("bhd,hde->bhe", hp, R).reshape(b, 4 * d)
        gi, gf, gz, go = jnp.split(wx_t + rec, 4, axis=-1)
        m_new = jnp.maximum(gf + st["m"], gi)
        i = jnp.exp(gi - m_new)
        f = jnp.exp(gf + st["m"] - m_new)
        c = f * st["c"] + i * jnp.tanh(gz)
        n = f * st["n"] + i
        hh = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
        return {"h": hh, "c": c, "n": n, "m": m_new}, hh

    state, ys = jax.lax.scan(step, state, wx.swapaxes(0, 1))
    y = ys.swapaxes(0, 1).astype(x.dtype)                 # (B,S,D)

    # gated FFN sub-block (proj factor 4/3)
    yn = rmsnorm(params["ffn_norm"], y)
    f = params["ffn"]
    hdn = jax.nn.gelu(jnp.einsum("bsd,df->bsf", yn, f["wg"].astype(x.dtype))) \
        * jnp.einsum("bsd,df->bsf", yn, f["wi"].astype(x.dtype))
    hdn = constrain(dp, hdn, ("batch", "seq", "mlp"), tag="slstm/ffn")
    out = y + jnp.einsum("bsf,fd->bsd", hdn, f["wo"].astype(x.dtype))
    out = constrain(dp, out, ("batch", "seq", "embed"), tag="slstm/out")
    return out, state


def xlstm_state_slot_insert(state: dict, prefilled: dict, slot) -> dict:
    """Write one prefilled request's xLSTM unit state (batch row 0 of a
    batch-1 state dict from :func:`mlstm_state_init` /
    :func:`slstm_state_init`) into slot ``slot`` of a persistent
    multi-slot state.

    Unit-local states carry batch on axis 0; once the model stacks the
    block-repeat axis in front (models/xlstm_model.py) batch becomes
    axis 1 and the engine uses ``state_slot_insert`` on the whole cache.
    Every leaf — mLSTM's (C, n, m) matrix memory and conv tail, sLSTM's
    (h, c, n, m) scalar memory — is an O(1) summary, so the insert
    replaces the slot's state wholesale (no validity-masked tail)."""
    from repro.layers.kvcache import state_slot_insert
    return state_slot_insert(state, prefilled, slot, batch_axis=0)


__all__ = [
    "mlstm_init", "mlstm", "mlstm_state_init",
    "slstm_init", "slstm", "slstm_state_init",
    "xlstm_state_slot_insert",
]
