"""Neural network layers (pure functions over explicit param pytrees)."""

from repro.layers.attention import (
    attend,
    attend_flash,
    attend_naive,
    attention_init,
    make_mask,
    output_project,
    qkv_project,
)
from repro.layers.common import (
    act_fn,
    constrain,
    dense_init,
    dtype_of,
    embed_init,
    layernorm,
    layernorm_init,
    rmsnorm,
    rmsnorm_init,
    softcap,
)
from repro.layers.embedding import embed, embedding_init, logits
from repro.layers.kvcache import (
    cache_positions,
    cache_validity,
    kv_cache_init,
    kv_update,
)
from repro.layers.mamba import mamba, mamba_init, mamba_state_init
from repro.layers.mlp import mlp, mlp_init
from repro.layers.moe import moe, moe_init, route
from repro.layers.rope import apply_rope, sinusoidal_positions
from repro.layers.xlstm import (
    mlstm,
    mlstm_init,
    mlstm_state_init,
    slstm,
    slstm_init,
    slstm_state_init,
)
