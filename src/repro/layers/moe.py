"""Mixture-of-Experts layer: top-k routing with **block-wise capacity**
dispatch (GShard/MaxText-style "dropping" MoE), EP-shardable under GSPMD.

Tokens are grouped into blocks of ``group_size``; each block dispatches to
all experts with a per-block capacity C = ceil(group_size·k·cf / E).  The
dispatch/combine tensors are (G, n, E, C) with E·C ≈ group_size·k·cf —
their footprint is **independent of the expert count**, which is what
keeps arctic-480b (128 experts) inside HBM at 256-way SPMD.

Capacity dropping applies at **training only**.  Inference (``train=False``)
is dropless (C = n·k over a small block), because serving exactness —
continuous batching ≡ gang decode at temperature 0, bit-exact slot
preempt/resume — requires per-token outputs that are invariant to batch
composition, and capacity races between co-resident tokens break that.

Sharding (via the dataplane): blocks G → data axis, experts E → model
axis.  The G↔E resharding between dispatch and expert compute is the EP
all-to-all, materialized by GSPMD from the constraints this module issues.

Arctic-style ``dense_residual``: a dense MLP runs in parallel and its
output is added to the expert output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.layers.common import act_fn, constrain, dense_init


def moe_init(rng, d_model: int, d_ff: int, cfg: MoEConfig,
             gated: bool = True) -> dict:
    r = jax.random.split(rng, 5)
    e = cfg.num_experts
    p = {
        "router": dense_init(r[0], d_model, e, scale=1e-2),
        "wi": dense_init(r[1], d_model, e, d_ff).transpose(1, 0, 2),  # (E,D,F)
        "wo": dense_init(r[2], d_ff, e, d_model).transpose(1, 0, 2),  # (E,F,D)
    }
    if gated:
        p["wg"] = dense_init(r[3], d_model, e, d_ff).transpose(1, 0, 2)
    if cfg.dense_residual:
        from repro.layers.mlp import mlp_init
        p["dense"] = mlp_init(r[4], d_model, cfg.dense_residual_ff, gated)
    return p


def _capacity(group: int, cfg: MoEConfig) -> int:
    c = int(group * cfg.top_k * cfg.capacity_factor / max(cfg.num_experts, 1))
    return max(c, 1)


def route(params: dict, x2d: jax.Array, cfg: MoEConfig, *,
          train: bool, rng=None):
    """Router: top-k gates + aux losses. x2d: (T, D) flat tokens."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    if train and cfg.router_jitter > 0 and rng is not None:
        logits += cfg.router_jitter * jax.random.normal(rng, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)              # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # aux losses (Switch-style)
    me = probs.mean(axis=0)                                    # (E,)
    ce = jnp.zeros_like(me).at[idx[:, 0]].add(1.0) / idx.shape[0]
    lb_loss = cfg.num_experts * jnp.sum(me * ce) * cfg.load_balance_loss
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_z_loss
    return gates, idx, lb_loss + z_loss


def moe(params: dict, x: jax.Array, cfg: MoEConfig, *, act: str = "silu",
        group_size: int = 512, train: bool = False, rng=None, dp=None):
    """Apply the MoE layer. x: (B, S, D). Returns (out, aux_loss)."""
    b, s, d = x.shape
    tokens = b * s
    # Inference is dropless: serving correctness (continuous ≡ gang at
    # temp 0, slot-exact preempt/resume) needs per-token outputs that do
    # not depend on which other rows share the batch, and capacity
    # dropping is exactly such a coupling (the block cumsum races tokens
    # for expert queue slots).  With C = n·k no token can ever drop, and
    # co-token contributions enter every einsum as exact zeros, so each
    # token's output is invariant to grouping and batch composition.
    # Training keeps the fixed-capacity dispatch (EP all-to-all friendly,
    # bounded footprint); the smaller eval group bounds the dropless
    # (G,n,E,C≈n·k) dispatch tensor.
    g_sz = min(group_size if train else min(group_size, 64), tokens)
    while tokens % g_sz:
        g_sz -= 1
    g = tokens // g_sz
    e = cfg.num_experts
    c = _capacity(g_sz, cfg) if train else g_sz * cfg.top_k

    xf = x.reshape(tokens, d)
    gates, idx, aux = route(params, xf, cfg, train=train, rng=rng)

    # block-local positions in each expert queue
    onehot = jax.nn.one_hot(idx.reshape(g, g_sz, cfg.top_k), e,
                            dtype=jnp.int32)                   # (G,n,k,E)
    flat = onehot.reshape(g, g_sz * cfg.top_k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                         # (G,n*k,E)
    pos = pos.reshape(g, g_sz, cfg.top_k, e)
    keep = (pos < c) & (onehot > 0)
    slot = jax.nn.one_hot(jnp.where(keep, pos, -1), c,
                          dtype=x.dtype)                       # (G,n,k,E,C)
    dispatch = slot.sum(2)                                     # (G,n,E,C)
    gmat = (gates.reshape(g, g_sz, cfg.top_k, 1, 1) * slot).sum(2)

    xg = xf.reshape(g, g_sz, d)
    xg = constrain(dp, xg, ("exp_groups", None, "embed"), tag="moe/tokens")
    # dispatch/combine edges carry the "moe-dispatch" QoS class: the EP
    # all-to-alls are latency-critical (every token waits on them), so the
    # chunk scheduler can prioritize them over bulk traffic.
    dispatch = constrain(dp, dispatch, ("exp_groups", None, "experts", None),
                         tag="moe/dispatch", qos="moe-dispatch")
    # EP all-to-all edge: (G blocks on data) -> (E experts on model)
    ein = jnp.einsum("gnec,gnd->gecd", dispatch, xg)
    ein = constrain(dp, ein, ("exp_groups", "experts", None, "embed"),
                    tag="moe/expert_in", qos="moe-dispatch")

    h = jnp.einsum("gecd,edf->gecf", ein, params["wi"].astype(x.dtype))
    if "wg" in params:
        gate = jnp.einsum("gecd,edf->gecf", ein, params["wg"].astype(x.dtype))
        h = act_fn(act)(gate) * h
    else:
        h = act_fn(act)(h)
    h = constrain(dp, h, ("exp_groups", "experts", None, "expert_mlp"),
                  tag="moe/hidden")
    eo = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(x.dtype))
    eo = constrain(dp, eo, ("exp_groups", "experts", None, "embed"),
                   tag="moe/expert_out")

    # combine: EP all-to-all back (E on model) -> (G on data)
    out = jnp.einsum("gnec,gecd->gnd", gmat.astype(x.dtype), eo)
    out = out.reshape(b, s, d)
    out = constrain(dp, out, ("batch", "seq", "embed"), tag="moe/out",
                    qos="moe-dispatch")

    if "dense" in params:  # arctic dense residual
        from repro.layers.mlp import mlp as dense_mlp
        out = out + dense_mlp(params["dense"], x, act=act, dp=dp,
                              tag="moe/dense_residual")
    return out, aux


__all__ = ["moe_init", "moe", "route"]
