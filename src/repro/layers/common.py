"""Shared layer primitives: norms, activations, initializers, dense ops.

All layers are pure functions over explicit parameter pytrees (dicts), so
the whole stack is `jax.lax.scan`-able over stacked per-layer params —
essential to keep HLO size bounded at 256/512-way SPMD.

Every function takes an optional ``dp`` (Dataplane) used to issue logical
sharding constraints — communication edges — through the paper's
mediation layer.  ``dp=None`` means local/unsharded execution.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def constrain(dp, x: jax.Array, names: Sequence, tag: str = "act",
              qos: str = "default") -> jax.Array:
    """Issue a sharding edge through the dataplane's mediation pipeline.
    ``qos`` names the op's priority class (QoSPolicy)."""
    if dp is None:
        return x
    return dp.constrain(x, names, tag=tag, qos=qos)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(rng, in_dim: int, *out_dims: int, dtype=jnp.float32,
               scale: float | None = None) -> jax.Array:
    """Truncated-normal fan-in init for a (in_dim, *out_dims) kernel."""
    shape = (in_dim, *out_dims)
    std = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype=jnp.float32) -> jax.Array:
    # std 1/sqrt(dim): with the sqrt(d) input scaling this gives unit-variance
    # activations AND ~unit-variance tied logits (initial loss ≈ ln V).
    return (jax.random.truncated_normal(rng, -2.0, 2.0, (vocab, dim),
                                        jnp.float32)
            / np.sqrt(dim)).astype(dtype)


def stacked_init(rng, num: int, init_fn) -> jax.Array | dict:
    """vmap an init over ``num`` layers → leading layer axis for lax.scan."""
    rngs = jax.random.split(rng, num)
    return jax.vmap(init_fn)(rngs)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int) -> dict:
    return {"scale": jnp.zeros((dim,), jnp.float32)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale): zero-init scale = identity at init
    return (x * (1.0 + params["scale"])).astype(dtype)


def layernorm_init(dim: int) -> dict:
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


__all__ = [
    "constrain", "act_fn", "dense_init", "embed_init", "stacked_init",
    "rmsnorm_init", "rmsnorm", "layernorm_init", "layernorm",
    "softcap", "dtype_of",
]
