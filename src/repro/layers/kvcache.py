"""KV / recurrent-state caches for serving.

Caches are pytrees with a leading layer axis so the decode step can
``lax.scan`` over layers, slicing one layer's cache in and the updated
slice out.  Sharding is issued through the dataplane by the serve step
(kv_seq → data/model axes depending on the shape cell, see
parallel/sharding.py): :func:`kv_cache_constrain` routes the cache's
sharding edges through the mediation pipeline like any other dataplane
traffic, so cache placement is visible to (and accountable by) the same
policies that see the collectives.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# logical axis names of a (layers, batch, kv_seq, kv_heads, head_dim) cache
KV_CACHE_AXES = (None, "batch", "kv_seq", "kv_heads", "head_dim")


def kv_cache_init(layers: int, batch: int, max_len: int, kv_heads: int,
                  head_dim: int, dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((layers, batch, max_len, kv_heads, head_dim), dtype),
        "v": jnp.zeros((layers, batch, max_len, kv_heads, head_dim), dtype),
    }


def kv_update(cache_k: jax.Array, cache_v: jax.Array, k: jax.Array,
              v: jax.Array, pos) -> tuple[jax.Array, jax.Array]:
    """Insert (B, s, KVH, hd) new keys/values at position ``pos`` into a
    single layer's (B, S_max, KVH, hd) cache."""
    pos = jnp.asarray(pos, jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                      (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                      (0, pos, 0, 0))
    return ck, cv


def cache_positions(max_len: int) -> jax.Array:
    return jnp.arange(max_len, dtype=jnp.int32)


def cache_validity(max_len: int, filled_len) -> jax.Array:
    """Boolean (max_len,) mask of filled cache slots."""
    return jnp.arange(max_len, dtype=jnp.int32) < filled_len


def kv_cache_constrain(dp, cache, *, tag: str = "kvcache",
                       qos: str = "kvcache", tenant: str | None = None):
    """Issue the KV cache's sharding edges through the dataplane.

    Applies to {"k","v"}-style caches of rank-5 leaves (other recurrent
    cache layouts pass through untouched).  A no-op without a dataplane."""
    if dp is None or not isinstance(cache, dict):
        return cache
    return {k: (dp.constrain(v, KV_CACHE_AXES, tag=f"{tag}/{k}", qos=qos,
                             tenant=tenant)
                if hasattr(v, "ndim") and v.ndim == 5 else v)
            for k, v in cache.items()}


__all__ = ["kv_cache_init", "kv_update", "cache_positions", "cache_validity",
           "kv_cache_constrain", "KV_CACHE_AXES"]
