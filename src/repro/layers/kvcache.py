"""KV / recurrent-state caches for serving.

Caches are pytrees with a leading layer axis so the decode step can
``lax.scan`` over layers, slicing one layer's cache in and the updated
slice out.  Sharding is issued through the dataplane by the serve step
(kv_seq → data/model axes depending on the shape cell, see
parallel/sharding.py): :func:`kv_cache_constrain` routes the cache's
sharding edges through the mediation pipeline like any other dataplane
traffic, so cache placement is visible to (and accountable by) the same
policies that see the collectives.

Slot-aware helpers (persistent-slot continuous batching, serve/engine.py):
the engine preallocates ONE ``(layers, max_batch, max_cache_len, ...)``
cache whose batch rows are long-lived *slots*.  A request is prefilled
alone (batch 1, prompt-length-bucketed), its cache written into a free
slot with :func:`kv_slot_insert`, and the fixed-shape decode step advances
every slot at its own position (:func:`kv_update_slots`) behind a per-slot
validity mask (:func:`slot_validity`).  Entries beyond a slot's position
are never attended, so stale bytes from a previous resident (or prefill
padding) are harmless — each position is rewritten by the current resident
before it first becomes valid.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# logical axis names of a (layers, batch, kv_seq, kv_heads, head_dim) cache
KV_CACHE_AXES = (None, "batch", "kv_seq", "kv_heads", "head_dim")


def kv_cache_init(layers: int, batch: int, max_len: int, kv_heads: int,
                  head_dim: int, dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((layers, batch, max_len, kv_heads, head_dim), dtype),
        "v": jnp.zeros((layers, batch, max_len, kv_heads, head_dim), dtype),
    }


def kv_update(cache_k: jax.Array, cache_v: jax.Array, k: jax.Array,
              v: jax.Array, pos) -> tuple[jax.Array, jax.Array]:
    """Insert (B, s, KVH, hd) new keys/values at position ``pos`` into a
    single layer's (B, S_max, KVH, hd) cache."""
    pos = jnp.asarray(pos, jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                      (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                      (0, pos, 0, 0))
    return ck, cv


def kv_update_slots(cache_k: jax.Array, cache_v: jax.Array, k: jax.Array,
                    v: jax.Array, pos) -> tuple[jax.Array, jax.Array]:
    """Per-slot cache write: insert (B, s, KVH, hd) new keys/values into a
    (B, S_max, KVH, hd) cache at *per-slot* positions ``pos`` (B,) — the
    continuous-batching analogue of :func:`kv_update`, where every batch
    row is a slot advancing independently."""
    pos = jnp.asarray(pos, jnp.int32)

    def one(ck, cv, kk, vv, p):
        return (jax.lax.dynamic_update_slice(ck, kk.astype(ck.dtype),
                                             (p, 0, 0)),
                jax.lax.dynamic_update_slice(cv, vv.astype(cv.dtype),
                                             (p, 0, 0)))

    return jax.vmap(one)(cache_k, cache_v, k, v, pos)


def kv_slot_insert(cache: dict, prefilled: dict, slot) -> dict:
    """Write one prefilled request's cache (leading batch dim 1) into slot
    ``slot`` of a persistent slot cache.

    ``slot`` may be a traced scalar, so one jitted insert serves every
    slot.  Positions beyond the prefill capacity keep whatever the slot
    held before; the per-slot validity mask makes them unreachable until
    the new resident overwrites them token by token."""
    slot = jnp.asarray(slot, jnp.int32)
    zero = jnp.zeros((), jnp.int32)

    def ins(dst, src):
        if not (hasattr(dst, "ndim") and dst.ndim == 5):
            return dst
        start = (zero, slot, zero, zero, zero)
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)

    return {name: ins(dst, prefilled[name]) for name, dst in cache.items()}


def state_slot_insert(cache, prefilled, slot, *, batch_axis: int = 1):
    """Family-agnostic slot insert: write one prefilled request's decode
    state (batch dim 1 at ``batch_axis``) into row ``slot`` of every array
    leaf of a persistent slot cache.

    This is the :func:`kv_slot_insert` analogue for recurrent and hybrid
    caches: mamba's ``(L, B, W-1, d_inner)`` conv tail and ``(L, B,
    d_inner, N)`` SSM state, xLSTM's per-unit ``(reps, B, ...)`` matrix/
    scalar memories, and encdec's rank-5 cross-attention cache all carry
    batch on axis 1, so one tree-map of ``dynamic_update_slice`` covers
    every family.  KV stripe leaves whose source is shorter than the
    stripe (prefill capacity < kv_cache_len) are written only over their
    leading positions, exactly like :func:`kv_slot_insert` — the tail
    stays masked by per-slot validity until the resident reaches it.

    ``batch_axis=0`` serves the layer-local states (before the model
    stacks a layer axis in front): see ``mamba_state_slot_insert`` /
    ``xlstm_state_slot_insert`` in layers/mamba.py / layers/xlstm.py.
    """
    slot = jnp.asarray(slot, jnp.int32)
    zero = jnp.zeros((), jnp.int32)

    def ins(dst, src):
        start = tuple(slot if d == batch_axis else zero
                      for d in range(dst.ndim))
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)

    return jax.tree.map(ins, cache, prefilled)


def slot_vectors_init(slots: int) -> dict:
    """Per-slot bookkeeping vectors: next write position, active flag and
    tenant index (−1 = free) — the host-mirrored slot state of the
    continuous-batching engine.  Host-side numpy by design: the engine
    mutates them in place between decode steps and feeds the position
    vector to the fixed-shape decode step each tick."""
    import numpy as np
    return {
        "pos": np.zeros((slots,), np.int32),
        "active": np.zeros((slots,), bool),
        "tenant": np.full((slots,), -1, np.int32),
    }


def slot_validity(max_len: int, pos) -> jax.Array:
    """(B, max_len) mask of cache entries visible to each slot decoding at
    per-slot position ``pos`` (inclusive: the entry written at ``pos``
    this step is attended)."""
    return (jnp.arange(max_len, dtype=jnp.int32)[None, :]
            <= jnp.asarray(pos, jnp.int32)[:, None])


def cache_positions(max_len: int) -> jax.Array:
    return jnp.arange(max_len, dtype=jnp.int32)


def cache_validity(max_len: int, filled_len) -> jax.Array:
    """Boolean (max_len,) mask of filled cache slots."""
    return jnp.arange(max_len, dtype=jnp.int32) < filled_len


# ---------------------------------------------------------------------------
# Paged KV block pool (vLLM-style, docs/serving.md)
# ---------------------------------------------------------------------------
#
# The paged layout replaces the per-slot stripe with ONE shared pool of
# fixed-size blocks: ``(layers, n_blocks + 1, block_size, kv_heads,
# head_dim)`` per k/v leaf, plus a host-side ``(max_batch, tables_len)``
# int32 block table mapping each slot's logical block index to a physical
# pool block.  Physical block 0 is reserved as a shared *null* block:
# free slots and unallocated table tail entries point at it, so gathers
# stay total functions of the table (garbage rows are masked by the same
# per-slot validity that guards stripe decode).  Scatters for inactive
# slots are routed to the out-of-bounds index ``n_blocks + 1`` and
# dropped (`mode="drop"`), never corrupting block 0.

def kv_pool_init(layers: int, n_blocks: int, block_size: int, kv_heads: int,
                 head_dim: int, dtype=jnp.bfloat16) -> dict:
    """Block pool with ``n_blocks`` usable blocks (physical ids 1..n_blocks;
    id 0 is the shared null block)."""
    shape = (layers, n_blocks + 1, block_size, kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_pool_gather(pool: dict, tables, block_size: int) -> dict:
    """Materialise a dense (layers, B, T*block_size, KVH, hd) decode cache
    from the pool by per-slot block table (B, T) — the paged engine's view
    for the UNCHANGED fixed-shape decode step.  Rows mapped to the null
    block read zeros; validity masking keeps them unattended."""
    tables = jnp.asarray(tables, jnp.int32)

    def one(buf):
        ll, _, bs, kvh, hd = buf.shape
        b, t = tables.shape
        g = buf[:, tables]                     # (L, B, T, bs, KVH, hd)
        return g.reshape(ll, b, t * bs, kvh, hd)

    return {name: one(buf) for name, buf in pool.items()}


def kv_pool_scatter_token(pool: dict, cache: dict, tables, pos, active,
                          block_size: int) -> dict:
    """Write back the ONE token each active slot appended this decode tick.

    ``cache`` is the gathered dense cache AFTER the decode step (the new
    token sits at per-slot ``pos``); the token is extracted per slot and
    scattered to pool block ``tables[slot, pos // block_size]`` at offset
    ``pos % block_size``.  Inactive slots scatter to the out-of-bounds
    physical index and are dropped."""
    tables = jnp.asarray(tables, jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)
    active = jnp.asarray(active, bool)
    b = tables.shape[0]
    rows = jnp.arange(b, dtype=jnp.int32)

    def one(buf, dense):
        n_total = buf.shape[1]                 # n_blocks + 1
        tok = dense[:, rows, pos]              # (L, B, KVH, hd)
        blk = tables[rows, pos // block_size]  # (B,) physical ids
        blk = jnp.where(active, blk, jnp.int32(n_total))  # OOB → dropped
        return buf.at[:, blk, pos % block_size].set(
            tok.astype(buf.dtype), mode="drop")

    return {name: one(buf, cache[name]) for name, buf in pool.items()}


def kv_pool_insert(pool: dict, prefilled: dict, block_ids,
                   block_size: int) -> dict:
    """Insert one prefilled request's cache (leading batch dim 1, capacity
    ``cap``) into the pool blocks ``block_ids`` (static-length int32 array,
    ceil(cap / block_size) entries; pad unused entries with the OOB index
    so they drop)."""
    block_ids = jnp.asarray(block_ids, jnp.int32)

    def one(buf, src):
        ll, _, bs, kvh, hd = buf.shape
        src = src[:, 0]                        # (L, cap, KVH, hd)
        cap = src.shape[1]
        pad = (-cap) % bs
        if pad:
            src = jnp.pad(src, ((0, 0), (0, pad), (0, 0), (0, 0)))
        chunks = src.reshape(ll, -1, bs, kvh, hd)   # (L, nblk, bs, KVH, hd)
        return buf.at[:, block_ids].set(chunks.astype(buf.dtype),
                                        mode="drop")

    return {name: one(buf, prefilled[name]) for name, buf in pool.items()}


def kv_pool_scatter_chunk(pool: dict, cache: dict, table_row, offset,
                          chunk: int, block_size: int) -> dict:
    """Scatter one prefill chunk (written into a dense batch-1 ``cache`` at
    traced ``offset``) into the pool.  ``offset`` and ``chunk`` are multiples
    of ``block_size`` (ServeConfig validation), so the chunk covers whole
    blocks: ids come from ``table_row[offset//bs : offset//bs + chunk//bs]``
    via a traced dynamic slice."""
    table_row = jnp.asarray(table_row, jnp.int32)
    offset = jnp.asarray(offset, jnp.int32)
    nblk = chunk // block_size

    def one(buf, dense):
        ll, _, bs, kvh, hd = buf.shape
        piece = jax.lax.dynamic_slice(
            dense, (0, 0, offset, 0, 0),
            (ll, 1, chunk, kvh, hd))[:, 0]          # (L, chunk, KVH, hd)
        chunks = piece.reshape(ll, nblk, bs, kvh, hd)
        ids = jax.lax.dynamic_slice(table_row, (offset // bs,), (nblk,))
        return buf.at[:, ids].set(chunks.astype(buf.dtype), mode="drop")

    return {name: one(buf, cache[name]) for name, buf in pool.items()}


class BlockAllocator:
    """Host-side free-list over the pool's usable physical blocks
    (ids 1..n_blocks; 0 is the null block).  All-or-nothing ``alloc``;
    double-free raises — table bugs corrupt *other tenants'* caches, so
    they must fail loudly."""

    def __init__(self, n_blocks: int):
        if n_blocks < 1:
            raise ValueError(f"need n_blocks >= 1, got {n_blocks}")
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks, 0, -1))   # pop() yields 1, 2, ...
        self._held: set[int] = set()

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def alloc(self, k: int) -> list[int] | None:
        """Claim ``k`` blocks, or None (and no change) if fewer are free."""
        if k < 0:
            raise ValueError(f"need k >= 0, got {k}")
        if k > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(k)]
        self._held.update(ids)
        return ids

    def free(self, ids) -> None:
        for i in ids:
            if i not in self._held:
                raise ValueError(f"double free / foreign block id {i}")
            self._held.discard(i)
            self._free.append(int(i))


def kv_cache_constrain(dp, cache, *, tag: str = "kvcache",
                       qos: str = "kvcache", tenant: str | None = None):
    """Issue the KV cache's sharding edges through the dataplane.

    Applies to {"k","v"}-style caches of rank-5 leaves (other recurrent
    cache layouts pass through untouched).  A no-op without a dataplane."""
    if dp is None or not isinstance(cache, dict):
        return cache
    return {k: (dp.constrain(v, KV_CACHE_AXES, tag=f"{tag}/{k}", qos=qos,
                             tenant=tenant)
                if hasattr(v, "ndim") and v.ndim == 5 else v)
            for k, v in cache.items()}


__all__ = ["kv_cache_init", "kv_update", "kv_update_slots", "kv_slot_insert",
           "state_slot_insert",
           "slot_vectors_init", "slot_validity", "cache_positions",
           "cache_validity", "kv_cache_constrain", "KV_CACHE_AXES",
           "kv_pool_init", "kv_pool_gather", "kv_pool_scatter_token",
           "kv_pool_insert", "kv_pool_scatter_chunk", "BlockAllocator"]
