"""KV / recurrent-state caches for serving.

Caches are pytrees with a leading layer axis so the decode step can
``lax.scan`` over layers, slicing one layer's cache in and the updated
slice out.  Sharding is issued through the dataplane by the serve step
(kv_seq → data/model axes depending on the shape cell, see
parallel/sharding.py): :func:`kv_cache_constrain` routes the cache's
sharding edges through the mediation pipeline like any other dataplane
traffic, so cache placement is visible to (and accountable by) the same
policies that see the collectives.

Slot-aware helpers (persistent-slot continuous batching, serve/engine.py):
the engine preallocates ONE ``(layers, max_batch, max_cache_len, ...)``
cache whose batch rows are long-lived *slots*.  A request is prefilled
alone (batch 1, prompt-length-bucketed), its cache written into a free
slot with :func:`kv_slot_insert`, and the fixed-shape decode step advances
every slot at its own position (:func:`kv_update_slots`) behind a per-slot
validity mask (:func:`slot_validity`).  Entries beyond a slot's position
are never attended, so stale bytes from a previous resident (or prefill
padding) are harmless — each position is rewritten by the current resident
before it first becomes valid.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# logical axis names of a (layers, batch, kv_seq, kv_heads, head_dim) cache
KV_CACHE_AXES = (None, "batch", "kv_seq", "kv_heads", "head_dim")


def kv_cache_init(layers: int, batch: int, max_len: int, kv_heads: int,
                  head_dim: int, dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((layers, batch, max_len, kv_heads, head_dim), dtype),
        "v": jnp.zeros((layers, batch, max_len, kv_heads, head_dim), dtype),
    }


def kv_update(cache_k: jax.Array, cache_v: jax.Array, k: jax.Array,
              v: jax.Array, pos) -> tuple[jax.Array, jax.Array]:
    """Insert (B, s, KVH, hd) new keys/values at position ``pos`` into a
    single layer's (B, S_max, KVH, hd) cache."""
    pos = jnp.asarray(pos, jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                      (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                      (0, pos, 0, 0))
    return ck, cv


def kv_update_slots(cache_k: jax.Array, cache_v: jax.Array, k: jax.Array,
                    v: jax.Array, pos) -> tuple[jax.Array, jax.Array]:
    """Per-slot cache write: insert (B, s, KVH, hd) new keys/values into a
    (B, S_max, KVH, hd) cache at *per-slot* positions ``pos`` (B,) — the
    continuous-batching analogue of :func:`kv_update`, where every batch
    row is a slot advancing independently."""
    pos = jnp.asarray(pos, jnp.int32)

    def one(ck, cv, kk, vv, p):
        return (jax.lax.dynamic_update_slice(ck, kk.astype(ck.dtype),
                                             (p, 0, 0)),
                jax.lax.dynamic_update_slice(cv, vv.astype(cv.dtype),
                                             (p, 0, 0)))

    return jax.vmap(one)(cache_k, cache_v, k, v, pos)


def kv_slot_insert(cache: dict, prefilled: dict, slot) -> dict:
    """Write one prefilled request's cache (leading batch dim 1) into slot
    ``slot`` of a persistent slot cache.

    ``slot`` may be a traced scalar, so one jitted insert serves every
    slot.  Positions beyond the prefill capacity keep whatever the slot
    held before; the per-slot validity mask makes them unreachable until
    the new resident overwrites them token by token."""
    slot = jnp.asarray(slot, jnp.int32)
    zero = jnp.zeros((), jnp.int32)

    def ins(dst, src):
        if not (hasattr(dst, "ndim") and dst.ndim == 5):
            return dst
        start = (zero, slot, zero, zero, zero)
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)

    return {name: ins(dst, prefilled[name]) for name, dst in cache.items()}


def slot_vectors_init(slots: int) -> dict:
    """Per-slot bookkeeping vectors: next write position, active flag and
    tenant index (−1 = free) — the host-mirrored slot state of the
    continuous-batching engine.  Host-side numpy by design: the engine
    mutates them in place between decode steps and feeds the position
    vector to the fixed-shape decode step each tick."""
    import numpy as np
    return {
        "pos": np.zeros((slots,), np.int32),
        "active": np.zeros((slots,), bool),
        "tenant": np.full((slots,), -1, np.int32),
    }


def slot_validity(max_len: int, pos) -> jax.Array:
    """(B, max_len) mask of cache entries visible to each slot decoding at
    per-slot position ``pos`` (inclusive: the entry written at ``pos``
    this step is attended)."""
    return (jnp.arange(max_len, dtype=jnp.int32)[None, :]
            <= jnp.asarray(pos, jnp.int32)[:, None])


def cache_positions(max_len: int) -> jax.Array:
    return jnp.arange(max_len, dtype=jnp.int32)


def cache_validity(max_len: int, filled_len) -> jax.Array:
    """Boolean (max_len,) mask of filled cache slots."""
    return jnp.arange(max_len, dtype=jnp.int32) < filled_len


def kv_cache_constrain(dp, cache, *, tag: str = "kvcache",
                       qos: str = "kvcache", tenant: str | None = None):
    """Issue the KV cache's sharding edges through the dataplane.

    Applies to {"k","v"}-style caches of rank-5 leaves (other recurrent
    cache layouts pass through untouched).  A no-op without a dataplane."""
    if dp is None or not isinstance(cache, dict):
        return cache
    return {k: (dp.constrain(v, KV_CACHE_AXES, tag=f"{tag}/{k}", qos=qos,
                             tenant=tenant)
                if hasattr(v, "ndim") and v.ndim == 5 else v)
            for k, v in cache.items()}


__all__ = ["kv_cache_init", "kv_update", "kv_update_slots", "kv_slot_insert",
           "slot_vectors_init", "slot_validity", "cache_positions",
           "cache_validity", "kv_cache_constrain", "KV_CACHE_AXES"]
