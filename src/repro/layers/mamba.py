"""Mamba (S6) selective-state-space block with a chunked parallel scan.

The recurrence  h_t = Ā_t ⊙ h_{t-1} + B̄_t x_t,  y_t = C_t·h_t + D x_t
is evaluated chunk-parallel: within a chunk by ``associative_scan`` (or the
Pallas ssm_scan kernel on TPU), across chunks by a short ``lax.scan`` that
carries the (B, d_inner, N) state.  Memory high-water is
(B, chunk, d_inner, N) — independent of sequence length.

Decode keeps (conv_tail, h) as recurrent cache: O(1) per token — this is
what makes hymba runnable at the 500k-token cell.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.layers.common import constrain, dense_init


def mamba_init(rng, d_model: int, cfg: SSMConfig) -> dict:
    di = cfg.expand * d_model
    dt_rank = cfg.dt_rank or max(1, math.ceil(d_model / 16))
    r = jax.random.split(rng, 6)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, cfg.state_size + 1, dtype=jnp.float32)[None, :],
                 (di, 1))
    dt = jnp.exp(jax.random.uniform(r[0], (di,)) *
                 (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(r[1], d_model, 2 * di),
        "conv": jax.random.normal(r[2], (cfg.conv_width, di), jnp.float32)
                 / math.sqrt(cfg.conv_width),
        "conv_bias": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(r[3], di, dt_rank + 2 * cfg.state_size),
        "dt_proj": dense_init(r[4], dt_rank, di, scale=dt_rank ** -0.5),
        "dt_bias": dt_bias,
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(r[5], di, d_model),
    }


def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array,
                 tail: jax.Array | None = None):
    """Depthwise causal conv. x: (B,S,di), w: (W,di). Returns (out, new_tail)
    where tail is the last (W-1) inputs for streaming decode."""
    wlen = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], wlen - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(wlen))
    new_tail = xp[:, -(wlen - 1):, :] if wlen > 1 else tail
    return out + bias.astype(x.dtype), new_tail


def ssm_scan_chunked(dt: jax.Array, x: jax.Array, a: jax.Array,
                     bc: jax.Array, cc: jax.Array, h0: jax.Array, *,
                     chunk: int = 128):
    """Evaluate the diagonal SSM recurrence, chunk-parallel.

    dt/x: (B,S,di); a: (di,N); bc/cc: (B,S,N); h0: (B,di,N).
    Returns y: (B,S,di), h_final: (B,di,N).

    The discretization exp(dt·A) is computed INSIDE the chunk step — the
    (B, S, di, N) dA tensor must never exist at full sequence length
    (at hymba prefill_32k it would be 13 TB; see EXPERIMENTS.md §Perf).
    Same signature as the Pallas kernel (repro.kernels.ssm_scan)."""
    b, s, di = dt.shape
    n = a.shape[1]
    ck = min(chunk, s)
    while s % ck:
        ck -= 1
    nc = s // ck

    resh3 = lambda t: t.reshape(b, nc, ck, -1).swapaxes(0, 1)

    def chunk_step(h, args):
        dt_c, x_c, b_c, c_c = args                    # (B,ck,·)
        da = jnp.exp(dt_c[..., None] * a)             # (B,ck,di,N)
        dbx = (dt_c * x_c)[..., None] * b_c[..., None, :]

        # intra-chunk associative scan of (a, b) pairs
        def comb(l, r):
            return l[0] * r[0], r[0] * l[1] + r[1]
        a_sc, b_sc = jax.lax.associative_scan(comb, (da, dbx), axis=1)
        # prepend carry: h_t = a_sc * h0 + b_sc
        h_all = a_sc * h[:, None] + b_sc                      # (B,ck,di,N)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, c_c)
        return h_all[:, -1], y

    h_final, ys = jax.lax.scan(
        chunk_step, h0, (resh3(dt), resh3(x), resh3(bc), resh3(cc)))
    return ys.swapaxes(0, 1).reshape(b, s, di), h_final


def mamba(params: dict, x: jax.Array, cfg: SSMConfig, *,
          state: dict | None = None, dp=None, chunk: int = 128):
    """Mamba block. x: (B,S,D). ``state`` (decode): {"conv": tail, "h": h}.

    Returns (out, new_state)."""
    b, s, d = x.shape
    di = cfg.expand * d
    n = cfg.state_size

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = constrain(dp, xi, ("batch", "seq", "mlp"), tag="mamba/inner")

    tail = state["conv"].astype(xi.dtype) if state is not None else None
    xi, new_tail = _causal_conv(xi, params["conv"], params["conv_bias"], tail)
    xi = jax.nn.silu(xi)

    proj = jnp.einsum("bse,ef->bsf", xi, params["x_proj"].astype(x.dtype))
    dt_rank = params["dt_proj"].shape[0]
    dt_low, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_low, params["dt_proj"].astype(x.dtype))
        .astype(jnp.float32) + params["dt_bias"])              # (B,S,di)
    A = -jnp.exp(params["A_log"])                              # (di,N)

    h0 = (state["h"] if state is not None
          else jnp.zeros((b, di, n), jnp.float32))
    y, h_final = ssm_scan_chunked(dt, xi.astype(jnp.float32), A,
                                  Bc.astype(jnp.float32),
                                  Cc.astype(jnp.float32), h0, chunk=chunk)
    y = y.astype(x.dtype) + params["D"].astype(x.dtype) * xi
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))
    out = constrain(dp, out, ("batch", "seq", "embed"), tag="mamba/out")
    new_state = {"conv": new_tail.astype(jnp.float32), "h": h_final}
    return out, new_state


def mamba_state_init(batch: int, d_model: int, cfg: SSMConfig, dtype) -> dict:
    di = cfg.expand * d_model
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, di), dtype),
            "h": jnp.zeros((batch, di, cfg.state_size), jnp.float32)}


def mamba_state_slot_insert(state: dict, prefilled: dict, slot) -> dict:
    """Write one prefilled request's mamba decode state (batch row 0 of a
    batch-1 ``{"conv", "h"}`` dict from :func:`mamba_state_init` /
    :func:`mamba`) into slot ``slot`` of a persistent multi-slot state.

    Layer-local states carry batch on axis 0; once the model stacks a
    layer axis in front (models/hybrid.py) batch becomes axis 1 and the
    engine uses ``state_slot_insert`` directly on the whole cache.  Unlike
    a KV stripe there is no sequence tail to mask: ``conv`` and ``h`` are
    O(1) summaries, so the insert replaces the slot's state wholesale."""
    from repro.layers.kvcache import state_slot_insert
    return state_slot_insert(state, prefilled, slot, batch_axis=0)


__all__ = ["mamba_init", "mamba", "mamba_state_init",
           "mamba_state_slot_insert", "ssm_scan_chunked"]
