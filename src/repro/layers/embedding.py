"""Token embedding (vocab-sharded) and logits projection (tied or untied)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.layers.common import constrain, embed_init


def embedding_init(rng, vocab: int, dim: int, tied: bool = True) -> dict:
    r = jax.random.split(rng, 2)
    p = {"tok": embed_init(r[0], vocab, dim)}
    if not tied:
        p["head"] = embed_init(r[1], vocab, dim)
    return p


def embed(params: dict, tokens: jax.Array, dtype, *, scale: bool = True,
          dp=None) -> jax.Array:
    tab = constrain(dp, params["tok"], ("vocab", "embed"), tag="embed/table")
    x = tab.astype(dtype)[tokens]
    if scale:  # gemma-style sqrt(d) embedding scale
        x = x * jnp.asarray(math.sqrt(x.shape[-1]), dtype)
    return constrain(dp, x, ("batch", "seq", "embed"), tag="embed/out")


def logits(params: dict, x: jax.Array, dp=None,
           softcap_val: float = 0.0) -> jax.Array:
    tab = params.get("head", params["tok"])
    tab = constrain(dp, tab, ("vocab", "embed"), tag="logits/table")
    out = jnp.einsum("bsd,vd->bsv", x, tab.astype(x.dtype),
                     preferred_element_type=jnp.float32)
    if softcap_val > 0:
        out = softcap_val * jnp.tanh(out / softcap_val)
    return constrain(dp, out, ("batch", "seq", "vocab"), tag="logits/out")


__all__ = ["embedding_init", "embed", "logits"]
