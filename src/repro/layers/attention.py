"""Grouped-query attention with causal / sliding-window / bidirectional
masking, RoPE, qk-norm, logit softcap, and a memory-bounded blocked
("flash"-style) XLA path.

Layouts: activations (B, S, H, D); KV (B, S, KVH, D); GQA groups the H
query heads into KVH groups of size G = H // KVH.

The blocked path (``impl="flash"``) is an online-softmax scan over KV
chunks, with queries processed in blocks — this is what keeps the 32k
prefill and 4k train cells inside per-device HBM at 256-way SPMD.  The
TPU production path swaps in the Pallas kernel (repro.kernels.flash_attention);
both are validated against ``impl="naive"``.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.layers.common import constrain, dense_init, rmsnorm, softcap as _softcap

NEG_INF = -2.0**30   # large-negative for masking (safe in bf16 after cast)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attention_init(rng, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, qk_norm: bool = False) -> dict:
    r = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(r[0], d_model, num_heads, head_dim),
        "wk": dense_init(r[1], d_model, num_kv_heads, head_dim),
        "wv": dense_init(r[2], d_model, num_kv_heads, head_dim),
        "wo": dense_init(r[3], num_heads * head_dim, d_model,
                         scale=1.0 / math.sqrt(num_heads * head_dim)),
    }
    if qk_norm:
        p["q_norm"] = {"scale": jnp.zeros((head_dim,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.zeros((head_dim,), jnp.float32)}
    return p


def qkv_project(params: dict, x: jax.Array, *, num_kv_heads: int,
                positions: jax.Array, theta, qk_norm: bool,
                eps: float, dp=None, kv_input: jax.Array | None = None):
    """Project to q, k, v (with RoPE + optional qk-norm applied).

    ``kv_input`` (cross-attention) routes k/v projections off a different
    sequence (encoder output); positions then only rotate q."""
    xkv = x if kv_input is None else kv_input
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", xkv, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", xkv, params["wv"].astype(x.dtype))
    if qk_norm:
        q = rmsnorm(params["q_norm"], q, eps)
        k = rmsnorm(params["k_norm"], k, eps)
    if theta is not None:
        from repro.layers.rope import apply_rope
        q = apply_rope(q, positions, theta)
        if kv_input is None:
            k = apply_rope(k, positions, theta)
    q = constrain(dp, q, ("batch", "seq", "heads", "head_dim"), tag="attn/q")
    k = constrain(dp, k, ("batch", "seq", "kv_heads", "head_dim"), tag="attn/k")
    v = constrain(dp, v, ("batch", "seq", "kv_heads", "head_dim"), tag="attn/v")
    return q, k, v


def output_project(params: dict, o: jax.Array, dp=None) -> jax.Array:
    b, s, h, d = o.shape
    out = jnp.einsum("bsf,fd->bsd", o.reshape(b, s, h * d),
                     params["wo"].astype(o.dtype))
    return constrain(dp, out, ("batch", "seq", "embed"), tag="attn/out")


# ---------------------------------------------------------------------------
# masking
# ---------------------------------------------------------------------------

def make_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
              window, k_valid: jax.Array | None = None) -> jax.Array:
    """Boolean mask (Sq, Sk) from 1-D position vectors. ``window`` may be a
    traced scalar; 0 means "no window" (global layers).

    Positions are deliberately batch-free: a batched mask here gets hoisted
    out of the flash scans by XLA as a (nq, nk, B, qb, kb) monster buffer
    (measured: ~10 GiB/device at 4k×256 — see EXPERIMENTS.md §Perf)."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    mask = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        w = jnp.asarray(window)
        mask &= jnp.where(w > 0, qp - kp < w, True)
    if k_valid is not None:
        mask &= k_valid[..., None, :]
    return mask


# ---------------------------------------------------------------------------
# reference (naive) attention — the oracle
# ---------------------------------------------------------------------------

def attend_naive(q, k, v, mask, *, logit_cap: float = 0.0,
                 scale: float | None = None) -> jax.Array:
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, kvh, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, logit_cap)
    if mask.ndim == 2:        # (Sq, Sk) from 1-D positions
        mask = mask[None, None, None]
    elif mask.ndim == 3:      # (B, Sq, Sk)
        mask = mask[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, d)


# ---------------------------------------------------------------------------
# blocked flash-style attention (pure XLA, memory-bounded)
#
# Forward: online-softmax scan over KV blocks (queries in blocks).
# Backward: custom VJP recomputing per-block probabilities from the saved
# (q, k, v, o, lse) — the real flash-attention algorithm, so the residual
# footprint is O(B·S·H·d) instead of O(B·H·S·S/blocks) saved probabilities.
# ---------------------------------------------------------------------------

def _float0_like(x):
    import numpy as _np
    return _np.zeros(x.shape, jax.dtypes.float0)


def attend_flash(q, k, v, *, q_pos, k_pos, causal: bool, window,
                 logit_cap: float = 0.0, k_valid=None,
                 q_block: int = 512, kv_block: int = 1024,
                 scale: float | None = None) -> jax.Array:
    """Flash attention with memory-bounded forward AND backward."""
    if window is None:
        window = jnp.zeros((), jnp.int32)        # 0 = no window
    if k_valid is None:
        k_valid = jnp.ones(k.shape[1], bool)
    q_pos = q_pos[0] if q_pos.ndim == 2 else jnp.broadcast_to(q_pos, (q.shape[1],))
    k_pos = jnp.broadcast_to(k_pos, (k.shape[1],))
    k_valid = jnp.broadcast_to(k_valid, (k.shape[1],))
    return _flash(q, k, v, q_pos, k_pos, jnp.asarray(window), k_valid,
                  causal, float(logit_cap), int(q_block), int(kv_block),
                  scale or 1.0 / math.sqrt(q.shape[-1]))


@partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11))
def _flash(q, k, v, q_pos, k_pos, window, k_valid, causal, logit_cap,
           q_block, kv_block, scale):
    o, _ = _flash_fwd_impl(q, k, v, q_pos, k_pos, window, k_valid, causal,
                           logit_cap, q_block, kv_block, scale)
    return o


def _blocking(sq, skv, q_block, kv_block):
    qb = min(q_block, sq)
    while sq % qb:
        qb -= 1
    kb = min(kv_block, skv)
    while skv % kb:
        kb -= 1
    return qb, kb


def _block_logits(qi, kj, qpos_i, kpos_j, kval_j, *, causal, window,
                  logit_cap, scale):
    """Masked, (soft-capped) scaled logits for one (q, kv) block pair.
    qi: (b,qb,kvh,g,d); kj: (b,kb,kvh,d) → (b,kvh,g,qb,kb)."""
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj,
                        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, logit_cap)
    mask = make_mask(qpos_i, kpos_j, causal=causal, window=window,
                     k_valid=kval_j)                          # (qb, kb)
    return jnp.where(mask[None, None, None], logits, NEG_INF), mask


def _flash_fwd_impl(q, k, v, q_pos, k_pos, window, k_valid, causal,
                    logit_cap, q_block, kv_block, scale):
    """Returns (o, lse). lse: (B,KVH,G,Sq) log-sum-exp of scaled logits."""
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qb, kb = _blocking(sq, skv, q_block, kv_block)
    nq, nk = sq // qb, skv // kb

    qg = q.reshape(b, nq, qb, kvh, g, d)
    q_pos_b = q_pos.reshape(nq, qb)
    kc = k.reshape(b, nk, kb, kvh, d)
    vc = v.reshape(b, nk, kb, kvh, d)
    k_pos_b = k_pos.reshape(nk, kb)
    kv_valid_b = k_valid.reshape(nk, kb)

    def q_step(_, q_args):
        qi, qpos_i = q_args                       # (b, qb, kvh, g, d), (qb,)

        def kv_step(carry, kv_args):
            m, l, acc = carry
            kj, vj, kpos_j, kval_j = kv_args
            logits, _ = _block_logits(qi, kj, qpos_i, kpos_j, kval_j,
                                      causal=causal, window=window,
                                      logit_cap=logit_cap, scale=scale)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vj.dtype), vj)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qb), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qb, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), k_pos_b, kv_valid_b))
        o = acc / jnp.maximum(l[..., None], 1e-30)            # (b,kvh,g,qb,d)
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, qb, h, d)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))              # (b,kvh,g,qb)
        return None, (o.astype(q.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_step, None,
                                   (qg.swapaxes(0, 1), q_pos_b))
    o = outs.swapaxes(0, 1).reshape(b, sq, h, d)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, kvh, g, sq)
    return o, lse


def _flash_fwd(q, k, v, q_pos, k_pos, window, k_valid, causal, logit_cap,
               q_block, kv_block, scale):
    o, lse = _flash_fwd_impl(q, k, v, q_pos, k_pos, window, k_valid, causal,
                             logit_cap, q_block, kv_block, scale)
    return o, (q, k, v, o, lse, q_pos, k_pos, window, k_valid)


def _flash_bwd(causal, logit_cap, q_block, kv_block, scale, res, do):
    q, k, v, o, lse, q_pos, k_pos, window, k_valid = res
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qb, kb = _blocking(sq, skv, q_block, kv_block)
    nq, nk = sq // qb, skv // kb

    # delta = rowsum(do * o)  (B,KVH,G,Sq)
    delta = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)
    delta = delta.reshape(b, sq, kvh, g).transpose(0, 2, 3, 1)

    qg = q.reshape(b, nq, qb, kvh, g, d).swapaxes(0, 1)
    dog = do.reshape(b, nq, qb, kvh, g, d).swapaxes(0, 1)
    lse_b = lse.reshape(b, kvh, g, nq, qb).transpose(3, 0, 1, 2, 4)
    delta_b = delta.reshape(b, kvh, g, nq, qb).transpose(3, 0, 1, 2, 4)
    qpos_b = q_pos.reshape(nq, qb)
    kc = k.reshape(b, nk, kb, kvh, d).swapaxes(0, 1)
    vc = v.reshape(b, nk, kb, kvh, d).swapaxes(0, 1)
    kpos_b = k_pos.reshape(nk, kb)
    kval_b = k_valid.reshape(nk, kb)

    def kv_step(carry, kv_args):
        dk, dv = carry
        kj, vj, kpos_j, kval_j = kv_args

        def q_step(carry2, q_args):
            dkj, dvj = carry2
            qi, doi, lse_i, delta_i, qpos_i = q_args
            logits, _ = _block_logits(qi, kj, qpos_i, kpos_j, kval_j,
                                      causal=causal, window=window,
                                      logit_cap=logit_cap, scale=scale)
            p = jnp.exp(logits - lse_i[..., None])            # (b,h,g,qb,kb)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doi.astype(jnp.float32),
                            vj.astype(jnp.float32))
            ds = p * (dp - delta_i[..., None])
            if logit_cap > 0:   # softcap derivative: 1 - tanh(raw/cap)^2
                ds = ds * (1.0 - jnp.square(jnp.tanh(
                    jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj,
                               preferred_element_type=jnp.float32)
                    * scale / logit_cap)))
            dq_i = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kj.astype(jnp.float32)) * scale
            dkj = dkj + jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                                   qi.astype(jnp.float32)) * scale
            dvj = dvj + jnp.einsum("bhgqk,bqhgd->bkhd", p,
                                   doi.astype(jnp.float32))
            return (dkj, dvj), dq_i

        dk0 = jnp.zeros((b, kb, kvh, d), jnp.float32)
        dv0 = jnp.zeros((b, kb, kvh, d), jnp.float32)
        (dkj, dvj), dq_blocks = jax.lax.scan(
            q_step, (dk0, dv0), (qg, dog, lse_b, delta_b, qpos_b))
        return (dk, dv), (dkj, dvj, dq_blocks)

    # iterate kv blocks in the outer scan, accumulating dq across them
    def kv_step2(dq_acc, kv_args):
        (_, _), (dkj, dvj, dq_blocks) = kv_step((None, None), kv_args)
        return dq_acc + dq_blocks, (dkj, dvj)

    dq0 = jnp.zeros((nq, b, qb, kvh, g, d), jnp.float32)
    dq_acc, (dk_blocks, dv_blocks) = jax.lax.scan(
        kv_step2, dq0, (kc, vc, kpos_b, kval_b))

    dq = dq_acc.swapaxes(0, 1).reshape(b, sq, h, d).astype(q.dtype)
    dk = dk_blocks.swapaxes(0, 1).reshape(b, skv, kvh, d).astype(k.dtype)
    dv = dv_blocks.swapaxes(0, 1).reshape(b, skv, kvh, d).astype(v.dtype)
    zero = _float0_like
    return (dq, dk, dv, zero(q_pos), zero(k_pos), zero(window),
            zero(k_valid))


_flash.defvjp(_flash_fwd, _flash_bwd)


def attend(q, k, v, *, q_pos, k_pos, causal: bool = True, window=None,
           logit_cap: float = 0.0, k_valid=None, impl: str = "flash",
           q_block: int = 512, kv_block: int = 1024) -> jax.Array:
    if impl == "naive":
        qp = q_pos[0] if q_pos.ndim == 2 else q_pos
        mask = make_mask(qp, k_pos, causal=causal, window=window,
                         k_valid=k_valid)
        return attend_naive(q, k, v, mask, logit_cap=logit_cap)
    if impl == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                               causal=causal, window=window,
                               logit_cap=logit_cap)
    return attend_flash(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                        window=window, logit_cap=logit_cap, k_valid=k_valid,
                        q_block=q_block, kv_block=kv_block)


__all__ = [
    "attention_init", "qkv_project", "output_project", "make_mask",
    "attend", "attend_naive", "attend_flash", "NEG_INF",
]
