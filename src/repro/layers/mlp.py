"""Feed-forward blocks: gated (SwiGLU/GeGLU) and classic 2-matrix MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.common import act_fn, constrain, dense_init


def mlp_init(rng, d_model: int, d_ff: int, gated: bool = True) -> dict:
    r = jax.random.split(rng, 3)
    p = {"wi": dense_init(r[0], d_model, d_ff),
         "wo": dense_init(r[1], d_ff, d_model)}
    if gated:
        p["wg"] = dense_init(r[2], d_model, d_ff)
    return p


def mlp(params: dict, x: jax.Array, *, act: str = "silu", dp=None,
        tag: str = "mlp") -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(x.dtype))
    if "wg" in params:
        g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(x.dtype))
        h = act_fn(act)(g) * h
    else:
        h = act_fn(act)(h)
    h = constrain(dp, h, ("batch", "seq", "mlp"), tag=f"{tag}/hidden")
    out = jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(x.dtype))
    return constrain(dp, out, ("batch", "seq", "embed"), tag=f"{tag}/out")


__all__ = ["mlp_init", "mlp"]
