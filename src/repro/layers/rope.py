"""Rotary position embeddings, with per-layer theta (gemma3 uses a larger
base on global layers than on sliding-window layers)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: jax.Array | float) -> jax.Array:
    """Inverse frequencies (head_dim//2,). ``theta`` may be a traced scalar
    (per-layer value inside a scan)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: jax.Array | float = 10_000.0) -> jax.Array:
    """Rotate ``x`` of shape (..., seq, heads, head_dim) by ``positions``
    of shape (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., :, None, :]                          # (..., S, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(max_len: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (max_len, dim)."""
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


__all__ = ["rope_freqs", "apply_rope", "sinusoidal_positions"]
