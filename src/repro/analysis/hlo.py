"""Loop-aware analysis of post-SPMD compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — for a
scan-over-layers transformer that undercounts FLOPs by ~the layer count
(verified in EXPERIMENTS.md §Dry-run calibration).  This module re-derives
the roofline raw terms by walking the HLO call graph with multipliers:

  * ``while`` bodies × their ``known_trip_count`` (XLA annotates scans),
  * ``fusion`` / ``call`` / ``conditional`` computations × 1,

counting per computation:
  * FLOPs: ``dot`` (2·result·contracted) and ``convolution``; elementwise
    ops at 1 flop/element for fusion roots (dominated by dots anyway),
  * bytes: operands + result of materialized ops (fusion boundaries, dots,
    copies, DUS/DS, converts at top level) — fusion-internal virtual
    intermediates are not counted, matching buffer-assignment semantics,
  * collective bytes: operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (× loop multiplier).

All numbers are PER DEVICE (the compiled module is the per-partition
program).
"""

from __future__ import annotations

import gzip
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DT_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
             "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
             "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
             "c128": 16, "token": 0, "u4": 1, "s4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result type is either a tuple "(...)" (may contain /*index=N*/ comments,
# never nested parens) or a single "dtype[shape]{layout}"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]"
    r"(?:\{[^}]*\})?))\s*([\w\-]+)\((.*)$")
# header: "%name (params...) -> result {"; params may contain nested
# parens (tuple types), so only anchor on the name + "(" prefix.
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
# called computations always print with a % prefix; requiring it keeps the
# match from swallowing the following ", body=..." attribute.
_CALLED_RE = re.compile(r"(?:body|condition|calls|to_apply|branch_computations)="
                        r"\{?%([\w.\-]+(?:,\s*%[\w.\-]+)*)\}?")

_COLLECTIVES = {"all-gather": "all_gather", "all-reduce": "all_reduce",
                "reduce-scatter": "reduce_scatter", "all-to-all": "all_to_all",
                "collective-permute": "collective_permute"}

# ops whose results/operands are materialized buffers at top level
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "iota", "partition-id", "replica-id"}


def _shape_elems_bytes(txt: str):
    elems = bytes_ = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DT_BYTES[dt]
    return elems, bytes_


@dataclass
class Instr:
    name: str
    result_txt: str
    opcode: str
    rest: str
    operands: list[str] = field(default_factory=list)


def parse_module(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    for line in text.splitlines():
        if line.startswith(("%", "ENTRY")) and line.rstrip().endswith("{") \
                and "->" in line:
            m = _COMP_RE.match(line)
            if m:
                comps[m.group(1)] = cur = []
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_txt, opcode, rest = m.groups()
        ops = re.findall(r"%([\w.\-]+)", rest.split(")", 1)[0])
        cur.append(Instr(name, result_txt, opcode.replace("-start", ""),
                         rest, ops))
    return comps


def _multipliers(comps: dict[str, list[Instr]], entry: str) -> dict[str, float]:
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # iterate until fixpoint (call graph is a DAG; simple BFS suffices)
    work = [entry]
    while work:
        cname = work.pop()
        m = mult[cname]
        for ins in comps.get(cname, []):
            called = _CALLED_RE.findall(ins.rest)
            if not called:
                continue
            trip = 1.0
            if ins.opcode == "while":
                tm = _TRIP_RE.search(ins.rest)
                trip = float(tm.group(1)) if tm else 1.0
            for group in called:
                for sub in re.split(r",\s*%", group):
                    sub = sub.strip()
                    if sub in comps:
                        mult[sub] += m * trip
                        work.append(sub)
    return dict(mult)


def _symbols(instrs: list[Instr]) -> dict[str, str]:
    return {i.name: i.result_txt for i in instrs}


def _dot_flops(ins: Instr, syms: dict[str, str]) -> float:
    res_elems, _ = _shape_elems_bytes(ins.result_txt)
    lhs_shape = syms.get(ins.operands[0], "") if ins.operands else ""
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    contract = 1
    if m and lhs_shape:
        dims_m = _SHAPE_RE.search(lhs_shape)
        if dims_m:
            dims = [int(d) for d in dims_m.group(2).split(",") if d]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    return 2.0 * res_elems * contract


def analyze(text: str, entry: str | None = None) -> dict:
    comps = parse_module(text)
    if not comps:
        return {"flops": 0, "bytes": 0, "collectives": {}}
    if entry is None:
        # ENTRY computation: the one containing the module's root — take the
        # last parsed ENTRY line match; fall back to the largest computation.
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        entry = m.group(1) if m else max(comps, key=lambda c: len(comps[c]))
    mult = _multipliers(comps, entry)

    # fusion-internal computations: bytes not counted (virtual), dots counted
    fused: set[str] = set()
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.opcode == "fusion":
                for g in _CALLED_RE.findall(ins.rest):
                    for sub in re.split(r",\s*%", g):
                        fused.add(sub.strip())

    flops = 0.0
    bytes_ = 0.0
    transcendentals = 0.0
    coll: dict[str, dict[str, float]] = {}
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        syms = _symbols(instrs)
        for ins in instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, syms)
            elif ins.opcode in ("convolution",):
                # approximate: 2 * result * (kernel elems / output channels)
                res_elems, _ = _shape_elems_bytes(ins.result_txt)
                k_elems, _ = _shape_elems_bytes(syms.get(
                    ins.operands[1], "")) if len(ins.operands) > 1 else (1, 0)
                flops += m * 2.0 * res_elems * max(k_elems, 1) ** 0.5
            elif ins.opcode in ("exponential", "tanh", "log", "rsqrt",
                                "power", "sine", "cosine"):
                res_elems, _ = _shape_elems_bytes(ins.result_txt)
                transcendentals += m * res_elems

            base = _COLLECTIVES.get(ins.opcode.replace("-done", ""))
            if base and not ins.opcode.endswith("-done"):
                _, ob = _shape_elems_bytes(
                    " ".join(syms.get(o, "") for o in ins.operands))
                if ob == 0:
                    _, ob = _shape_elems_bytes(ins.result_txt)
                    if base == "all_gather":
                        ob = 0  # result counts gathered size; skip if unknown
                d = coll.setdefault(base, {"ops": 0.0, "bytes": 0.0})
                d["ops"] += m
                d["bytes"] += m * ob

            if cname not in fused and ins.opcode not in _FREE_OPS:
                _, rb = _shape_elems_bytes(ins.result_txt)
                _, ob = _shape_elems_bytes(
                    " ".join(syms.get(o, "") for o in ins.operands))
                bytes_ += m * (rb + ob)

    return {
        "flops": flops,
        "bytes": bytes_,
        "transcendentals": transcendentals,
        "collectives": coll,
        "collective_bytes": sum(v["bytes"] for v in coll.values()),
        "n_computations": len(comps),
    }


def analyze_file(path: str) -> dict:
    op = gzip.open if path.endswith(".gz") else open
    with op(path, "rt") as f:
        return analyze(f.read())


__all__ = ["analyze", "analyze_file", "parse_module"]
