from repro.serve.engine import Engine, Request, sample

__all__ = ["Engine", "Request", "sample"]
