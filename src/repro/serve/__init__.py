from repro.serve.engine import (
    Engine,
    Request,
    ServeError,
    WFQScheduler,
    prompt_bucket,
    sample,
)

__all__ = ["Engine", "Request", "ServeError", "WFQScheduler",
           "prompt_bucket", "sample"]
