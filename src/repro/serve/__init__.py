from repro.serve.engine import Engine, Request, WFQScheduler, prompt_bucket, sample

__all__ = ["Engine", "Request", "WFQScheduler", "prompt_bucket", "sample"]
