"""Serving engine: batched prefill + decode with slot-based continuous
batching (lite) and per-tenant admission control.

Requests enter a queue; the engine packs up to ``max_batch`` active slots,
prefills new prompts (padded to the slot prompt capacity), then steps all
active slots together with one jitted decode step per token.  Finished
slots (EOS or max_new_tokens) are refilled from the queue — the standard
continuous-batching shape, kept single-process.

All model communication flows through the dataplane; the decode step's KV
cache sharding comes from parallel/sharding.py decode rules, issued
through the mediation pipeline (``kv_cache_constrain``).

Multi-tenancy: each :class:`Request` names a tenant.  When the dataplane
carries a :class:`~repro.core.policies.QoSPolicy` with per-tenant rates,
the engine runs the *host-side mirror* of the pipeline's token bucket
(:class:`~repro.core.mediation.HostTokenBucket`) as admission control —
requests from tenants over their rate are deferred to later batching
rounds instead of being packed, throttling each tenant's serve rate with
the same bucket semantics the traced dataplane applies per op.  Per-tenant
served-token accounting lands in :meth:`Engine.tenant_report`.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core.mediation import HostTokenBucket
from repro.core.policies import QoSPolicy
from repro.layers.kvcache import kv_cache_constrain

# Bound on consecutive all-throttled refill rounds before the engine
# force-admits the queue head (guarantees progress under any rate config).
_MAX_STARVED_ROUNDS = 10_000


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 16
    tenant: str = "default"
    out_tokens: list = field(default_factory=list)
    done: bool = False


def sample(logits: jax.Array, rng, temperature: float):
    if temperature <= 0:
        return logits.argmax(-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature).astype(jnp.int32)


class Engine:
    def __init__(self, model, params, cfg: ModelConfig, serve: ServeConfig,
                 dp=None, eos_id: int = 1):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.scfg = serve
        self.dp = dp
        self.eos_id = eos_id
        # cache sharding edges are issued inside the traced prefill, so
        # policy enforcement/telemetry happen once per compiled shape (like
        # every other dataplane edge), not once per host batching round
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, kv_cache_constrain(dp, c),
                                          dp=dp))
        self._step = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos, dp=dp))
        qos = next((p for p in (dp.policies if dp is not None else [])
                    if isinstance(p, QoSPolicy)), None)
        self._buckets = HostTokenBucket.from_policy(qos)
        self.tenant_stats: dict[str, dict[str, float]] = defaultdict(
            lambda: {"requests": 0, "tokens": 0, "deferrals": 0})

    # ------------------------------------------------------------------
    # tenant admission (host-side token bucket, serve-level throttling)
    # ------------------------------------------------------------------
    def _admit_batch(self, queue: list[Request]) -> tuple[list[Request],
                                                          list[Request]]:
        """Pick up to ``max_batch`` requests the buckets admit; the rest
        stay queued.  Refills until at least one request is admissible
        (guaranteed progress); a request counts as deferred at most once
        per batching round, on the round's first refill."""
        B = self.scfg.max_batch
        for round_ in range(_MAX_STARVED_ROUNDS):
            for b in self._buckets.values():
                b.refill()
            admitted, deferred = [], []
            for r in queue:
                bucket = self._buckets.get(r.tenant)
                if len(admitted) < B and (bucket is None or bucket.take()):
                    admitted.append(r)
                else:
                    if bucket is not None and len(admitted) < B \
                            and round_ == 0:
                        self.tenant_stats[r.tenant]["deferrals"] += 1
                    deferred.append(r)
            if admitted:
                return admitted, deferred
        # pathological rates (≈0): force progress with the queue head
        return queue[:1], queue[1:]

    # ------------------------------------------------------------------
    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        cap = max(len(r.prompt) for r in reqs)
        cap = max(cap, 8)
        toks = np.zeros((len(reqs), cap), np.int32)
        for i, r in enumerate(reqs):
            toks[i, -len(r.prompt):] = r.prompt      # left-pad
        return toks

    def run(self, requests: list[Request], rng=None) -> list[Request]:
        """Serve all requests to completion; returns them with outputs."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        queue = list(requests)
        done: list[Request] = []

        while queue:
            batch_reqs, queue = self._admit_batch(queue)
            toks = self._pad_prompts(batch_reqs)
            b, prompt_len = toks.shape
            cache_len = prompt_len + self.scfg.max_new_tokens + 1
            cache = self.model.init_cache(b, cache_len)
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)}, cache)
            rng, k = jax.random.split(rng)
            tok = sample(logits[:, -1, :], k, self.scfg.temperature)[:, None]
            active = np.ones(b, bool)
            for j, (r, t) in enumerate(zip(batch_reqs, np.asarray(tok)[:, 0])):
                r.out_tokens.append(int(t))
                if t == self.eos_id:
                    active[j] = False

            for i in range(self.scfg.max_new_tokens - 1):
                if not active.any():
                    break
                pos = jnp.asarray(prompt_len + i, jnp.int32)
                logits, cache = self._step(self.params, tok, cache, pos)
                rng, k = jax.random.split(rng)
                tok = sample(logits[:, -1, :], k, self.scfg.temperature)[:, None]
                arr = np.asarray(tok)[:, 0]
                for j, r in enumerate(batch_reqs):
                    if active[j]:
                        r.out_tokens.append(int(arr[j]))
                        if arr[j] == self.eos_id:
                            active[j] = False
            for r in batch_reqs:
                r.done = True
                stats = self.tenant_stats[r.tenant]
                stats["requests"] += 1
                stats["tokens"] += len(r.out_tokens)
                done.append(r)
        return done

    def tenant_report(self) -> dict[str, dict[str, float]]:
        """Per-tenant serve accounting: requests, tokens, deferrals."""
        return {t: dict(v) for t, v in self.tenant_stats.items()}


__all__ = ["Engine", "Request", "sample"]
