"""Serving engine: batched prefill + decode with slot-based continuous
batching (lite).

Requests enter a queue; the engine packs up to ``max_batch`` active slots,
prefills new prompts (padded to the slot prompt capacity), then steps all
active slots together with one jitted decode step per token.  Finished
slots (EOS or max_new_tokens) are refilled from the queue — the standard
continuous-batching shape, kept single-process.

All model communication flows through the dataplane; the decode step's KV
cache sharding comes from parallel/sharding.py decode rules.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False


def sample(logits: jax.Array, rng, temperature: float):
    if temperature <= 0:
        return logits.argmax(-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature).astype(jnp.int32)


class Engine:
    def __init__(self, model, params, cfg: ModelConfig, serve: ServeConfig,
                 dp=None, eos_id: int = 1):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.scfg = serve
        self.dp = dp
        self.eos_id = eos_id
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, c, dp=dp))
        self._step = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos, dp=dp))

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        cap = max(len(r.prompt) for r in reqs)
        cap = max(cap, 8)
        toks = np.zeros((len(reqs), cap), np.int32)
        for i, r in enumerate(reqs):
            toks[i, -len(r.prompt):] = r.prompt      # left-pad
        return toks

    def run(self, requests: list[Request], rng=None) -> list[Request]:
        """Serve all requests to completion; returns them with outputs."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        queue = list(requests)
        done: list[Request] = []
        B = self.scfg.max_batch

        while queue:
            batch_reqs = queue[:B]
            queue = queue[B:]
            toks = self._pad_prompts(batch_reqs)
            b, prompt_len = toks.shape
            cache_len = prompt_len + self.scfg.max_new_tokens + 1
            cache = self.model.init_cache(b, cache_len)
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)}, cache)
            rng, k = jax.random.split(rng)
            tok = sample(logits[:, -1, :], k, self.scfg.temperature)[:, None]
            active = np.ones(b, bool)
            for r, t in zip(batch_reqs, np.asarray(tok)[:, 0]):
                r.out_tokens.append(int(t))

            for i in range(self.scfg.max_new_tokens - 1):
                pos = jnp.asarray(prompt_len + i, jnp.int32)
                logits, cache = self._step(self.params, tok, cache, pos)
                rng, k = jax.random.split(rng)
                tok = sample(logits[:, -1, :], k, self.scfg.temperature)[:, None]
                arr = np.asarray(tok)[:, 0]
                for j, r in enumerate(batch_reqs):
                    if active[j]:
                        r.out_tokens.append(int(arr[j]))
                        if arr[j] == self.eos_id:
                            active[j] = False
                if not active.any():
                    break
            for r in batch_reqs:
                r.done = True
                done.append(r)
        return done


__all__ = ["Engine", "Request", "sample"]
