"""Serving engine: persistent-slot continuous batching with a fixed-shape
decode step, WFQ slot packing, and per-tenant admission control.

**Slot lifecycle** (``scheduler="continuous"``, the default whenever the
model family has a slot-aware decode path):

1. The engine preallocates ONE ``(layers, max_batch, kv_cache_len, ...)``
   KV cache whose batch rows are long-lived *slots*, plus per-slot
   position / token vectors (layers/kvcache.py slot helpers).
2. A granted request is prefilled alone (batch 1), right-padded to a
   power-of-two *prompt bucket* — right padding sits causally after every
   real token, so bucketing never perturbs logits, and the prefill
   compile cache stays bounded at O(log max_prompt) entries.
3. ``kv_slot_insert`` writes the prefilled cache into the free slot; the
   slot joins the batch at its own position.
4. One jitted decode step advances ALL slots each tick.  Its shapes are
   functions of the slot geometry only — ``(max_batch, 1)`` tokens,
   ``(max_batch,)`` positions, the fixed cache — so it compiles **once
   per engine** regardless of the request mix (vs. one compile per
   distinct batch shape under gang scheduling).
5. A slot that finishes (EOS or token budget) is refilled from the queue
   *mid-decode* — no convoy effect: co-residents keep decoding while the
   freed slot takes new work.

**WFQ slot packing** is the QoS mechanism: a weighted-fair-queueing
scheduler (:class:`WFQScheduler`) keeps a virtual time per tenant, with
weights from :class:`~repro.core.policies.QoSPolicy` ``rates``.  Granting
a slot advances the tenant's virtual time by the request's decode-step
cost over its weight, and the tenant with the smallest virtual time wins
the next free slot — so decode-slot occupancy splits proportionally to
weights under saturation.  ``ServeConfig.max_slots_per_tenant`` adds a
hard per-tenant budget on concurrently held slots.  The host-side token
bucket (:class:`~repro.core.mediation.HostTokenBucket`) still gates
admission underneath WFQ, charging ``len(prompt)`` tokens per request
(the host analogue of the traced bucket's byte-proportional debits);
bucket-starved grants are counted as deferrals.  Occupancy, grants and
deferrals land in :meth:`Engine.tenant_report` and, in counter-block
layout, :meth:`Engine.runtime_counters`; attach a
:class:`~repro.core.obs.CounterTimeline` (``Engine(..., obs=...)``) to
stream that block — plus active-slot / queue-depth gauges — into a
per-tick timeline artifact and sparkline panels (docs/observability.md).

``scheduler="gang"`` keeps the legacy behaviour — admit up to
``max_batch`` requests, batch-prefill them left-padded, decode the gang
to completion with shape-derived (recompiling) prefill/decode steps —
as the benchmark baseline and the fallback for model families without
``decode_step_slots``.

At temperature 0 both schedulers produce identical output tokens when
gang batches carry uniform prompt lengths.  With mixed lengths the gang
path left-pads to the batch max and *attends the pads* (a legacy gang
property), perturbing its logits; the continuous path is padding-
invariant by construction (right-padded buckets sit causally after the
prompt; stale slot bytes are validity-masked).
"""

from __future__ import annotations

import time
from collections import Counter, defaultdict, deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core import telemetry as tl
from repro.core.mediation import HostTokenBucket
from repro.core.policies import QoSPolicy
from repro.layers.kvcache import (
    kv_cache_constrain,
    kv_slot_insert,
    slot_vectors_init,
)

# Bound on consecutive all-throttled refill rounds before the engine
# force-admits the queue head (guarantees progress under any rate config).
_MAX_STARVED_ROUNDS = 10_000
_MIN_PROMPT_BUCKET = 8


@dataclass(eq=False)                 # identity semantics: rid is
class Request:                       # caller-supplied and prompt is an
    rid: int                         # ndarray (elementwise ==)
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 16
    tenant: str = "default"
    out_tokens: list = field(default_factory=list)
    done: bool = False
    t_first: float | None = None     # perf_counter stamp of the first token


def sample(logits: jax.Array, rng, temperature: float):
    if temperature <= 0:
        return logits.argmax(-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature).astype(jnp.int32)


def prompt_bucket(n: int) -> int:
    """Power-of-two prompt capacity ≥ max(n, 8): bounds the number of
    distinct prefill shapes (and thus compiles) at O(log max_prompt)."""
    b = _MIN_PROMPT_BUCKET
    while b < n:
        b *= 2
    return b


class WFQScheduler:
    """Weighted fair queueing over decode slots.

    Each tenant carries a *virtual time*; granting a slot advances it by
    the request's expected decode-step cost divided by the tenant's
    weight.  The backlogged tenant with the smallest virtual time wins
    the next free slot, so long-run slot grants — and decode-slot
    occupancy — split proportionally to weights under saturation.

    A monotone *virtual clock* tracks the smallest virtual time among
    the tenants backlogged each scheduling round (``note_backlog``); a
    grant starts no earlier than the clock, so a tenant re-entering
    after idling resumes at the current service level instead of
    spending its idle time as hoarded credit.  Unknown tenants get
    ``default_weight``."""

    def __init__(self, weights: dict[str, float] | None = None,
                 default_weight: float = 1.0):
        self.weights = dict(weights or {})
        self.default_weight = float(default_weight)
        self.vtime: dict[str, float] = {}
        self.vclock = 0.0

    def weight(self, tenant: str) -> float:
        return max(float(self.weights.get(tenant, self.default_weight)),
                   1e-9)

    def order(self, tenants) -> list[str]:
        """Tenants in grant-preference order (smallest virtual time
        first; ties keep the caller's order)."""
        return sorted(tenants, key=lambda t: self.vtime.get(t, 0.0))

    def note_backlog(self, tenants) -> None:
        """Advance the virtual clock to the backlogged minimum (call once
        per scheduling round with every queued or slot-holding tenant)."""
        vs = [self.vtime.get(t, 0.0) for t in tenants]
        if vs:
            self.vclock = max(self.vclock, min(vs))

    def grant(self, tenant: str, cost: float) -> None:
        v = max(self.vtime.get(tenant, 0.0), self.vclock)
        self.vtime[tenant] = v + float(cost) / self.weight(tenant)


class Engine:
    def __init__(self, model, params, cfg: ModelConfig, serve: ServeConfig,
                 dp=None, eos_id: int = 1, obs=None, obs_every: int = 1):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.scfg = serve
        self.dp = dp
        self.eos_id = eos_id
        # optional CounterTimeline (core/obs.py): one snapshot of the
        # per-tenant counter block + run gauges every ``obs_every``-th
        # decode tick (ObsConfig.every), taken on the host between jitted
        # steps — never inside traced code
        self.obs = obs
        self.obs_every = max(int(obs_every), 1)
        self._obs_tick_no = 0
        # cache sharding edges are issued inside the traced prefill, so
        # policy enforcement/telemetry happen once per compiled shape (like
        # every other dataplane edge), not once per host batching round
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, kv_cache_constrain(dp, c),
                                          dp=dp))
        self._step = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos, dp=dp))
        step_slots = getattr(model, "decode_step_slots", None)
        self._slot_support = step_slots is not None
        if self._slot_support:
            # prefill-to-slot is ONE traced op: batch-1 bucketed prefill
            # whose cache lands directly in the target slot of the
            # persistent cache (one dispatch per admitted request, one
            # compile per prompt bucket)
            def _prefill_into_slot(p, t, pc, cache, slot, last):
                logits, pc = model.prefill(p, {"tokens": t},
                                           kv_cache_constrain(dp, pc),
                                           dp=dp, last_pos=last)
                return logits, kv_slot_insert(cache, pc, slot)

            # the persistent cache is donated: XLA updates it in place
            # instead of copying the full buffer per tick / per insert
            # (a no-op with a warning on backends without aliasing)
            self._prefill_slot = jax.jit(_prefill_into_slot,
                                         donate_argnums=(3,))
            self._step_slots = jax.jit(
                lambda p, t, c, pos: step_slots(p, t, c, pos, dp=dp),
                donate_argnums=(2,))
        qos = next((p for p in (dp.policies if dp is not None else [])
                    if isinstance(p, QoSPolicy)), None)
        self._buckets = HostTokenBucket.from_policy(
            qos, scale=serve.admission_token_scale)
        self._wfq = WFQScheduler(qos.rates if qos is not None else {})
        self.tenant_stats: dict[str, dict[str, float]] = defaultdict(
            lambda: {"requests": 0, "tokens": 0, "deferrals": 0,
                     "wfq_grants": 0, "occupancy_steps": 0})
        self._tenant_ids: dict[str, int] = {}
        self._decode_shapes: set[tuple] = set()

    def _tenant_id(self, tenant: str) -> int:
        """Stable small integer per tenant (for the slot tenant vector)."""
        return self._tenant_ids.setdefault(tenant, len(self._tenant_ids))

    # ------------------------------------------------------------------
    # tenant admission (host-side token bucket, serve-level throttling)
    # ------------------------------------------------------------------
    @staticmethod
    def _admission_cost(r: Request, bucket: HostTokenBucket | None) -> float:
        """Bucket debit for admitting ``r``: its prompt tokens, clamped to
        the bucket's burst so a prompt longer than the bucket can ever
        hold still drains a full bucket instead of being permanently
        inadmissible (the classic token-bucket cost clamp)."""
        cost = float(len(r.prompt))
        return min(cost, bucket.burst) if bucket is not None else cost

    def _admit_batch(self, queue: list[Request]) -> tuple[list[Request],
                                                          list[Request]]:
        """Gang admission: pick up to ``max_batch`` requests the buckets
        admit; the rest stay queued.  Refills until at least one request
        is admissible (guaranteed progress).  Bucket starvation is
        observed with ``can_take`` *before* the batch-fullness check, so
        a starved request behind a full batch is still counted as
        deferred (once per batching round, on the round's first refill);
        the bucket is only debited — by ``len(prompt)`` tokens — when the
        request is actually admitted."""
        B = self.scfg.max_batch
        for round_ in range(_MAX_STARVED_ROUNDS):
            for b in self._buckets.values():
                b.refill()
            admitted, deferred = [], []
            for r in queue:
                bucket = self._buckets.get(r.tenant)
                cost = self._admission_cost(r, bucket)
                if bucket is not None and not bucket.can_take(cost):
                    if round_ == 0:
                        self.tenant_stats[r.tenant]["deferrals"] += 1
                    deferred.append(r)
                elif len(admitted) < B:
                    if bucket is not None:
                        bucket.take(cost)
                    admitted.append(r)
                else:
                    deferred.append(r)
            if admitted:
                return admitted, deferred
        # pathological rates (≈0): force progress with the queue head
        return queue[:1], queue[1:]

    def _obs_snapshot(self, *, active: int, queued: int) -> None:
        """Feed the attached timeline one engine tick: the serve counter
        block (WFQ grants / tokens / occupancy / deferrals in telemetry
        column layout) plus slot-level run gauges."""
        if self.obs is None:
            return
        self._obs_tick_no += 1
        if self._obs_tick_no % self.obs_every:
            return
        ctrs, tenants = self.runtime_counters()
        self.obs.snapshot_block(self._obs_tick_no, ctrs, tenants,
                                gauges={"active_slots": active,
                                        "queued": queued})

    # ------------------------------------------------------------------
    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        cap = max(len(r.prompt) for r in reqs)
        cap = max(cap, 8)
        toks = np.zeros((len(reqs), cap), np.int32)
        for i, r in enumerate(reqs):
            toks[i, -len(r.prompt):] = r.prompt      # left-pad
        return toks

    def _finish(self, r: Request, done: list[Request]) -> None:
        r.done = True
        stats = self.tenant_stats[r.tenant]
        stats["requests"] += 1
        stats["tokens"] += len(r.out_tokens)
        done.append(r)

    def _emit(self, r: Request, token: int) -> None:
        if not r.out_tokens:
            r.t_first = time.perf_counter()
        r.out_tokens.append(token)

    # ------------------------------------------------------------------
    # public entry
    # ------------------------------------------------------------------
    def run(self, requests: list[Request], rng=None,
            scheduler: str | None = None) -> list[Request]:
        """Serve all requests to completion; returns them with outputs.

        ``scheduler`` overrides ``ServeConfig.scheduler`` for this run;
        "continuous" silently falls back to "gang" when the model family
        has no slot-aware decode path."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        sched = scheduler or self.scfg.scheduler
        if sched not in ("continuous", "gang"):
            raise ValueError(f"unknown scheduler {sched!r}; "
                             f"expected 'continuous' or 'gang'")
        if sched == "continuous" and self._slot_support:
            return self._run_continuous(list(requests), rng)
        return self._run_gang(list(requests), rng)

    # ------------------------------------------------------------------
    # continuous: persistent slots, fixed-shape decode, WFQ packing
    # ------------------------------------------------------------------
    def _bucket_cap(self, prompt_len: int) -> int:
        cap = prompt_bucket(prompt_len)
        need = cap + self.scfg.max_new_tokens + 1
        if need > self.scfg.kv_cache_len:
            raise ValueError(
                f"request needs {need} cache positions (prompt bucket {cap}"
                f" + max_new_tokens {self.scfg.max_new_tokens} + 1) but "
                f"kv_cache_len is {self.scfg.kv_cache_len}")
        return cap

    def _start_request(self, r: Request, slot: int, cache, slots, vecs, tok,
                       ntok, done, rng):
        """Prefill one request (bucketed, batch 1), insert its cache into
        ``slot``, and emit its first token.  Returns (cache, rng)."""
        cap = self._bucket_cap(len(r.prompt))
        toks = np.zeros((1, cap), np.int32)
        toks[0, :len(r.prompt)] = r.prompt           # right-pad
        pcache = self.model.init_cache(1, cap)
        last = np.asarray([len(r.prompt) - 1], np.int32)
        logits, cache = self._prefill_slot(self.params, jnp.asarray(toks),
                                           pcache, cache, jnp.int32(slot),
                                           jnp.asarray(last))
        rng, k = jax.random.split(rng)
        t = int(np.asarray(sample(logits[:, -1, :], k,
                                  self.scfg.temperature))[0])
        self._emit(r, t)
        limit = min(r.max_new_tokens, self.scfg.max_new_tokens)
        if t == self.eos_id or limit <= 1:
            self._finish(r, done)                    # slot stays free
            return cache, rng
        slots[slot] = r
        vecs["pos"][slot] = len(r.prompt)
        vecs["active"][slot] = True
        vecs["tenant"][slot] = self._tenant_id(r.tenant)
        tok[slot, 0] = t
        ntok[slot] = 1
        return cache, rng

    def _fill_slots(self, slots, queue, cache, vecs, tok, ntok, done, rng):
        """WFQ slot packing: hand each free slot to the backlogged tenant
        with the smallest virtual time whose bucket admits its head
        request.  Returns (cache, rng, granted_count)."""
        scfg = self.scfg
        granted_n = 0
        if not queue:
            return cache, rng, granted_n
        for b in self._buckets.values():
            b.refill()                   # one refill per scheduling round
        occupancy = Counter(s.tenant for s in slots if s is not None)
        self._wfq.note_backlog({r.tenant for r in queue} | set(occupancy))
        # Bucket starvation is counted per scheduling round for every
        # backlogged tenant, independent of slot availability — a starved
        # tenant waiting behind fully occupied slots is still deferred.
        heads: dict[str, Request] = {}
        for r in queue:                  # FIFO head per backlogged tenant
            heads.setdefault(r.tenant, r)
        deferred_round: set[str] = set()
        for tenant, r in heads.items():
            bucket = self._buckets.get(tenant)
            if bucket is not None and \
                    not bucket.can_take(self._admission_cost(r, bucket)):
                self.tenant_stats[tenant]["deferrals"] += 1
                deferred_round.add(tenant)
        for slot in range(scfg.max_batch):
            if slots[slot] is not None or not heads:
                continue
            granted = None
            for tenant in self._wfq.order(heads):
                r = heads[tenant]
                if scfg.max_slots_per_tenant and \
                        occupancy[tenant] >= scfg.max_slots_per_tenant:
                    continue             # over its slot budget this tick
                bucket = self._buckets.get(tenant)
                cost = self._admission_cost(r, bucket)
                if bucket is not None and not bucket.can_take(cost):
                    # starved — possibly only mid-round (an earlier grant
                    # drained the bucket), so count if the round-start
                    # scan didn't
                    if tenant not in deferred_round:
                        self.tenant_stats[tenant]["deferrals"] += 1
                        deferred_round.add(tenant)
                    continue
                if bucket is not None:
                    bucket.take(cost)
                granted = r
                break
            if granted is None:
                break                    # nothing admissible this round
            for qi, q in enumerate(queue):
                if q is granted:         # remove by identity: rid is not
                    del queue[qi]        # unique and prompt is an ndarray
                    break
            nxt = next((q for q in queue if q.tenant == granted.tenant),
                       None)
            if nxt is None:
                heads.pop(granted.tenant)
            else:
                heads[granted.tenant] = nxt
            self._wfq.grant(granted.tenant,
                            cost=min(granted.max_new_tokens,
                                     scfg.max_new_tokens))
            self.tenant_stats[granted.tenant]["wfq_grants"] += 1
            occupancy[granted.tenant] += 1
            granted_n += 1
            cache, rng = self._start_request(granted, slot, cache, slots,
                                             vecs, tok, ntok, done, rng)
            if slots[slot] is None:      # finished on its first token
                occupancy[granted.tenant] -= 1
        return cache, rng, granted_n

    def _run_continuous(self, requests: list[Request], rng) -> list[Request]:
        scfg = self.scfg
        B = scfg.max_batch
        for r in requests:
            self._bucket_cap(len(r.prompt))          # validate up front
        cache = self.model.init_cache(B, scfg.kv_cache_len)
        vecs = slot_vectors_init(B)      # per-slot pos/active/tenant
        self._slot_vecs = vecs           # exposed via slot_report()
        tok = np.zeros((B, 1), np.int32)
        ntok = np.zeros(B, np.int32)
        slots: list[Request | None] = [None] * B
        queue = deque(requests)
        done: list[Request] = []
        starved = 0

        while queue or vecs["active"].any():
            cache, rng, granted = self._fill_slots(slots, queue, cache, vecs,
                                                   tok, ntok, done, rng)
            active = np.nonzero(vecs["active"])[0]
            if not len(active):
                if not queue:
                    break
                starved = 0 if granted else starved + 1
                if starved > _MAX_STARVED_ROUNDS:
                    # pathological rates (≈0): force progress, bypassing
                    # the bucket, with the queue head
                    r = queue.popleft()
                    cache, rng = self._start_request(r, 0, cache, slots,
                                                     vecs, tok, ntok, done,
                                                     rng)
                    starved = 0
                continue
            starved = 0

            self._decode_shapes.add(("slots", B, scfg.kv_cache_len))
            logits, cache = self._step_slots(self.params, jnp.asarray(tok),
                                             cache, jnp.asarray(vecs["pos"]))
            rng, k = jax.random.split(rng)
            nxt = np.asarray(sample(logits[:, -1, :], k, scfg.temperature))
            for i in active:
                r = slots[i]
                t = int(nxt[i])
                self._emit(r, t)
                self.tenant_stats[r.tenant]["occupancy_steps"] += 1
                ntok[i] += 1
                vecs["pos"][i] += 1
                tok[i, 0] = t
                if t == self.eos_id or \
                        ntok[i] >= min(r.max_new_tokens, scfg.max_new_tokens):
                    self._finish(r, done)
                    slots[i] = None                  # freed mid-decode
                    vecs["active"][i] = False
                    vecs["tenant"][i] = -1
            self._obs_snapshot(active=int(vecs["active"].sum()),
                               queued=len(queue))
        return done

    # ------------------------------------------------------------------
    # gang (legacy baseline): batch to completion, shape-derived compiles
    # ------------------------------------------------------------------
    def _run_gang(self, requests: list[Request], rng) -> list[Request]:
        queue = list(requests)
        done: list[Request] = []

        while queue:
            batch_reqs, queue = self._admit_batch(queue)
            toks = self._pad_prompts(batch_reqs)
            b, prompt_len = toks.shape
            cache_len = prompt_len + self.scfg.max_new_tokens + 1
            cache = self.model.init_cache(b, cache_len)
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)}, cache)
            rng, k = jax.random.split(rng)
            tok = sample(logits[:, -1, :], k, self.scfg.temperature)[:, None]
            limits = [min(r.max_new_tokens, self.scfg.max_new_tokens)
                      for r in batch_reqs]
            active = np.ones(b, bool)
            for j, (r, t) in enumerate(zip(batch_reqs, np.asarray(tok)[:, 0])):
                self._emit(r, int(t))
                if t == self.eos_id or limits[j] <= 1:
                    active[j] = False

            for i in range(self.scfg.max_new_tokens - 1):
                if not active.any():
                    break
                self._decode_shapes.add(("gang", b, cache_len))
                pos = jnp.asarray(prompt_len + i, jnp.int32)
                logits, cache = self._step(self.params, tok, cache, pos)
                rng, k = jax.random.split(rng)
                tok = sample(logits[:, -1, :], k, self.scfg.temperature)[:, None]
                arr = np.asarray(tok)[:, 0]
                for j, r in enumerate(batch_reqs):
                    if active[j]:
                        self._emit(r, int(arr[j]))
                        # a slot whose request hits EOS or its token budget
                        # goes IDLE for the rest of the gang — the convoy
                        # effect continuous slot refill removes
                        if arr[j] == self.eos_id or \
                                len(r.out_tokens) >= limits[j]:
                            active[j] = False
                self._obs_snapshot(active=int(active.sum()),
                                   queued=len(queue))
            for r in batch_reqs:
                self._finish(r, done)
        return done

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    def tenant_report(self) -> dict[str, dict[str, float]]:
        """Per-tenant serve accounting: requests, tokens, deferrals, WFQ
        grants and decode-slot occupancy steps."""
        return {t: dict(v) for t, v in self.tenant_stats.items()}

    def slot_report(self) -> list[dict]:
        """Live per-slot view (position, active, tenant name) from the
        slot vectors — the serve-side feed for the per-tenant dashboards
        (ROADMAP): poll during a run to see who holds which slot."""
        vecs = getattr(self, "_slot_vecs", None)
        if vecs is None:
            return []
        names = {i: t for t, i in self._tenant_ids.items()}
        return [{"slot": i, "pos": int(vecs["pos"][i]),
                 "active": bool(vecs["active"][i]),
                 "tenant": names.get(int(vecs["tenant"][i]))}
                for i in range(len(vecs["pos"]))]

    def runtime_counters(self) -> tuple[np.ndarray, tuple[str, ...]]:
        """Serve accounting in per-tenant counter-block layout (rows match
        telemetry counter columns): ops = WFQ slot grants, bytes = served
        tokens, chunks = decode-slot occupancy steps, throttled = bucket
        deferrals.  Lets serve-side QoS land next to the dataplane's
        traced per-tenant runtime counters in dashboards."""
        tenants = tuple(self.tenant_stats)
        ctrs = np.zeros((len(tenants), tl.NUM_COUNTERS), np.float32)
        for i, t in enumerate(tenants):
            s = self.tenant_stats[t]
            ctrs[i, tl.CTR_OPS] = s["wfq_grants"] or s["requests"]
            ctrs[i, tl.CTR_BYTES] = s["tokens"]
            ctrs[i, tl.CTR_CHUNKS] = s["occupancy_steps"]
            ctrs[i, tl.CTR_THROTTLED] = s["deferrals"]
        return ctrs, tenants

    def decode_compile_count(self) -> int:
        """Decode-step compilations so far (jit cache entries across the
        gang and slot decode steps) — continuous batching holds this at 1
        per engine; gang scheduling pays one per distinct batch shape.
        Falls back to the engine's own distinct-decode-shape count if the
        jit cache stats API is unavailable (same value: one compile per
        distinct shape signature)."""
        n = 0
        for f in (getattr(self, "_step_slots", None), self._step):
            if f is None:
                continue
            try:
                n += f._cache_size()
            except Exception:           # jit cache introspection moved
                return len(self._decode_shapes)
        return n


__all__ = ["Engine", "Request", "WFQScheduler", "sample", "prompt_bucket"]
