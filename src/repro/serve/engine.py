"""Serving engine: persistent-slot continuous batching with a fixed-shape
decode step, WFQ slot packing, and per-tenant admission control.

**Slot lifecycle** (``scheduler="continuous"``, the default whenever the
model family has a slot-aware decode path):

1. The engine preallocates ONE ``(layers, max_batch, kv_cache_len, ...)``
   KV cache whose batch rows are long-lived *slots*, plus per-slot
   position / token vectors (layers/kvcache.py slot helpers).
2. A granted request is prefilled alone (batch 1), right-padded to a
   power-of-two *prompt bucket* — right padding sits causally after every
   real token, so bucketing never perturbs logits, and the prefill
   compile cache stays bounded at O(log max_prompt) entries.
3. ``kv_slot_insert`` writes the prefilled cache into the free slot; the
   slot joins the batch at its own position.
4. One jitted decode step advances ALL slots each tick.  Its shapes are
   functions of the slot geometry only — ``(max_batch, 1)`` tokens,
   ``(max_batch,)`` positions, the fixed cache — so it compiles **once
   per engine** regardless of the request mix (vs. one compile per
   distinct batch shape under gang scheduling).
5. A slot that finishes (EOS or token budget) is refilled from the queue
   *mid-decode* — no convoy effect: co-residents keep decoding while the
   freed slot takes new work.

**WFQ slot packing** is the QoS mechanism: a weighted-fair-queueing
scheduler (:class:`WFQScheduler`) keeps a virtual time per tenant, with
weights from :class:`~repro.core.policies.QoSPolicy` ``rates``.  Granting
a slot advances the tenant's virtual time by the request's decode-step
cost over its weight, and the tenant with the smallest virtual time wins
the next free slot — so decode-slot occupancy splits proportionally to
weights under saturation.  ``ServeConfig.max_slots_per_tenant`` adds a
hard per-tenant budget on concurrently held slots.  The host-side token
bucket (:class:`~repro.core.mediation.HostTokenBucket`) still gates
admission underneath WFQ, charging ``len(prompt)`` tokens per request
(the host analogue of the traced bucket's byte-proportional debits);
bucket-starved grants are counted as deferrals.  Occupancy, grants and
deferrals land in :meth:`Engine.tenant_report` and, in counter-block
layout, :meth:`Engine.runtime_counters`; attach a
:class:`~repro.core.obs.CounterTimeline` (``Engine(..., obs=...)``) to
stream that block — plus active-slot / queue-depth gauges — into a
per-tick timeline artifact and sparkline panels (docs/observability.md).

**Paged KV cache** (``ServeConfig.block_size > 0``, docs/serving.md):
instead of one ``kv_cache_len`` stripe per slot, the engine owns ONE
shared pool of fixed-size blocks plus a per-slot host block table
(layers/kvcache.py ``kv_pool_*`` helpers).  Each decode tick gathers
every slot's blocks into a dense cache, runs the UNCHANGED fixed-shape
slot decode, and scatters the one written token back — gather is a total
function of the table and garbage rows are validity-masked, so paged
decode is bit-identical to stripe decode at temperature 0.  Prefill
allocates a request's cover blocks at grant; decode growth claims one
block at a time, and pool pressure (or a lowered slot budget,
:meth:`Engine.set_slot_budget`) *preempts* a running slot: its emitted
tokens are the snapshot (writes are idempotent), its blocks return to
the pool, and the request re-queues for recompute/resume — counted as
``preemptions``/``restores`` in the tenant counter block and surfaced as
``preempt_s``/``restore_s`` timeline rates.  Slot count thus decouples
from context length: a prompt longer than any fixed stripe is admissible
while free blocks exist.

**Chunked prefill** (``ServeConfig.prefill_chunk > 0``): a prompt longer
than one chunk is prefilled one ``(1, prefill_chunk)`` chunk per engine
tick at a traced offset (``Model.prefill_chunk``), interleaved with the
decode ticks of co-resident slots — a long prompt no longer monopolizes
the engine, bounding co-residents' p99 TTFT — and every chunk pays its
mediation cost through the same fused pipeline as a decode tick.

``scheduler="gang"`` keeps the legacy behaviour — admit up to
``max_batch`` requests, batch-prefill them left-padded, decode the gang
to completion with shape-derived (recompiling) prefill/decode steps —
as the benchmark baseline and the fallback for model families without
``decode_step_slots``.

At temperature 0 both schedulers produce identical output tokens when
gang batches carry uniform prompt lengths.  With mixed lengths the gang
path left-pads to the batch max and *attends the pads* (a legacy gang
property), perturbing its logits; the continuous path is padding-
invariant by construction (right-padded buckets sit causally after the
prompt; stale slot bytes are validity-masked).
"""

from __future__ import annotations

import time
from collections import Counter, defaultdict, deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core import telemetry as tl
from repro.core.mediation import HostTokenBucket
from repro.core.policies import QoSPolicy
from repro.layers.kvcache import (
    BlockAllocator,
    kv_cache_constrain,
    kv_pool_gather,
    kv_pool_init,
    kv_pool_insert,
    kv_pool_scatter_chunk,
    kv_pool_scatter_token,
    kv_slot_insert,
    slot_vectors_init,
    state_slot_insert,
)

# Bound on consecutive all-throttled refill rounds before the engine
# force-admits the queue head (guarantees progress under any rate config).
_MAX_STARVED_ROUNDS = 10_000
_MIN_PROMPT_BUCKET = 8


class ServeError(ValueError):
    """A request the engine cannot serve under the current ServeConfig —
    raised at *submit* time (capacity checks), never mid-decode."""


@dataclass(eq=False)                 # identity semantics: rid is
class Request:                       # caller-supplied and prompt is an
    rid: int                         # ndarray (elementwise ==)
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 16
    tenant: str = "default"
    out_tokens: list = field(default_factory=list)
    done: bool = False
    t_first: float | None = None     # perf_counter stamp of the first token


def sample(logits: jax.Array, rng, temperature: float):
    if temperature <= 0:
        return logits.argmax(-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature).astype(jnp.int32)


def prompt_bucket(n: int) -> int:
    """Power-of-two prompt capacity ≥ max(n, 8): bounds the number of
    distinct prefill shapes (and thus compiles) at O(log max_prompt)."""
    b = _MIN_PROMPT_BUCKET
    while b < n:
        b *= 2
    return b


class WFQScheduler:
    """Weighted fair queueing over decode slots.

    Each tenant carries a *virtual time*; granting a slot advances it by
    the request's expected decode-step cost divided by the tenant's
    weight.  The backlogged tenant with the smallest virtual time wins
    the next free slot, so long-run slot grants — and decode-slot
    occupancy — split proportionally to weights under saturation.

    A monotone *virtual clock* tracks the smallest virtual time among
    the tenants backlogged each scheduling round (``note_backlog``); a
    grant starts no earlier than the clock, so a tenant re-entering
    after idling resumes at the current service level instead of
    spending its idle time as hoarded credit.  Unknown tenants get
    ``default_weight``."""

    def __init__(self, weights: dict[str, float] | None = None,
                 default_weight: float = 1.0):
        self.weights = dict(weights or {})
        self.default_weight = float(default_weight)
        self.vtime: dict[str, float] = {}
        self.vclock = 0.0

    def weight(self, tenant: str) -> float:
        return max(float(self.weights.get(tenant, self.default_weight)),
                   1e-9)

    def order(self, tenants) -> list[str]:
        """Tenants in grant-preference order (smallest virtual time
        first; ties keep the caller's order)."""
        return sorted(tenants, key=lambda t: self.vtime.get(t, 0.0))

    def note_backlog(self, tenants) -> None:
        """Advance the virtual clock to the backlogged minimum (call once
        per scheduling round with every queued or slot-holding tenant)."""
        vs = [self.vtime.get(t, 0.0) for t in tenants]
        if vs:
            self.vclock = max(self.vclock, min(vs))

    def grant(self, tenant: str, cost: float) -> None:
        v = max(self.vtime.get(tenant, 0.0), self.vclock)
        self.vtime[tenant] = v + float(cost) / self.weight(tenant)


class Engine:
    def __init__(self, model, params, cfg: ModelConfig, serve: ServeConfig,
                 dp=None, eos_id: int = 1, obs=None, obs_every: int = 1):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.scfg = serve
        self.dp = dp
        self.eos_id = eos_id
        # optional CounterTimeline (core/obs.py): one snapshot of the
        # per-tenant counter block + run gauges every ``obs_every``-th
        # decode tick (ObsConfig.every), taken on the host between jitted
        # steps — never inside traced code
        self.obs = obs
        self.obs_every = max(int(obs_every), 1)
        self._obs_tick_no = 0
        # control-plane hook: called as ``on_tick(engine)`` right after
        # each timeline snapshot lands, so a ServeElasticController
        # (runtime/elastic.py) can observe the fresh window and move the
        # slot budget while the engine is mid-run
        self.on_tick = None
        # cache sharding edges are issued inside the traced prefill, so
        # policy enforcement/telemetry happen once per compiled shape (like
        # every other dataplane edge), not once per host batching round
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, kv_cache_constrain(dp, c),
                                          dp=dp))
        self._step = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos, dp=dp))
        step_slots = getattr(model, "decode_step_slots", None)
        self._slot_support = step_slots is not None
        if self._slot_support:
            # prefill-to-slot is ONE traced op: batch-1 bucketed prefill
            # whose cache lands directly in the target slot of the
            # persistent cache (one dispatch per admitted request, one
            # compile per prompt bucket)
            def _prefill_into_slot(p, t, pc, cache, slot, last):
                logits, pc = model.prefill(p, {"tokens": t},
                                           kv_cache_constrain(dp, pc),
                                           dp=dp, last_pos=last)
                # family-agnostic: writes KV stripe leaves AND recurrent /
                # cross-attention state leaves at their batch row
                return logits, state_slot_insert(cache, pc, slot)

            # the persistent cache is donated: XLA updates it in place
            # instead of copying the full buffer per tick / per insert
            # (a no-op with a warning on backends without aliasing)
            self._prefill_slot = jax.jit(_prefill_into_slot,
                                         donate_argnums=(3,))
            self._step_slots = jax.jit(
                lambda p, t, c, pos: step_slots(p, t, c, pos, dp=dp),
                donate_argnums=(2,))

        # True for the recurrent families (mamba/xLSTM state): the engine
        # prefills them at exact prompt length — right padding advances a
        # recurrence, so bucketed prefill would corrupt the slot state
        # (one prefill compile per distinct prompt length, correctness
        # over compile reuse)
        self._recurrent = bool(getattr(model, "recurrent", False))

        # ---- paged KV block pool (block_size > 0) ---------------------
        bs = serve.block_size
        self.paged = bs > 0
        if self.paged:
            spec = (jax.eval_shape(lambda: model.init_cache(1, bs))
                    if self._slot_support else None)
            pageable = (isinstance(spec, dict) and set(spec) == {"k", "v"}
                        and all(len(v.shape) == 5 for v in spec.values()))
            if not pageable:
                # name the family and the flag — never a capacity message:
                # the config is *valid*, just not for this cache layout
                raise ServeError(
                    f"paged KV (block_size={bs}) is not supported for the "
                    f"{cfg.family!r} family ({cfg.name}): its decode cache "
                    f"holds recurrent/cross-attention state that cannot be "
                    f"block-paged. Set ServeConfig.block_size=0 "
                    f"(--block-size 0) to serve this family on the fixed "
                    f"stripe layout (continuous batching, chunk-exact "
                    f"preemption and WFQ budgets all still apply).")
        if self.paged:
            ks = spec["k"]
            # (layers, kv_heads, head_dim, dtype) from the model's own
            # cache layout, so the pool matches it bit-for-bit
            self._pool_geom = (ks.shape[0], ks.shape[3], ks.shape[4],
                               ks.dtype)
            self._n_usable = serve.n_blocks or \
                (serve.max_batch * serve.kv_cache_len // bs)
            self._tables_len = self._n_usable

            def _pool_step(p, t, pool, tables, pos, act):
                dense = kv_pool_gather(pool, tables, bs)
                logits, dense = step_slots(p, t, dense, pos, dp=dp)
                return logits, kv_pool_scatter_token(pool, dense, tables,
                                                     pos, act, bs)

            self._step_pool = jax.jit(_pool_step, donate_argnums=(2,))
            self._pool_insert = jax.jit(
                lambda pool, pc, ids: kv_pool_insert(pool, pc, ids, bs),
                donate_argnums=(0,))
            self._prefill_last = jax.jit(
                lambda p, t, c, last: model.prefill(
                    p, {"tokens": t}, kv_cache_constrain(dp, c), dp=dp,
                    last_pos=last))

        # ---- chunked prefill (prefill_chunk > 0) ----------------------
        chunk_fn = getattr(model, "prefill_chunk", None)
        self.chunked = (serve.prefill_chunk > 0 and chunk_fn is not None
                        and self._slot_support)
        if self.chunked:
            self._chunk = jax.jit(
                lambda p, t, c, off, last: chunk_fn(
                    p, {"tokens": t}, kv_cache_constrain(dp, c), off, dp=dp,
                    last_pos=last),
                donate_argnums=(2,))
            if self.paged:
                self._chunk_scatter = jax.jit(
                    lambda pool, pc, trow, off: kv_pool_scatter_chunk(
                        pool, pc, trow, off, serve.prefill_chunk, bs),
                    donate_argnums=(0,))
            else:
                self._slot_ins = jax.jit(
                    lambda c, pc, s: state_slot_insert(c, pc, s),
                    donate_argnums=(0,))

        # per-run slot bookkeeping (reset by _run_continuous)
        self._prefills: dict[int, dict] = {}
        self._prefill_q: deque = deque()
        self._budget_cap = 0             # 0 = use scfg.max_slots_per_tenant
        qos = next((p for p in (dp.policies if dp is not None else [])
                    if isinstance(p, QoSPolicy)), None)
        self._buckets = HostTokenBucket.from_policy(
            qos, scale=serve.admission_token_scale)
        self._wfq = WFQScheduler(qos.rates if qos is not None else {})
        self.tenant_stats: dict[str, dict[str, float]] = defaultdict(
            lambda: {"requests": 0, "tokens": 0, "deferrals": 0,
                     "wfq_grants": 0, "occupancy_steps": 0,
                     "preemptions": 0, "restores": 0})
        self._tenant_ids: dict[str, int] = {}
        self._decode_shapes: set[tuple] = set()

    def _tenant_id(self, tenant: str) -> int:
        """Stable small integer per tenant (for the slot tenant vector)."""
        return self._tenant_ids.setdefault(tenant, len(self._tenant_ids))

    # ------------------------------------------------------------------
    # tenant admission (host-side token bucket, serve-level throttling)
    # ------------------------------------------------------------------
    @staticmethod
    def _admission_cost(r: Request, bucket: HostTokenBucket | None) -> float:
        """Bucket debit for admitting ``r``: its prompt tokens, clamped to
        the bucket's burst so a prompt longer than the bucket can ever
        hold still drains a full bucket instead of being permanently
        inadmissible (the classic token-bucket cost clamp)."""
        cost = float(len(r.prompt))
        return min(cost, bucket.burst) if bucket is not None else cost

    def _admit_batch(self, queue: list[Request]) -> tuple[list[Request],
                                                          list[Request]]:
        """Gang admission: pick up to ``max_batch`` requests the buckets
        admit; the rest stay queued.  Refills until at least one request
        is admissible (guaranteed progress).  Bucket starvation is
        observed with ``can_take`` *before* the batch-fullness check, so
        a starved request behind a full batch is still counted as
        deferred (once per batching round, on the round's first refill);
        the bucket is only debited — by ``len(prompt)`` tokens — when the
        request is actually admitted."""
        B = self.scfg.max_batch
        for round_ in range(_MAX_STARVED_ROUNDS):
            for b in self._buckets.values():
                b.refill()
            admitted, deferred = [], []
            for r in queue:
                bucket = self._buckets.get(r.tenant)
                cost = self._admission_cost(r, bucket)
                if bucket is not None and not bucket.can_take(cost):
                    if round_ == 0:
                        self.tenant_stats[r.tenant]["deferrals"] += 1
                    deferred.append(r)
                elif len(admitted) < B:
                    if bucket is not None:
                        bucket.take(cost)
                    admitted.append(r)
                else:
                    deferred.append(r)
            if admitted:
                return admitted, deferred
        # pathological rates (≈0): force progress with the queue head
        return queue[:1], queue[1:]

    def _obs_snapshot(self, *, active: int, queued: int) -> None:
        """Feed the attached timeline one engine tick: the serve counter
        block (WFQ grants / tokens / occupancy / deferrals in telemetry
        column layout) plus slot-level run gauges."""
        if self.obs is None:
            return
        self._obs_tick_no += 1
        if self._obs_tick_no % self.obs_every:
            return
        ctrs, tenants = self.runtime_counters()
        gauges = {"active_slots": active, "queued": queued}
        if self.paged and getattr(self, "_alloc", None) is not None:
            gauges["free_blocks"] = self._alloc.free_blocks
        self.obs.snapshot_block(self._obs_tick_no, ctrs, tenants,
                                gauges=gauges)
        if self.on_tick is not None:
            self.on_tick(self)

    # ------------------------------------------------------------------
    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        cap = max(len(r.prompt) for r in reqs)
        cap = max(cap, 8)
        toks = np.zeros((len(reqs), cap), np.int32)
        for i, r in enumerate(reqs):
            toks[i, -len(r.prompt):] = r.prompt      # left-pad
        return toks

    def _finish(self, r: Request, done: list[Request]) -> None:
        r.done = True
        stats = self.tenant_stats[r.tenant]
        stats["requests"] += 1
        stats["tokens"] += len(r.out_tokens)
        done.append(r)

    def _emit(self, r: Request, token: int) -> None:
        if not r.out_tokens:
            r.t_first = time.perf_counter()
        r.out_tokens.append(token)

    # ------------------------------------------------------------------
    # public entry
    # ------------------------------------------------------------------
    def run(self, requests: list[Request], rng=None,
            scheduler: str | None = None) -> list[Request]:
        """Serve all requests to completion; returns them with outputs.

        ``scheduler`` overrides ``ServeConfig.scheduler`` for this run;
        "continuous" silently falls back to "gang" when the model family
        has no slot-aware decode path."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        sched = scheduler or self.scfg.scheduler
        if sched not in ("continuous", "gang"):
            raise ValueError(f"unknown scheduler {sched!r}; "
                             f"expected 'continuous' or 'gang'")
        if sched == "continuous" and self._slot_support:
            return self._run_continuous(list(requests), rng)
        for r in requests:               # clear error, never a mid-decode
            need = len(r.prompt) + \
                min(r.max_new_tokens, self.scfg.max_new_tokens) + 1
            if need > self.scfg.kv_cache_len:
                raise ServeError(
                    f"gang request needs {need} cache positions (prompt "
                    f"{len(r.prompt)} + new tokens + 1) but kv_cache_len "
                    f"is {self.scfg.kv_cache_len}")
        return self._run_gang(list(requests), rng)

    # ------------------------------------------------------------------
    # continuous: persistent slots, fixed-shape decode, WFQ packing
    # ------------------------------------------------------------------
    def _cover(self, n: int) -> int:
        """Prefill cache capacity for an ``n``-token sequence: the chunk
        cover (smallest multiple of ``prefill_chunk`` ≥ n) when chunked
        prefill applies, else the power-of-two prompt bucket.

        Recurrent families get the EXACT length: their prefill runs every
        cache position through the mamba/xLSTM recurrence, so padding to a
        bucket would fold pad tokens into the slot state.  Costs one
        prefill compile per distinct prompt length — the documented
        correctness-first tradeoff (docs/serving.md)."""
        if self._recurrent:
            return max(n, 1)
        C = self.scfg.prefill_chunk
        if self.chunked and n > C:
            return -(-n // C) * C
        return prompt_bucket(n)

    @staticmethod
    def _resume_len(r: Request) -> int:
        """Tokens re-prefilled when ``r`` restarts: the prompt plus every
        emitted token but the last (which becomes the pending decode
        input) — 0 emitted means a fresh start over the prompt alone."""
        k = len(r.out_tokens)
        return len(r.prompt) + k - 1 if k else len(r.prompt)

    def _blocks_for(self, r: Request) -> int:
        return -(-self._cover(self._resume_len(r)) // self.scfg.block_size)

    def _bucket_cap(self, prompt_len: int) -> int:
        cap = self._cover(prompt_len)
        need = cap + self.scfg.max_new_tokens + 1
        if need > self.scfg.kv_cache_len:
            raise ServeError(
                f"request needs {need} cache positions (prefill cover {cap}"
                f" + max_new_tokens {self.scfg.max_new_tokens} + 1) but "
                f"kv_cache_len is {self.scfg.kv_cache_len}")
        return cap

    def _check_capacity(self, r: Request) -> None:
        """Submit-time admission check (raises :class:`ServeError`).

        Paged: worst-case pool blocks over the request's whole lifetime —
        the prefill cover, the resume cover after a worst-case preemption
        (every budgeted token emitted), and the decode high-water mark —
        must fit the pool.  Stripe: the legacy per-slot stripe check."""
        if not self.paged:
            self._bucket_cap(len(r.prompt))
            return
        L = len(r.prompt)
        limit = min(r.max_new_tokens, self.scfg.max_new_tokens)
        need = max(self._cover(L), self._cover(L + max(limit - 1, 0)),
                   L + limit) + 1
        nblk = -(-need // self.scfg.block_size)
        if nblk > self._n_usable:
            raise ServeError(
                f"request needs {nblk} pool blocks ({need} cache positions"
                f" / block_size {self.scfg.block_size}) but the pool has "
                f"only {self._n_usable} usable blocks")

    def _resume_fits(self, r: Request) -> bool:
        """Whether preempting ``r`` now leaves it restartable.  Always true
        under paging (the submit check covered the worst-case resume);
        stripe resume re-prefills a *longer* sequence whose cover can
        outgrow the slot stripe mid-bucket."""
        if self.paged:
            return True
        eff = self._resume_len(r)
        limit = min(r.max_new_tokens, self.scfg.max_new_tokens)
        return max(self._cover(eff),
                   len(r.prompt) + limit) + 1 <= self.scfg.kv_cache_len

    # ------------------------------------------------------------------
    # preemption (pool pressure / slot budgets) and resume
    # ------------------------------------------------------------------
    def set_slot_budget(self, n: int) -> int:
        """Tighten (or with 0, relax back to ServeConfig) the per-tenant
        cap on concurrently held slots — the serve-side elastic control
        knob.  Takes effect on the next engine tick: over-budget tenants
        have their most recent slots preempted.  Returns the previous raw
        override (0 = none) so an elastic controller can restore exactly
        the pre-shrink setting on grow-back."""
        prev, self._budget_cap = self._budget_cap, max(int(n), 0)
        return prev

    def slot_budget(self) -> int:
        """The *effective* per-tenant slot cap right now: the runtime
        override if set, else ``ServeConfig.max_slots_per_tenant``, else
        ``max_batch`` (no per-tenant cap ⇒ the batch is the ceiling)."""
        return int(self._budget_cap or self.scfg.max_slots_per_tenant
                   or self.scfg.max_batch)

    def _release_slot(self, slot: int, vecs) -> None:
        """Return a slot's resources (pool blocks, slot vectors)."""
        if self.paged and self._slot_blocks[slot]:
            self._alloc.free(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            self._tables[slot, :] = 0
        vecs["active"][slot] = False
        vecs["tenant"][slot] = -1

    def _preempt_slot(self, slot: int, slots, vecs, tok, ntok,
                      queue) -> None:
        """Evict the resident request: its emitted tokens ARE the snapshot
        (prefill/decode writes are idempotent, so recompute is exact at
        temperature 0), its blocks return to the pool, and it re-queues at
        the front for resume."""
        r = slots[slot]
        st = self._prefills.pop(slot, None)
        if st is not None:               # mid-chunk-prefill: drop partials
            try:
                self._prefill_q.remove(slot)
            except ValueError:
                pass
        slots[slot] = None
        self._release_slot(slot, vecs)
        vecs["pos"][slot] = 0
        ntok[slot] = 0
        self.tenant_stats[r.tenant]["preemptions"] += 1
        queue.appendleft(r)

    def _enforce_budget(self, slots, vecs, tok, ntok, queue) -> None:
        """Preempt over-budget tenants' most recent slots down to the
        effective per-tenant cap (``set_slot_budget`` overrides the
        ServeConfig value) — what makes WFQ budgets *enforceable* instead
        of advisory."""
        cap = self._budget_cap or self.scfg.max_slots_per_tenant
        if not cap:
            return
        held: dict[str, list[int]] = defaultdict(list)
        for i, r in enumerate(slots):
            if r is not None:
                held[r.tenant].append(i)
        for tenant, idxs in held.items():
            extra = len(idxs) - cap
            if extra <= 0:
                continue
            for i in sorted(idxs, key=lambda j: self._slot_started[j],
                            reverse=True):
                if extra <= 0:
                    break
                if not self._resume_fits(slots[i]):
                    continue             # stripe: resume would not fit
                self._preempt_slot(i, slots, vecs, tok, ntok, queue)
                extra -= 1

    def _ensure_blocks(self, i: int, slots, vecs, tok, ntok, queue) -> bool:
        """Guarantee slot ``i`` owns the block its next decode write lands
        in, claiming from the pool on demand.  Pool pressure preempts the
        active slot whose tenant has the largest WFQ virtual time (the
        least entitled co-resident); with no other candidate the slot
        preempts itself — deadlock-free, since the submit check bounds any
        single request's need to the pool size.  Returns False when slot
        ``i`` itself was preempted."""
        bs = self.scfg.block_size
        while vecs["active"][i] and \
                int(vecs["pos"][i]) // bs >= len(self._slot_blocks[i]):
            got = self._alloc.alloc(1)
            if got is not None:
                self._slot_blocks[i].append(got[0])
                self._tables[i, len(self._slot_blocks[i]) - 1] = got[0]
                continue
            cands = [j for j in range(self.scfg.max_batch)
                     if j != i and slots[j] is not None and vecs["active"][j]]
            if not cands:
                self._preempt_slot(i, slots, vecs, tok, ntok, queue)
                return False
            victim = max(cands, key=lambda j: (
                self._wfq.vtime.get(slots[j].tenant, 0.0),
                self._slot_started[j]))
            self._preempt_slot(victim, slots, vecs, tok, ntok, queue)
        return bool(vecs["active"][i])

    # ------------------------------------------------------------------
    # prefill-to-slot (whole or chunked; fresh or resume)
    # ------------------------------------------------------------------
    def _activate(self, r: Request, slot: int, logits, cache, slots, vecs,
                  tok, ntok, done, rng, *, eff: int, k: int):
        """Post-prefill slot activation.  Fresh requests (k=0) sample and
        emit their first token; resumed requests re-enter decode with the
        token that was pending when they were preempted (no new sample —
        recompute is exact)."""
        limit = min(r.max_new_tokens, self.scfg.max_new_tokens)
        if k == 0:
            rng, key = jax.random.split(rng)
            t = int(np.asarray(sample(logits[:, -1, :], key,
                                      self.scfg.temperature))[0])
            self._emit(r, t)
            if t == self.eos_id or limit <= 1:
                self._finish(r, done)                # slot stays free
                slots[slot] = None
                self._release_slot(slot, vecs)
                return cache, rng
            nt = 1
        else:
            self.tenant_stats[r.tenant]["restores"] += 1
            t = int(r.out_tokens[-1])
            nt = k
        slots[slot] = r
        vecs["pos"][slot] = eff
        vecs["active"][slot] = True
        vecs["tenant"][slot] = self._tenant_id(r.tenant)
        tok[slot, 0] = t
        ntok[slot] = nt
        self._slot_seq += 1
        self._slot_started[slot] = self._slot_seq
        return cache, rng

    def _start_request(self, r: Request, slot: int, cache, slots, vecs, tok,
                       ntok, done, rng):
        """Prefill one request (batch 1) into ``slot`` — whole when it fits
        one chunk/bucket, else enqueued for chunk-at-a-time prefill — and
        emit / restore its next decode token.  Returns (cache, rng); with
        paging, ``cache`` is the block pool."""
        scfg = self.scfg
        k = len(r.out_tokens)            # > 0 ⇒ resume after preemption
        eff = self._resume_len(r)
        seq = (np.concatenate([np.asarray(r.prompt, np.int32),
                               np.asarray(r.out_tokens[:-1], np.int32)])
               if k else np.asarray(r.prompt, np.int32))
        cover = self._cover(eff)
        if self.paged:
            ids = self._alloc.alloc(-(-cover // scfg.block_size))
            if ids is None:              # callers check free_blocks first
                raise RuntimeError("block pool exhausted at grant")
            self._slot_blocks[slot] = list(ids)
            self._tables[slot, :] = 0
            self._tables[slot, :len(ids)] = ids
        toks = np.zeros((1, cover), np.int32)
        toks[0, :eff] = seq              # right-pad
        if self.chunked and eff > scfg.prefill_chunk:
            # chunk-at-a-time: one chunk advances per engine tick,
            # interleaved with decode (run loop); slot is held but not
            # active until the last chunk lands
            self._prefills[slot] = {
                "r": r, "toks": toks, "eff": eff, "off": 0, "cover": cover,
                "pcache": self.model.init_cache(1, cover), "k": k}
            self._prefill_q.append(slot)
            slots[slot] = r
            vecs["tenant"][slot] = self._tenant_id(r.tenant)
            self._slot_seq += 1
            self._slot_started[slot] = self._slot_seq
            return cache, rng
        pcache = self.model.init_cache(1, cover)
        last = np.asarray([eff - 1], np.int32)
        if self.paged:
            logits, pcache = self._prefill_last(self.params,
                                                jnp.asarray(toks), pcache,
                                                jnp.asarray(last))
            cache = self._pool_insert(cache, pcache,
                                      jnp.asarray(ids, jnp.int32))
        else:
            logits, cache = self._prefill_slot(self.params,
                                               jnp.asarray(toks), pcache,
                                               cache, jnp.int32(slot),
                                               jnp.asarray(last))
        return self._activate(r, slot, logits, cache, slots, vecs, tok,
                              ntok, done, rng, eff=eff, k=k)

    def _advance_chunk(self, cache, slots, vecs, tok, ntok, done, rng):
        """Advance the oldest chunk-prefilling slot by ONE chunk (paying
        one mediation-accounted traced step), activating it when the last
        chunk lands.  Returns (cache, rng)."""
        slot = self._prefill_q.popleft()
        st = self._prefills[slot]
        C = self.scfg.prefill_chunk
        off = st["off"]
        chunk = st["toks"][:, off:off + C]
        last = np.asarray([st["eff"] - 1], np.int32)
        logits, st["pcache"] = self._chunk(self.params, jnp.asarray(chunk),
                                           st["pcache"], jnp.int32(off),
                                           jnp.asarray(last))
        if self.paged:                   # scatter the chunk's blocks now
            cache = self._chunk_scatter(cache, st["pcache"],
                                        jnp.asarray(self._tables[slot]),
                                        jnp.int32(off))
        st["off"] = off + C
        if st["off"] < st["cover"]:
            self._prefill_q.append(slot)
            return cache, rng
        self._prefills.pop(slot)         # last chunk: logits are at eff-1
        if not self.paged:
            cache = self._slot_ins(cache, st["pcache"], jnp.int32(slot))
        return self._activate(st["r"], slot, logits, cache, slots, vecs,
                              tok, ntok, done, rng, eff=st["eff"],
                              k=st["k"])

    def _fill_slots(self, slots, queue, cache, vecs, tok, ntok, done, rng):
        """WFQ slot packing: hand each free slot to the backlogged tenant
        with the smallest virtual time whose bucket admits its head
        request.  Returns (cache, rng, granted_count)."""
        scfg = self.scfg
        granted_n = 0
        if not queue:
            return cache, rng, granted_n
        for b in self._buckets.values():
            b.refill()                   # one refill per scheduling round
        occupancy = Counter(s.tenant for s in slots if s is not None)
        self._wfq.note_backlog({r.tenant for r in queue} | set(occupancy))
        # Bucket starvation is counted per scheduling round for every
        # backlogged tenant, independent of slot availability — a starved
        # tenant waiting behind fully occupied slots is still deferred.
        heads: dict[str, Request] = {}
        for r in queue:                  # FIFO head per backlogged tenant
            heads.setdefault(r.tenant, r)
        deferred_round: set[str] = set()
        for tenant, r in heads.items():
            bucket = self._buckets.get(tenant)
            if bucket is not None and \
                    not bucket.can_take(self._admission_cost(r, bucket)):
                self.tenant_stats[tenant]["deferrals"] += 1
                deferred_round.add(tenant)
        slot_cap = self._budget_cap or scfg.max_slots_per_tenant
        for slot in range(scfg.max_batch):
            if slots[slot] is not None or not heads:
                continue
            granted = None
            for tenant in self._wfq.order(heads):
                r = heads[tenant]
                if slot_cap and occupancy[tenant] >= slot_cap:
                    continue             # over its slot budget this tick
                bucket = self._buckets.get(tenant)
                cost = self._admission_cost(r, bucket)
                if bucket is not None and not bucket.can_take(cost):
                    # starved — possibly only mid-round (an earlier grant
                    # drained the bucket), so count if the round-start
                    # scan didn't
                    if tenant not in deferred_round:
                        self.tenant_stats[tenant]["deferrals"] += 1
                        deferred_round.add(tenant)
                    continue
                if self.paged and \
                        self._blocks_for(r) > self._alloc.free_blocks:
                    continue             # pool pressure: wait or try next
                if bucket is not None:
                    bucket.take(cost)
                granted = r
                break
            if granted is None:
                break                    # nothing admissible this round
            for qi, q in enumerate(queue):
                if q is granted:         # remove by identity: rid is not
                    del queue[qi]        # unique and prompt is an ndarray
                    break
            nxt = next((q for q in queue if q.tenant == granted.tenant),
                       None)
            if nxt is None:
                heads.pop(granted.tenant)
            else:
                heads[granted.tenant] = nxt
            self._wfq.grant(granted.tenant,
                            cost=min(granted.max_new_tokens,
                                     scfg.max_new_tokens))
            self.tenant_stats[granted.tenant]["wfq_grants"] += 1
            occupancy[granted.tenant] += 1
            granted_n += 1
            cache, rng = self._start_request(granted, slot, cache, slots,
                                             vecs, tok, ntok, done, rng)
            if slots[slot] is None:      # finished on its first token
                occupancy[granted.tenant] -= 1
        return cache, rng, granted_n

    def _run_continuous(self, requests: list[Request], rng) -> list[Request]:
        scfg = self.scfg
        B = scfg.max_batch
        for r in requests:
            self._check_capacity(r)      # validate up front (ServeError)
        if self.paged:
            layers, kvh, hd, dt = self._pool_geom
            cache = kv_pool_init(layers, self._n_usable, scfg.block_size,
                                 kvh, hd, dtype=dt)
            self._alloc = BlockAllocator(self._n_usable)
            self._tables = np.zeros((B, self._tables_len), np.int32)
            self._slot_blocks: list[list[int]] = [[] for _ in range(B)]
        else:
            cache = self.model.init_cache(B, scfg.kv_cache_len)
        vecs = slot_vectors_init(B)      # per-slot pos/active/tenant
        self._slot_vecs = vecs           # exposed via slot_report()
        self._prefills = {}
        self._prefill_q = deque()
        self._slot_started = [0] * B
        self._slot_seq = 0
        tok = np.zeros((B, 1), np.int32)
        ntok = np.zeros(B, np.int32)
        slots: list[Request | None] = [None] * B
        queue = deque(requests)
        done: list[Request] = []
        starved = 0

        while queue or vecs["active"].any() or self._prefills:
            self._enforce_budget(slots, vecs, tok, ntok, queue)
            cache, rng, granted = self._fill_slots(slots, queue, cache, vecs,
                                                   tok, ntok, done, rng)
            if self._prefill_q:          # one chunk per tick, interleaved
                cache, rng = self._advance_chunk(cache, slots, vecs, tok,
                                                 ntok, done, rng)
            if self.paged:               # claim this tick's write blocks
                for i in np.nonzero(vecs["active"])[0]:
                    if vecs["active"][i]:
                        self._ensure_blocks(int(i), slots, vecs, tok, ntok,
                                            queue)
            active = np.nonzero(vecs["active"])[0]
            if not len(active):
                if not queue and not self._prefills:
                    break
                starved = 0 if granted or self._prefills else starved + 1
                if starved > _MAX_STARVED_ROUNDS:
                    # pathological rates (≈0): force progress, bypassing
                    # the bucket, with the queue head
                    r = queue.popleft()
                    cache, rng = self._start_request(r, 0, cache, slots,
                                                     vecs, tok, ntok, done,
                                                     rng)
                    starved = 0
                continue
            starved = 0

            if self.paged:
                self._decode_shapes.add(("pool", B,
                                         self._tables_len * scfg.block_size))
                logits, cache = self._step_pool(
                    self.params, jnp.asarray(tok), cache,
                    jnp.asarray(self._tables), jnp.asarray(vecs["pos"]),
                    jnp.asarray(vecs["active"]))
            else:
                self._decode_shapes.add(("slots", B, scfg.kv_cache_len))
                logits, cache = self._step_slots(self.params,
                                                 jnp.asarray(tok), cache,
                                                 jnp.asarray(vecs["pos"]))
            rng, k = jax.random.split(rng)
            nxt = np.asarray(sample(logits[:, -1, :], k, scfg.temperature))
            for i in active:
                r = slots[i]
                t = int(nxt[i])
                self._emit(r, t)
                self.tenant_stats[r.tenant]["occupancy_steps"] += 1
                ntok[i] += 1
                vecs["pos"][i] += 1
                tok[i, 0] = t
                if t == self.eos_id or \
                        ntok[i] >= min(r.max_new_tokens, scfg.max_new_tokens):
                    self._finish(r, done)
                    slots[i] = None                  # freed mid-decode
                    self._release_slot(i, vecs)      # blocks back to pool
            self._obs_snapshot(active=int(vecs["active"].sum()),
                               queued=len(queue))
        return done

    # ------------------------------------------------------------------
    # gang (legacy baseline): batch to completion, shape-derived compiles
    # ------------------------------------------------------------------
    def _run_gang(self, requests: list[Request], rng) -> list[Request]:
        queue = list(requests)
        done: list[Request] = []

        while queue:
            batch_reqs, queue = self._admit_batch(queue)
            toks = self._pad_prompts(batch_reqs)
            b, prompt_len = toks.shape
            cache_len = prompt_len + self.scfg.max_new_tokens + 1
            cache = self.model.init_cache(b, cache_len)
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)}, cache)
            rng, k = jax.random.split(rng)
            tok = sample(logits[:, -1, :], k, self.scfg.temperature)[:, None]
            limits = [min(r.max_new_tokens, self.scfg.max_new_tokens)
                      for r in batch_reqs]
            active = np.ones(b, bool)
            for j, (r, t) in enumerate(zip(batch_reqs, np.asarray(tok)[:, 0])):
                self._emit(r, int(t))
                if t == self.eos_id or limits[j] <= 1:
                    active[j] = False

            for i in range(self.scfg.max_new_tokens - 1):
                if not active.any():
                    break
                self._decode_shapes.add(("gang", b, cache_len))
                pos = jnp.asarray(prompt_len + i, jnp.int32)
                logits, cache = self._step(self.params, tok, cache, pos)
                rng, k = jax.random.split(rng)
                tok = sample(logits[:, -1, :], k, self.scfg.temperature)[:, None]
                arr = np.asarray(tok)[:, 0]
                for j, r in enumerate(batch_reqs):
                    if active[j]:
                        self._emit(r, int(arr[j]))
                        # a slot whose request hits EOS or its token budget
                        # goes IDLE for the rest of the gang — the convoy
                        # effect continuous slot refill removes
                        if arr[j] == self.eos_id or \
                                len(r.out_tokens) >= limits[j]:
                            active[j] = False
                self._obs_snapshot(active=int(active.sum()),
                                   queued=len(queue))
            for r in batch_reqs:
                self._finish(r, done)
        return done

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    def tenant_report(self) -> dict[str, dict[str, float]]:
        """Per-tenant serve accounting: requests, tokens, deferrals, WFQ
        grants and decode-slot occupancy steps."""
        return {t: dict(v) for t, v in self.tenant_stats.items()}

    def slot_report(self) -> list[dict]:
        """Live per-slot view (position, active, tenant name) from the
        slot vectors — the serve-side feed for the per-tenant dashboards
        (ROADMAP): poll during a run to see who holds which slot."""
        vecs = getattr(self, "_slot_vecs", None)
        if vecs is None:
            return []
        names = {i: t for t, i in self._tenant_ids.items()}
        return [{"slot": i, "pos": int(vecs["pos"][i]),
                 "active": bool(vecs["active"][i]),
                 "tenant": names.get(int(vecs["tenant"][i]))}
                for i in range(len(vecs["pos"]))]

    def runtime_counters(self) -> tuple[np.ndarray, tuple[str, ...]]:
        """Serve accounting in per-tenant counter-block layout (rows match
        telemetry counter columns): ops = WFQ slot grants, bytes = served
        tokens, chunks = decode-slot occupancy steps, throttled = bucket
        deferrals.  Lets serve-side QoS land next to the dataplane's
        traced per-tenant runtime counters in dashboards."""
        tenants = tuple(self.tenant_stats)
        ctrs = np.zeros((len(tenants), tl.NUM_COUNTERS), np.float32)
        for i, t in enumerate(tenants):
            s = self.tenant_stats[t]
            ctrs[i, tl.CTR_OPS] = s["wfq_grants"] or s["requests"]
            ctrs[i, tl.CTR_BYTES] = s["tokens"]
            ctrs[i, tl.CTR_CHUNKS] = s["occupancy_steps"]
            ctrs[i, tl.CTR_THROTTLED] = s["deferrals"]
            ctrs[i, tl.CTR_PREEMPTIONS] = s["preemptions"]
            ctrs[i, tl.CTR_RESTORES] = s["restores"]
        return ctrs, tenants

    def decode_compile_count(self) -> int:
        """Decode-step compilations so far (jit cache entries across the
        gang and slot decode steps) — continuous batching holds this at 1
        per engine; gang scheduling pays one per distinct batch shape.
        Falls back to the engine's own distinct-decode-shape count if the
        jit cache stats API is unavailable (same value: one compile per
        distinct shape signature)."""
        n = 0
        for f in (getattr(self, "_step_slots", None),
                  getattr(self, "_step_pool", None), self._step):
            if f is None:
                continue
            try:
                n += f._cache_size()
            except Exception:           # jit cache introspection moved
                return len(self._decode_shapes)
        return n


__all__ = ["Engine", "Request", "ServeError", "WFQScheduler", "sample",
           "prompt_bucket"]
