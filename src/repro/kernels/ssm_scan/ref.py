"""Pure-jnp oracle for the SSM selective scan: a literal lax.scan over
time — independent of both the kernel and the model's chunked
associative-scan path."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(dt, x, a, b, c, h0):
    """dt/x: (B, S, di); a: (di, N); b/c: (B, S, N); h0: (B, di, N).

    Returns (y: (B, S, di), h_final)."""
    dt = dt.astype(jnp.float32)
    x = x.astype(jnp.float32)
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)
    h0 = h0.astype(jnp.float32)

    def step(h, args):
        dt_t, x_t, b_t, c_t = args          # (B,di), (B,di), (B,N), (B,N)
        dA = jnp.exp(dt_t[..., None] * a)   # (B,di,N)
        h = dA * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h_final, ys = jax.lax.scan(
        step, h0,
        (dt.swapaxes(0, 1), x.swapaxes(0, 1),
         b.swapaxes(0, 1), c.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), h_final


__all__ = ["ssm_scan_ref"]
