"""Jitted public wrapper for the Pallas SSM-scan kernel: padding to chunk
multiples (state-neutral: dt=0), dtype handling, CPU interpret fallback."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.ssm_scan import ssm_scan_fwd


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssm_scan(dt, x, a, b, c, h0=None, *, chunk: int = 128,
             channel_block: int = 256, interpret: bool | None = None):
    """Selective scan. Shapes as ssm_scan_fwd; h0 defaults to zeros.

    Returns (y, h_final)."""
    if interpret is None:
        interpret = not _is_tpu()
    bsz, s, di = dt.shape
    n = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)

    ck = min(chunk, s)
    pad = (-s) % ck
    if pad:
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        dt, x, b, c = zf(dt), zf(x), zf(b), zf(c)   # dt=0 ⇒ state-neutral
    y, hf = ssm_scan_fwd(dt, x, a, b, c, h0, chunk=ck,
                         channel_block=channel_block, interpret=interpret)
    if pad:
        y = y[:, :s]
    return y, hf


__all__ = ["ssm_scan"]
