"""Pallas TPU kernel for the mamba selective-state-space scan.

    h_t = exp(dt_t ⊗ A) ⊙ h_{t-1} + (dt_t ⊙ x_t) ⊗ B_t
    y_t = (h_t · C_t)

TPU adaptation: the recurrence state h (channels × N)
lives in VMEM scratch and persists across the innermost chunk grid
dimension; channels are blocked to keep the (db, N) state VREG/VMEM
friendly; the discretization exp(dt·A) is computed in-kernel (never
materializing the (B, S, d_inner, N) dA tensor in HBM — that tensor is
what makes the XLA path memory-bound).

Grid: (batch, channel_blocks, chunks) — chunks sequential, rest parallel.
State-neutral padding: dt = 0 ⇒ dA = 1, dBx = 0 (h unchanged), so ragged
sequence lengths pad cleanly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import compat


def _ssm_kernel(dt_ref, x_ref, a_ref, b_ref, c_ref, h0_ref,
                y_ref, hf_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                 # (db, N)

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)     # (db,)
        x_t = x_ref[0, t, :].astype(jnp.float32)       # (db,)
        b_t = b_ref[0, t, :].astype(jnp.float32)       # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)       # (N,)
        dA = jnp.exp(dt_t[:, None] * a)                # (db, N)
        h = dA * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ci == nc - 1)
    def _finalize():
        hf_ref[0] = h_scr[...].astype(hf_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "channel_block", "interpret"))
def ssm_scan_fwd(dt, x, a, b, c, h0, *, chunk: int = 128,
                 channel_block: int = 256, interpret: bool = False):
    """dt/x: (B, S, di); a: (di, N); b/c: (B, S, N); h0: (B, di, N).

    Returns (y: (B, S, di), h_final: (B, di, N))."""
    bsz, s, di = dt.shape
    n = a.shape[1]
    ck = min(chunk, s)
    while s % ck:
        ck -= 1
    db = min(channel_block, di)
    while di % db:
        db -= 1
    nc, nd = s // ck, di // db

    return pl.pallas_call(
        functools.partial(_ssm_kernel, chunk=ck),
        grid=(bsz, nd, nc),
        in_specs=[
            pl.BlockSpec((1, ck, db), lambda b_, j, c_: (b_, c_, j)),   # dt
            pl.BlockSpec((1, ck, db), lambda b_, j, c_: (b_, c_, j)),   # x
            pl.BlockSpec((db, n), lambda b_, j, c_: (j, 0)),            # A
            pl.BlockSpec((1, ck, n), lambda b_, j, c_: (b_, c_, 0)),    # B
            pl.BlockSpec((1, ck, n), lambda b_, j, c_: (b_, c_, 0)),    # C
            pl.BlockSpec((1, db, n), lambda b_, j, c_: (b_, j, 0)),     # h0
        ],
        out_specs=[
            pl.BlockSpec((1, ck, db), lambda b_, j, c_: (b_, c_, j)),   # y
            pl.BlockSpec((1, db, n), lambda b_, j, c_: (b_, j, 0)),     # hf
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), dt.dtype),
            jax.ShapeDtypeStruct((bsz, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((db, n), jnp.float32)],
        compiler_params=compat.pallas_tpu_compiler_params(
            pltpu,
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dt, x, a, b, c, h0)


__all__ = ["ssm_scan_fwd"]
