"""Pure-jnp oracle for the flash-attention kernel.

Deliberately naive (materializes the full logits matrix) and written
independently of repro.layers.attention, so kernel bugs cannot hide
behind shared code.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -2.0**30


def flash_attention_ref(q, k, v, *, window: int = 0, valid_len: int | None = None,
                        causal: bool = True, logit_cap: float = 0.0):
    """q: (B, H, Sq, D); k/v: (B, KVH, Skv, D). Returns (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    if valid_len is None:
        valid_len = skv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)

    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if logit_cap > 0:
        logits = logit_cap * jnp.tanh(logits / logit_cap)

    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = kpos < valid_len
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (qpos - kpos < window)
    logits = jnp.where(mask, logits, NEG_INF)

    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p * mask  # fully-masked rows → 0 (flash convention), not uniform
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


__all__ = ["flash_attention_ref"]
