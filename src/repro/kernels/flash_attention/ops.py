"""Jitted public wrapper for the Pallas flash-attention kernel.

Accepts the framework's (B, S, H, D) activation layout, handles GQA
shapes, dynamic window / valid-length scalars, and padding of ragged
sequence lengths up to block multiples.  ``interpret=True`` (automatic on
CPU) runs the kernel body in Python for validation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, q_pos=None, k_pos=None, causal: bool = True,
                    window=None, logit_cap: float = 0.0,
                    valid_len=None, q_block: int = 256, kv_block: int = 512,
                    interpret: bool | None = None):
    """q: (B, S, H, D); k/v: (B, Skv, KVH, D) — framework layout.

    ``window``: int or traced scalar (0/None = global).
    ``valid_len``: filled KV length (decode); defaults to full."""
    if interpret is None:
        interpret = not _is_tpu()
    b, sq, h, d = q.shape
    skv = k.shape[1]
    w = jnp.asarray(0 if window is None else window, jnp.int32).reshape(())
    vl = jnp.asarray(skv if valid_len is None else valid_len,
                     jnp.int32).reshape(())
    scalars = jnp.stack([w, vl])

    qt = q.transpose(0, 2, 1, 3)        # (B, H, S, D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_fwd(qt, kt, vt, scalars, causal=causal,
                            logit_cap=logit_cap, q_block=q_block,
                            kv_block=kv_block, interpret=interpret)
    return o.transpose(0, 2, 1, 3)


__all__ = ["flash_attention"]
