"""Pallas TPU flash-attention kernel (forward).

TPU-native adaptation: the GPU flash algorithm's
shared-memory tiling becomes explicit VMEM BlockSpecs; the online-softmax
state (m, l, acc) lives in VMEM scratch that persists across the
innermost ("arbitrary") KV-block grid dimension; MXU-aligned block shapes
(multiples of 128 on the contracting/lane dims).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the first three are
parallel, the last sequential.  GQA is handled in the k/v index maps
(kv head = q head // group).  Sliding window and cache-valid length arrive
as dynamic scalars (per-layer values under a scan), so one compiled kernel
serves local and global layers; fully-masked KV blocks are skipped via
``pl.when``.

Validated in interpret mode against ``ref.py`` (pure jnp oracle); the
backward pass routes through the XLA flash custom-VJP
(repro.layers.attention) — residuals (o, lse) match.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import compat

NEG_INF = -2.0**30


def _flash_kernel(scal_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *,
                  causal: bool, logit_cap: float, scale: float,
                  q_block: int, kv_block: int):
    """One (b, h, qi, kj) grid step.

    scal_ref: (2,) int32 [window, kv_valid_len] (scalar block).
    q_ref: (1, 1, qb, d); k_ref/v_ref: (1, 1, kb, d); o_ref: (1, 1, qb, d).
    Scratch: acc (qb, d) f32; m/l (qb, 128) f32 (scalars on lane 0).
    """
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    window = scal_ref[0]
    valid_len = scal_ref[1]

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * q_block
    k_start = kj * kv_block

    # Block-level skip: past the valid length, above the causal diagonal,
    # or entirely left of the sliding window.
    run = k_start < valid_len
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + q_block - 1)
    run = jnp.logical_and(
        run,
        jnp.where(window > 0,
                  k_start + kv_block - 1 > q_start - window, True))

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)           # (qb, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (kb, d)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (qb, kb)
        if logit_cap > 0:
            logits = logit_cap * jnp.tanh(logits / logit_cap)

        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 1)
        mask = kpos < valid_len
        if causal:
            mask &= kpos <= qpos
        mask &= jnp.where(window > 0, qpos - kpos < window, True)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[:, 0]                          # (qb,)
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[:, None])          # (qb, kb)
        p = jnp.where(mask, p, 0.0)  # fully-masked rows stay 0, not uniform
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
        m_ref[:, 0] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(kj == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "logit_cap", "q_block", "kv_block",
                     "interpret"))
def flash_attention_fwd(q, k, v, scalars, *, causal: bool = True,
                        logit_cap: float = 0.0, q_block: int = 256,
                        kv_block: int = 512, interpret: bool = False):
    """q: (B, H, Sq, D); k/v: (B, KVH, Skv, D); scalars: (2,) int32
    [window (0 = none), valid_len]. Returns o: (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    qb = min(q_block, sq)
    while sq % qb:
        qb -= 1
    kb = min(kv_block, skv)
    while skv % kb:
        kb -= 1
    nq, nk = sq // qb, skv // kb
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, causal=causal, logit_cap=float(logit_cap),
        scale=scale, q_block=qb, kv_block=kb)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((2,), lambda b, h, i, j: (0,)),
            pl.BlockSpec((1, 1, qb, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kb, d), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, kb, d), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, d), jnp.float32),
            pltpu.VMEM((qb, 128), jnp.float32),
            pltpu.VMEM((qb, 128), jnp.float32),
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            pltpu,
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(scalars, q, k, v)


__all__ = ["flash_attention_fwd", "NEG_INF"]
