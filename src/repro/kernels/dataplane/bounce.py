"""Pallas TPU dataplane kernels: the mediation data-movement primitives.

The mediation pipeline's ``staged-copy`` stage and fused delay chain were
XLA-level emulations (``core/techniques.py``): real data movement and
real serial work, but shaped by what XLA happens to emit.  This module
implements the same primitives as explicit Pallas TPU kernels, so
measured-mode mediation cost is a *hardware measurement* — the DMA
engine moves the payload through a VMEM bounce buffer, and the delay is
a serial scalar chain executing on the core between the copy-in and the
copy-out, exactly where the emulated user→kernel crossing sits.

One kernel body serves both entry points:

* :func:`bounce_copy` — the zero-copy-removed bounce-buffer copy.  The
  payload is chunked; chunk DMAs HBM→VMEM are **double-buffered** over
  two scratch slots so the copy-in of chunk *i+1* overlaps the copy-out
  of chunk *i* (the overlapped copy-in/copy-out slots of a real bounce
  buffer).  Extra ``copies`` bounce the chunk VMEM→VMEM through a third
  slot — one round trip per extra pass, matching ``staged_copy``'s
  pass count.
* :func:`mediated_cost` — the fused-mediation cost kernel: the same
  copy path plus a calibrated serial delay burned *inside the kernel*
  between a chunk's copy-in and copy-out, with per-chunk cost counters
  (iters burned, copy passes) emitted as SMEM scalar outputs.  One
  launch covers a fused pipeline side's delay chain + staged copies.

Both are **bit-identical** to the emulations they replace: the payload
is only ever moved, never computed on — availability is delayed by
routing the chunk head through a select on the delay token (the same
``tie`` trick as ``core/techniques.py``, in-kernel).

``interpret=True`` (selected automatically off-TPU, pattern per
``kernels/flash_attention``) runs the kernel body — including the DMAs
and semaphores — in the Pallas interpreter for validation on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default chunk size through the VMEM bounce buffer, in elements.  At
# 4 B/elem this is a 32 KiB chunk — small enough that three slots fit
# comfortably in VMEM, large enough to amortize DMA issue overhead.
DEFAULT_CHUNK_ELEMS = 8192

# Columns of the per-chunk SMEM cost-counter output.
COST_ITERS = 0    # delay iterations burned for this chunk
COST_COPIES = 1   # bounce passes this chunk made through VMEM
NUM_COST_COLS = 2


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _burn(iters: int, seed):
    """The serial dependent fma chain from ``techniques.delay_scalar``,
    executed on the scalar core inside the kernel."""
    return jax.lax.fori_loop(0, iters,
                             lambda j, v: v * 1.0000001 + 1e-9, seed)


def _tie_slot(scratch, slot, tok):
    """Route the chunk head through a select on the delay token — the
    in-kernel mirror of ``techniques.tie``: O(1), bit-identical, and the
    copy-out cannot be reordered before the burn."""
    head = scratch[slot, 0]
    scratch[slot, 0] = jnp.where(tok == tok, head, head + 1)


def _bounce_kernel(x_hbm, o_hbm, ctr_ref, *, chunk: int, n_full: int,
                   tail: int, copies: int, iters_per_chunk: int):
    """Double-buffered bounce-buffer copy with in-kernel cost accounting.

    scratch slots 0/1 double-buffer the HBM↔VMEM chunk DMAs; slot 2 is
    the extra-pass bounce target.  ``ctr_ref`` is the (n_chunks, 2) SMEM
    per-chunk cost output."""

    def body(scratch, in_sem, out_sem, pass_sem):
        def dma_in(slot, i):
            return pltpu.make_async_copy(
                x_hbm.at[pl.ds(i * chunk, chunk)], scratch.at[slot, :chunk],
                in_sem.at[slot])

        def dma_out(slot, i):
            return pltpu.make_async_copy(
                scratch.at[slot, :chunk], o_hbm.at[pl.ds(i * chunk, chunk)],
                out_sem.at[slot])

        def extra_passes(slot, width):
            # each extra copy is one full round trip through the bounce
            # slot: VMEM slot -> slot 2 -> slot, two real data movements
            # per pass, like the roll/roll-back pair in staged_copy.
            for _ in range(copies - 1):
                d = pltpu.make_async_copy(scratch.at[slot, :width],
                                          scratch.at[2, :width], pass_sem)
                d.start()
                d.wait()
                d = pltpu.make_async_copy(scratch.at[2, :width],
                                          scratch.at[slot, :width], pass_sem)
                d.start()
                d.wait()

        if n_full:
            dma_in(0, 0).start()

            def loop(i, _):
                slot = i % 2

                @pl.when(i + 1 < n_full)
                def _prefetch():
                    dma_in((i + 1) % 2, i + 1).start()

                dma_in(slot, i).wait()
                extra_passes(slot, chunk)
                tok = _burn(iters_per_chunk, jnp.float32(1.0))
                live = (tok == tok).astype(jnp.int32)
                _tie_slot(scratch, slot, tok)
                ctr_ref[i, COST_ITERS] = iters_per_chunk * live
                ctr_ref[i, COST_COPIES] = copies
                out = dma_out(slot, i)
                out.start()
                out.wait()
                return 0

            jax.lax.fori_loop(0, n_full, loop, 0)

        if tail:
            # the ragged tail chunk rides through slot 0 after the
            # double-buffered full chunks have drained
            d = pltpu.make_async_copy(
                x_hbm.at[pl.ds(n_full * chunk, tail)],
                scratch.at[0, :tail], in_sem.at[0])
            d.start()
            d.wait()
            extra_passes(0, tail)
            tok = _burn(iters_per_chunk, jnp.float32(1.0))
            live = (tok == tok).astype(jnp.int32)
            _tie_slot(scratch, 0, tok)
            ctr_ref[n_full, COST_ITERS] = iters_per_chunk * live
            ctr_ref[n_full, COST_COPIES] = copies
            d = pltpu.make_async_copy(
                scratch.at[0, :tail],
                o_hbm.at[pl.ds(n_full * chunk, tail)], out_sem.at[0])
            d.start()
            d.wait()

    pl.run_scoped(
        body,
        scratch=pltpu.VMEM((3, chunk), x_hbm.dtype),
        in_sem=pltpu.SemaphoreType.DMA((2,)),
        out_sem=pltpu.SemaphoreType.DMA((2,)),
        pass_sem=pltpu.SemaphoreType.DMA(()),
    )


@functools.partial(
    jax.jit,
    static_argnames=("copies", "delay_iters", "chunk_elems", "interpret"))
def _bounce_fwd(flat, *, copies: int, delay_iters: int, chunk_elems: int,
                interpret: bool):
    """Launch the bounce kernel over a flat payload.  Returns
    ``(out, counters)`` with counters ``(n_chunks, 2)`` int32 from SMEM."""
    n = flat.shape[0]
    chunk = max(1, min(chunk_elems, n))
    n_full, tail = divmod(n, chunk)
    n_chunks = n_full + (1 if tail else 0)
    # total delay split evenly across chunks, rounded up: the kernel
    # burns at least the requested iterations (counters report actuals).
    iters_per_chunk = -(-delay_iters // n_chunks) if delay_iters > 0 else 0
    kernel = functools.partial(
        _bounce_kernel, chunk=chunk, n_full=n_full, tail=tail,
        copies=copies, iters_per_chunk=iters_per_chunk)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=(pl.BlockSpec(memory_space=pltpu.ANY),
                   pl.BlockSpec(memory_space=pltpu.SMEM)),
        out_shape=(jax.ShapeDtypeStruct((n,), flat.dtype),
                   jax.ShapeDtypeStruct((n_chunks, NUM_COST_COLS),
                                        jnp.int32)),
        interpret=interpret,
    )(flat)


def _launch(x, *, copies: int, delay_iters: int, chunk_elems: int,
            interpret: bool | None):
    if interpret is None:
        interpret = not _is_tpu()
    flat = x.reshape(-1)
    out, ctrs = _bounce_fwd(flat, copies=int(copies),
                            delay_iters=int(delay_iters),
                            chunk_elems=int(chunk_elems),
                            interpret=bool(interpret))
    return out.reshape(x.shape), ctrs


def bounce_copy(x: jax.Array, copies: int = 1, *,
                chunk_elems: int = DEFAULT_CHUNK_ELEMS,
                interpret: bool | None = None) -> jax.Array:
    """``copies`` real bounce-buffer passes of ``x`` through VMEM.

    Drop-in for ``techniques.staged_copy``: bit-identical output, but
    the copies are explicit double-buffered DMA transfers instead of an
    XLA roll/barrier emulation.  ``copies <= 0`` is the identity."""
    if copies <= 0 or x.size == 0:
        return x
    out, _ = _launch(x, copies=copies, delay_iters=0,
                     chunk_elems=chunk_elems, interpret=interpret)
    return out


def kernel_cost_totals(nelems: int, delay_iters: int, copies: int = 0,
                       chunk_elems: int = DEFAULT_CHUNK_ELEMS
                       ) -> tuple[int, int]:
    """Static ``(total_iters, total_copy_passes)`` the cost kernel's SMEM
    counters sum to for a payload of ``nelems`` elements — the exact
    chunk split of :func:`mediated_cost` (even per-chunk delay split,
    rounded up; ``copies`` passes per chunk), mirrored host-side.

    The fused mediation pipeline uses this to bump the tenant
    ``kernel_iters``/``kernel_copies`` counters identically whether the
    cost ran as the Pallas kernel or the XLA emulation, keeping reports
    bit-identical across backends (tests/test_dataplane_kernels.py)."""
    if (delay_iters <= 0 and copies <= 0) or nelems <= 0:
        return 0, 0
    chunk = max(1, min(chunk_elems, nelems))
    n_full, tail = divmod(nelems, chunk)
    n_chunks = n_full + (1 if tail else 0)
    iters_per_chunk = -(-delay_iters // n_chunks) if delay_iters > 0 else 0
    return iters_per_chunk * n_chunks, copies * n_chunks


def mediated_cost(x: jax.Array, delay_iters: int, copies: int = 0, *,
                  chunk_elems: int = DEFAULT_CHUNK_ELEMS,
                  interpret: bool | None = None):
    """One kernel launch covering a fused mediation side's cost: burn
    ``delay_iters`` of serial work in-kernel and make ``copies`` bounce
    passes, returning ``(out, counters)``.

    ``out`` is bit-identical to ``x`` (``delay_chain`` tie semantics:
    availability is delayed, values never touched).  ``counters`` is the
    per-chunk ``(n_chunks, 2)`` int32 SMEM cost output — column
    ``COST_ITERS`` sums to at least ``delay_iters`` (even split, rounded
    up), column ``COST_COPIES`` is the pass count per chunk."""
    if (delay_iters <= 0 and copies <= 0) or x.size == 0:
        return x, jnp.zeros((1, NUM_COST_COLS), jnp.int32)
    return _launch(x, copies=copies, delay_iters=delay_iters,
                   chunk_elems=chunk_elems, interpret=interpret)


__all__ = ["bounce_copy", "mediated_cost", "kernel_cost_totals",
           "DEFAULT_CHUNK_ELEMS",
           "COST_ITERS", "COST_COPIES", "NUM_COST_COLS"]
