"""Backend selection + calibration for the Pallas dataplane kernels.

The mediation pipeline asks this module two questions:

* :func:`use_pallas_dataplane` — should this dataplane run the real
  Pallas kernels?  ``"auto"`` (the default) says yes only on TPU, where
  the kernels are hardware measurements; off-TPU the XLA emulations are
  both faster and what the interpret-mode tests validate against.
  ``"on"`` forces the kernels everywhere (interpret mode off-TPU — the
  bit-equivalence test path); ``"off"`` keeps the XLA emulation.

* :func:`kernel_iters_for_ns` — how many *in-kernel* delay iterations
  equal a requested wall-clock cost.  The scalar-core fma chain inside
  a Pallas kernel does not retire at the same rate as the XLA
  ``delay_chain`` loop, so reusing ``techniques.calibrate()``'s slope
  would silently rescale every emulated cost when the kernels switch
  on.  :func:`kernel_calibrate` measures the in-kernel slope once per
  process per backend (same memoization discipline as
  ``techniques.calibrate``); off-TPU it falls back to the XLA slope so
  interpret-mode runs keep iteration counts comparable with the
  emulation they are checked against.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import techniques as tech
from repro.kernels.dataplane.bounce import bounce_copy, mediated_cost


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_pallas_dataplane(setting: str | bool) -> bool:
    """Resolve a ``DataplaneConfig.pallas_dataplane`` setting to a bool."""
    if isinstance(setting, bool):
        return setting
    if setting == "auto":
        return _is_tpu()
    if setting in ("on", "true", "1"):
        return True
    if setting in ("off", "false", "0"):
        return False
    raise ValueError(
        f"pallas_dataplane must be auto/on/off, got {setting!r}")


_KERNEL_CALIBRATION: dict[str, float] = {}   # backend -> ns per iter


def kernel_calibrate(probe_iters: int = 200_000) -> float:
    """ns per in-kernel delay iteration on this backend (memoized).

    Only measured on TPU, where the kernel path is live; elsewhere the
    XLA slope is reused (interpret-mode kernels are correctness
    artifacts, not timing sources)."""
    backend = jax.default_backend()
    hit = _KERNEL_CALIBRATION.get(backend)
    if hit is not None:
        return hit
    if not _is_tpu():
        ns = tech.calibrate()
    else:
        x = jnp.zeros((256,), jnp.float32)
        f = jax.jit(lambda v: mediated_cost(v, probe_iters)[0])
        f(x).block_until_ready()          # compile
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            f(x).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        ns = best * 1e9 / probe_iters
    _KERNEL_CALIBRATION[backend] = ns
    return ns


def kernel_iters_for_ns(ns: float) -> int:
    """Requested emulated cost (ns) -> in-kernel delay iterations."""
    if ns <= 0:
        return 0
    return max(1, int(ns / kernel_calibrate()))


def rescale_iters(xla_iters: int) -> int:
    """Convert a stage's XLA-calibrated iteration count to the in-kernel
    count burning the same wall-clock time.  Identity off-TPU (both
    slopes read the same calibration)."""
    if xla_iters <= 0:
        return 0
    ratio = tech.calibrate() / kernel_calibrate()
    return max(1, int(round(xla_iters * ratio)))


__all__ = ["bounce_copy", "mediated_cost", "use_pallas_dataplane",
           "kernel_calibrate", "kernel_iters_for_ns", "rescale_iters"]
