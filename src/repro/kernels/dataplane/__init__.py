"""Pallas TPU dataplane kernels — the mediation data-movement
primitives as real hardware kernels (docs/kernels.md).

* ``bounce.py`` — double-buffered bounce-buffer copy + in-kernel cost
  accounting kernel (one shared kernel body, two entry points).
* ``ops.py`` — backend selection (``pallas_dataplane`` auto/on/off)
  and in-kernel delay calibration.

The XLA oracles these kernels are validated against live in
``core/techniques.py`` (``staged_copy`` / ``delay_chain``); the
interpret-mode bit-equivalence tests are
``tests/test_dataplane_kernels.py``.
"""

from repro.kernels.dataplane.bounce import (
    COST_COPIES,
    COST_ITERS,
    DEFAULT_CHUNK_ELEMS,
    NUM_COST_COLS,
    bounce_copy,
    kernel_cost_totals,
    mediated_cost,
)
from repro.kernels.dataplane.ops import (
    kernel_calibrate,
    kernel_iters_for_ns,
    rescale_iters,
    use_pallas_dataplane,
)

__all__ = [
    "bounce_copy", "mediated_cost", "kernel_cost_totals",
    "use_pallas_dataplane",
    "kernel_calibrate", "kernel_iters_for_ns", "rescale_iters",
    "DEFAULT_CHUNK_ELEMS", "COST_ITERS", "COST_COPIES", "NUM_COST_COLS",
]
