"""Training launcher: ``python -m repro.launch.train --arch gemma3-1b
[--mode cord] [--timeline] [--elastic] [key=value overrides...]``

Runs the explicit-DP trainer on the local CPU mesh (all host devices) with
the fault-tolerant runtime; production meshes use the same RunConfig with
make_production_mesh on real hardware.

``--timeline`` switches the step to ``runtime_accounting=True`` (the
per-tenant runtime-state pytree threaded through the gradient sync) and
snapshots ``dp.runtime_report`` into a
:class:`~repro.core.obs.CounterTimeline` after each step — host-side
reads between steps only, so traced results are bit-identical to a run
without the flag (tests/test_obs.py).  The run writes the
schema-versioned artifact ``runs/<arch>_timeline.json`` and prints
per-tenant sparkline panels (docs/observability.md).
``--timeline-sink PATH`` additionally streams every snapshot/event to a
JSONL file as the run progresses; ``--timeline-rotate BYTES`` seals the
sink into ``PATH.1..N`` segments once each passes the size budget, so a
long run never grows one unbounded file
(``CounterTimeline.read_rotated`` stitches the segments back together —
docs/observability.md).

``--elastic`` (implies ``--timeline``) closes the control loop
(docs/elasticity.md): an :class:`~repro.runtime.elastic.ElasticController`
watches the timeline's rate series against ``ElasticConfig`` thresholds
with hysteresis, and on a sustained over-threshold signal remeshes the
live TrainState onto a shrunken mesh slice mid-run, rebuilding the
dataplane and the jitted step against the new mesh and recording
``trigger``/``remesh`` events into the timeline artifact.  Configure via
``elastic.*`` overrides, e.g. ``elastic.thresholds=denied_pct=50
elastic.sustain=3 elastic.meter_quota_bytes=1000000``.
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import apply_overrides, get_model_config
from repro.configs.base import (
    DataplaneConfig,
    ElasticConfig,
    ObsConfig,
    RunConfig,
    TrainConfig,
)
from repro.core import CounterTimeline, Dataplane
from repro.core.policies import QuotaPolicy, TelemetryPolicy
from repro.data import DataConfig, ShardedLoader, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.runtime import ElasticController, run_loop
from repro.train import init_state, make_explicit_dp_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--mode", default="cord",
                    choices=["bypass", "cord", "socket"])
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--timeline", action="store_true",
                    help="thread per-tenant runtime accounting through the "
                         "step and write runs/<arch>_timeline.json")
    ap.add_argument("--timeline-sink", default=None, metavar="PATH",
                    help="stream timeline snapshots/events to a JSONL file "
                         "as the run progresses (docs/observability.md)")
    ap.add_argument("--timeline-rotate", type=int, default=0,
                    metavar="BYTES",
                    help="rotate the JSONL sink into PATH.1..N segments "
                         "once each passes this many bytes (0 = never)")
    ap.add_argument("--elastic", action="store_true",
                    help="watch the timeline rate series and remesh onto a "
                         "shrunken mesh slice on sustained over-threshold "
                         "windows (implies --timeline; docs/elasticity.md)")
    ap.add_argument("overrides", nargs="*", default=[])
    args = ap.parse_args()

    cfg = get_model_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    train = TrainConfig()
    train = apply_overrides(train, [o for o in args.overrides
                                    if not o.startswith(("model.",
                                                         "elastic."))])
    elastic = apply_overrides(
        ElasticConfig(enabled=args.elastic),
        [o[len("elastic."):] for o in args.overrides
         if o.startswith("elastic.")])
    obs = ObsConfig(timeline=args.timeline or elastic.enabled
                    or bool(args.timeline_sink))
    run = RunConfig(train=train, obs=obs, elastic=elastic)

    mesh = make_local_mesh()
    policies = None
    if elastic.enabled and elastic.meter_quota_bytes:
        # observe-only metering: runtime traffic over the budget marks the
        # tenant's `denied` counter — the watcher's default trigger signal
        policies = [TelemetryPolicy(),
                    QuotaPolicy(hard=False,
                                limits={"default": elastic.meter_quota_bytes})]

    ctx = {"dp": Dataplane(DataplaneConfig(mode=args.mode), mesh=mesh,
                           policies=policies)}
    ctx["step"] = make_explicit_dp_step(model, run, ctx["dp"], axis="data",
                                        runtime_accounting=obs.timeline)
    state = init_state(model, jax.random.PRNGKey(train.seed),
                       compression=train.grad_compression)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                seq_len=train.seq_len,
                                global_batch=train.global_batch,
                                seed=train.seed))
    loader = ShardedLoader(ds)

    timeline = CounterTimeline(source=f"train/{args.arch}",
                               sink=args.timeline_sink,
                               rotate_bytes=args.timeline_rotate
                               if args.timeline_sink else 0) \
        if obs.timeline else None
    controller = ElasticController(elastic, timeline, mesh) \
        if elastic.enabled else None
    rt = {"state": ctx["dp"].runtime_init(), "step": 0} \
        if obs.timeline else None

    def rebuild(new_mesh) -> None:
        """Recompile the dataplane + step against the shrunken mesh,
        keeping the policy objects (cumulative trace-time metering)."""
        ctx["dp"] = Dataplane(DataplaneConfig(mode=args.mode), mesh=new_mesh,
                              policies=ctx["dp"].policies)
        ctx["step"] = make_explicit_dp_step(model, run, ctx["dp"],
                                            axis="data",
                                            runtime_accounting=True)

    def wrap(s, b):
        b = {k: jnp.asarray(v) for k, v in b.items()}
        if rt is None:
            return ctx["step"](s, b)
        s, metrics, rt["state"] = ctx["step"](s, b, rt["state"])
        rt["step"] += 1
        if timeline is not None and rt["step"] % obs.every == 0:
            # host-side read of the accumulated counter block, strictly
            # between steps — the traced computation never sees the obs
            gauges = controller.watcher.gauges() if controller else None
            timeline.snapshot(rt["step"],
                              ctx["dp"].runtime_report(rt["state"]),
                              gauges=gauges)
            if controller is not None:
                s, moved = controller.drive(s, rt["step"])
                if moved:
                    rebuild(controller.mesh)
                    # runtime counters survive the move as host arrays
                    rt["state"] = jax.tree.map(
                        lambda x: np.asarray(x),
                        jax.device_get(rt["state"]))
                    print(f"[elastic] remeshed onto "
                          f"{controller.mesh.devices.shape} at step "
                          f"{rt['step']}")
        return s, metrics

    state, report = run_loop(
        wrap, state, loader, steps=train.steps,
        ckpt_dir=train.checkpoint_dir if train.checkpoint_every else None,
        checkpoint_every=train.checkpoint_every,
        async_ckpt=train.async_checkpoint, log_every=train.log_every)
    print(f"done: {report.steps_run} steps, "
          f"final loss {report.metrics[-1]['loss']:.4f}")
    print(ctx["dp"].telemetry.report())
    if timeline is not None:
        path = timeline.save(os.path.join(obs.out_dir,
                                          f"{args.arch}_timeline.json"))
        timeline.close()
        print(f"timeline artifact: {path} ({len(timeline.samples)} samples, "
              f"{len(timeline.events)} events)")
        for ev in timeline.events:
            print(f"  event step {ev['step']:4d} {ev['kind']:8s} "
                  f"{ev['tenant']}: {ev['detail']}")
        if obs.panel:
            print(timeline.panel(width=obs.spark_width))


if __name__ == "__main__":
    main()
