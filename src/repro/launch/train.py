"""Training launcher: ``python -m repro.launch.train --arch gemma3-1b
[--mode cord] [--timeline] [key=value overrides...]``

Runs the explicit-DP trainer on the local CPU mesh (all host devices) with
the fault-tolerant runtime; production meshes use the same RunConfig with
make_production_mesh on real hardware.

``--timeline`` switches the step to ``runtime_accounting=True`` (the
per-tenant runtime-state pytree threaded through the gradient sync) and
snapshots ``dp.runtime_report`` into a
:class:`~repro.core.obs.CounterTimeline` after each step — host-side
reads between steps only, so traced results are bit-identical to a run
without the flag (tests/test_obs.py).  The run writes the
schema-versioned artifact ``runs/<arch>_timeline.json`` and prints
per-tenant sparkline panels (docs/observability.md).
"""

import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import apply_overrides, get_model_config
from repro.configs.base import DataplaneConfig, ObsConfig, RunConfig, TrainConfig
from repro.core import CounterTimeline, Dataplane
from repro.data import DataConfig, ShardedLoader, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.runtime import run_loop
from repro.train import init_state, make_explicit_dp_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--mode", default="cord",
                    choices=["bypass", "cord", "socket"])
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--timeline", action="store_true",
                    help="thread per-tenant runtime accounting through the "
                         "step and write runs/<arch>_timeline.json")
    ap.add_argument("overrides", nargs="*", default=[])
    args = ap.parse_args()

    cfg = get_model_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    train = TrainConfig()
    train = apply_overrides(train, [o for o in args.overrides
                                    if not o.startswith("model.")])
    obs = ObsConfig(timeline=args.timeline)
    run = RunConfig(train=train, obs=obs)

    mesh = make_local_mesh()
    dp = Dataplane(DataplaneConfig(mode=args.mode), mesh=mesh)
    step = make_explicit_dp_step(model, run, dp, axis="data",
                                 runtime_accounting=obs.timeline)
    state = init_state(model, jax.random.PRNGKey(train.seed),
                       compression=train.grad_compression)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                seq_len=train.seq_len,
                                global_batch=train.global_batch,
                                seed=train.seed))
    loader = ShardedLoader(ds)

    timeline = CounterTimeline(source=f"train/{args.arch}") \
        if obs.timeline else None
    rt = {"state": dp.runtime_init(), "step": 0} if obs.timeline else None

    def wrap(s, b):
        b = {k: jnp.asarray(v) for k, v in b.items()}
        if rt is None:
            return step(s, b)
        s, metrics, rt["state"] = step(s, b, rt["state"])
        rt["step"] += 1
        if timeline is not None and rt["step"] % obs.every == 0:
            # host-side read of the accumulated counter block, strictly
            # between steps — the traced computation never sees the obs
            timeline.snapshot(rt["step"], dp.runtime_report(rt["state"]))
        return s, metrics

    state, report = run_loop(
        wrap, state, loader, steps=train.steps,
        ckpt_dir=train.checkpoint_dir if train.checkpoint_every else None,
        checkpoint_every=train.checkpoint_every,
        async_ckpt=train.async_checkpoint, log_every=train.log_every)
    print(f"done: {report.steps_run} steps, "
          f"final loss {report.metrics[-1]['loss']:.4f}")
    print(dp.telemetry.report())
    if timeline is not None:
        path = timeline.save(os.path.join(obs.out_dir,
                                          f"{args.arch}_timeline.json"))
        print(f"timeline artifact: {path} ({len(timeline.samples)} samples)")
        if obs.panel:
            print(timeline.panel(width=obs.spark_width))


if __name__ == "__main__":
    main()
