"""Training launcher: ``python -m repro.launch.train --arch gemma3-1b
[--mode cord] [--steps 100] [key=value overrides...]``

Runs the explicit-DP trainer on the local CPU mesh (all host devices) with
the fault-tolerant runtime; production meshes use the same RunConfig with
make_production_mesh on real hardware.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import apply_overrides, get_model_config
from repro.configs.base import DataplaneConfig, RunConfig, TrainConfig
from repro.core import Dataplane
from repro.data import DataConfig, ShardedLoader, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.runtime import run_loop
from repro.train import init_state, make_explicit_dp_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--mode", default="cord",
                    choices=["bypass", "cord", "socket"])
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("overrides", nargs="*", default=[])
    args = ap.parse_args()

    cfg = get_model_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    train = TrainConfig()
    train = apply_overrides(train, [o for o in args.overrides
                                    if not o.startswith("model.")])
    run = RunConfig(train=train)

    mesh = make_local_mesh()
    dp = Dataplane(DataplaneConfig(mode=args.mode), mesh=mesh)
    step = make_explicit_dp_step(model, run, dp, axis="data")
    state = init_state(model, jax.random.PRNGKey(train.seed),
                       compression=train.grad_compression)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                seq_len=train.seq_len,
                                global_batch=train.global_batch,
                                seed=train.seed))
    loader = ShardedLoader(ds)

    def wrap(s, b):
        return step(s, {k: jnp.asarray(v) for k, v in b.items()})

    state, report = run_loop(
        wrap, state, loader, steps=train.steps,
        ckpt_dir=train.checkpoint_dir if train.checkpoint_every else None,
        checkpoint_every=train.checkpoint_every,
        async_ckpt=train.async_checkpoint, log_every=train.log_every)
    print(f"done: {report.steps_run} steps, "
          f"final loss {report.metrics[-1]['loss']:.4f}")
    print(dp.telemetry.report())


if __name__ == "__main__":
    main()
