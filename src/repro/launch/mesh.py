"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  Single pod: 16×16 = 256 chips
(v5e pod); multi-pod: 2×16×16 = 512 chips with a leading "pod" axis (DP
across pods over DCN, TP kept inside the pod over ICI).
"""

from __future__ import annotations

import jax

from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_local_mesh(n: int | None = None, model: int = 1):
    """CPU-device mesh for measured runs/tests: (data = n/model, model)."""
    devs = jax.devices()
    n = n or len(devs)
    return compat.make_mesh((n // model, model), ("data", "model"),
                            devices=devs[:n])


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


__all__ = ["make_production_mesh", "make_local_mesh", "mesh_axis_sizes"]
