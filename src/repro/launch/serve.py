"""Serving launcher: batched requests through the Engine.

``python -m repro.launch.serve --arch gemma3-1b --requests 8``
"""

import argparse

import jax
import numpy as np

from repro.configs import get_model_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_model_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, cfg,
                 ServeConfig(max_batch=4, max_new_tokens=args.max_new_tokens),
                 eos_id=-1)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6 + i % 5))
            for i in range(args.requests)]
    import time
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
