"""Serving launcher: batched requests through the Engine.

``python -m repro.launch.serve --arch gemma3-1b --requests 8
[--scheduler continuous|gang] [--block-size 16] [--n-blocks N]
[--prefill-chunk 512] [--timeline]``

``--block-size`` switches the continuous engine to the paged KV block
pool (docs/serving.md); ``--n-blocks`` sizes the pool (0 = the stripe
layout's token capacity); ``--prefill-chunk`` bounds how many prompt
tokens one engine tick may prefill (0 disables chunking).

``--timeline`` attaches a :class:`~repro.core.obs.CounterTimeline` to the
engine: one per-tick snapshot of the serve counter block (WFQ grants,
served tokens, slot occupancy, deferrals) plus active-slot / queue-depth
gauges, written to ``runs/<arch>_serve_timeline.json`` with per-tenant
sparkline panels on the console (docs/observability.md).

``--elastic`` (implies ``--timeline``) closes the serve-side control
loop (docs/elasticity.md): a
:class:`~repro.runtime.elastic.ServeElasticController` rides the
engine's ``on_tick`` hook, watching the timeline rate series — by
default ``throttled_pct`` (admission deferrals), since decode traffic is
slot-bound — and on a sustained over-threshold signal shrinks the
per-tenant slot budget (``Engine.set_slot_budget``, enforced by
preemption with exact temp-0 resume) instead of remeshing; the release
arm restores the pre-shrink budget after sustained quiet.  Configure via
``elastic.*`` overrides, e.g. ``elastic.thresholds=throttled_pct=50
elastic.release_thresholds=throttled_pct=10 elastic.sustain=2``.
"""

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import apply_overrides, get_model_config
from repro.configs.base import ElasticConfig, ObsConfig, ServeConfig
from repro.core import CounterTimeline
from repro.models import build_model
from repro.runtime import ServeElasticController
from repro.serve import Engine, Request, prompt_bucket


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--scheduler", default="continuous",
                    choices=("continuous", "gang"))
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=0,
                    help="paged KV: pool block size in tokens (0 = legacy "
                         "fixed stripe; 16 is a good starting point)")
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="paged KV: usable pool blocks (0 = auto: the "
                         "stripe layout's token capacity)")
    ap.add_argument("--prefill-chunk", type=int, default=512,
                    help="chunked prefill: tokens per prefill tick "
                         "(power of two >= 8; 0 disables chunking)")
    ap.add_argument("--timeline", action="store_true",
                    help="per-tick engine snapshots into "
                         "runs/<arch>_serve_timeline.json")
    ap.add_argument("--elastic", action="store_true",
                    help="watch the serve timeline and move the per-tenant "
                         "slot budget down/up on sustained threshold "
                         "crossings (implies --timeline; docs/elasticity.md)")
    ap.add_argument("overrides", nargs="*", default=[],
                    help="elastic.* key=value overrides")
    args = ap.parse_args()

    cfg = get_model_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # cache sized for the longest prompt bucket (prompts are 6..10 tokens)
    # plus the requested decode budget
    kv_len = prompt_bucket(10) + args.max_new_tokens + 1
    kv_len = max(kv_len, 128)
    if args.block_size > 0:              # keep block_size | kv_cache_len
        kv_len = -(-kv_len // args.block_size) * args.block_size
    # serve-appropriate elastic defaults: deferral share is the decode
    # pressure signal (denied never moves on the serve counter block)
    elastic = apply_overrides(
        ElasticConfig(enabled=args.elastic,
                      thresholds=("throttled_pct=50",),
                      release_thresholds=("throttled_pct=10",)),
        [o[len("elastic."):] for o in args.overrides
         if o.startswith("elastic.")])
    obs = ObsConfig(timeline=args.timeline or elastic.enabled)
    timeline = CounterTimeline(source=f"serve/{args.arch}") \
        if obs.timeline else None
    eng = Engine(model, params, cfg,
                 ServeConfig(max_batch=args.max_batch,
                             max_new_tokens=args.max_new_tokens,
                             kv_cache_len=kv_len,
                             scheduler=args.scheduler,
                             block_size=args.block_size,
                             n_blocks=args.n_blocks,
                             prefill_chunk=args.prefill_chunk),
                 eos_id=-1, obs=timeline, obs_every=obs.every)
    controller = None
    if elastic.enabled:
        controller = ServeElasticController(elastic, timeline, eng)
        eng.on_tick = controller.tick
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6 + i % 5),
                    max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    ttft = [r.t_first - t0 for r in done if r.t_first is not None]
    print(f"served {len(done)} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s, {args.scheduler} scheduler, "
          f"{eng.decode_compile_count()} decode compiles, "
          f"mean TTFT {1e3*sum(ttft)/max(len(ttft),1):.0f} ms)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")
    for tenant, stats in eng.tenant_report().items():
        print(f"  tenant {tenant}: {stats}")
    if controller is not None:
        print(f"elastic: {controller.shrinks} budget shrinks, "
              f"{controller.grows} grow-backs "
              f"(slot budget now {eng.slot_budget()})")
    if timeline is not None:
        path = timeline.save(os.path.join(
            obs.out_dir, f"{args.arch}_serve_timeline.json"))
        print(f"timeline artifact: {path} "
              f"({len(timeline.samples)} ticks, "
              f"{len(timeline.events)} events)")
        for ev in timeline.events:
            print(f"  event step {ev['step']:4d} {ev['kind']:8s} "
                  f"{ev['tenant']}: {ev['detail']}")
        if obs.panel:
            print(timeline.panel(width=obs.spark_width))


if __name__ == "__main__":
    main()
