import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init), which is why they precede the docstring and
# why this module has no `from __future__ import annotations`.

DOC = """Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell and extract the roofline raw terms from the compiled artifact.

For each cell this produces (and caches to JSON):
  * ``memory_analysis``  — per-device bytes (proves the cell fits HBM)
  * ``cost_analysis``    — per-device HLO FLOPs / bytes accessed
  * ``collectives``      — bytes per collective kind, parsed from the
    post-SPMD compiled HLO (the roofline collective term)
  * the dataplane's logical telemetry (what the mediation layer saw)

Usage:
  python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out runs/dryrun]
"""

import argparse
import gzip
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, apply_overrides, cells, get_model_config
from repro.configs.base import DataplaneConfig, ModelConfig, RunConfig, ShapeConfig, TrainConfig
from repro.core.dataplane import Dataplane
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.models import build_model, input_specs
from repro.parallel.sharding import (
    activation_rules,
    batch_specs,
    cache_spec_tree,
    filter_spec,
    param_specs,
)
from repro.train.step import TrainState, make_train_step

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DT_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
             "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
             "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_COLL_RE = re.compile(
    r"=\s+((?:\(|\w+\[)[^=]*?)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start|-done)?\(([^)]*)\)")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from post-partitioning HLO.

    ``-done`` ops are skipped (their ``-start`` twin carries the operands).
    Returns {kind: {"ops": n, "bytes": operand_bytes}}."""
    out: dict[str, dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        result_txt, kind, args_txt = m.groups()
        operand_bytes = _shape_bytes(args_txt)
        if operand_bytes == 0:
            # operand types not printed; fall back to the result shape
            operand_bytes = _shape_bytes(result_txt)
        d = out.setdefault(kind, {"ops": 0, "bytes": 0})
        d["ops"] += 1
        d["bytes"] += operand_bytes
    return out


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def _abstract_params(model, dtype=None):
    tree = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    if dtype is None:
        return tree
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, dtype if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype),
        tree)


def _to_sh(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _sharded_bytes(tree, spec_tree, sizes) -> int:
    """Semantic per-device bytes of a pytree under the given specs.

    memory_analysis() on the CPU backend is inflated by f32 upcasts of
    bf16 dot operands (hoisted whole-stack converts) that do not exist on
    TPU — this gives the TPU-real resident footprint."""
    from repro.parallel.sharding import _axis_size
    total = 0
    leaves = jax.tree.leaves(tree)
    specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(leaves, specs):
        ways = 1
        for ax in tuple(spec):
            ways *= _axis_size(ax, sizes)
        total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize \
            // max(ways, 1)
    return total


def build_cell(arch: str, shape: ShapeConfig, *, multi_pod: bool,
               overrides: list[str] | None = None,
               remat: str = "full", seq_shard_prefill: bool = True):
    """Returns (jitted_fn, abstract_args, dp, meta)."""
    cfg = get_model_config(arch)
    if overrides:
        cfg = apply_overrides(cfg, [o for o in overrides
                                    if o.startswith(tuple(
                                        f.name for f in
                                        __import__("dataclasses").fields(ModelConfig)))])
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = mesh_axis_sizes(mesh)
    rules = activation_rules(cfg, shape, multi_pod=multi_pod,
                             seq_shard_prefill=seq_shard_prefill)
    dp = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh, rules=rules)
    big = cfg.param_count() > 20e9
    meta = {"arch": arch, "shape": shape.name, "kind": shape.kind,
            "multi_pod": multi_pod, "params": cfg.param_count(),
            "active_params": cfg.active_param_count(), "rules": {
                k: (list(v) if isinstance(v, tuple) else v)
                for k, v in rules.items()}}

    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        # Gradient accumulation sized so the remat-saved activation stack
        # (L, B_local, S, D) stays under ~4.5 GB/device; bf16 master weights
        # for >100B archs.
        data_ways = sizes.get("data", 1) * sizes.get("pod", 1)
        s_total = shape.seq_len + (cfg.num_patches if cfg.family == "vlm" else 0)
        stack_per_seq = (cfg.num_layers + cfg.encoder_layers) * s_total \
            * cfg.d_model * 2
        mb_local = max(1, int(4.5e9 // max(stack_per_seq, 1)))
        mb_global = min(mb_local * data_ways, shape.global_batch)
        while shape.global_batch % mb_global:
            mb_global -= 1
        microbatch = 0 if mb_global >= shape.global_batch else mb_global
        huge = cfg.param_count() > 100e9
        run = RunConfig(train=TrainConfig(
            remat=remat, microbatch=microbatch,
            opt_dtype="bfloat16" if big else "float32"))
        meta["microbatch"] = microbatch
        meta["param_dtype"] = "bfloat16" if huge else "float32"
        _, sharded_jit = make_train_step(model, run, dp, fsdp=True)
        params_abs = _abstract_params(
            model, dtype=jnp.bfloat16 if huge else None)
        from repro.optim.adamw import adamw_init
        state_abs = jax.eval_shape(
            lambda p: TrainState(params=p,
                                 opt=adamw_init(p, run.train.opt_dtype),
                                 step=jnp.zeros((), jnp.int32), err=None),
            params_abs)
        jitted = sharded_jit(state_abs, specs)
        from repro.train.step import make_train_step as _m  # noqa: F401
        pspec_t = param_specs(params_abs, fsdp=True, mesh_sizes=sizes)
        meta["state_bytes_per_device"] = (
            _sharded_bytes(params_abs, pspec_t, sizes)
            + 2 * _sharded_bytes(state_abs.opt.mu, pspec_t, sizes))
        meta["remat_stack_bytes_per_device"] = int(
            stack_per_seq * max(mb_local, 1))
        return jitted, (state_abs, specs), dp, meta

    params_abs = _abstract_params(model, dtype=jnp.bfloat16)
    # Serving: weights statically resident — dense archs shard over model
    # only; MoE archs get 2D expert sharding (no FSDP regathers).
    pspec = param_specs(params_abs, fsdp=False, mesh_sizes=sizes,
                        serve_moe_2d=(cfg.family == "moe"))
    psh = _to_sh(mesh, pspec)

    meta["params_bytes_per_device"] = _sharded_bytes(params_abs, pspec, sizes)

    if shape.kind == "prefill":
        cache_len = shape.seq_len
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, cache_len))
        cspec = cache_spec_tree(cache_abs, rules, sizes)
        meta["cache_bytes_per_device"] = _sharded_bytes(cache_abs, cspec, sizes)
        csh = _to_sh(mesh, cspec)
        bsh = _to_sh(mesh, batch_specs(specs, rules, sizes))

        def prefill_fn(params, batch, cache):
            return model.prefill(params, batch, cache, dp=dp)

        jitted = jax.jit(prefill_fn, in_shardings=(psh, bsh, csh),
                         out_shardings=(None, csh), donate_argnums=(2,))
        return jitted, (params_abs, specs, cache_abs), dp, meta

    # decode
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    cspec = cache_spec_tree(cache_abs, rules, sizes)
    meta["cache_bytes_per_device"] = _sharded_bytes(cache_abs, cspec, sizes)
    csh = _to_sh(mesh, cspec)
    token_abs = specs["token"]
    tsh = NamedSharding(mesh, filter_spec(
        P(rules.get("batch")), token_abs.shape, sizes))
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_fn(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos, dp=dp)

    jitted = jax.jit(decode_fn, in_shardings=(psh, tsh, csh, None),
                     out_shardings=(None, csh), donate_argnums=(2,))
    return jitted, (params_abs, token_abs, cache_abs, pos_abs), dp, meta


# ---------------------------------------------------------------------------
# run + analyze one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             remat: str = "full", seq_shard_prefill: bool = True,
             save_hlo: str = None) -> dict:
    shape = SHAPES[shape_name]
    t0 = time.time()
    jitted, args, dp, meta = build_cell(arch, shape, multi_pod=multi_pod,
                                        remat=remat,
                                        seq_shard_prefill=seq_shard_prefill)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    if save_hlo:
        with gzip.open(save_hlo, "wt") as f:
            f.write(hlo)
    coll = parse_collectives(hlo)

    result = {
        **meta,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {
            "flops_per_device": cost.get("flops"),
            "bytes_per_device": cost.get("bytes accessed"),
            "transcendentals": cost.get("transcendentals"),
        },
        "collectives": coll,
        "collective_bytes_total": sum(v["bytes"] for v in coll.values()),
        "dataplane": {
            "mode": dp.mode,
            "logical_ops": dp.telemetry.by_kind(),
        },
    }
    # memory_analysis pretty print (the 'proves it fits' artifact)
    print(f"[{arch} × {shape_name} × "
          f"{'multi' if multi_pod else 'single'}-pod]")
    print(f"  memory_analysis: {mem}")
    print(f"  cost_analysis: flops/dev={cost.get('flops'):.3e} "
          f"bytes/dev={cost.get('bytes accessed'):.3e}")
    print(f"  collectives: { {k: (int(v['ops']), round(v['bytes']/2**20, 1)) for k, v in coll.items()} } (ops, MiB)")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)

    if args.all:
        todo = [(a, s.name) for a, s in cells()]
    else:
        todo = [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or
                               (args.all and not args.multi_pod)) else \
        [args.multi_pod]

    failures = 0
    for arch, shape_name in todo:
        for mp in meshes:
            tag = f"{arch}__{shape_name}__{'multi' if mp else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"skip {tag} (cached)")
                continue
            try:
                res = run_cell(arch, shape_name, multi_pod=mp,
                               remat=args.remat,
                               seq_shard_prefill=not args.no_seq_shard,
                               save_hlo=os.path.join(
                                   args.out, tag + ".hlo.gz"))
            except Exception as e:  # noqa: BLE001 — record failures
                failures += 1
                res = {"arch": arch, "shape": shape_name, "multi_pod": mp,
                       "ok": False, "error": str(e),
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"FAILED {tag}: {e}")
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
