"""Dataplane telemetry — the observability the paper gains by removing
kernel bypass (CoRD §1: "facilitate application observability").

Two mechanisms:

* **Trace-time records** (`Telemetry`): every op issued through the
  Dataplane is recorded with its logical tag, collective kind, byte size and
  mesh axes while the computation is being traced.  This is the exact
  information an OS would collect at the syscall boundary, and it is also
  the source of the roofline collective term (benchmarks/roofline.py).

* **In-graph counters** (`CounterState`): a tiny traced array of per-class
  counters threaded through measured paths (perftest / NPB / the explicit
  trainer), so that `cord` mode performs *real* per-op mediation work at run
  time — the analogue of the user→kernel crossing cost.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

# Counter classes for in-graph accounting.
CTR_OPS = 0          # number of dataplane ops issued
CTR_BYTES = 1        # bytes moved through the dataplane
CTR_DENIED = 2       # ops rejected by policy (quota/security)
CTR_CHUNKS = 3       # chunks issued by the QoS scheduler
NUM_COUNTERS = 4


@dataclass
class OpRecord:
    kind: str                 # all_reduce | all_gather | reduce_scatter | ...
    tag: str                  # logical name, e.g. "grads/psum" or "moe/dispatch"
    bytes: int                # payload bytes (per-shard operand size)
    axes: tuple[str, ...]     # mesh axes the op spans
    shape: tuple[int, ...] = ()
    dtype: str = ""
    mode: str = "cord"
    qos: str = "default"
    count: int = 1


@dataclass
class Telemetry:
    """Trace-time op registry. Cheap, purely host-side."""

    records: list[OpRecord] = field(default_factory=list)
    enabled: bool = True

    def record(self, rec: OpRecord) -> None:
        if self.enabled:
            self.records.append(rec)

    def reset(self) -> None:
        self.records.clear()

    # ---- reporting ------------------------------------------------------
    def total_bytes(self, kinds: tuple[str, ...] | None = None) -> int:
        return sum(r.bytes * r.count for r in self.records
                   if kinds is None or r.kind in kinds)

    def by_kind(self) -> dict[str, dict[str, float]]:
        agg: dict[str, dict[str, float]] = defaultdict(lambda: {"ops": 0, "bytes": 0})
        for r in self.records:
            agg[r.kind]["ops"] += r.count
            agg[r.kind]["bytes"] += r.bytes * r.count
        return dict(agg)

    def by_tag(self) -> dict[str, dict[str, float]]:
        agg: dict[str, dict[str, float]] = defaultdict(lambda: {"ops": 0, "bytes": 0})
        for r in self.records:
            agg[r.tag]["ops"] += r.count
            agg[r.tag]["bytes"] += r.bytes * r.count
        return dict(agg)

    def report(self) -> str:
        lines = [f"{'kind':18s} {'ops':>8s} {'MiB':>12s}"]
        for kind, v in sorted(self.by_kind().items()):
            lines.append(f"{kind:18s} {int(v['ops']):8d} {v['bytes']/2**20:12.3f}")
        lines.append(f"{'TOTAL':18s} {sum(int(v['ops']) for v in self.by_kind().values()):8d}"
                     f" {self.total_bytes()/2**20:12.3f}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# In-graph counter state
# ---------------------------------------------------------------------------

def counters_init() -> jax.Array:
    return jnp.zeros((NUM_COUNTERS,), dtype=jnp.float32)


def counters_bump(ctrs: jax.Array, *, ops: int = 0, bytes: int = 0,
                  denied: int = 0, chunks: int = 0) -> jax.Array:
    """Return updated counters. This is the per-op mediation computation in
    cord mode — a handful of scalar adds, the 'syscall body'."""
    upd = jnp.zeros_like(ctrs)
    upd = upd.at[CTR_OPS].add(float(ops))
    upd = upd.at[CTR_BYTES].add(float(bytes))
    upd = upd.at[CTR_DENIED].add(float(denied))
    upd = upd.at[CTR_CHUNKS].add(float(chunks))
    return ctrs + upd


def counters_dict(ctrs: np.ndarray) -> dict[str, float]:
    c = np.asarray(ctrs)
    return {"ops": float(c[CTR_OPS]), "bytes": float(c[CTR_BYTES]),
            "denied": float(c[CTR_DENIED]), "chunks": float(c[CTR_CHUNKS])}


def nbytes(x) -> int:
    """Payload size of an abstract/concrete array."""
    dt = jnp.dtype(x.dtype)
    return int(np.prod(x.shape)) * dt.itemsize


def describe(x) -> tuple[tuple[int, ...], str]:
    return tuple(x.shape), str(jnp.dtype(x.dtype).name)


__all__ = [
    "OpRecord", "Telemetry", "counters_init", "counters_bump",
    "counters_dict", "nbytes", "describe",
    "CTR_OPS", "CTR_BYTES", "CTR_DENIED", "CTR_CHUNKS", "NUM_COUNTERS",
]
