"""Dataplane telemetry — the observability the paper gains by removing
kernel bypass (CoRD §1: "facilitate application observability").

Three mechanisms:

* **Trace-time records** (`Telemetry`): every op issued through the
  Dataplane is recorded with its logical tag, collective kind, byte size and
  mesh axes while the computation is being traced.  This is the exact
  information an OS would collect at the syscall boundary, and it is also
  the source of the roofline collective term (benchmarks/roofline.py).

* **In-graph counters** (`counters_init`/`counters_bump`): a tiny traced
  array of per-class counters threaded through measured paths (perftest /
  NPB), so that `cord` mode performs *real* per-op mediation work at run
  time — the analogue of the user→kernel crossing cost.

* **Per-tenant counter blocks** (`tenant_counters_*`): a
  ``(num_tenants, NUM_COUNTERS)`` float32 block carried in the runtime
  state the mediation pipeline, QoS/quota policies, verbs CQ runtime and
  serving engine all bump — the multi-tenant accounting substrate.  The
  column order is ``COUNTER_NAMES`` everywhere (``counters_dict`` and
  ``tenant_counters_report`` share it; tests/test_obs.py pins it), and
  every column is cumulative except ``cq_depth``, a high-water mark
  folded in with ``tenant_counters_peak``.  ``CounterTimeline``
  (core/obs.py) snapshots these blocks into per-tenant timelines;
  docs/observability.md documents each counter's semantics.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

# Counter classes for in-graph accounting.
CTR_OPS = 0          # number of dataplane ops issued
CTR_BYTES = 1        # bytes moved through the dataplane
CTR_DENIED = 2       # ops over a policy limit (quota) observed at run time
CTR_CHUNKS = 3       # chunks issued by the QoS scheduler
CTR_THROTTLED = 4    # ops stalled by the QoS token bucket
CTR_STALLS = 5       # sender ticks stalled on exhausted rx credits (verbs)
CTR_CREDITS = 6      # rx credits consumed by two-sided sends (verbs)
CTR_COMPLETIONS = 7  # CQEs drained from a completion queue (verbs)
CTR_CQ_DEPTH = 8     # CQ occupancy high-water mark (a peak, not a sum)
CTR_RETRANSMITS = 9  # WRs re-posted by the retransmission machine (verbs)
CTR_TIMEOUTS = 10    # RTO expiries (silent wire loss detected) (verbs)
CTR_SRQ_GRANTS = 11  # shared-receive-queue buffers granted to a delivery
CTR_CQE_ERRORS = 12  # error-status CQEs drained (CQE_ERR_*)
CTR_CQ_SHED = 13     # CQEs shed on CQ-ring overrun (lost completions)
CTR_KERNEL_ITERS = 14   # delay iterations burned in-kernel (mediated_cost)
CTR_KERNEL_COPIES = 15  # bounce-copy passes executed in-kernel
CTR_PREEMPTIONS = 16    # decode slots preempted (pool pressure / budget)
CTR_RESTORES = 17       # preempted requests resumed (recompute prefill)
NUM_COUNTERS = 18
COUNTER_NAMES = ("ops", "bytes", "denied", "chunks", "throttled",
                 "stalls", "credits", "completions", "cq_depth",
                 "retransmits", "timeouts", "srq_grants", "cqe_errors",
                 "cq_shed", "kernel_iters", "kernel_copies",
                 "preemptions", "restores")


@dataclass
class OpRecord:
    kind: str                 # all_reduce | all_gather | reduce_scatter | ...
    tag: str                  # logical name, e.g. "grads/psum" or "moe/dispatch"
    bytes: int                # payload bytes (per-shard operand size)
    axes: tuple[str, ...]     # mesh axes the op spans
    shape: tuple[int, ...] = ()
    dtype: str = ""
    mode: str = "cord"
    qos: str = "default"
    count: int = 1
    # QoS tokens for this op were already debited at a finer granularity
    # (chunk-level preemption, core/chunking.py) — the token-bucket
    # stage must not charge it again.
    precharged: bool = False


@dataclass
class Telemetry:
    """Trace-time op registry. Cheap, purely host-side."""

    records: list[OpRecord] = field(default_factory=list)
    enabled: bool = True

    def record(self, rec: OpRecord) -> None:
        if self.enabled:
            self.records.append(rec)

    def reset(self) -> None:
        self.records.clear()

    # ---- reporting ------------------------------------------------------
    def total_bytes(self, kinds: tuple[str, ...] | None = None) -> int:
        return sum(r.bytes * r.count for r in self.records
                   if kinds is None or r.kind in kinds)

    def by_kind(self) -> dict[str, dict[str, float]]:
        agg: dict[str, dict[str, float]] = defaultdict(lambda: {"ops": 0, "bytes": 0})
        for r in self.records:
            agg[r.kind]["ops"] += r.count
            agg[r.kind]["bytes"] += r.bytes * r.count
        return dict(agg)

    def by_tag(self) -> dict[str, dict[str, float]]:
        agg: dict[str, dict[str, float]] = defaultdict(lambda: {"ops": 0, "bytes": 0})
        for r in self.records:
            agg[r.tag]["ops"] += r.count
            agg[r.tag]["bytes"] += r.bytes * r.count
        return dict(agg)

    def report(self) -> str:
        lines = [f"{'kind':18s} {'ops':>8s} {'MiB':>12s}"]
        for kind, v in sorted(self.by_kind().items()):
            lines.append(f"{kind:18s} {int(v['ops']):8d} {v['bytes']/2**20:12.3f}")
        lines.append(f"{'TOTAL':18s} {sum(int(v['ops']) for v in self.by_kind().values()):8d}"
                     f" {self.total_bytes()/2**20:12.3f}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# In-graph counter state
# ---------------------------------------------------------------------------

def counters_init() -> jax.Array:
    return jnp.zeros((NUM_COUNTERS,), dtype=jnp.float32)


def _counter_row(ops, bytes, denied, chunks, throttled, stalls, credits,
                 completions, retransmits=0, timeouts=0, srq_grants=0,
                 cqe_errors=0, cq_shed=0, kernel_iters=0,
                 kernel_copies=0, preemptions=0, restores=0) -> jax.Array:
    # CQ depth is a high-water mark, never additive — it has no slot in the
    # bump row (see tenant_counters_peak) and stays 0 here.
    return jnp.stack([jnp.asarray(v, jnp.float32)
                      for v in (ops, bytes, denied, chunks, throttled,
                                stalls, credits, completions, 0,
                                retransmits, timeouts, srq_grants,
                                cqe_errors, cq_shed, kernel_iters,
                                kernel_copies, preemptions, restores)])


def counters_bump(ctrs: jax.Array, *, ops=0, bytes=0, denied=0, chunks=0,
                  throttled=0, stalls=0, credits=0, completions=0,
                  retransmits=0, timeouts=0, srq_grants=0, cqe_errors=0,
                  cq_shed=0, kernel_iters=0, kernel_copies=0,
                  preemptions=0, restores=0) -> jax.Array:
    """Return updated counters. This is the per-op mediation computation in
    cord mode — a handful of scalar adds, the 'syscall body'."""
    return ctrs + _counter_row(ops, bytes, denied, chunks, throttled,
                               stalls, credits, completions, retransmits,
                               timeouts, srq_grants, cqe_errors, cq_shed,
                               kernel_iters, kernel_copies, preemptions,
                               restores)


def counters_dict(ctrs: np.ndarray) -> dict[str, float]:
    c = np.asarray(ctrs)
    return {name: float(c[i]) for i, name in enumerate(COUNTER_NAMES)}


# ---------------------------------------------------------------------------
# Per-tenant counter blocks (runtime accounting for multi-tenant dataplanes)
# ---------------------------------------------------------------------------

def tenant_counters_init(num_tenants: int) -> jax.Array:
    """A (num_tenants, NUM_COUNTERS) float32 counter block — the per-tenant
    runtime state the mediation pipeline bumps inside traced code."""
    return jnp.zeros((num_tenants, NUM_COUNTERS), dtype=jnp.float32)


def tenant_counters_bump(ctrs: jax.Array, tenant_idx, *, ops=0, bytes=0,
                         denied=0, chunks=0, throttled=0, stalls=0, credits=0,
                         completions=0, retransmits=0, timeouts=0,
                         srq_grants=0, cqe_errors=0, cq_shed=0,
                         kernel_iters=0, kernel_copies=0, preemptions=0,
                         restores=0) -> jax.Array:
    """Bump one tenant's counter row.  ``tenant_idx`` is an index into the
    dataplane's tenant table — usually a static int, but ``.at[].add``
    accepts a traced index too (the multi-QP connection table routes
    per-delivery bumps by the delivering QP's tenant id); the bump values
    may be traced scalars."""
    return ctrs.at[tenant_idx].add(
        _counter_row(ops, bytes, denied, chunks, throttled,
                     stalls, credits, completions, retransmits, timeouts,
                     srq_grants, cqe_errors, cq_shed, kernel_iters,
                     kernel_copies, preemptions, restores))


def tenant_counters_peak(ctrs: jax.Array, tenant_idx: int, *,
                         cq_depth) -> jax.Array:
    """Fold a completion-queue occupancy sample into one tenant's
    ``cq_depth`` high-water mark (a max, unlike every additive counter)."""
    return ctrs.at[tenant_idx, CTR_CQ_DEPTH].max(
        jnp.asarray(cq_depth, jnp.float32))


def tenant_counters_report(ctrs, tenants: tuple[str, ...]) -> dict:
    """Host-side view: {tenant: {ops, bytes, denied, chunks, throttled}}."""
    c = np.asarray(ctrs)
    return {t: {name: float(c[i, j]) for j, name in enumerate(COUNTER_NAMES)}
            for i, t in enumerate(tenants)}


def nbytes(x) -> int:
    """Payload size of an abstract/concrete array."""
    dt = jnp.dtype(x.dtype)
    return int(np.prod(x.shape)) * dt.itemsize


def describe(x) -> tuple[tuple[int, ...], str]:
    return tuple(x.shape), str(jnp.dtype(x.dtype).name)


def normalize_axes(axes) -> tuple[str, ...]:
    """Flatten any axes description — a string, a (possibly nested) tuple,
    or a PartitionSpec — into the tuple of mesh-axis names an OpRecord
    stores.  Shared by GSPMD constraints and the explicit collectives."""
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    leaves = jax.tree.leaves(tuple(axes))
    return tuple(a for a in leaves if isinstance(a, str) and a)


__all__ = [
    "OpRecord", "Telemetry", "counters_init", "counters_bump",
    "counters_dict", "tenant_counters_init", "tenant_counters_bump",
    "tenant_counters_peak", "tenant_counters_report", "nbytes", "describe",
    "normalize_axes",
    "CTR_OPS", "CTR_BYTES", "CTR_DENIED", "CTR_CHUNKS", "CTR_THROTTLED",
    "CTR_STALLS", "CTR_CREDITS", "CTR_COMPLETIONS", "CTR_CQ_DEPTH",
    "CTR_RETRANSMITS", "CTR_TIMEOUTS", "CTR_SRQ_GRANTS", "CTR_CQE_ERRORS",
    "CTR_CQ_SHED", "CTR_KERNEL_ITERS", "CTR_KERNEL_COPIES",
    "CTR_PREEMPTIONS", "CTR_RESTORES",
    "NUM_COUNTERS", "COUNTER_NAMES",
]
