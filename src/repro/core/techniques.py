"""Emulation of the three RDMA performance techniques (paper §2, Fig. 1).

The paper "removes" each technique from perftest to quantify its value:

* **zero-copy removed**  → an extra memory copy on send and on receive.
  Here: the payload is staged through a bounce buffer; an
  ``optimization_barrier`` fence prevents XLA from eliding the copies.
* **kernel-bypass removed** → a ``getppid`` syscall per op in the paper.
  Here: a calibrated dependent-compute delay (the user→kernel crossing) plus
  the in-graph policy work of the mediation layer.
* **polling removed** → wait-for-interrupt instead of busy polling.
  Here: a (much larger) calibrated delay modelling interrupt delivery +
  wakeup on the completion path.

The delay primitive is a serial dependent FLOP chain: XLA cannot
parallelise or elide it, so its wall-time scales linearly with the trip
count on any backend.  ``calibrate()`` measures ns/iteration once per
process and converts requested nanoseconds into iterations.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Serial delay primitive
# ---------------------------------------------------------------------------

def delay_scalar(iters, seed=None) -> jax.Array:
    """A serial dependent scalar computation of ``iters`` steps.

    ``iters`` may be a static int (unrollable fori_loop) or a traced int32
    scalar (lowers to a while loop with a dynamic trip count)."""
    def body(i, v):
        # dependent fma chain; cannot be vectorized away
        return v * 1.0000001 + 1e-9

    if isinstance(iters, jax.Array):
        iters = jnp.maximum(iters.astype(jnp.int32), 0)
    else:
        iters = max(int(iters), 0)
    return jax.lax.fori_loop(0, iters,
                             body, seed if seed is not None
                             else jnp.float32(1.0))


def tie(x: jax.Array, tok: jax.Array) -> jax.Array:
    """Make ``x`` data-depend on ``tok`` with O(1) work, value-identical.

    A bare optimization_barrier gets pruned when its token output is
    unused; instead the first element of ``x`` is routed through a select
    on ``tok == tok`` (true at run time, not foldable under NaN
    semantics)."""
    tok = tok.astype(jnp.float32)
    head = jax.lax.dynamic_slice_in_dim(x.reshape(-1), 0, 1, 0)
    head = jnp.where(tok == tok, head, head + jnp.ones_like(head))
    flat = jax.lax.dynamic_update_slice_in_dim(x.reshape(-1), head, 0, 0)
    return flat.reshape(x.shape)


def delay_chain(x: jax.Array, iters: int) -> jax.Array:
    """Delay the availability of ``x`` by a serial ``iters``-step chain.

    Bit-identical output: the chain runs on a scalar token that ``x`` is
    barrier-tied to — no copy or arithmetic touches the payload."""
    if iters <= 0:
        return x
    return tie(x, delay_scalar(iters))


def delay_chain_dyn(x: jax.Array, iters: jax.Array) -> jax.Array:
    """``delay_chain`` with a *traced* trip count (lowers to a while loop).

    Used by runtime policies whose stall length depends on traced state —
    e.g. the QoS token bucket stalling proportionally to its deficit.
    Zero iterations is a cheap no-op loop; the output stays bit-identical."""
    return tie(x, delay_scalar(jnp.maximum(jnp.asarray(iters, jnp.int32), 0)))


_CALIBRATION: dict[tuple[str, int], float] = {}   # (backend, iters) -> ns/iter


def calibrate(probe_iters: int = 200_000) -> float:
    """Measure ns per delay_chain iteration on this host.

    Memoized per process, keyed on the active JAX backend: repeated
    measured-mode setup (every ``Dataplane`` with ``emulate_costs``
    calls this eagerly) reuses the cached slope, and a backend switch
    within one process (``JAX_PLATFORMS`` juggling in tests) re-probes
    instead of reusing a stale slope."""
    key = (jax.default_backend(), probe_iters)
    hit = _CALIBRATION.get(key)
    if hit is not None:
        return hit
    f = jax.jit(lambda x: delay_chain(x, probe_iters))
    x = jnp.zeros((), jnp.float32)
    f(x).block_until_ready()              # compile
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        f(x).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    ns = best * 1e9 / probe_iters
    _CALIBRATION[key] = ns
    return ns


def iters_for_ns(ns: float) -> int:
    """Requested emulated cost (ns) -> delay iterations, off the cached
    calibration slope (probe runs at most once per backend)."""
    if ns <= 0:
        return 0
    return max(1, int(ns / calibrate()))


# ---------------------------------------------------------------------------
# Copy emulation (zero-copy removed / socket bounce buffers)
# ---------------------------------------------------------------------------

def staged_copy(x: jax.Array, copies: int = 1) -> jax.Array:
    """Force ``copies`` real materialized copies of ``x`` (bounce buffer).

    Barriers fence each stage so XLA cannot fuse or elide the copies; the
    final output is bit-identical to ``x``."""
    shape = x.shape
    flat = x.reshape(-1) if x.ndim != 1 else x
    for _ in range(copies):
        # roll / barrier / roll-back: two real data movements XLA cannot
        # fold (the barrier blocks roll∘roll simplification) — the copy
        # into and out of the bounce buffer.
        flat = jnp.roll(flat, 1, axis=0)
        (flat,) = jax.lax.optimization_barrier((flat,))
        flat = jnp.roll(flat, -1, axis=0)
    return flat.reshape(shape)


__all__ = ["delay_chain", "delay_chain_dyn", "delay_scalar", "tie",
           "calibrate", "iters_for_ns", "staged_copy"]
