"""ibverbs-style point-to-point layer over shard_map + ppermute.

This is the "narrow waist" (paper §4) the perftest reproduction runs on:

* **Queue pairs** are functional ring buffers of fixed-size message slots
  (the registered memory the NIC reads from / writes to).
* **post_send / post_recv** enqueue work requests.  In ``cord``/``socket``
  mode each post crosses the mediation layer (the syscall); in ``bypass``
  it is a bare ring write (the doorbell in user space).  ``post_recv``
  doubles as the credit grant of the flow-control protocol: every posted
  receive buffer is one credit the sender may spend.
* **flush** performs the actual transfer (the NIC DMA): one
  ``ppermute`` of the ring over the ``rank`` axis — zero-copy, the payload
  moves directly from the registered ring memory.
* **completion queue** — a real ring of per-entry status/wr_id records
  (``cq_status`` / ``cq_wrid``): the NIC pushes CQEs at ``cq_head``,
  software consumes them at ``cq_tail``.  ``poll_cq`` drains it; with
  polling disabled the completion path pays the emulated interrupt cost.
* **windowed_send** is the asynchronous runtime: a ``lax.while_loop``
  drives a sender window of up to ``max_outstanding`` work requests in
  flight.  When the window fills the sender drains its CQ (paying the
  completion-side pipeline cost per CQE); when the receiver's credits run
  out the sender stalls in traced code (paying the interrupt-wait cost)
  until the receiver re-posts its consumed buffers.
* **live migration** — because the QP is a pytree and every WR crosses
  the mediation layer, a connection can be stopped at a clean point and
  moved MigrOS-style: ``qp_quiesce`` drains the sender window to an
  empty CQ, ``qp_snapshot`` stop-and-copies the QP/CQ/credit state to
  host memory, and ``qp_restore`` device_puts it onto a (new) mesh's
  shardings (``qp_specs``), after which ``windowed_send`` resumes with
  counters and outstanding credits intact (docs/elasticity.md).
* **retransmission** — arming ``windowed_send`` with a
  :class:`~repro.runtime.fault.WireFault` turns every ``CQE_ERR_*``
  status and RTO expiry into a go-back-N rewind + mediated re-post
  (bounded by ``QPConfig.retry_limit``), so injected wire loss or
  corruption completes bit-identically to a lossless run instead of
  dying (docs/transport.md).
* **connection table** — ``conn_init``/``conn_send`` multiplex many QPs
  onto ONE shared CQ (per-CQE qp_id + epoch tag, single drain loop) and
  ONE shared receive queue, with post order across tenants' QPs
  arbitrated by the mediation layer's QoS token buckets;
  ``conn_quiesce``/``conn_snapshot``/``conn_restore`` migrate the whole
  table — in-flight retry state included — in one stop-and-copy.

Mediation is NOT reimplemented here: the per-endpoint issue/completion
work is the dataplane's :class:`~repro.core.mediation.MediationPipeline`
(``dp.pipeline``), applied on the active rank only via
:func:`rank_mediate` / :func:`rank_complete` — the same composable stages
the collectives and GSPMD constraints run.  Both follow the uniform
``(x, state)`` runtime convention: pass ``state=dp.runtime_init()`` and
verbs traffic lands in the per-tenant counters ``dp.runtime_report``
reads (ops, bytes, stalls, credits, completions, cq_depth).

SPMD note: queue counters (heads, tails, credits) are *connection state*
— both ranks compute them identically, which keeps ``while_loop`` trip
counts uniform across the mesh.  Payload data and runtime-counter
*state* diverge per rank (only the active endpoint's pipeline bumps);
aggregate with :func:`allreduce_state` before reporting or before
snapshotting into a :class:`~repro.core.obs.CounterTimeline`.  An
aggregated state is a *report*, not a resumable state: feeding it back
into another mediated transfer would psum the already-summed base again
(exponential double counting) — start each transfer from a fresh
``runtime_init()`` and accumulate reports host-side instead, as
benchmarks/run.py's dry-run timeline does (docs/observability.md defines
the stall/credit/completion/cq_depth semantics).

Transports: ``RC`` (any message size, send/recv + one-sided READ/WRITE)
and ``UD`` (≤ 4 KiB MTU, send/recv only) — mirroring the paper's matrix.
One-sided ops mediate only on the *active* side (paper Fig. 3: RDMA read
with CoRD on the passive server has zero overhead) and consume no
receiver credits (they bypass the recv queue entirely).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import techniques as tech
from repro.core import telemetry as tl
from repro.core.dataplane import Dataplane

UD_MTU = 4096

# Completion-queue entry status codes.
CQE_EMPTY = 0      # unowned slot
CQE_SEND = 1       # send/write/read WR completed (sender-side CQE)
CQE_RECV = 2       # receive completed (delivered into a posted recv buffer)
CQE_ERR_RETRY = 3  # WR failed retryably (wire corruption NAK) — re-post it
CQE_ERR_FATAL = 4  # retry budget exhausted — the WR is abandoned


class TransportError(Exception):
    pass


@dataclass(frozen=True)
class QPConfig:
    transport: str = "RC"          # RC | UD
    msg_bytes: int = 4096
    depth: int = 16                # ring slots
    max_outstanding: int = 8       # sender window (WRs in flight)
    cq_depth: int = 0              # CQ ring entries; 0 = max(depth, window)
    dtype: str = "uint8"           # slot element type
    axis: str = "rank"
    # retransmission state machine (docs/transport.md): a WR whose CQE
    # comes back CQE_ERR_RETRY — or that never completes within
    # ``rto_ticks`` loop ticks — is re-posted go-back-N style after
    # ``backoff_ticks`` of backoff, at most ``retry_limit`` consecutive
    # times before the QP turns fatal (CQE_ERR_FATAL).
    retry_limit: int = 7
    rto_ticks: int = 8
    backoff_ticks: int = 1
    # adaptive RTO: re-arm the retransmission timer from an EWMA of the
    # QP's observed drain latency (see :func:`adaptive_rto`) instead of
    # the static ``rto_ticks``.  The static value stays the hard ceiling
    # — fuel bounds and worst-case latency are unchanged — and the
    # fallback until the first in-order completion is sampled.
    adaptive_rto: bool = True

    def __post_init__(self):
        if self.transport not in ("RC", "UD"):
            raise TransportError(f"unknown transport {self.transport!r}")
        if self.transport == "UD" and self.msg_bytes > UD_MTU:
            raise TransportError(
                f"UD supports messages up to {UD_MTU} B, got {self.msg_bytes}")
        if self.depth < 1 or self.max_outstanding < 1:
            raise TransportError(
                f"depth/max_outstanding must be >= 1, got "
                f"{self.depth}/{self.max_outstanding}")
        if self.retry_limit < 0 or self.rto_ticks < 1 or self.backoff_ticks < 0:
            raise TransportError(
                f"need retry_limit >= 0, rto_ticks >= 1, backoff_ticks >= 0, "
                f"got {self.retry_limit}/{self.rto_ticks}/{self.backoff_ticks}")
        itemsize = jnp.dtype(self.dtype).itemsize
        if self.msg_bytes < itemsize or self.msg_bytes % itemsize:
            raise TransportError(
                f"msg_bytes={self.msg_bytes} is not a positive multiple of "
                f"dtype {self.dtype!r} itemsize ({itemsize} B) — ring slots "
                f"would silently truncate")

    @property
    def effective_cq_depth(self) -> int:
        return self.cq_depth or max(self.depth, self.max_outstanding)


def qp_init(cfg: QPConfig, dtype=None) -> dict:
    """Create QP state: send/recv rings, queue counters, and the CQ ring
    (per-entry status + wr_id, producer/consumer cursors) — a pytree."""
    dt = jnp.dtype(dtype if dtype is not None else cfg.dtype)
    if cfg.msg_bytes % dt.itemsize:
        raise TransportError(
            f"msg_bytes={cfg.msg_bytes} not a multiple of dtype {dt.name!r} "
            f"itemsize ({dt.itemsize} B)")
    slot = cfg.msg_bytes // dt.itemsize
    D = cfg.effective_cq_depth
    i32 = lambda: jnp.zeros((), jnp.int32)
    return {
        "send_ring": jnp.zeros((cfg.depth, slot), dt),
        "recv_ring": jnp.zeros((cfg.depth, slot), dt),
        "sq_head": i32(),        # posted sends
        "cq_sent": i32(),        # completed (consumed) sends
        "cq_rcvd": i32(),        # completed (polled) recvs
        # the completion queue proper
        "cq_status": jnp.zeros((D,), jnp.int32),
        "cq_wrid": jnp.full((D,), -1, jnp.int32),
        "cq_head": i32(),        # CQEs produced (NIC side)
        "cq_tail": i32(),        # CQEs consumed (software side)
        "cq_hwm": i32(),         # CQ occupancy high-water mark
        # credit-based flow control
        "credits": i32(),        # rx buffers granted via post_recv
        "rx_owed": i32(),        # delivered recvs awaiting re-post
        "win_hwm": i32(),        # max observed in-flight window
        # retransmission machine + CQ-overrun visibility
        "retry_cnt": i32(),      # consecutive retries of the oldest WR
        "backoff": i32(),        # remaining backoff ticks before re-post
        "rtx_pending": i32(),    # WRs a quiesce found unacked (must re-post)
        "cq_shed": i32(),        # CQEs shed on ring overrun (cumulative)
    }


# ---------------------------------------------------------------------------
# per-rank conditional mediation: client and server may independently run
# bypass (BP) or CoRD (CD) — the paper's fig. 3 matrix.  Both sides'
# work is the dataplane's mediation pipeline, gated by lax.cond, with the
# uniform (x, state) runtime convention threaded through the cond.
# ---------------------------------------------------------------------------

def _verbs_rec(dp: Dataplane, x: jax.Array, tag: str) -> tl.OpRecord:
    shape, dtype = tl.describe(x)
    return tl.OpRecord(kind="verbs", tag=tag, bytes=tl.nbytes(x),
                       axes=("rank",), shape=shape, dtype=dtype,
                       mode=dp.mode)


def rank_mediate(x: jax.Array, rank: jax.Array, active_rank,
                 dp: Dataplane, tag: str = "verbs/post", state=None,
                 tenant: str | None = None):
    """Apply ``dp.pipeline``'s issue-side stages only on ``active_rank``
    (SPMD-safe).  Returns ``(x, state)``: the active rank's runtime state
    picks up the pipeline's per-tenant accounting, other ranks pass
    through untouched."""
    rec = _verbs_rec(dp, x, tag)
    ti = dp.tenant_index(tenant)
    return jax.lax.cond(rank == active_rank,
                        lambda ops: dp.pipeline.send(ops[0], rec, ops[1], ti),
                        lambda ops: ops, (x, state))


def rank_complete(x: jax.Array, rank: jax.Array, active_rank,
                  dp: Dataplane, tag: str = "verbs/completion", state=None,
                  tenant: str | None = None):
    """Apply ``dp.pipeline``'s completion-side stages only on
    ``active_rank`` (interrupt wait / bounce copy).  Returns
    ``(x, state)`` — same convention as :func:`rank_mediate`."""
    rec = _verbs_rec(dp, x, tag)
    ti = dp.tenant_index(tenant)
    return jax.lax.cond(
        rank == active_rank,
        lambda ops: dp.pipeline.complete(ops[0], rec, ops[1], ti),
        lambda ops: ops, (x, state))


def _bump(state, tenant_idx: int, mask, **kw):
    """Masked per-tenant counter bump; no-op when state carries none."""
    if state is None or "counters" not in state:
        return state
    m = jnp.asarray(mask).astype(jnp.float32)
    ctrs = tl.tenant_counters_bump(state["counters"], tenant_idx,
                                   **{k: m * v for k, v in kw.items()})
    return {**state, "counters": ctrs}


def _peak(state, tenant_idx: int, mask, depth):
    if state is None or "counters" not in state:
        return state
    m = jnp.asarray(mask).astype(jnp.float32)
    ctrs = tl.tenant_counters_peak(state["counters"], tenant_idx,
                                   cq_depth=m * depth)
    return {**state, "counters": ctrs}


def allreduce_state(state, axis: str = "rank"):
    """Aggregate a runtime-state pytree over the mesh axis so a single
    report covers both endpoints (each side's pipeline bumps only its own
    rank's state).  Additive counters are summed; the ``cq_depth``
    high-water column is a peak, so it takes the max across ranks.  Call
    as the last step of a shard_map body."""
    if state is None:
        return None
    out = {}
    for k, v in state.items():
        summed = jax.tree.map(lambda a: jax.lax.psum(a, axis), v)
        if k == "counters":
            peak = jax.lax.pmax(v[..., tl.CTR_CQ_DEPTH], axis)
            summed = summed.at[..., tl.CTR_CQ_DEPTH].set(peak)
        out[k] = summed
    return out


# ---------------------------------------------------------------------------
# CQ ring primitives (uniform connection state — no rank gating)
# ---------------------------------------------------------------------------

def _cqe_push(qp: dict, cfg: QPConfig, do, status: int, wrid):
    """Push one CQE when ``do`` (traced bool) holds; track the occupancy
    high-water mark.  A full ring drops the CQE (a real CQ overrun is
    fatal; the emulation sheds instead — the legacy counters still
    advance, so poll counts stay correct) and the shed is counted in the
    QP's cumulative ``cq_shed`` so overrun is observable before it turns
    into a retransmission storm."""
    D = cfg.effective_cq_depth
    want = jnp.asarray(do)
    do = want & (qp["cq_head"] - qp["cq_tail"] < D)
    shed = (want & ~do).astype(jnp.int32)
    slot = jnp.mod(qp["cq_head"], D)
    st = jnp.where(do, status, qp["cq_status"][slot])
    wi = jnp.where(do, wrid, qp["cq_wrid"][slot])
    head = qp["cq_head"] + do.astype(jnp.int32)
    occ = head - qp["cq_tail"]
    return {**qp,
            "cq_status": qp["cq_status"].at[slot].set(st),
            "cq_wrid": qp["cq_wrid"].at[slot].set(wi),
            "cq_head": head,
            "cq_hwm": jnp.maximum(qp["cq_hwm"], occ),
            "cq_shed": qp["cq_shed"] + shed}


def _cqe_push_n(qp: dict, cfg: QPConfig, n, status: int, wrid0):
    """Push ``n`` CQEs (traced count) with consecutive wr_ids starting at
    ``wrid0``, clamped to the ring's free space — excess CQEs are shed
    rather than overwriting unconsumed entries and counted in
    ``cq_shed`` (see :func:`_cqe_push`)."""
    D = cfg.effective_cq_depth
    free = jnp.maximum(D - (qp["cq_head"] - qp["cq_tail"]), 0)
    want = jnp.maximum(jnp.asarray(n, jnp.int32), 0)
    n = jnp.minimum(want, free)
    shed = want - n
    k = jnp.arange(D, dtype=jnp.int32)
    mask = k < n
    idx = jnp.mod(qp["cq_head"] + k, D)
    st = jnp.where(mask, status, qp["cq_status"][idx])
    wi = jnp.where(mask, wrid0 + k, qp["cq_wrid"][idx])
    head = qp["cq_head"] + n
    occ = head - qp["cq_tail"]
    return {**qp,
            "cq_status": qp["cq_status"].at[idx].set(st),
            "cq_wrid": qp["cq_wrid"].at[idx].set(wi),
            "cq_head": head,
            "cq_hwm": jnp.maximum(qp["cq_hwm"], occ),
            "cq_shed": qp["cq_shed"] + shed}


def _cqe_consume(qp: dict, cfg: QPConfig, n):
    """Consume ``n`` CQEs from the tail (slots return to CQE_EMPTY)."""
    D = cfg.effective_cq_depth
    avail = qp["cq_head"] - qp["cq_tail"]
    n = jnp.clip(jnp.asarray(n, jnp.int32), 0, jnp.minimum(avail, D))
    k = jnp.arange(D, dtype=jnp.int32)
    mask = k < n
    idx = jnp.mod(qp["cq_tail"] + k, D)
    st = jnp.where(mask, CQE_EMPTY, qp["cq_status"][idx])
    return {**qp,
            "cq_status": qp["cq_status"].at[idx].set(st),
            "cq_tail": qp["cq_tail"] + n}


def cq_occupancy(qp: dict) -> jax.Array:
    """Outstanding (unconsumed) CQEs."""
    return qp["cq_head"] - qp["cq_tail"]


# ---------------------------------------------------------------------------
# data-plane verbs (call inside shard_map over cfg.axis)
# ---------------------------------------------------------------------------

def post_send(dp: Dataplane, cfg: QPConfig, qp: dict, buf: jax.Array,
              rank: jax.Array, src: int, state=None,
              tenant: str | None = None) -> tuple[dict, object]:
    """Enqueue ``buf`` into the send ring on rank ``src`` (the syscall).
    Returns ``(qp, state)``."""
    buf, state = rank_mediate(buf, rank, src, dp, tag="verbs/post_send",
                              state=state, tenant=tenant)
    slot = jnp.mod(qp["sq_head"], cfg.depth)
    ring = jax.lax.dynamic_update_index_in_dim(qp["send_ring"], buf, slot, 0)
    return {**qp, "send_ring": ring, "sq_head": qp["sq_head"] + 1}, state


def post_recv(dp: Dataplane, cfg: QPConfig, qp: dict, rank: jax.Array,
              dst: int, n: int = 1, state=None,
              tenant: str | None = None) -> tuple[dict, object]:
    """Post ``n`` receive buffers on rank ``dst`` — the receiver's syscall
    and the credit grant of the flow-control protocol.  Returns
    ``(qp, state)``."""
    tok = jnp.zeros((), jnp.float32)
    tok, state = rank_mediate(tok, rank, dst, dp, tag="verbs/post_recv",
                              state=state, tenant=tenant)
    ring = tech.tie(qp["recv_ring"], tok)
    return {**qp, "recv_ring": ring,
            "credits": qp["credits"] + jnp.int32(n)}, state


def flush_send(dp: Dataplane, cfg: QPConfig, qp: dict, rank: jax.Array,
               src: int, dst: int, *, op: str = "send",
               state=None, tenant: str | None = None) -> tuple[dict, object]:
    """The NIC DMA: move the send ring src→dst (or dst→src for READ).

    ``op``: "send" (two-sided), "write" / "read" (one-sided; RC only).
    Send/write completions land in the CQ ring; a READ moves remote
    memory without completing any posted send (one-sided ops never touch
    the send queue's completions).  CQEs shed on a full CQ ring land in
    the issuing tenant's ``cq_shed`` runtime counter.  Returns
    ``(qp, state)`` — the uniform dataplane state convention."""
    if op != "send" and cfg.transport != "RC":
        raise TransportError(f"one-sided {op!r} requires RC transport")
    perm = [(src, dst)] if op != "read" else [(dst, src)]
    ring = qp["send_ring"] if op != "read" else qp["recv_ring"]
    r, state = dp.ppermute(ring, cfg.axis, perm, tag=f"verbs/{op}",
                           mr=None, state=state)
    new = dict(qp)
    if op == "read":
        new["send_ring"] = r      # reader pulled remote memory
    else:
        new["recv_ring"] = r
        # the DMA completes every posted send — push their CQEs
        ncomp = qp["sq_head"] - qp["cq_sent"]
        new = _cqe_push_n(new, cfg, ncomp, CQE_SEND, qp["cq_sent"])
        new["cq_sent"] = qp["sq_head"]
        state = _bump(state, dp.tenant_index(tenant), rank == src,
                      cq_shed=new["cq_shed"] - qp["cq_shed"])
    return new, state


def poll_cq(dp: Dataplane, cfg: QPConfig, qp: dict, rank: jax.Array,
            poller: int, state=None,
            tenant: str | None = None) -> tuple[jax.Array, dict, object]:
    """Drain the completion queue on rank ``poller``.

    Returns ``(completions, qp, state)`` where ``completions`` is the
    number of deliveries since the last poll (``cq_sent - cq_rcvd``) —
    real counts, not a stale counter.  Consumes every outstanding CQE in
    the ring and bumps the poller's ``completions`` runtime counter;
    error-status CQEs (``CQE_ERR_*``) additionally land in the
    ``cqe_errors`` counter so a poller sees wire faults, not just
    successes.  Pays the interrupt cost on the polling rank when polling
    is disabled."""
    ring, state = rank_complete(qp["recv_ring"], rank, poller, dp,
                                tag="verbs/poll_cq", state=state,
                                tenant=tenant)
    completed = qp["cq_sent"] - qp["cq_rcvd"]
    D = cfg.effective_cq_depth
    k = jnp.arange(D, dtype=jnp.int32)
    live = k < jnp.minimum(cq_occupancy(qp), D)
    st = qp["cq_status"][jnp.mod(qp["cq_tail"] + k, D)]
    nerr = jnp.sum((live & ((st == CQE_ERR_RETRY) | (st == CQE_ERR_FATAL)))
                   .astype(jnp.int32))
    state = _bump(state, dp.tenant_index(tenant), rank == poller,
                  completions=completed, cqe_errors=nerr)
    qp = _cqe_consume(qp, cfg, cq_occupancy(qp))
    qp = {**qp, "recv_ring": ring, "cq_rcvd": qp["cq_sent"]}
    return completed, qp, state


# ---------------------------------------------------------------------------
# the CQ-driven async runtime: sender window + credit flow control
# ---------------------------------------------------------------------------

def windowed_send(dp: Dataplane, cfg: QPConfig, qp: dict, msgs: jax.Array,
                  rank: jax.Array, src: int, dst: int, *, op: str = "send",
                  state=None, tenant: str | None = None,
                  dp_peer: Dataplane | None = None, fault=None
                  ) -> tuple[jax.Array, dict, object]:
    """Transmit ``msgs`` (n, slot) src→dst through the async CQ runtime.

    A ``lax.while_loop`` drives one WR event per tick:

    * **post** — when the window (``cfg.max_outstanding``) has room and
      (two-sided only) a receiver credit is available: the payload is
      written into the send ring (send-side pipeline cost on ``src``),
      DMA'd, delivered on the receiving rank, and its CQE pushed.
    * **drain** — when the window is full (or input is exhausted): the
      sender consumes the oldest CQE, paying the completion-side pipeline
      cost — lazy polling, exactly perftest's post-then-poll loop.
    * **stall** — two-sided sends with no credits left: the sender pays
      the interrupt-wait cost in traced code, after which the receiver
      re-posts its consumed buffers (credits resume).

    Returns ``(out, qp, state)``: ``out`` is (n, slot) with the delivered
    payloads on the receiving rank (``dst``, or ``src`` for READ — other
    ranks hold zeros).  Queue counters are connection state (identical on
    both ranks — uniform while_loop trip counts); runtime-counter state
    diverges per rank and should be aggregated with
    :func:`allreduce_state` before reporting.

    For ``op="send"`` the receiver must have granted credits via
    :func:`post_recv` first; a zero-credit sender can never resume (the
    loop's fuel bound then returns undelivered zeros).  One-sided
    write/read consume no credits.  For ``op="read"`` ``msgs`` is the
    remote memory (resident on ``dst``) and the reader pulls it.

    ``fault`` (a :class:`~repro.runtime.fault.WireFault`, or anything
    duck-typing its ``active``/``drops_wr``/``corrupts_wr``) injects
    wire loss/corruption per transmission and arms the go-back-N
    retransmission machine (docs/transport.md): a corrupted WR completes
    with ``CQE_ERR_RETRY`` (a NAK), a dropped one times out after
    ``cfg.rto_ticks`` idle ticks, and either rewinds the window to the
    last in-order ack, backs off ``cfg.backoff_ticks``, and re-posts —
    paying the full send-side mediation cost per retry — so the
    delivered payload is **bit-identical to a lossless run**.  Retries
    and timeouts land in the tenant's runtime counters; after
    ``cfg.retry_limit`` consecutive failed retries the QP turns fatal
    (``CQE_ERR_FATAL`` CQE, ``qp["retry_cnt"] > cfg.retry_limit``) and
    undelivered slots stay zero."""
    if op not in ("send", "write", "read"):
        raise TransportError(f"unknown windowed op {op!r}")
    if op != "send" and cfg.transport != "RC":
        raise TransportError(f"one-sided {op!r} requires RC transport")
    n = int(msgs.shape[0])
    if n == 0:
        return jnp.zeros_like(msgs), qp, state
    if fault is not None and fault.active:
        return _windowed_send_rtx(dp, cfg, qp, msgs, rank, src, dst, op=op,
                                  state=state, tenant=tenant,
                                  dp_peer=dp_peer, fault=fault)
    W = min(cfg.max_outstanding, cfg.effective_cq_depth)
    uses_credits = op == "send"
    dp_peer = dp_peer if dp_peer is not None else dp
    ti = dp.tenant_index(tenant)
    perm = [(src, dst)] if op != "read" else [(dst, src)]
    stall_iters = (tech.iters_for_ns(dp.cfg.interrupt_cost_us * 1e3)
                   if dp.cfg.emulate_costs else 0)
    # fuel: every message needs at most post + drain + stall ticks, plus
    # the tail drain of a full window — a hard bound on loop length.
    fuel = 3 * n + 2 * W + 8
    tag = f"verbs/windowed_{op}"

    sq0, cs0 = qp["sq_head"], qp["cq_sent"]
    out0 = jnp.zeros_like(msgs)

    def cond(carry):
        t, i, qp, out, state = carry
        done = (i >= n) & (qp["cq_sent"] - cs0 >= n)
        return (t < fuel) & ~done

    def body(carry):
        t, i, qp, out, state = carry
        in_flight = qp["sq_head"] - qp["cq_sent"]
        have_credit = (qp["credits"] > 0) if uses_credits \
            else jnp.bool_(True)
        can_post = (i < n) & (in_flight < W) & have_credit
        cq_ready = cq_occupancy(qp) > 0
        do_drain = ~can_post & cq_ready & ((in_flight >= W) | (i >= n))
        do_stall = ~can_post & ~do_drain & (i < n) & (in_flight < W)
        posted = can_post.astype(jnp.int32)
        on_src = rank == src

        # -- post: the sender's syscall ---------------------------------
        idx = jnp.minimum(i, n - 1)
        payload = jax.lax.dynamic_index_in_dim(msgs, idx, 0, keepdims=False)
        wire = jnp.where(can_post, payload, jnp.zeros_like(payload))
        wire, state = jax.lax.cond(
            can_post,
            lambda ops: rank_mediate(ops[0], rank, src, dp, tag=tag,
                                     state=ops[1], tenant=tenant),
            lambda ops: ops, (wire, state))
        ring_slot = jnp.mod(qp["sq_head"], cfg.depth)
        send_ring = jax.lax.cond(
            can_post,
            lambda r: jax.lax.dynamic_update_index_in_dim(r, wire,
                                                          ring_slot, 0),
            lambda r: r, qp["send_ring"])
        # the NIC reads the registered ring directly (zero copy)
        wr = jax.lax.dynamic_index_in_dim(send_ring, ring_slot, 0,
                                          keepdims=False)
        if op == "read":
            # reader pulls remote memory: the wire carries dst's msgs[idx]
            wr = jnp.where(can_post, payload, jnp.zeros_like(payload))

        # -- DMA --------------------------------------------------------
        rx = jax.lax.ppermute(wr, cfg.axis, perm)

        # -- delivery: land the payload, ack with a CQE -----------------
        if uses_credits:
            # receiver-side completion handling (per-message poll or
            # interrupt on dst) — one-sided ops involve no remote CPU
            rx, state = jax.lax.cond(
                can_post,
                lambda ops: rank_complete(ops[0], rank, dst, dp_peer,
                                          tag="verbs/rx_complete",
                                          state=ops[1], tenant=tenant),
                lambda ops: ops, (rx, state))
        recv_ring = jax.lax.cond(
            can_post,
            lambda r: jax.lax.dynamic_update_index_in_dim(
                r, rx, jnp.mod(ring_slot, cfg.depth), 0),
            lambda r: r, qp["recv_ring"])
        out = jax.lax.cond(
            can_post,
            lambda o: jax.lax.dynamic_update_index_in_dim(o, rx, idx, 0),
            lambda o: o, out)
        qp = {**qp, "send_ring": send_ring, "recv_ring": recv_ring}
        qp = _cqe_push(qp, cfg, can_post, CQE_SEND, qp["sq_head"])
        sq_head = qp["sq_head"] + posted
        credits = qp["credits"] - (posted if uses_credits else 0)
        rx_owed = qp["rx_owed"] + (posted if uses_credits else 0)
        win = sq_head - qp["cq_sent"]
        qp = {**qp, "sq_head": sq_head, "credits": credits,
              "rx_owed": rx_owed,
              "win_hwm": jnp.maximum(qp["win_hwm"], win)}

        # -- drain: lazy CQ poll on the sender --------------------------
        tok = jnp.float32(1.0)
        tok, state = jax.lax.cond(
            do_drain,
            lambda ops: rank_complete(ops[0], rank, src, dp,
                                      tag="verbs/cq_drain", state=ops[1],
                                      tenant=tenant),
            lambda ops: ops, (tok, state))
        qp = _cqe_consume(qp, cfg, do_drain.astype(jnp.int32))
        qp = {**qp, "cq_sent": qp["cq_sent"] + do_drain.astype(jnp.int32)}

        # -- stall: credit exhaustion -----------------------------------
        if uses_credits:
            if stall_iters:
                tok = jax.lax.cond(
                    do_stall & on_src,
                    lambda v: tech.delay_chain(v, stall_iters),
                    lambda v: v, tok)
            # the stalled sender's wakeup: the receiver polled its recvs
            # and re-posted every consumed buffer
            repost = jnp.where(do_stall, qp["rx_owed"], 0)
            qp = {**qp, "credits": qp["credits"] + repost,
                  "rx_owed": qp["rx_owed"] - repost}
        out = tech.tie(out, tok)

        # -- runtime accounting (active side only) ----------------------
        state = _bump(state, ti, on_src & can_post,
                      credits=1 if uses_credits else 0)
        state = _bump(state, ti, on_src & do_drain, completions=1)
        state = _bump(state, ti, on_src & do_stall, stalls=1)
        state = _peak(state, ti, on_src, cq_occupancy(qp))
        return t + 1, i + posted, qp, out, state

    _, _, qp, out, state = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.int32(0), qp, out0, state))
    return out, qp, state


def adaptive_rto(srtt, nsamp, cfg: QPConfig) -> jax.Array:
    """Retransmission timeout derived from the observed drain latency:
    ``2 * ceil(srtt) + 1`` ticks, clamped to ``[2, cfg.rto_ticks]``.
    ``srtt`` is an EWMA (gain 1/8) of in-order ack spacing in loop ticks;
    ``nsamp`` counts samples.  With no samples yet the static
    ``cfg.rto_ticks`` is returned unchanged, and the clamp keeps the
    static value a hard ceiling so retry fuel bounds stay valid.  Works
    elementwise, so per-QP ``(Q,)`` estimates vectorise for free."""
    est = 2 * jnp.ceil(srtt).astype(jnp.int32) + 1
    return jnp.where(nsamp > 0, jnp.clip(est, 2, cfg.rto_ticks),
                     jnp.int32(cfg.rto_ticks))


def _windowed_send_rtx(dp: Dataplane, cfg: QPConfig, qp: dict,
                       msgs: jax.Array, rank: jax.Array, src: int, dst: int,
                       *, op: str, state, tenant, dp_peer, fault
                       ) -> tuple[jax.Array, dict, object]:
    """The lossy-wire variant of :func:`windowed_send`: the same
    post/drain/stall event loop with the go-back-N retransmission machine
    armed (docs/transport.md).  Compiled only when a ``fault`` is active,
    so lossless callers keep the exact legacy loop.

    Per-WR faults are rolled from ``(wr, attempt)`` so a retry re-rolls a
    fresh outcome.  A corrupted transmission is NAK'd (``CQE_ERR_RETRY``
    CQE, delivery suppressed); a dropped one is silent — no CQE — and the
    RTO countdown catches it.  Either rewinds the window to the last
    in-order ack (flush the CQ, ``sq_head`` back to ``cq_sent``), backs
    off, and re-posts through the full mediation path.  Deliveries are
    content-addressed by message index, so a duplicate arrival (ack lost,
    payload delivered) is idempotent — completion is bit-identical to a
    lossless run."""
    n = int(msgs.shape[0])
    W = min(cfg.max_outstanding, cfg.effective_cq_depth)
    uses_credits = op == "send"
    dp_peer = dp_peer if dp_peer is not None else dp
    ti = dp.tenant_index(tenant)
    perm = [(src, dst)] if op != "read" else [(dst, src)]
    stall_iters = (tech.iters_for_ns(dp.cfg.interrupt_cost_us * 1e3)
                   if dp.cfg.emulate_costs else 0)
    # fuel: the lossless bound per full pass, times the retry budget, plus
    # RTO countdowns and backoff between passes.
    fuel = (cfg.retry_limit + 2) * (3 * n + 2 * W
                                    + cfg.rto_ticks + cfg.backoff_ticks + 8)
    tag = f"verbs/windowed_{op}"

    cs0 = qp["cq_sent"]
    out0 = jnp.zeros_like(msgs)
    # per-message transmission counts: attempt k re-rolls the fault hash
    attempts0 = jnp.zeros((n,), jnp.int32)
    ar = jnp.arange(n, dtype=jnp.int32)
    D = cfg.effective_cq_depth

    def cond(carry):
        t, i, qp, out, state, attempts, rto, fatal = carry[:8]
        done = ((i >= n) & (qp["cq_sent"] - cs0 >= n)) | fatal
        return (t < fuel) & ~done

    def body(carry):
        (t, i, qp, out, state, attempts, rto, fatal,
         srtt, nsamp, last_ack) = carry
        in_flight = qp["sq_head"] - qp["cq_sent"]
        on_src = rank == src
        have_credit = (qp["credits"] > 0) if uses_credits \
            else jnp.bool_(True)
        backing_off = qp["backoff"] > 0
        can_post = ((i < n) & (in_flight < W) & have_credit & ~backing_off)
        cq_ready = cq_occupancy(qp) > 0
        do_drain = ~can_post & cq_ready
        # silent loss: nothing to post, no CQE arriving, WRs in flight —
        # the retransmission timer runs down to an RTO expiry.
        timeout = (~can_post & ~cq_ready & ~backing_off
                   & (in_flight > 0) & (rto <= 0))
        do_stall = (~can_post & ~do_drain & ~backing_off & ~timeout
                    & (i < n) & (in_flight < W))
        posted = can_post.astype(jnp.int32)

        # -- post (possibly a retransmission): the sender's syscall -----
        idx = jnp.minimum(i, n - 1)
        att = attempts[idx]
        payload = jax.lax.dynamic_index_in_dim(msgs, idx, 0, keepdims=False)
        wire = jnp.where(can_post, payload, jnp.zeros_like(payload))
        wire, state = jax.lax.cond(
            can_post,
            lambda ops: rank_mediate(ops[0], rank, src, dp, tag=tag,
                                     state=ops[1], tenant=tenant),
            lambda ops: ops, (wire, state))
        ring_slot = jnp.mod(qp["sq_head"], cfg.depth)
        send_ring = jax.lax.cond(
            can_post,
            lambda r: jax.lax.dynamic_update_index_in_dim(r, wire,
                                                          ring_slot, 0),
            lambda r: r, qp["send_ring"])
        wr = jax.lax.dynamic_index_in_dim(send_ring, ring_slot, 0,
                                          keepdims=False)
        if op == "read":
            wr = jnp.where(can_post, payload, jnp.zeros_like(payload))

        # -- DMA, through the injected wire fault -----------------------
        rx = jax.lax.ppermute(wr, cfg.axis, perm)
        lost = can_post & fault.drops_wr(idx, att)
        bad = can_post & ~lost & fault.corrupts_wr(idx, att)
        deliver = can_post & ~lost & ~bad

        # -- delivery: only an undamaged arrival lands + acks -----------
        if uses_credits:
            rx, state = jax.lax.cond(
                deliver,
                lambda ops: rank_complete(ops[0], rank, dst, dp_peer,
                                          tag="verbs/rx_complete",
                                          state=ops[1], tenant=tenant),
                lambda ops: ops, (rx, state))
        recv_ring = jax.lax.cond(
            deliver,
            lambda r: jax.lax.dynamic_update_index_in_dim(
                r, rx, jnp.mod(ring_slot, cfg.depth), 0),
            lambda r: r, qp["recv_ring"])
        out = jax.lax.cond(
            deliver,
            lambda o: jax.lax.dynamic_update_index_in_dim(o, rx, idx, 0),
            lambda o: o, out)
        qp = {**qp, "send_ring": send_ring, "recv_ring": recv_ring}
        # invariant: sq_head == cs0 + i, so the CQE wr_id is absolute
        qp = _cqe_push(qp, cfg, deliver, CQE_SEND, qp["sq_head"])
        qp = _cqe_push(qp, cfg, bad, CQE_ERR_RETRY, qp["sq_head"])
        sq_head = qp["sq_head"] + posted
        credits = qp["credits"] - (posted if uses_credits else 0)
        rx_owed = qp["rx_owed"] + (posted if uses_credits else 0)
        win = sq_head - qp["cq_sent"]
        qp = {**qp, "sq_head": sq_head, "credits": credits,
              "rx_owed": rx_owed,
              "win_hwm": jnp.maximum(qp["win_hwm"], win)}

        # -- drain one CQE, routed by status + wr_id --------------------
        tslot = jnp.mod(qp["cq_tail"], D)
        cqe_st = qp["cq_status"][tslot]
        cqe_wr = qp["cq_wrid"][tslot]
        is_err = do_drain & (cqe_st == CQE_ERR_RETRY)
        in_order = do_drain & (cqe_st == CQE_SEND) & (cqe_wr == qp["cq_sent"])
        is_gap = do_drain & (cqe_st == CQE_SEND) & (cqe_wr != qp["cq_sent"])
        tok = jnp.float32(1.0)
        tok, state = jax.lax.cond(
            do_drain,
            lambda ops: rank_complete(ops[0], rank, src, dp,
                                      tag="verbs/cq_drain", state=ops[1],
                                      tenant=tenant),
            lambda ops: ops, (tok, state))
        qp = _cqe_consume(qp, cfg, do_drain.astype(jnp.int32))
        qp = {**qp, "cq_sent": qp["cq_sent"] + in_order.astype(jnp.int32)}

        # -- adaptive RTO: sample in-order ack spacing (drain latency) ---
        sample = (t - last_ack).astype(jnp.float32)
        srtt = jnp.where(in_order,
                         jnp.where(nsamp == 0, sample,
                                   0.875 * srtt + 0.125 * sample), srtt)
        nsamp = nsamp + in_order.astype(jnp.int32)
        last_ack = jnp.where(in_order, t, last_ack)

        # -- go-back-N rewind: NAK, sequence gap, or RTO expiry ---------
        rew = is_err | is_gap | timeout
        new_retry = qp["retry_cnt"] + rew.astype(jnp.int32)
        give_up = rew & (new_retry > cfg.retry_limit)
        do_rew = rew & ~give_up
        acked_i = qp["cq_sent"] - cs0
        attempts = jnp.where(do_rew & (ar >= acked_i) & (ar < i),
                             attempts + 1, attempts)
        qp = _cqe_consume(qp, cfg,
                          jnp.where(do_rew, cq_occupancy(qp), 0))
        qp = {**qp,
              "sq_head": jnp.where(do_rew, qp["cq_sent"], qp["sq_head"]),
              "backoff": jnp.where(
                  do_rew, jnp.int32(cfg.backoff_ticks),
                  jnp.maximum(
                      qp["backoff"] - backing_off.astype(jnp.int32), 0)),
              "retry_cnt": jnp.where(
                  rew, new_retry,
                  jnp.where(in_order, 0, qp["retry_cnt"]))}
        i = jnp.where(do_rew, acked_i, i + posted)
        fatal = fatal | give_up
        qp = _cqe_push(qp, cfg, give_up, CQE_ERR_FATAL, qp["cq_sent"])

        # -- stall / backoff: both pay the interrupt-wait cost ----------
        if stall_iters:
            tok = jax.lax.cond(
                (do_stall | backing_off) & on_src,
                lambda v: tech.delay_chain(v, stall_iters),
                lambda v: v, tok)
        if uses_credits:
            repost = jnp.where(do_stall, qp["rx_owed"], 0)
            qp = {**qp, "credits": qp["credits"] + repost,
                  "rx_owed": qp["rx_owed"] - repost}
        out = tech.tie(out, tok)

        # any forward progress (or a rewind) re-arms the RTO
        armed = adaptive_rto(srtt, nsamp, cfg) if cfg.adaptive_rto \
            else jnp.int32(cfg.rto_ticks)
        rto = jnp.where(can_post | do_drain | rew | backing_off,
                        armed, rto - 1)

        # -- runtime accounting (active side only) ----------------------
        state = _bump(state, ti, on_src & can_post,
                      credits=1 if uses_credits else 0,
                      retransmits=(att > 0).astype(jnp.int32))
        state = _bump(state, ti, on_src & do_drain, completions=1,
                      cqe_errors=is_err.astype(jnp.int32))
        state = _bump(state, ti, on_src & do_stall, stalls=1)
        state = _bump(state, ti, on_src & timeout, timeouts=1)
        state = _peak(state, ti, on_src, cq_occupancy(qp))
        return (t + 1, i, qp, out, state, attempts, rto, fatal,
                srtt, nsamp, last_ack)

    i0 = qp["sq_head"] - cs0   # resume mid-window after a restore
    _, _, qp, out, state, *_ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), i0, qp, out0, state, attempts0,
                     jnp.int32(cfg.rto_ticks), jnp.bool_(False),
                     jnp.float32(0.0), jnp.int32(0), jnp.int32(0)))
    return out, qp, state


# ---------------------------------------------------------------------------
# live QP migration (MigrOS-style): quiesce → stop-and-copy → restore.
# The OS-control payoff of staying on the dataplane (docs/elasticity.md):
# because every WR crosses the mediation layer, the kernel can stop a
# connection at a clean point, copy its state, and resume it elsewhere —
# exactly what kernel bypass gives up.
# ---------------------------------------------------------------------------

# Payload rings diverge per rank; every other QP leaf is uniform
# connection state (see the SPMD note in the module docstring).
_QP_RING_KEYS = ("send_ring", "recv_ring")
_QP_UNIFORM_KEYS = ("sq_head", "cq_sent", "cq_rcvd", "cq_status", "cq_wrid",
                    "cq_head", "cq_tail", "cq_hwm", "credits", "rx_owed",
                    "win_hwm", "retry_cnt", "backoff", "rtx_pending",
                    "cq_shed")


def qp_specs(axis: str = "rank") -> dict:
    """shard_map PartitionSpecs for a QP pytree: payload rings are
    sharded over ``axis`` (they diverge per rank), queue cursors, the CQ
    ring and the credit counters are uniform connection state and stay
    unsharded.  Use as in/out specs when threading a QP through a
    shard_map boundary, so the pytree can be snapshotted between calls
    and migrated across meshes."""
    specs = {k: P() for k in _QP_UNIFORM_KEYS}
    specs.update({k: P(axis, None) for k in _QP_RING_KEYS})
    return specs


def qp_quiesce(dp: Dataplane, cfg: QPConfig, qp: dict, rank: jax.Array,
               src: int, state=None, tenant: str | None = None
               ) -> tuple[dict, object]:
    """Drain the connection to a migratable snapshot (MigrOS's stop
    phase).  A bounded ``while_loop`` consumes the CQ one entry per tick,
    paying the completion-side pipeline cost per CQE on ``src`` exactly
    like ``windowed_send``'s lazy drains, routing each CQE the same way
    the retransmission machine does: an in-order ``CQE_SEND`` acks
    (``cq_sent`` advances); an error CQE or a sequence gap marks its WR
    in ``rtx_pending`` instead of force-acking a transfer the wire never
    completed.  After the drain, any in-flight WR that produced no CQE
    at all (silently dropped) also lands in ``rtx_pending`` and the
    window is rewound (``sq_head`` back to ``cq_sent``) — the go-back-N
    rewind frozen at the migration point.

    On return the CQ is empty and the sender window is closed; credits,
    ``rx_owed``, ``retry_cnt``/``backoff`` and every cumulative counter
    are untouched, so a windowed transfer split around a quiesce →
    :func:`qp_snapshot` → :func:`qp_restore` sequence completes
    bit-identically to an uninterrupted one — lossless *or* lossy
    (tests/test_elastic_trigger.py, tests/test_transport.py).  The
    caller learns how many WRs acked from the ``cq_sent`` delta and
    re-sends the rest.  Returns ``(qp, state)`` — the uniform dataplane
    convention."""
    ti = dp.tenant_index(tenant)
    D = cfg.effective_cq_depth

    def cond(carry):
        qp, _, _ = carry
        return cq_occupancy(qp) > 0

    def body(carry):
        qp, state, tok = carry
        tok, state = rank_complete(tok, rank, src, dp, tag="verbs/quiesce",
                                   state=state, tenant=tenant)
        tslot = jnp.mod(qp["cq_tail"], D)
        st = qp["cq_status"][tslot]
        wr = qp["cq_wrid"][tslot]
        is_err = (st == CQE_ERR_RETRY) | (st == CQE_ERR_FATAL)
        in_order = (st == CQE_SEND) & (wr == qp["cq_sent"])
        is_gap = (st == CQE_SEND) & (wr > qp["cq_sent"])
        # wr < cq_sent (an already-acked flush CQE) just drains.
        state = _bump(state, ti, rank == src, completions=1,
                      cqe_errors=is_err.astype(jnp.int32))
        qp = _cqe_consume(qp, cfg, 1)
        qp = {**qp,
              "cq_sent": qp["cq_sent"] + in_order.astype(jnp.int32),
              "rtx_pending": qp["rtx_pending"]
              + (is_err | is_gap).astype(jnp.int32)}
        return qp, state, tok

    qp, state, tok = jax.lax.while_loop(
        cond, body, (qp, state, jnp.float32(1.0)))
    dropped = qp["sq_head"] - qp["cq_sent"]   # in flight, no CQE: lost
    qp = {**qp,
          "send_ring": tech.tie(qp["send_ring"], tok),
          "rtx_pending": qp["rtx_pending"] + dropped,
          "sq_head": qp["cq_sent"],
          "cq_rcvd": qp["cq_sent"]}
    return qp, state


def qp_snapshot(qp: dict) -> dict:
    """Stop-and-copy: fetch a (quiesced) QP pytree into host memory as
    plain numpy — checkpointable, and the input :func:`qp_restore`
    expects.  Call on the global (post-shard_map) pytree, strictly
    between traced calls."""
    return {k: np.asarray(jax.device_get(v)) for k, v in qp.items()}


def qp_restore(qp_host: dict, mesh, *, axis: str = "rank") -> dict:
    """MigrOS restore: ``device_put`` a QP snapshot onto ``mesh``'s
    shardings (:func:`qp_specs` — rings sharded over ``axis``, connection
    state replicated) so a windowed transfer resumes where it stopped —
    queue cursors, outstanding credits and owed re-posts intact — on the
    new mesh."""
    specs = qp_specs(axis)
    missing = set(specs) - set(qp_host)
    if missing:
        raise TransportError(
            f"QP snapshot missing keys {sorted(missing)} — not a "
            f"qp_init/qp_snapshot pytree")
    return {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
            for k, v in qp_host.items()}


# ---------------------------------------------------------------------------
# the connection table: many QPs on one shared CQ + SRQ (docs/transport.md).
# RDMAvisor's observation is that per-connection queue state is the
# scalability killer for RDMA-as-a-service; the converged dataplane
# answer is to multiplex every QP onto ONE completion queue (each CQE
# tagged with its qp_id + epoch, one drain loop for the whole table) and
# ONE shared receive queue whose buffers are granted to whichever QP
# delivers next.  Post order across tenants' QPs is arbitrated by the
# QoS token buckets the mediation layer already owns.
# ---------------------------------------------------------------------------

_CONN_RING_KEYS = ("send_ring", "recv_ring")
_CONN_QP_KEYS = ("sq_head", "cq_sent", "cq_rcvd", "win_hwm", "retry_cnt",
                 "backoff", "rtx_pending", "epoch", "srq_grants",
                 "retransmits", "timeouts")
_CONN_CQ_KEYS = ("cq_status", "cq_wrid", "cq_qp", "cq_epoch")
_CONN_SCALAR_KEYS = ("cq_head", "cq_tail", "cq_hwm", "cq_shed",
                     "srq_credits", "srq_owed")


def conn_init(cfg: QPConfig, num_qps: int, dtype=None) -> dict:
    """Create a connection table: ``num_qps`` QPs sharing one CQ and one
    SRQ — a pytree, like :func:`qp_init`.

    Per-QP state is vectorized ``(Q,)`` (rings ``(Q, depth, slot)``); the
    shared CQ is one ring whose entries carry ``(status, wr_id, qp_id,
    epoch)`` — the qp_id routes each completion back to its connection,
    the epoch lets a rewound QP's stale CQEs be discarded at drain time
    without flushing other QPs' completions.  ``cfg.cq_depth == 0`` sizes
    the shared ring to hold every QP's full window at once."""
    if num_qps < 1:
        raise TransportError(f"need num_qps >= 1, got {num_qps}")
    dt = jnp.dtype(dtype if dtype is not None else cfg.dtype)
    if cfg.msg_bytes % dt.itemsize:
        raise TransportError(
            f"msg_bytes={cfg.msg_bytes} not a multiple of dtype {dt.name!r} "
            f"itemsize ({dt.itemsize} B)")
    slot = cfg.msg_bytes // dt.itemsize
    Q = int(num_qps)
    D = cfg.cq_depth or max(cfg.depth, cfg.max_outstanding) * Q
    i32v = lambda: jnp.zeros((Q,), jnp.int32)
    i32 = lambda: jnp.zeros((), jnp.int32)
    conn = {
        "send_ring": jnp.zeros((Q, cfg.depth, slot), dt),
        "recv_ring": jnp.zeros((Q, cfg.depth, slot), dt),
        # per-QP queue counters (connection state, SPMD-uniform)
        "sq_head": i32v(), "cq_sent": i32v(), "cq_rcvd": i32v(),
        "win_hwm": i32v(), "retry_cnt": i32v(), "backoff": i32v(),
        "rtx_pending": i32v(), "epoch": i32v(), "srq_grants": i32v(),
        "retransmits": i32v(), "timeouts": i32v(),
        # the shared CQ
        "cq_status": jnp.zeros((D,), jnp.int32),
        "cq_wrid": jnp.full((D,), -1, jnp.int32),
        "cq_qp": jnp.full((D,), -1, jnp.int32),
        "cq_epoch": jnp.zeros((D,), jnp.int32),
        "cq_head": i32(), "cq_tail": i32(), "cq_hwm": i32(),
        "cq_shed": i32(),
        # the shared receive queue
        "srq_credits": i32(), "srq_owed": i32(),
    }
    return conn


def conn_specs(num_qps: int | None = None, axis: str = "rank") -> dict:
    """shard_map PartitionSpecs for a connection-table pytree (the
    :func:`qp_specs` analogue): payload rings sharded over ``axis``,
    everything else uniform connection state.  ``num_qps`` is accepted
    for symmetry but unused — specs are shape-free."""
    specs = {k: P() for k in
             _CONN_QP_KEYS + _CONN_CQ_KEYS + _CONN_SCALAR_KEYS}
    specs.update({k: P(axis, None, None) for k in _CONN_RING_KEYS})
    return specs


def _conn_cqe_push(conn: dict, do, status: int, wrid, qp_id, epoch) -> dict:
    """Push one tagged CQE onto the shared CQ when ``do`` holds; sheds on
    overrun into the table's cumulative ``cq_shed`` (see
    :func:`_cqe_push`)."""
    D = conn["cq_status"].shape[0]
    want = jnp.asarray(do)
    do = want & (conn["cq_head"] - conn["cq_tail"] < D)
    shed = (want & ~do).astype(jnp.int32)
    slot = jnp.mod(conn["cq_head"], D)
    upd = lambda ring, v: ring.at[slot].set(
        jnp.where(do, jnp.asarray(v, ring.dtype), ring[slot]))
    head = conn["cq_head"] + do.astype(jnp.int32)
    occ = head - conn["cq_tail"]
    return {**conn,
            "cq_status": upd(conn["cq_status"], status),
            "cq_wrid": upd(conn["cq_wrid"], wrid),
            "cq_qp": upd(conn["cq_qp"], qp_id),
            "cq_epoch": upd(conn["cq_epoch"], epoch),
            "cq_head": head,
            "cq_hwm": jnp.maximum(conn["cq_hwm"], occ),
            "cq_shed": conn["cq_shed"] + shed}


def _conn_cqe_pop(conn: dict, do) -> dict:
    """Consume the tail CQE of the shared CQ when ``do`` holds."""
    D = conn["cq_status"].shape[0]
    do = jnp.asarray(do) & (cq_occupancy(conn) > 0)
    slot = jnp.mod(conn["cq_tail"], D)
    st = jnp.where(do, CQE_EMPTY, conn["cq_status"][slot])
    return {**conn,
            "cq_status": conn["cq_status"].at[slot].set(st),
            "cq_tail": conn["cq_tail"] + do.astype(jnp.int32)}


def srq_post(dp: Dataplane, cfg: QPConfig, conn: dict, rank: jax.Array,
             dst: int, n: int = 1, state=None,
             tenant: str | None = None) -> tuple[dict, object]:
    """Post ``n`` receive buffers to the *shared* receive queue on rank
    ``dst`` — one mediated syscall grants credits any QP in the table may
    consume (the SRQ's whole point: receive memory scales with the
    table's aggregate rate, not with the QP count).  Returns
    ``(conn, state)``."""
    tok = jnp.zeros((), jnp.float32)
    tok, state = rank_mediate(tok, rank, dst, dp, tag="verbs/srq_post",
                              state=state, tenant=tenant)
    ring = tech.tie(conn["recv_ring"], tok)
    return {**conn, "recv_ring": ring,
            "srq_credits": conn["srq_credits"] + jnp.int32(n)}, state


def conn_send(dp: Dataplane, cfg: QPConfig, conn: dict, msgs: jax.Array,
              rank: jax.Array, src: int, dst: int, *, state=None,
              tenants: tuple[str, ...] | None = None, fault=None
              ) -> tuple[jax.Array, dict, object]:
    """Transmit ``msgs`` (Q, n, slot) src→dst: every QP in the table
    sends its n messages, multiplexed through the shared CQ and SRQ by
    one event loop — the connection-table analogue of
    :func:`windowed_send`.

    One event fires per tick:

    * **post** — the QoS token buckets arbitrate which eligible QP posts
      next (:meth:`~repro.core.policies.QoSPolicy.arb_scores`: the QP
      whose tenant has the most tokens-after-refill wins; ties rotate
      round-robin).  The winner pays the pipeline's send-side cost, is
      charged a token at its *traced* tenant index, consumes one SRQ
      credit, and its delivery is granted an SRQ buffer
      (``srq_grants``).  The CQE lands in the shared CQ tagged with the
      QP's id and current epoch.
    * **drain** — when no QP can post, the oldest shared CQE routes back
      to its QP by ``qp_id``: an in-order ``CQE_SEND`` acks it, a NAK or
      sequence gap rewinds *that QP only* — its epoch increments, so its
      stale CQEs are discarded at drain instead of flushing the shared
      ring under every other QP.
    * **stall** — SRQ dry: the receiver re-posts consumed buffers
      (``srq_owed``), the sender pays the interrupt-wait cost.
    * **RTO** — per-QP retransmission timers run down on idle ticks and
      rewind silently-dropped windows, exactly like
      :func:`_windowed_send_rtx`.

    ``tenants`` maps each QP to a tenant name (default: the dataplane's
    default tenant); ``fault`` injects per-transmission wire faults with
    WR identity ``qp * n + msg``.  SRQ credits must be granted via
    :func:`srq_post` first.  A QP whose retry budget exhausts turns
    fatal (``retry_cnt > cfg.retry_limit``) and its undelivered slots
    stay zero; every other QP completes bit-identically to a lossless
    run.  Returns ``(out, conn, state)``."""
    if cfg.transport != "RC":
        raise TransportError("conn_send requires RC transport")
    Q, n = int(msgs.shape[0]), int(msgs.shape[1])
    if Q != conn["sq_head"].shape[0]:
        raise TransportError(
            f"msgs has {Q} QPs but the table holds "
            f"{conn['sq_head'].shape[0]}")
    if n == 0:
        return jnp.zeros_like(msgs), conn, state
    tenants = tuple(tenants) if tenants is not None \
        else (dp.tenant,) * Q
    if len(tenants) != Q:
        raise TransportError(
            f"tenants has {len(tenants)} entries for {Q} QPs")
    W = min(cfg.max_outstanding, cfg.depth)
    ti_arr = jnp.array([dp.tenant_index(t) for t in tenants], jnp.int32)
    perm = [(src, dst)]
    stall_iters = (tech.iters_for_ns(dp.cfg.interrupt_cost_us * 1e3)
                   if dp.cfg.emulate_costs else 0)
    # per-op mediation cost, paid explicitly (the pipeline's stateful
    # stages key on a *static* tenant index; the arbitration winner is
    # traced, so the cost/bucket/counter work is applied by hand here
    # with the same stage-reported totals)
    rec = _verbs_rec(dp, msgs[0, 0], "verbs/conn_send")
    send_iters = dp.pipeline.send_delay_iters(rec)
    send_copies = dp.pipeline.send_copies(rec)
    comp_iters = dp.pipeline.complete_delay_iters(rec)
    comp_copies = dp.pipeline.complete_copies(rec)
    from repro.core.policies import QoSPolicy, QuotaPolicy
    qos = next((p for p in dp.policies
                if isinstance(p, QoSPolicy) and p.rates), None) \
        if dp.enforce else None
    rates_arr = jnp.array(qos.rates_for(tenants), jnp.float32) \
        if qos is not None else None
    quota = next((p for p in dp.policies if isinstance(p, QuotaPolicy)),
                 None) if (dp.enforce and not dp.kernel_bypass) else None
    lim_arr = jnp.array([float(quota.limits.get(t, np.inf))
                         for t in tenants], jnp.float32) \
        if quota is not None else None
    mediated = not dp.kernel_bypass

    def _pay(x, iters, copies):
        if iters:
            x = tech.delay_chain(x, iters)
        if copies:
            x = tech.staged_copy(x, copies=copies)
        return x

    fuel = ((cfg.retry_limit + 2) * Q
            * (3 * n + 2 * W + cfg.rto_ticks + cfg.backoff_ticks + 8))
    cs0 = conn["cq_sent"]
    out0 = jnp.zeros_like(msgs)
    attempts0 = jnp.zeros((Q, n), jnp.int32)
    arq = jnp.arange(Q, dtype=jnp.int32)
    arn = jnp.arange(n, dtype=jnp.int32)

    def cond(carry):
        t, conn, i_arr, out, state, attempts, rto_arr, rr = carry[:8]
        acked = conn["cq_sent"] - cs0
        fatal_q = conn["retry_cnt"] > cfg.retry_limit
        return (t < fuel) & ~jnp.all((acked >= n) | fatal_q)

    def body(carry):
        (t, conn, i_arr, out, state, attempts, rto_arr, rr,
         srtt_q, nsamp_q, last_ack_q) = carry
        on_src = rank == src
        in_flight = conn["sq_head"] - conn["cq_sent"]        # (Q,)
        fatal_q = conn["retry_cnt"] > cfg.retry_limit
        backing = conn["backoff"] > 0
        elig = ((i_arr < n) & (in_flight < W) & ~backing & ~fatal_q)
        have_srq = conn["srq_credits"] > 0
        can_post = have_srq & jnp.any(elig)
        cq_ready = cq_occupancy(conn) > 0
        do_drain = ~can_post & cq_ready
        timeout_q = ((~can_post & ~cq_ready) & (in_flight > 0)
                     & ~backing & (rto_arr <= 0))             # (Q,)
        any_timeout = jnp.any(timeout_q)
        do_stall = (~can_post & ~cq_ready & ~any_timeout
                    & ~have_srq & jnp.any(elig))

        # -- arbitration: the mediation layer's token buckets pick the
        #    next QP to post (most tokens-after-refill wins, ties rotate
        #    round-robin so equal tenants interleave fairly) -----------
        if qos is not None and state is not None and qos.name in state:
            score = qos.arb_scores(state, ti_arr, rates_arr)
        else:
            score = jnp.ones((Q,), jnp.float32)
        score = jnp.where(elig, score, -jnp.inf)
        best = jnp.max(score)
        cand = elig & (score >= best - 1e-6)
        ordk = jnp.mod(arq - rr, Q)
        pick = jnp.argmin(jnp.where(cand, ordk, Q)).astype(jnp.int32)
        oh_pick = (arq == pick) & can_post                    # (Q,)
        posted = can_post.astype(jnp.int32)
        ti_pick = ti_arr[pick]

        # -- post: cost, token charge, accounting, fault, delivery -----
        idx = jnp.minimum(i_arr[pick], n - 1)
        att = attempts[pick, idx]
        payload = msgs[pick, idx]
        wire = jnp.where(can_post, payload, jnp.zeros_like(payload))
        wire = jax.lax.cond(
            can_post & on_src,
            lambda v: _pay(v, send_iters, send_copies),
            lambda v: v, wire)
        if qos is not None:
            state = qos.charge_wr(state, ti_pick, rates_arr[pick],
                                  can_post, bump_mask=on_src)
        if mediated:
            state = _bump(state, ti_pick, on_src & can_post,
                          ops=1, bytes=rec.bytes,
                          retransmits=(att > 0).astype(jnp.int32))
        ring_slot = jnp.mod(conn["sq_head"][pick], cfg.depth)
        cur = conn["send_ring"][pick, ring_slot]
        send_ring = conn["send_ring"].at[pick, ring_slot].set(
            jnp.where(can_post, wire, cur))
        wr_payload = send_ring[pick, ring_slot]

        # -- DMA through the injected wire fault ------------------------
        rx = jax.lax.ppermute(wr_payload, cfg.axis, perm)
        wr_global = pick * n + idx
        if fault is not None:
            lost = can_post & fault.drops_wr(wr_global, att)
            bad = can_post & ~lost & fault.corrupts_wr(wr_global, att)
        else:
            lost = jnp.bool_(False)
            bad = jnp.bool_(False)
        deliver = can_post & ~lost & ~bad

        # -- delivery: an SRQ buffer is granted to whichever QP lands --
        rx = jax.lax.cond(
            deliver & (rank == dst),
            lambda v: _pay(v, comp_iters, comp_copies),
            lambda v: v, rx)
        cur = conn["recv_ring"][pick, ring_slot]
        recv_ring = conn["recv_ring"].at[pick, ring_slot].set(
            jnp.where(deliver, rx, cur))
        cur = out[pick, idx]
        out = out.at[pick, idx].set(jnp.where(deliver, rx, cur))
        conn = {**conn, "send_ring": send_ring, "recv_ring": recv_ring}
        conn = _conn_cqe_push(conn, deliver, CQE_SEND,
                              conn["sq_head"][pick], pick,
                              conn["epoch"][pick])
        conn = _conn_cqe_push(conn, bad, CQE_ERR_RETRY,
                              conn["sq_head"][pick], pick,
                              conn["epoch"][pick])
        dgrant = deliver.astype(jnp.int32)
        sq_head = conn["sq_head"] + oh_pick.astype(jnp.int32)
        conn = {**conn,
                "sq_head": sq_head,
                "srq_credits": conn["srq_credits"] - posted,
                "srq_owed": conn["srq_owed"] + posted,
                "srq_grants": conn["srq_grants"]
                + oh_pick.astype(jnp.int32) * dgrant,
                "retransmits": conn["retransmits"]
                + oh_pick.astype(jnp.int32) * (att > 0).astype(jnp.int32),
                "win_hwm": jnp.maximum(conn["win_hwm"],
                                       sq_head - conn["cq_sent"])}
        i_arr = i_arr + oh_pick.astype(jnp.int32)
        state = _bump(state, ti_pick, on_src & can_post,
                      credits=1, srq_grants=dgrant)

        # -- drain: route the oldest shared CQE back to its QP ----------
        D = conn["cq_status"].shape[0]
        tslot = jnp.mod(conn["cq_tail"], D)
        cqe_st = conn["cq_status"][tslot]
        cqe_wr = conn["cq_wrid"][tslot]
        qt = jnp.clip(conn["cq_qp"][tslot], 0, Q - 1)
        cqe_ep = conn["cq_epoch"][tslot]
        stale = do_drain & (cqe_ep != conn["epoch"][qt])
        live = do_drain & ~stale
        is_err = live & (cqe_st == CQE_ERR_RETRY)
        in_order = live & (cqe_st == CQE_SEND) \
            & (cqe_wr == conn["cq_sent"][qt])
        is_gap = live & (cqe_st == CQE_SEND) \
            & (cqe_wr > conn["cq_sent"][qt])
        oh_qt = (arq == qt)
        tok = jnp.float32(1.0)
        tok = jax.lax.cond(
            live & on_src,
            lambda v: _pay(v, comp_iters, comp_copies),
            lambda v: v, tok)
        conn = _conn_cqe_pop(conn, do_drain)
        conn = {**conn,
                "cq_sent": conn["cq_sent"]
                + (oh_qt & in_order).astype(jnp.int32)}

        # -- adaptive RTO: per-QP EWMA of in-order ack spacing -----------
        hit = oh_qt & in_order                                # (Q,)
        sample = (t - last_ack_q).astype(jnp.float32)
        srtt_q = jnp.where(hit,
                           jnp.where(nsamp_q == 0, sample,
                                     0.875 * srtt_q + 0.125 * sample),
                           srtt_q)
        nsamp_q = nsamp_q + hit.astype(jnp.int32)
        last_ack_q = jnp.where(hit, t, last_ack_q)
        if mediated:
            state = _bump(state, ti_arr[qt], on_src & live,
                          completions=1,
                          cqe_errors=is_err.astype(jnp.int32))

        # -- go-back-N rewind, per QP: NAK, gap, or RTO expiry ----------
        rew_q = (oh_qt & (is_err | is_gap)) | timeout_q       # (Q,)
        new_retry = conn["retry_cnt"] + rew_q.astype(jnp.int32)
        give_up_q = rew_q & (new_retry > cfg.retry_limit)
        do_rew_q = rew_q & ~give_up_q
        acked = conn["cq_sent"] - cs0                         # (Q,)
        attempts = attempts + (do_rew_q[:, None]
                               & (arn[None, :] >= acked[:, None])
                               & (arn[None, :] < i_arr[:, None])
                               ).astype(jnp.int32)
        i_arr = jnp.where(do_rew_q, acked, i_arr)
        conn = {**conn,
                "sq_head": jnp.where(do_rew_q, conn["cq_sent"],
                                     conn["sq_head"]),
                # the rewound QP's stale CQEs are epoch-discarded at
                # drain — the shared ring is never flushed under others
                "epoch": conn["epoch"] + do_rew_q.astype(jnp.int32),
                "backoff": jnp.where(
                    do_rew_q, jnp.int32(cfg.backoff_ticks),
                    jnp.maximum(
                        conn["backoff"] - backing.astype(jnp.int32), 0)),
                "retry_cnt": jnp.where(
                    rew_q, new_retry,
                    jnp.where(oh_qt & in_order, 0, conn["retry_cnt"])),
                "timeouts": conn["timeouts"] + timeout_q.astype(jnp.int32)}
        if state is not None and "counters" in state:
            m = (timeout_q & on_src).astype(jnp.float32)
            ctrs = state["counters"].at[ti_arr, tl.CTR_TIMEOUTS].add(m)
            state = {**state, "counters": ctrs}

        # -- quota marking (runtime plane, traced index) ----------------
        if quota is not None and state is not None \
                and "counters" in state:
            used = state["counters"][ti_pick, tl.CTR_BYTES]
            over = (used > lim_arr[pick]) & can_post & on_src
            ctrs = state["counters"].at[ti_pick, tl.CTR_DENIED].add(
                over.astype(jnp.float32))
            state = {**state, "counters": ctrs}

        # -- stall: SRQ dry — receiver re-posts, sender waits -----------
        if stall_iters:
            tok = jax.lax.cond(
                (do_stall | jnp.any(backing)) & on_src,
                lambda v: tech.delay_chain(v, stall_iters),
                lambda v: v, tok)
        repost = jnp.where(do_stall, conn["srq_owed"], 0)
        conn = {**conn,
                "srq_credits": conn["srq_credits"] + repost,
                "srq_owed": conn["srq_owed"] - repost}
        starved = jnp.argmax(elig).astype(jnp.int32)
        state = _bump(state, ti_arr[starved], on_src & do_stall, stalls=1)
        out = tech.tie(out, tok)
        state = _peak(state, ti_pick, on_src & can_post,
                      cq_occupancy(conn))

        # -- per-QP RTO: served QPs re-arm, idle in-flight QPs count down
        served = (oh_pick & can_post) | (oh_qt & live) | rew_q | backing
        armed = adaptive_rto(srtt_q, nsamp_q, cfg) if cfg.adaptive_rto \
            else jnp.full((Q,), cfg.rto_ticks, jnp.int32)
        rto_arr = jnp.where(
            served, armed,
            jnp.where((conn["sq_head"] - conn["cq_sent"]) > 0,
                      rto_arr - 1, armed))
        rr = jnp.where(can_post, jnp.mod(pick + 1, Q), rr)
        return (t + 1, conn, i_arr, out, state, attempts, rto_arr, rr,
                srtt_q, nsamp_q, last_ack_q)

    carry = (jnp.int32(0), conn, conn["sq_head"] - cs0, out0, state,
             attempts0, jnp.full((Q,), cfg.rto_ticks, jnp.int32),
             jnp.int32(0), jnp.zeros((Q,), jnp.float32),
             jnp.zeros((Q,), jnp.int32), jnp.zeros((Q,), jnp.int32))
    _, conn, _, out, state, *_ = jax.lax.while_loop(cond, body, carry)
    return out, conn, state


def conn_quiesce(dp: Dataplane, cfg: QPConfig, conn: dict, rank: jax.Array,
                 src: int, state=None,
                 tenants: tuple[str, ...] | None = None
                 ) -> tuple[dict, object]:
    """Quiesce the whole connection table (the :func:`qp_quiesce`
    analogue): drain the shared CQ one CQE per tick — routing each to its
    QP by ``qp_id``, discarding stale-epoch entries, acking in-order
    completions, marking errors and gaps in the owning QP's
    ``rtx_pending`` — then rewind every QP's unacked window into
    ``rtx_pending`` and close it.  Retry counters, backoff, epochs and
    SRQ credits are preserved, so a migrated table resumes its
    retransmission state bit-identically.  Returns ``(conn, state)``."""
    Q = int(conn["sq_head"].shape[0])
    tenants = tuple(tenants) if tenants is not None \
        else (dp.tenant,) * Q
    ti_arr = jnp.array([dp.tenant_index(t) for t in tenants], jnp.int32)
    arq = jnp.arange(Q, dtype=jnp.int32)
    D = conn["cq_status"].shape[0]

    def cond(carry):
        conn, _, _ = carry
        return cq_occupancy(conn) > 0

    def body(carry):
        conn, state, tok = carry
        tok, state = rank_complete(tok, rank, src, dp, tag="verbs/quiesce",
                                   state=state)
        tslot = jnp.mod(conn["cq_tail"], D)
        st = conn["cq_status"][tslot]
        wr = conn["cq_wrid"][tslot]
        qt = jnp.clip(conn["cq_qp"][tslot], 0, Q - 1)
        live = conn["cq_epoch"][tslot] == conn["epoch"][qt]
        is_err = live & ((st == CQE_ERR_RETRY) | (st == CQE_ERR_FATAL))
        in_order = live & (st == CQE_SEND) & (wr == conn["cq_sent"][qt])
        is_gap = live & (st == CQE_SEND) & (wr > conn["cq_sent"][qt])
        oh_qt = (arq == qt)
        state = _bump(state, ti_arr[qt], rank == src, completions=1,
                      cqe_errors=is_err.astype(jnp.int32))
        conn = _conn_cqe_pop(conn, True)
        conn = {**conn,
                "cq_sent": conn["cq_sent"]
                + (oh_qt & in_order).astype(jnp.int32),
                "rtx_pending": conn["rtx_pending"]
                + (oh_qt & (is_err | is_gap)).astype(jnp.int32)}
        return conn, state, tok

    conn, state, tok = jax.lax.while_loop(
        cond, body, (conn, state, jnp.float32(1.0)))
    dropped = conn["sq_head"] - conn["cq_sent"]   # in flight, no CQE
    conn = {**conn,
            "send_ring": tech.tie(conn["send_ring"], tok),
            "rtx_pending": conn["rtx_pending"] + dropped,
            "sq_head": conn["cq_sent"],
            "cq_rcvd": conn["cq_sent"]}
    return conn, state


def conn_snapshot(conn: dict) -> dict:
    """Stop-and-copy a (quiesced) connection table to host memory — the
    whole table, shared CQ, SRQ and in-flight retry state, in one
    checkpointable dict (see :func:`qp_snapshot`)."""
    return {k: np.asarray(jax.device_get(v)) for k, v in conn.items()}


def conn_restore(conn_host: dict, mesh, *, axis: str = "rank") -> dict:
    """``device_put`` a connection-table snapshot onto ``mesh``'s
    shardings (:func:`conn_specs`) — live migration of every QP in the
    table at once, retransmission state included."""
    specs = conn_specs(axis=axis)
    missing = set(specs) - set(conn_host)
    if missing:
        raise TransportError(
            f"connection-table snapshot missing keys {sorted(missing)} — "
            f"not a conn_init/conn_snapshot pytree")
    return {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
            for k, v in conn_host.items()}


__all__ = [
    "QPConfig", "TransportError", "UD_MTU",
    "CQE_EMPTY", "CQE_SEND", "CQE_RECV", "CQE_ERR_RETRY", "CQE_ERR_FATAL",
    "qp_init", "adaptive_rto",
    "post_send", "post_recv", "flush_send", "poll_cq", "windowed_send",
    "qp_specs", "qp_quiesce", "qp_snapshot", "qp_restore",
    "conn_init", "conn_specs", "srq_post", "conn_send",
    "conn_quiesce", "conn_snapshot", "conn_restore",
    "rank_mediate", "rank_complete", "allreduce_state", "cq_occupancy",
]
