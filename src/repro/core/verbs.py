"""ibverbs-style point-to-point layer over shard_map + ppermute.

This is the "narrow waist" (paper §4) the perftest reproduction runs on:

* **Queue pairs** are functional ring buffers of fixed-size message slots
  (the registered memory the NIC reads from / writes to).
* **post_send / post_recv** enqueue work requests.  In ``cord``/``socket``
  mode each post crosses the mediation layer (the syscall); in ``bypass``
  it is a bare ring write (the doorbell in user space).
* **flush** performs the actual transfer (the NIC DMA): one
  ``ppermute`` of the ring over the ``rank`` axis — zero-copy, the payload
  moves directly from the registered ring memory.
* **poll_cq** completes operations; with polling disabled the completion
  path pays the emulated interrupt cost.

Mediation is NOT reimplemented here: the per-endpoint issue/completion
work is the dataplane's :class:`~repro.core.mediation.MediationPipeline`
(``dp.pipeline``), applied on the active rank only via
:func:`rank_mediate` / :func:`rank_complete` — the same composable stages
the collectives and GSPMD constraints run.

Transports: ``RC`` (any message size, send/recv + one-sided READ/WRITE)
and ``UD`` (≤ 4 KiB MTU, send/recv only) — mirroring the paper's matrix.
One-sided ops mediate only on the *active* side (paper Fig. 3: RDMA read
with CoRD on the passive server has zero overhead).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import telemetry as tl
from repro.core.dataplane import Dataplane

UD_MTU = 4096


class TransportError(Exception):
    pass


@dataclass(frozen=True)
class QPConfig:
    transport: str = "RC"          # RC | UD
    msg_bytes: int = 4096
    depth: int = 16                # ring slots
    axis: str = "rank"

    def __post_init__(self):
        if self.transport not in ("RC", "UD"):
            raise TransportError(f"unknown transport {self.transport!r}")
        if self.transport == "UD" and self.msg_bytes > UD_MTU:
            raise TransportError(
                f"UD supports messages up to {UD_MTU} B, got {self.msg_bytes}")


def qp_init(cfg: QPConfig, dtype=jnp.uint8) -> dict:
    """Create QP state: send/recv rings + queue counters (a pytree)."""
    slot = cfg.msg_bytes // jnp.dtype(dtype).itemsize
    return {
        "send_ring": jnp.zeros((cfg.depth, slot), dtype),
        "recv_ring": jnp.zeros((cfg.depth, slot), dtype),
        "sq_head": jnp.zeros((), jnp.int32),     # posted sends
        "cq_sent": jnp.zeros((), jnp.int32),     # completed sends
        "cq_rcvd": jnp.zeros((), jnp.int32),     # completed (polled) recvs
    }


# ---------------------------------------------------------------------------
# per-rank conditional mediation: client and server may independently run
# bypass (BP) or CoRD (CD) — the paper's fig. 3 matrix.  Both sides'
# work is the dataplane's mediation pipeline, gated by lax.cond.
# ---------------------------------------------------------------------------

def _verbs_rec(dp: Dataplane, x: jax.Array, tag: str) -> tl.OpRecord:
    shape, dtype = tl.describe(x)
    return tl.OpRecord(kind="verbs", tag=tag, bytes=tl.nbytes(x),
                       axes=("rank",), shape=shape, dtype=dtype,
                       mode=dp.mode)


def rank_mediate(x: jax.Array, rank: jax.Array, active_rank: int,
                 dp: Dataplane, tag: str = "verbs/post") -> jax.Array:
    """Apply ``dp.pipeline``'s issue-side stages only on ``active_rank``
    (SPMD-safe; value-only — no runtime state crosses the cond)."""
    rec = _verbs_rec(dp, x, tag)
    return jax.lax.cond(rank == active_rank,
                        lambda v: dp.pipeline.send(v, rec)[0],
                        lambda v: v, x)


def rank_complete(x: jax.Array, rank: jax.Array, active_rank: int,
                  dp: Dataplane, tag: str = "verbs/completion") -> jax.Array:
    """Apply ``dp.pipeline``'s completion-side stages only on
    ``active_rank`` (interrupt wait / bounce copy)."""
    rec = _verbs_rec(dp, x, tag)
    return jax.lax.cond(rank == active_rank,
                        lambda v: dp.pipeline.complete(v, rec)[0],
                        lambda v: v, x)


# ---------------------------------------------------------------------------
# data-plane verbs (call inside shard_map over cfg.axis)
# ---------------------------------------------------------------------------

def post_send(dp: Dataplane, cfg: QPConfig, qp: dict, buf: jax.Array,
              rank: jax.Array, src: int) -> dict:
    """Enqueue ``buf`` into the send ring on rank ``src`` (the syscall)."""
    buf = rank_mediate(buf, rank, src, dp, tag="verbs/post_send")
    slot = jnp.mod(qp["sq_head"], cfg.depth)
    ring = jax.lax.dynamic_update_index_in_dim(qp["send_ring"], buf, slot, 0)
    return {**qp, "send_ring": ring, "sq_head": qp["sq_head"] + 1}


def flush_send(dp: Dataplane, cfg: QPConfig, qp: dict, rank: jax.Array,
               src: int, dst: int, *, op: str = "send",
               state=None) -> tuple[dict, object]:
    """The NIC DMA: move the send ring src→dst (or dst→src for READ).

    ``op``: "send" (two-sided), "write" / "read" (one-sided; RC only).
    Returns ``(qp, state)`` — the uniform dataplane state convention."""
    if op != "send" and cfg.transport != "RC":
        raise TransportError(f"one-sided {op!r} requires RC transport")
    perm = [(src, dst)] if op != "read" else [(dst, src)]
    ring = qp["send_ring"] if op != "read" else qp["recv_ring"]
    r, state = dp.ppermute(ring, cfg.axis, perm, tag=f"verbs/{op}",
                           mr=None, state=state)
    new = dict(qp)
    if op == "read":
        new["send_ring"] = r      # reader pulled remote memory
    else:
        new["recv_ring"] = r
    # every posted send is completed by the DMA
    new["cq_sent"] = qp["sq_head"]
    return new, state


def poll_cq(dp: Dataplane, cfg: QPConfig, qp: dict, rank: jax.Array,
            poller: int) -> tuple[jax.Array, dict]:
    """Drain the completion queue on rank ``poller``.

    Returns ``(completions, qp)`` where ``completions`` is the number of
    deliveries since the last poll (``cq_sent - cq_rcvd``) — real counts,
    not a stale counter.  Pays the interrupt cost on the polling rank when
    polling is disabled."""
    ring = rank_complete(qp["recv_ring"], rank, poller, dp,
                         tag="verbs/poll_cq")
    completed = qp["cq_sent"] - qp["cq_rcvd"]
    qp = {**qp, "recv_ring": ring, "cq_rcvd": qp["cq_sent"]}
    return completed, qp


__all__ = [
    "QPConfig", "TransportError", "UD_MTU", "qp_init",
    "post_send", "flush_send", "poll_cq", "rank_mediate", "rank_complete",
]
