"""ibverbs-style point-to-point layer over shard_map + ppermute.

This is the "narrow waist" (paper §4) the perftest reproduction runs on:

* **Queue pairs** are functional ring buffers of fixed-size message slots
  (the registered memory the NIC reads from / writes to).
* **post_send / post_recv** enqueue work requests.  In ``cord``/``socket``
  mode each post crosses the mediation layer (the syscall); in ``bypass``
  it is a bare ring write (the doorbell in user space).  ``post_recv``
  doubles as the credit grant of the flow-control protocol: every posted
  receive buffer is one credit the sender may spend.
* **flush** performs the actual transfer (the NIC DMA): one
  ``ppermute`` of the ring over the ``rank`` axis — zero-copy, the payload
  moves directly from the registered ring memory.
* **completion queue** — a real ring of per-entry status/wr_id records
  (``cq_status`` / ``cq_wrid``): the NIC pushes CQEs at ``cq_head``,
  software consumes them at ``cq_tail``.  ``poll_cq`` drains it; with
  polling disabled the completion path pays the emulated interrupt cost.
* **windowed_send** is the asynchronous runtime: a ``lax.while_loop``
  drives a sender window of up to ``max_outstanding`` work requests in
  flight.  When the window fills the sender drains its CQ (paying the
  completion-side pipeline cost per CQE); when the receiver's credits run
  out the sender stalls in traced code (paying the interrupt-wait cost)
  until the receiver re-posts its consumed buffers.
* **live migration** — because the QP is a pytree and every WR crosses
  the mediation layer, a connection can be stopped at a clean point and
  moved MigrOS-style: ``qp_quiesce`` drains the sender window to an
  empty CQ, ``qp_snapshot`` stop-and-copies the QP/CQ/credit state to
  host memory, and ``qp_restore`` device_puts it onto a (new) mesh's
  shardings (``qp_specs``), after which ``windowed_send`` resumes with
  counters and outstanding credits intact (docs/elasticity.md).

Mediation is NOT reimplemented here: the per-endpoint issue/completion
work is the dataplane's :class:`~repro.core.mediation.MediationPipeline`
(``dp.pipeline``), applied on the active rank only via
:func:`rank_mediate` / :func:`rank_complete` — the same composable stages
the collectives and GSPMD constraints run.  Both follow the uniform
``(x, state)`` runtime convention: pass ``state=dp.runtime_init()`` and
verbs traffic lands in the per-tenant counters ``dp.runtime_report``
reads (ops, bytes, stalls, credits, completions, cq_depth).

SPMD note: queue counters (heads, tails, credits) are *connection state*
— both ranks compute them identically, which keeps ``while_loop`` trip
counts uniform across the mesh.  Payload data and runtime-counter
*state* diverge per rank (only the active endpoint's pipeline bumps);
aggregate with :func:`allreduce_state` before reporting or before
snapshotting into a :class:`~repro.core.obs.CounterTimeline`.  An
aggregated state is a *report*, not a resumable state: feeding it back
into another mediated transfer would psum the already-summed base again
(exponential double counting) — start each transfer from a fresh
``runtime_init()`` and accumulate reports host-side instead, as
benchmarks/run.py's dry-run timeline does (docs/observability.md defines
the stall/credit/completion/cq_depth semantics).

Transports: ``RC`` (any message size, send/recv + one-sided READ/WRITE)
and ``UD`` (≤ 4 KiB MTU, send/recv only) — mirroring the paper's matrix.
One-sided ops mediate only on the *active* side (paper Fig. 3: RDMA read
with CoRD on the passive server has zero overhead) and consume no
receiver credits (they bypass the recv queue entirely).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import techniques as tech
from repro.core import telemetry as tl
from repro.core.dataplane import Dataplane

UD_MTU = 4096

# Completion-queue entry status codes.
CQE_EMPTY = 0     # unowned slot
CQE_SEND = 1      # send/write/read WR completed (sender-side CQE)
CQE_RECV = 2      # receive completed (delivered into a posted recv buffer)


class TransportError(Exception):
    pass


@dataclass(frozen=True)
class QPConfig:
    transport: str = "RC"          # RC | UD
    msg_bytes: int = 4096
    depth: int = 16                # ring slots
    max_outstanding: int = 8       # sender window (WRs in flight)
    cq_depth: int = 0              # CQ ring entries; 0 = max(depth, window)
    dtype: str = "uint8"           # slot element type
    axis: str = "rank"

    def __post_init__(self):
        if self.transport not in ("RC", "UD"):
            raise TransportError(f"unknown transport {self.transport!r}")
        if self.transport == "UD" and self.msg_bytes > UD_MTU:
            raise TransportError(
                f"UD supports messages up to {UD_MTU} B, got {self.msg_bytes}")
        if self.depth < 1 or self.max_outstanding < 1:
            raise TransportError(
                f"depth/max_outstanding must be >= 1, got "
                f"{self.depth}/{self.max_outstanding}")
        itemsize = jnp.dtype(self.dtype).itemsize
        if self.msg_bytes < itemsize or self.msg_bytes % itemsize:
            raise TransportError(
                f"msg_bytes={self.msg_bytes} is not a positive multiple of "
                f"dtype {self.dtype!r} itemsize ({itemsize} B) — ring slots "
                f"would silently truncate")

    @property
    def effective_cq_depth(self) -> int:
        return self.cq_depth or max(self.depth, self.max_outstanding)


def qp_init(cfg: QPConfig, dtype=None) -> dict:
    """Create QP state: send/recv rings, queue counters, and the CQ ring
    (per-entry status + wr_id, producer/consumer cursors) — a pytree."""
    dt = jnp.dtype(dtype if dtype is not None else cfg.dtype)
    if cfg.msg_bytes % dt.itemsize:
        raise TransportError(
            f"msg_bytes={cfg.msg_bytes} not a multiple of dtype {dt.name!r} "
            f"itemsize ({dt.itemsize} B)")
    slot = cfg.msg_bytes // dt.itemsize
    D = cfg.effective_cq_depth
    i32 = lambda: jnp.zeros((), jnp.int32)
    return {
        "send_ring": jnp.zeros((cfg.depth, slot), dt),
        "recv_ring": jnp.zeros((cfg.depth, slot), dt),
        "sq_head": i32(),        # posted sends
        "cq_sent": i32(),        # completed (consumed) sends
        "cq_rcvd": i32(),        # completed (polled) recvs
        # the completion queue proper
        "cq_status": jnp.zeros((D,), jnp.int32),
        "cq_wrid": jnp.full((D,), -1, jnp.int32),
        "cq_head": i32(),        # CQEs produced (NIC side)
        "cq_tail": i32(),        # CQEs consumed (software side)
        "cq_hwm": i32(),         # CQ occupancy high-water mark
        # credit-based flow control
        "credits": i32(),        # rx buffers granted via post_recv
        "rx_owed": i32(),        # delivered recvs awaiting re-post
        "win_hwm": i32(),        # max observed in-flight window
    }


# ---------------------------------------------------------------------------
# per-rank conditional mediation: client and server may independently run
# bypass (BP) or CoRD (CD) — the paper's fig. 3 matrix.  Both sides'
# work is the dataplane's mediation pipeline, gated by lax.cond, with the
# uniform (x, state) runtime convention threaded through the cond.
# ---------------------------------------------------------------------------

def _verbs_rec(dp: Dataplane, x: jax.Array, tag: str) -> tl.OpRecord:
    shape, dtype = tl.describe(x)
    return tl.OpRecord(kind="verbs", tag=tag, bytes=tl.nbytes(x),
                       axes=("rank",), shape=shape, dtype=dtype,
                       mode=dp.mode)


def rank_mediate(x: jax.Array, rank: jax.Array, active_rank,
                 dp: Dataplane, tag: str = "verbs/post", state=None,
                 tenant: str | None = None):
    """Apply ``dp.pipeline``'s issue-side stages only on ``active_rank``
    (SPMD-safe).  Returns ``(x, state)``: the active rank's runtime state
    picks up the pipeline's per-tenant accounting, other ranks pass
    through untouched."""
    rec = _verbs_rec(dp, x, tag)
    ti = dp.tenant_index(tenant)
    return jax.lax.cond(rank == active_rank,
                        lambda ops: dp.pipeline.send(ops[0], rec, ops[1], ti),
                        lambda ops: ops, (x, state))


def rank_complete(x: jax.Array, rank: jax.Array, active_rank,
                  dp: Dataplane, tag: str = "verbs/completion", state=None,
                  tenant: str | None = None):
    """Apply ``dp.pipeline``'s completion-side stages only on
    ``active_rank`` (interrupt wait / bounce copy).  Returns
    ``(x, state)`` — same convention as :func:`rank_mediate`."""
    rec = _verbs_rec(dp, x, tag)
    ti = dp.tenant_index(tenant)
    return jax.lax.cond(
        rank == active_rank,
        lambda ops: dp.pipeline.complete(ops[0], rec, ops[1], ti),
        lambda ops: ops, (x, state))


def _bump(state, tenant_idx: int, mask, **kw):
    """Masked per-tenant counter bump; no-op when state carries none."""
    if state is None or "counters" not in state:
        return state
    m = jnp.asarray(mask).astype(jnp.float32)
    ctrs = tl.tenant_counters_bump(state["counters"], tenant_idx,
                                   **{k: m * v for k, v in kw.items()})
    return {**state, "counters": ctrs}


def _peak(state, tenant_idx: int, mask, depth):
    if state is None or "counters" not in state:
        return state
    m = jnp.asarray(mask).astype(jnp.float32)
    ctrs = tl.tenant_counters_peak(state["counters"], tenant_idx,
                                   cq_depth=m * depth)
    return {**state, "counters": ctrs}


def allreduce_state(state, axis: str = "rank"):
    """Aggregate a runtime-state pytree over the mesh axis so a single
    report covers both endpoints (each side's pipeline bumps only its own
    rank's state).  Additive counters are summed; the ``cq_depth``
    high-water column is a peak, so it takes the max across ranks.  Call
    as the last step of a shard_map body."""
    if state is None:
        return None
    out = {}
    for k, v in state.items():
        summed = jax.tree.map(lambda a: jax.lax.psum(a, axis), v)
        if k == "counters":
            peak = jax.lax.pmax(v[..., tl.CTR_CQ_DEPTH], axis)
            summed = summed.at[..., tl.CTR_CQ_DEPTH].set(peak)
        out[k] = summed
    return out


# ---------------------------------------------------------------------------
# CQ ring primitives (uniform connection state — no rank gating)
# ---------------------------------------------------------------------------

def _cqe_push(qp: dict, cfg: QPConfig, do, status: int, wrid):
    """Push one CQE when ``do`` (traced bool) holds; track the occupancy
    high-water mark.  A full ring drops the CQE (a real CQ overrun is
    fatal; the emulation sheds instead — the legacy counters still
    advance, so poll counts stay correct)."""
    D = cfg.effective_cq_depth
    do = do & (qp["cq_head"] - qp["cq_tail"] < D)
    slot = jnp.mod(qp["cq_head"], D)
    st = jnp.where(do, status, qp["cq_status"][slot])
    wi = jnp.where(do, wrid, qp["cq_wrid"][slot])
    head = qp["cq_head"] + do.astype(jnp.int32)
    occ = head - qp["cq_tail"]
    return {**qp,
            "cq_status": qp["cq_status"].at[slot].set(st),
            "cq_wrid": qp["cq_wrid"].at[slot].set(wi),
            "cq_head": head,
            "cq_hwm": jnp.maximum(qp["cq_hwm"], occ)}


def _cqe_push_n(qp: dict, cfg: QPConfig, n, status: int, wrid0):
    """Push ``n`` CQEs (traced count) with consecutive wr_ids starting at
    ``wrid0``, clamped to the ring's free space — excess CQEs are shed
    rather than overwriting unconsumed entries (see :func:`_cqe_push`)."""
    D = cfg.effective_cq_depth
    free = jnp.maximum(D - (qp["cq_head"] - qp["cq_tail"]), 0)
    n = jnp.clip(jnp.asarray(n, jnp.int32), 0, free)
    k = jnp.arange(D, dtype=jnp.int32)
    mask = k < n
    idx = jnp.mod(qp["cq_head"] + k, D)
    st = jnp.where(mask, status, qp["cq_status"][idx])
    wi = jnp.where(mask, wrid0 + k, qp["cq_wrid"][idx])
    head = qp["cq_head"] + n
    occ = head - qp["cq_tail"]
    return {**qp,
            "cq_status": qp["cq_status"].at[idx].set(st),
            "cq_wrid": qp["cq_wrid"].at[idx].set(wi),
            "cq_head": head,
            "cq_hwm": jnp.maximum(qp["cq_hwm"], occ)}


def _cqe_consume(qp: dict, cfg: QPConfig, n):
    """Consume ``n`` CQEs from the tail (slots return to CQE_EMPTY)."""
    D = cfg.effective_cq_depth
    avail = qp["cq_head"] - qp["cq_tail"]
    n = jnp.clip(jnp.asarray(n, jnp.int32), 0, jnp.minimum(avail, D))
    k = jnp.arange(D, dtype=jnp.int32)
    mask = k < n
    idx = jnp.mod(qp["cq_tail"] + k, D)
    st = jnp.where(mask, CQE_EMPTY, qp["cq_status"][idx])
    return {**qp,
            "cq_status": qp["cq_status"].at[idx].set(st),
            "cq_tail": qp["cq_tail"] + n}


def cq_occupancy(qp: dict) -> jax.Array:
    """Outstanding (unconsumed) CQEs."""
    return qp["cq_head"] - qp["cq_tail"]


# ---------------------------------------------------------------------------
# data-plane verbs (call inside shard_map over cfg.axis)
# ---------------------------------------------------------------------------

def post_send(dp: Dataplane, cfg: QPConfig, qp: dict, buf: jax.Array,
              rank: jax.Array, src: int, state=None,
              tenant: str | None = None) -> tuple[dict, object]:
    """Enqueue ``buf`` into the send ring on rank ``src`` (the syscall).
    Returns ``(qp, state)``."""
    buf, state = rank_mediate(buf, rank, src, dp, tag="verbs/post_send",
                              state=state, tenant=tenant)
    slot = jnp.mod(qp["sq_head"], cfg.depth)
    ring = jax.lax.dynamic_update_index_in_dim(qp["send_ring"], buf, slot, 0)
    return {**qp, "send_ring": ring, "sq_head": qp["sq_head"] + 1}, state


def post_recv(dp: Dataplane, cfg: QPConfig, qp: dict, rank: jax.Array,
              dst: int, n: int = 1, state=None,
              tenant: str | None = None) -> tuple[dict, object]:
    """Post ``n`` receive buffers on rank ``dst`` — the receiver's syscall
    and the credit grant of the flow-control protocol.  Returns
    ``(qp, state)``."""
    tok = jnp.zeros((), jnp.float32)
    tok, state = rank_mediate(tok, rank, dst, dp, tag="verbs/post_recv",
                              state=state, tenant=tenant)
    ring = tech.tie(qp["recv_ring"], tok)
    return {**qp, "recv_ring": ring,
            "credits": qp["credits"] + jnp.int32(n)}, state


def flush_send(dp: Dataplane, cfg: QPConfig, qp: dict, rank: jax.Array,
               src: int, dst: int, *, op: str = "send",
               state=None) -> tuple[dict, object]:
    """The NIC DMA: move the send ring src→dst (or dst→src for READ).

    ``op``: "send" (two-sided), "write" / "read" (one-sided; RC only).
    Send/write completions land in the CQ ring; a READ moves remote
    memory without completing any posted send (one-sided ops never touch
    the send queue's completions).  Returns ``(qp, state)`` — the uniform
    dataplane state convention."""
    if op != "send" and cfg.transport != "RC":
        raise TransportError(f"one-sided {op!r} requires RC transport")
    perm = [(src, dst)] if op != "read" else [(dst, src)]
    ring = qp["send_ring"] if op != "read" else qp["recv_ring"]
    r, state = dp.ppermute(ring, cfg.axis, perm, tag=f"verbs/{op}",
                           mr=None, state=state)
    new = dict(qp)
    if op == "read":
        new["send_ring"] = r      # reader pulled remote memory
    else:
        new["recv_ring"] = r
        # the DMA completes every posted send — push their CQEs
        ncomp = qp["sq_head"] - qp["cq_sent"]
        new = _cqe_push_n(new, cfg, ncomp, CQE_SEND, qp["cq_sent"])
        new["cq_sent"] = qp["sq_head"]
    return new, state


def poll_cq(dp: Dataplane, cfg: QPConfig, qp: dict, rank: jax.Array,
            poller: int, state=None,
            tenant: str | None = None) -> tuple[jax.Array, dict, object]:
    """Drain the completion queue on rank ``poller``.

    Returns ``(completions, qp, state)`` where ``completions`` is the
    number of deliveries since the last poll (``cq_sent - cq_rcvd``) —
    real counts, not a stale counter.  Consumes every outstanding CQE in
    the ring and bumps the poller's ``completions`` runtime counter.
    Pays the interrupt cost on the polling rank when polling is
    disabled."""
    ring, state = rank_complete(qp["recv_ring"], rank, poller, dp,
                                tag="verbs/poll_cq", state=state,
                                tenant=tenant)
    completed = qp["cq_sent"] - qp["cq_rcvd"]
    state = _bump(state, dp.tenant_index(tenant), rank == poller,
                  completions=completed)
    qp = _cqe_consume(qp, cfg, cq_occupancy(qp))
    qp = {**qp, "recv_ring": ring, "cq_rcvd": qp["cq_sent"]}
    return completed, qp, state


# ---------------------------------------------------------------------------
# the CQ-driven async runtime: sender window + credit flow control
# ---------------------------------------------------------------------------

def windowed_send(dp: Dataplane, cfg: QPConfig, qp: dict, msgs: jax.Array,
                  rank: jax.Array, src: int, dst: int, *, op: str = "send",
                  state=None, tenant: str | None = None,
                  dp_peer: Dataplane | None = None
                  ) -> tuple[jax.Array, dict, object]:
    """Transmit ``msgs`` (n, slot) src→dst through the async CQ runtime.

    A ``lax.while_loop`` drives one WR event per tick:

    * **post** — when the window (``cfg.max_outstanding``) has room and
      (two-sided only) a receiver credit is available: the payload is
      written into the send ring (send-side pipeline cost on ``src``),
      DMA'd, delivered on the receiving rank, and its CQE pushed.
    * **drain** — when the window is full (or input is exhausted): the
      sender consumes the oldest CQE, paying the completion-side pipeline
      cost — lazy polling, exactly perftest's post-then-poll loop.
    * **stall** — two-sided sends with no credits left: the sender pays
      the interrupt-wait cost in traced code, after which the receiver
      re-posts its consumed buffers (credits resume).

    Returns ``(out, qp, state)``: ``out`` is (n, slot) with the delivered
    payloads on the receiving rank (``dst``, or ``src`` for READ — other
    ranks hold zeros).  Queue counters are connection state (identical on
    both ranks — uniform while_loop trip counts); runtime-counter state
    diverges per rank and should be aggregated with
    :func:`allreduce_state` before reporting.

    For ``op="send"`` the receiver must have granted credits via
    :func:`post_recv` first; a zero-credit sender can never resume (the
    loop's fuel bound then returns undelivered zeros).  One-sided
    write/read consume no credits.  For ``op="read"`` ``msgs`` is the
    remote memory (resident on ``dst``) and the reader pulls it."""
    if op not in ("send", "write", "read"):
        raise TransportError(f"unknown windowed op {op!r}")
    if op != "send" and cfg.transport != "RC":
        raise TransportError(f"one-sided {op!r} requires RC transport")
    n = int(msgs.shape[0])
    if n == 0:
        return jnp.zeros_like(msgs), qp, state
    W = min(cfg.max_outstanding, cfg.effective_cq_depth)
    uses_credits = op == "send"
    dp_peer = dp_peer if dp_peer is not None else dp
    ti = dp.tenant_index(tenant)
    perm = [(src, dst)] if op != "read" else [(dst, src)]
    stall_iters = (tech.iters_for_ns(dp.cfg.interrupt_cost_us * 1e3)
                   if dp.cfg.emulate_costs else 0)
    # fuel: every message needs at most post + drain + stall ticks, plus
    # the tail drain of a full window — a hard bound on loop length.
    fuel = 3 * n + 2 * W + 8
    tag = f"verbs/windowed_{op}"

    sq0, cs0 = qp["sq_head"], qp["cq_sent"]
    out0 = jnp.zeros_like(msgs)

    def cond(carry):
        t, i, qp, out, state = carry
        done = (i >= n) & (qp["cq_sent"] - cs0 >= n)
        return (t < fuel) & ~done

    def body(carry):
        t, i, qp, out, state = carry
        in_flight = qp["sq_head"] - qp["cq_sent"]
        have_credit = (qp["credits"] > 0) if uses_credits \
            else jnp.bool_(True)
        can_post = (i < n) & (in_flight < W) & have_credit
        cq_ready = cq_occupancy(qp) > 0
        do_drain = ~can_post & cq_ready & ((in_flight >= W) | (i >= n))
        do_stall = ~can_post & ~do_drain & (i < n) & (in_flight < W)
        posted = can_post.astype(jnp.int32)
        on_src = rank == src

        # -- post: the sender's syscall ---------------------------------
        idx = jnp.minimum(i, n - 1)
        payload = jax.lax.dynamic_index_in_dim(msgs, idx, 0, keepdims=False)
        wire = jnp.where(can_post, payload, jnp.zeros_like(payload))
        wire, state = jax.lax.cond(
            can_post,
            lambda ops: rank_mediate(ops[0], rank, src, dp, tag=tag,
                                     state=ops[1], tenant=tenant),
            lambda ops: ops, (wire, state))
        ring_slot = jnp.mod(qp["sq_head"], cfg.depth)
        send_ring = jax.lax.cond(
            can_post,
            lambda r: jax.lax.dynamic_update_index_in_dim(r, wire,
                                                          ring_slot, 0),
            lambda r: r, qp["send_ring"])
        # the NIC reads the registered ring directly (zero copy)
        wr = jax.lax.dynamic_index_in_dim(send_ring, ring_slot, 0,
                                          keepdims=False)
        if op == "read":
            # reader pulls remote memory: the wire carries dst's msgs[idx]
            wr = jnp.where(can_post, payload, jnp.zeros_like(payload))

        # -- DMA --------------------------------------------------------
        rx = jax.lax.ppermute(wr, cfg.axis, perm)

        # -- delivery: land the payload, ack with a CQE -----------------
        if uses_credits:
            # receiver-side completion handling (per-message poll or
            # interrupt on dst) — one-sided ops involve no remote CPU
            rx, state = jax.lax.cond(
                can_post,
                lambda ops: rank_complete(ops[0], rank, dst, dp_peer,
                                          tag="verbs/rx_complete",
                                          state=ops[1], tenant=tenant),
                lambda ops: ops, (rx, state))
        recv_ring = jax.lax.cond(
            can_post,
            lambda r: jax.lax.dynamic_update_index_in_dim(
                r, rx, jnp.mod(ring_slot, cfg.depth), 0),
            lambda r: r, qp["recv_ring"])
        out = jax.lax.cond(
            can_post,
            lambda o: jax.lax.dynamic_update_index_in_dim(o, rx, idx, 0),
            lambda o: o, out)
        qp = {**qp, "send_ring": send_ring, "recv_ring": recv_ring}
        qp = _cqe_push(qp, cfg, can_post, CQE_SEND, qp["sq_head"])
        sq_head = qp["sq_head"] + posted
        credits = qp["credits"] - (posted if uses_credits else 0)
        rx_owed = qp["rx_owed"] + (posted if uses_credits else 0)
        win = sq_head - qp["cq_sent"]
        qp = {**qp, "sq_head": sq_head, "credits": credits,
              "rx_owed": rx_owed,
              "win_hwm": jnp.maximum(qp["win_hwm"], win)}

        # -- drain: lazy CQ poll on the sender --------------------------
        tok = jnp.float32(1.0)
        tok, state = jax.lax.cond(
            do_drain,
            lambda ops: rank_complete(ops[0], rank, src, dp,
                                      tag="verbs/cq_drain", state=ops[1],
                                      tenant=tenant),
            lambda ops: ops, (tok, state))
        qp = _cqe_consume(qp, cfg, do_drain.astype(jnp.int32))
        qp = {**qp, "cq_sent": qp["cq_sent"] + do_drain.astype(jnp.int32)}

        # -- stall: credit exhaustion -----------------------------------
        if uses_credits:
            if stall_iters:
                tok = jax.lax.cond(
                    do_stall & on_src,
                    lambda v: tech.delay_chain(v, stall_iters),
                    lambda v: v, tok)
            # the stalled sender's wakeup: the receiver polled its recvs
            # and re-posted every consumed buffer
            repost = jnp.where(do_stall, qp["rx_owed"], 0)
            qp = {**qp, "credits": qp["credits"] + repost,
                  "rx_owed": qp["rx_owed"] - repost}
        out = tech.tie(out, tok)

        # -- runtime accounting (active side only) ----------------------
        state = _bump(state, ti, on_src & can_post,
                      credits=1 if uses_credits else 0)
        state = _bump(state, ti, on_src & do_drain, completions=1)
        state = _bump(state, ti, on_src & do_stall, stalls=1)
        state = _peak(state, ti, on_src, cq_occupancy(qp))
        return t + 1, i + posted, qp, out, state

    _, _, qp, out, state = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.int32(0), qp, out0, state))
    return out, qp, state


# ---------------------------------------------------------------------------
# live QP migration (MigrOS-style): quiesce → stop-and-copy → restore.
# The OS-control payoff of staying on the dataplane (docs/elasticity.md):
# because every WR crosses the mediation layer, the kernel can stop a
# connection at a clean point, copy its state, and resume it elsewhere —
# exactly what kernel bypass gives up.
# ---------------------------------------------------------------------------

# Payload rings diverge per rank; every other QP leaf is uniform
# connection state (see the SPMD note in the module docstring).
_QP_RING_KEYS = ("send_ring", "recv_ring")
_QP_UNIFORM_KEYS = ("sq_head", "cq_sent", "cq_rcvd", "cq_status", "cq_wrid",
                    "cq_head", "cq_tail", "cq_hwm", "credits", "rx_owed",
                    "win_hwm")


def qp_specs(axis: str = "rank") -> dict:
    """shard_map PartitionSpecs for a QP pytree: payload rings are
    sharded over ``axis`` (they diverge per rank), queue cursors, the CQ
    ring and the credit counters are uniform connection state and stay
    unsharded.  Use as in/out specs when threading a QP through a
    shard_map boundary, so the pytree can be snapshotted between calls
    and migrated across meshes."""
    specs = {k: P() for k in _QP_UNIFORM_KEYS}
    specs.update({k: P(axis, None) for k in _QP_RING_KEYS})
    return specs


def qp_quiesce(dp: Dataplane, cfg: QPConfig, qp: dict, rank: jax.Array,
               src: int, state=None, tenant: str | None = None
               ) -> tuple[dict, object]:
    """Drain the connection to a migratable snapshot (MigrOS's stop
    phase).  A bounded ``while_loop`` consumes the CQ one entry per tick,
    paying the completion-side pipeline cost per CQE on ``src`` exactly
    like ``windowed_send``'s lazy drains, then acknowledges every
    completed WR (``cq_sent``/``cq_rcvd`` catch up to ``sq_head``).

    On return the CQ is empty and the sender window is closed; credits,
    ``rx_owed`` and every cumulative counter are untouched, so a
    windowed transfer split around a quiesce → :func:`qp_snapshot` →
    :func:`qp_restore` sequence completes bit-identically to an
    uninterrupted one (tests/test_elastic_trigger.py).  Returns
    ``(qp, state)`` — the uniform dataplane convention."""
    ti = dp.tenant_index(tenant)

    def cond(carry):
        qp, _, _ = carry
        return cq_occupancy(qp) > 0

    def body(carry):
        qp, state, tok = carry
        tok, state = rank_complete(tok, rank, src, dp, tag="verbs/quiesce",
                                   state=state, tenant=tenant)
        state = _bump(state, ti, rank == src, completions=1)
        qp = _cqe_consume(qp, cfg, 1)
        return qp, state, tok

    qp, state, tok = jax.lax.while_loop(
        cond, body, (qp, state, jnp.float32(1.0)))
    qp = {**qp,
          "send_ring": tech.tie(qp["send_ring"], tok),
          "cq_sent": qp["sq_head"],
          "cq_rcvd": qp["sq_head"]}
    return qp, state


def qp_snapshot(qp: dict) -> dict:
    """Stop-and-copy: fetch a (quiesced) QP pytree into host memory as
    plain numpy — checkpointable, and the input :func:`qp_restore`
    expects.  Call on the global (post-shard_map) pytree, strictly
    between traced calls."""
    return {k: np.asarray(jax.device_get(v)) for k, v in qp.items()}


def qp_restore(qp_host: dict, mesh, *, axis: str = "rank") -> dict:
    """MigrOS restore: ``device_put`` a QP snapshot onto ``mesh``'s
    shardings (:func:`qp_specs` — rings sharded over ``axis``, connection
    state replicated) so a windowed transfer resumes where it stopped —
    queue cursors, outstanding credits and owed re-posts intact — on the
    new mesh."""
    specs = qp_specs(axis)
    missing = set(specs) - set(qp_host)
    if missing:
        raise TransportError(
            f"QP snapshot missing keys {sorted(missing)} — not a "
            f"qp_init/qp_snapshot pytree")
    return {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
            for k, v in qp_host.items()}


__all__ = [
    "QPConfig", "TransportError", "UD_MTU",
    "CQE_EMPTY", "CQE_SEND", "CQE_RECV", "qp_init",
    "post_send", "post_recv", "flush_send", "poll_cq", "windowed_send",
    "qp_specs", "qp_quiesce", "qp_snapshot", "qp_restore",
    "rank_mediate", "rank_complete", "allreduce_state", "cq_occupancy",
]
