"""The composable mediation pipeline — CoRD's "kernel on the data path"
as one reusable artifact.

The paper's claim is that OS-level control over the RDMA dataplane is
cheap because mediation is built from a handful of composable techniques.
This module is that composition: a :class:`MediationPipeline` is an
ordered list of :class:`MediationStage` objects, compiled once per
:class:`~repro.core.dataplane.Dataplane` from its mode, technique toggles
and policy set by :func:`build_pipeline`.  Every path that crosses the
dataplane — GSPMD sharding constraints, explicit shard_map collectives,
and the ibverbs-style point-to-point layer — runs the *same* pipeline, so
mode/policy ablations apply identically everywhere.

Stages (declared order):

  ============== ========================================== ==============
  stage          emulates                                   side
  ============== ========================================== ==============
  syscall-cost   user→kernel crossing (kernel bypass off)   send
  socket-stack   full kernel network stack + per-byte cost  send
  staged-copy    bounce-buffer copies (zero copy off)       send+complete
  interrupt-wait interrupt delivery + wakeup (polling off)  complete
  token-bucket   per-tenant QoS rate limiting (QoSPolicy)   send
  counter-bump   per-tenant runtime accounting + quota mark send
  ============== ========================================== ==============

Every stage preserves values bit-exactly: mediation changes *cost* and
*state*, never results.

Runtime state is a pytree dict threaded through shard_map bodies with the
uniform ``(x, state)`` convention:

    state = dp.runtime_init()              # {"counters": (T, C) f32, ...}
    out, state = dp.psum(x, "data", state=state)

``state=None`` disables all stateful stages (GSPMD constraint paths,
where no state can be threaded, pass None).

:class:`HostTokenBucket` is the host-side mirror of the traced token
bucket, used by the serving engine for tenant admission control.

The per-tenant counter blocks the ``counter-bump`` stage maintains are
the feed for the observability timelines (core/obs.py): snapshot
``dp.runtime_report(state)`` between steps to stream this pipeline's
accounting into rate series and panels.  docs/architecture.md maps the
stages to the paper's techniques; docs/observability.md defines each
counter.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import techniques as tech
from repro.core import telemetry as tl
from repro.core.policies import Policy, QoSPolicy, QuotaPolicy


# ---------------------------------------------------------------------------
# Stage protocol
# ---------------------------------------------------------------------------

class MediationStage:
    """One composable mediation technique.

    ``send`` runs on the issue side (before the NIC DMA / collective);
    ``complete`` on the completion side.  Both must return ``x``
    value-identical — a stage may delay, copy, account or throttle, never
    alter.  ``send_delay_iters`` / ``complete_delay_iters`` report the
    stage's static serial-delay cost so benchmark harnesses can aggregate
    per-op mediation work without reimplementing the cost model.

    ``stateful = False`` declares a *pure cost* stage: its entire effect is
    the static delay iterations and staged-copy passes it reports, so a
    fused pipeline may sum those across stages and emit ONE delay chain
    and ONE copy pass per side instead of running the stage hooks.
    Stateful stages (accounting, throttling, anything a subclass adds)
    always run their hooks in declared order."""

    name = "stage"
    stateful = True

    def send(self, x, rec: tl.OpRecord, state, tenant_idx: int):
        return x, state

    def complete(self, x, rec: tl.OpRecord, state, tenant_idx: int):
        return x, state

    def send_delay_iters(self, rec: tl.OpRecord) -> int:
        return 0

    def complete_delay_iters(self, rec: tl.OpRecord) -> int:
        return 0

    def send_copies(self, rec: tl.OpRecord) -> int:
        return 0

    def complete_copies(self, rec: tl.OpRecord) -> int:
        return 0

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


class SyscallCostStage(MediationStage):
    """The user→kernel crossing paid per op when kernel bypass is off."""

    name = "syscall-cost"
    stateful = False

    def __init__(self, syscall_ns: float):
        self.syscall_ns = float(syscall_ns)

    def send(self, x, rec, state, tenant_idx):
        return tech.delay_chain(x, self.send_delay_iters(rec)), state

    def send_delay_iters(self, rec):
        return tech.iters_for_ns(self.syscall_ns)


class SocketStackStage(MediationStage):
    """The extra cost of the full kernel network stack (socket mode):
    a fixed per-op term plus a per-payload-byte term (IPoIB bandwidth
    degradation)."""

    name = "socket-stack"
    stateful = False

    def __init__(self, stack_ns: float, ns_per_byte: float):
        self.stack_ns = float(stack_ns)
        self.ns_per_byte = float(ns_per_byte)

    def send(self, x, rec, state, tenant_idx):
        return tech.delay_chain(x, self.send_delay_iters(rec)), state

    def send_delay_iters(self, rec):
        return tech.iters_for_ns(self.stack_ns + rec.bytes * self.ns_per_byte)


class StagedCopyStage(MediationStage):
    """Bounce-buffer copies on both sides when zero copy is removed.

    With ``pallas=True`` the copies are the real Pallas bounce-buffer
    kernel (``kernels/dataplane``): double-buffered DMA through a VMEM
    scratch slot instead of the XLA roll/barrier emulation.  Output is
    bit-identical either way."""

    name = "staged-copy"
    stateful = False

    def __init__(self, copies: int = 1, pallas: bool = False):
        self.copies = int(copies)
        self.pallas = bool(pallas)

    def _copy(self, x):
        if self.pallas:
            from repro.kernels import dataplane as dk
            return dk.bounce_copy(x, copies=self.copies)
        return tech.staged_copy(x, copies=self.copies)

    def send(self, x, rec, state, tenant_idx):
        return self._copy(x), state

    def complete(self, x, rec, state, tenant_idx):
        return self._copy(x), state

    def send_copies(self, rec):
        return self.copies

    def complete_copies(self, rec):
        return self.copies


class InterruptWaitStage(MediationStage):
    """Wait-for-event completion: interrupt delivery + wakeup instead of
    busy polling."""

    name = "interrupt-wait"
    stateful = False

    def __init__(self, interrupt_us: float):
        self.interrupt_us = float(interrupt_us)

    def complete(self, x, rec, state, tenant_idx):
        return tech.delay_chain(x, self.complete_delay_iters(rec)), state

    def complete_delay_iters(self, rec):
        return tech.iters_for_ns(self.interrupt_us * 1e3)


class TokenBucketStage(MediationStage):
    """Per-tenant QoS throttling: delegates to QoSPolicy.on_op_runtime
    (the traced token bucket)."""

    name = "token-bucket"

    def __init__(self, policy: QoSPolicy, tenants: tuple[str, ...]):
        self.policy = policy
        self.tenants = tenants

    def send(self, x, rec, state, tenant_idx):
        if rec.precharged:
            # chunk-granular preemption (core/chunking.py) already
            # debited this op's tokens chunk by chunk — charging the
            # assembled op again would double-bill the tenant.
            return x, state
        return self.policy.on_op_runtime(x, state, rec,
                                         self.tenants[tenant_idx], tenant_idx)


class CounterBumpStage(MediationStage):
    """The 'syscall body': bump the issuing tenant's runtime counters, then
    let the quota policy mark over-budget traffic."""

    name = "counter-bump"

    def __init__(self, tenants: tuple[str, ...],
                 quota: QuotaPolicy | None = None):
        self.tenants = tenants
        self.quota = quota

    def send(self, x, rec, state, tenant_idx):
        if state is None or "counters" not in state:
            return x, state
        ctrs = tl.tenant_counters_bump(state["counters"], tenant_idx,
                                       ops=rec.count,
                                       bytes=rec.bytes * rec.count)
        state = {**state, "counters": ctrs}
        if self.quota is not None:
            x, state = self.quota.on_op_runtime(
                x, state, rec, self.tenants[tenant_idx], tenant_idx)
        return x, state


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------

class MediationPipeline:
    """An ordered composition of mediation stages.

    ``send``/``complete`` apply the stages in declared order.  An empty
    pipeline (bypass mode) is the identity — the OS is off the data path.

    With ``fused=True`` (the default) the pure-cost stages are *fused*:
    their static delay iterations are summed into ONE ``delay_chain`` and
    their bounce-buffer passes into ONE ``staged_copy`` per side, instead
    of one chain/copy per stage.  That shrinks the per-op HLO on every
    dataplane edge (one while-loop + one barrier pair instead of N) while
    staying bit-identical — every fused stage is value-preserving by
    contract, and total serial cost is unchanged because delay iterations
    add linearly.  Stateful stages (token-bucket, counter-bump, custom
    subclasses) still run their hooks in declared order.

    With ``pallas=True`` a fused pure-cost side is ONE Pallas kernel
    launch (``mediated_cost`` in kernels/dataplane): the summed delay
    iterations burn on the scalar core between a chunk's DMA copy-in
    and copy-out, and the summed bounce passes are real double-buffered
    VMEM copies — measured-mode mediation cost becomes a hardware
    measurement instead of an XLA emulation, still bit-identical."""

    def __init__(self, stages=(), fused: bool = True, pallas: bool = False):
        self.stages: tuple[MediationStage, ...] = tuple(stages)
        self.fused = bool(fused)
        self.pallas = bool(pallas)

    @property
    def stage_names(self) -> tuple[str, ...]:
        return tuple(s.name for s in self.stages)

    def _pure_cost(self, rec, side: str) -> tuple[int, int]:
        iters = sum(getattr(s, f"{side}_delay_iters")(rec)
                    for s in self.stages if not s.stateful)
        copies = sum(getattr(s, f"{side}_copies")(rec)
                     for s in self.stages if not s.stateful)
        return iters, copies

    def _kernel_ctr_bump(self, state, tenant_idx, kernel_iters,
                         kernel_copies):
        """Land a side's in-kernel cost work in the tenant counter block
        (``kernel_iters``/``kernel_copies``)."""
        if state is None or "counters" not in state:
            return state
        ctrs = tl.tenant_counters_bump(state["counters"], tenant_idx,
                                       kernel_iters=kernel_iters,
                                       kernel_copies=kernel_copies)
        return {**state, "counters": ctrs}

    def _static_cost_bump(self, x, rec, state, tenant_idx, side: str):
        """The XLA-emulation (and unfused) half of the kernel-cost
        accounting: bump the totals the cost kernel's SMEM counters
        *would* sum to for this payload, so reports are bit-identical
        across pallas on/off and fused/unfused."""
        iters, copies = self._pure_cost(rec, side)
        if not (iters or copies) or state is None or "counters" not in state:
            return state
        from repro.kernels.dataplane import kernel_cost_totals
        kit, kcp = kernel_cost_totals(x.size, iters, copies)
        return self._kernel_ctr_bump(state, tenant_idx, kit, kcp)

    def _fused_side(self, x, rec, state, tenant_idx, side: str):
        iters, copies = self._pure_cost(rec, side)
        if self.pallas and (iters or copies):
            from repro.kernels import dataplane as dk
            x, kctrs = dk.mediated_cost(x, dk.rescale_iters(iters), copies)
            # the per-chunk SMEM cost counters, summed into the tenant
            # block: what the hardware actually burned/copied
            state = self._kernel_ctr_bump(
                state, tenant_idx,
                jnp.sum(kctrs[:, dk.COST_ITERS]),
                jnp.sum(kctrs[:, dk.COST_COPIES]))
        else:
            if iters:
                x = tech.delay_chain(x, iters)
            if copies:
                x = tech.staged_copy(x, copies=copies)
            state = self._static_cost_bump(x, rec, state, tenant_idx, side)
        for s in self.stages:
            if s.stateful:
                x, state = getattr(s, side)(x, rec, state, tenant_idx)
        return x, state

    def send(self, x, rec: tl.OpRecord, state=None, tenant_idx: int = 0):
        if self.fused:
            return self._fused_side(x, rec, state, tenant_idx, "send")
        for s in self.stages:
            x, state = s.send(x, rec, state, tenant_idx)
        return x, self._static_cost_bump(x, rec, state, tenant_idx, "send")

    def complete(self, x, rec: tl.OpRecord, state=None, tenant_idx: int = 0):
        if self.fused:
            return self._fused_side(x, rec, state, tenant_idx, "complete")
        for s in self.stages:
            x, state = s.complete(x, rec, state, tenant_idx)
        return x, self._static_cost_bump(x, rec, state, tenant_idx,
                                         "complete")

    def send_delay_iters(self, rec: tl.OpRecord) -> int:
        return sum(s.send_delay_iters(rec) for s in self.stages)

    def complete_delay_iters(self, rec: tl.OpRecord) -> int:
        return sum(s.complete_delay_iters(rec) for s in self.stages)

    def send_copies(self, rec: tl.OpRecord) -> int:
        return sum(s.send_copies(rec) for s in self.stages)

    def complete_copies(self, rec: tl.OpRecord) -> int:
        return sum(s.complete_copies(rec) for s in self.stages)

    def __repr__(self) -> str:
        fused = "" if self.fused else " unfused"
        return f"MediationPipeline{self.stage_names}{fused}"


def build_pipeline(dp) -> MediationPipeline:
    """Compile a dataplane's effective techniques + policies into stages.

    ``dp`` duck-types a Dataplane: cfg, mode, kernel_bypass, zero_copy,
    polling, enforce, policies, tenants."""
    from repro.kernels.dataplane import use_pallas_dataplane
    cfg = dp.cfg
    pallas = use_pallas_dataplane(getattr(cfg, "pallas_dataplane", "auto"))
    stages: list[MediationStage] = []
    mediated = not dp.kernel_bypass        # the OS sees this traffic
    if mediated and cfg.emulate_costs:
        stages.append(SyscallCostStage(cfg.syscall_cost_ns))
        if dp.mode == "socket":
            stages.append(SocketStackStage(cfg.socket_stack_ns,
                                           cfg.socket_ns_per_byte))
    if not dp.zero_copy:
        stages.append(StagedCopyStage(pallas=pallas))
    if not dp.polling and cfg.emulate_costs:
        stages.append(InterruptWaitStage(cfg.interrupt_cost_us))
    if dp.enforce:
        qos = next((p for p in dp.policies
                    if isinstance(p, QoSPolicy) and p.rates), None)
        if qos is not None:
            stages.append(TokenBucketStage(qos, dp.tenants))
    if mediated:
        quota = next((p for p in dp.policies
                      if isinstance(p, QuotaPolicy)), None) \
            if dp.enforce else None
        stages.append(CounterBumpStage(dp.tenants, quota))
    return MediationPipeline(stages,
                             fused=getattr(cfg, "fuse_mediation", True),
                             pallas=pallas)


def runtime_state_init(tenants: tuple[str, ...],
                       policies: list[Policy]) -> dict:
    """The per-tenant runtime-state pytree threaded through shard_map:
    a counter block plus each stateful policy's slice keyed by name."""
    state = {"counters": tl.tenant_counters_init(len(tenants))}
    for p in policies:
        ps = p.init_state(len(tenants))
        if ps is not None:
            state[p.name] = ps
    return state


# ---------------------------------------------------------------------------
# Host-side token bucket (serving admission control)
# ---------------------------------------------------------------------------

class HostTokenBucket:
    """Pure-python mirror of the traced QoS token bucket.

    The serving engine refills explicitly once per batching round (the
    host-side analogue of per-op refill), keeping admission deterministic
    and clock-free for tests.  Serve-side admission charges *prompt
    tokens* per request — matching the traced bucket's byte-proportional
    debits — so ``from_policy`` scales rate and burst by ``scale`` tokens
    per traced-rate unit."""

    def __init__(self, rate: float, burst: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)

    def refill(self) -> None:
        self.tokens = min(self.tokens + self.rate, self.burst)

    def can_take(self, n: float = 1.0) -> bool:
        return self.tokens >= n

    def take(self, n: float = 1.0) -> bool:
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False

    @classmethod
    def from_policy(cls, qos: QoSPolicy | None,
                    scale: float = 1.0) -> dict[str, "HostTokenBucket"]:
        if qos is None:
            return {}
        return {t: cls(rate * scale, qos.burst * scale)
                for t, rate in qos.rates.items() if rate > 0}


__all__ = [
    "MediationStage", "MediationPipeline", "build_pipeline",
    "runtime_state_init", "SyscallCostStage", "SocketStackStage",
    "StagedCopyStage", "InterruptWaitStage", "TokenBucketStage",
    "CounterBumpStage", "HostTokenBucket",
]
