"""Memory-region registration — the security half of CoRD.

The paper (§4): "If the application passes an invalid address, the NIC
returns an error but does not access any memory that was not explicitly
provided to the application."  On TPU there are no raw pointers; the
analogue is that the dataplane only moves arrays belonging to *registered
memory regions*.  Registration is a control-plane operation (goes through
``ioctl`` in the paper → goes through the host-side registry here), and in
``cord``/``socket`` mode every dataplane op validates its operand against
the registry (shape/dtype signature match).  ``bypass`` mode skips the
check — exactly the uncontrolled behaviour the paper criticizes.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


class MRError(Exception):
    """Dataplane operand does not belong to a registered memory region."""


@dataclass(frozen=True)
class MemoryRegion:
    name: str
    shape: tuple[int, ...]
    dtype: str
    lkey: int                   # local key, as in ibverbs
    tenant: str = "default"

    def matches(self, x) -> bool:
        return tuple(x.shape) == self.shape and str(jnp.dtype(x.dtype).name) == self.dtype


class MRRegistry:
    """Control-plane registry of communicable memory regions."""

    def __init__(self) -> None:
        self._regions: dict[str, MemoryRegion] = {}
        self._next_key = 0x1000

    def reg_mr(self, name: str, x, tenant: str = "default") -> MemoryRegion:
        """Register an array (or ShapeDtypeStruct) as a memory region."""
        self._next_key += 1
        mr = MemoryRegion(name=name, shape=tuple(x.shape),
                          dtype=str(jnp.dtype(x.dtype).name),
                          lkey=self._next_key, tenant=tenant)
        self._regions[name] = mr
        return mr

    def reg_pytree(self, prefix: str, tree, tenant: str = "default") -> int:
        """Register every leaf of a pytree (e.g. the full gradient tree)."""
        leaves = jax.tree_util.tree_leaves_with_path(tree)
        for path, leaf in leaves:
            self.reg_mr(prefix + jax.tree_util.keystr(path), leaf, tenant)
        return len(leaves)

    def dereg_mr(self, name: str) -> None:
        self._regions.pop(name, None)

    def lookup(self, name: str) -> MemoryRegion | None:
        return self._regions.get(name)

    def check(self, name: str, x) -> MemoryRegion:
        """Validate that ``x`` matches registered region ``name``."""
        mr = self._regions.get(name)
        if mr is None:
            raise MRError(f"dataplane op on unregistered memory region {name!r}")
        if not mr.matches(x):
            raise MRError(
                f"MR {name!r} signature mismatch: registered "
                f"{mr.shape}/{mr.dtype}, got {tuple(x.shape)}/{jnp.dtype(x.dtype).name}")
        return mr

    def __len__(self) -> int:
        return len(self._regions)


__all__ = ["MemoryRegion", "MRRegistry", "MRError"]
