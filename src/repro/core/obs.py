"""Per-tenant observability timelines — the dataplane's state made
first-class and inspectable over *time*, not just per step.

The mediation pipeline (core/mediation.py), the verbs CQ runtime
(core/verbs.py) and the serving engine (serve/engine.py) all account
traffic into per-tenant counter blocks, but ``dp.runtime_report`` /
``Engine.tenant_report`` are one flat view per step.  A
:class:`CounterTimeline` turns those flat views into an append-only
host-side time series:

* :meth:`CounterTimeline.snapshot` appends one sample — a per-tenant
  counter dict (``dp.runtime_report(state)``, ``Engine`` counters, or any
  ``{tenant: {counter: cumulative_value}}``) plus optional run-wide
  *gauges* (active slots, queue depth).  Snapshots only **read** host /
  device arrays between steps — never inside traced code — so with the
  toggle off (or on) traced results are bit-identical
  (tests/test_obs.py asserts this against a traced train step).
* :meth:`CounterTimeline.rates` derives per-window series from
  consecutive samples: ``ops_s`` / ``bytes_s`` / ``chunks_s`` (deltas
  over wall time), ``throttled_pct`` / ``stalls_pct`` / ``denied_pct``
  (share of the window's ops), and the ``cq_depth`` high-water level.
* :meth:`CounterTimeline.save` writes a schema-versioned JSON run
  artifact (``runs/<name>_timeline.json``, see docs/observability.md for
  the schema) and :meth:`CounterTimeline.panel` renders per-tenant ASCII
  sparkline panels for the console.

Everything here is host-side Python + numpy: no jax tracing, no device
allocation.  Counter *names* come from core/telemetry.py so the timeline
columns can never drift from the counter-block layout.
"""

from __future__ import annotations

import json
import math
import os
import time

import numpy as np

from repro.core import telemetry as tl

# Artifact schema identifier.  Bump the version when the document layout
# changes; validate_timeline() refuses unknown schemas.
TIMELINE_SCHEMA = "cord-timeline/v1"

# Derived per-window rate series (docs/observability.md for semantics).
RATE_FIELDS = ("ops_s", "bytes_s", "chunks_s", "throttled_pct",
               "stalls_pct", "denied_pct", "cq_depth")

_SPARK_BLOCKS = "▁▂▃▄▅▆▇█"


def sparkline(values, width: int = 48) -> str:
    """Render a numeric series as a unicode block sparkline.

    Series longer than ``width`` are bucket-averaged down; flat series
    render as a mid-height line so "constant" is distinguishable from
    "empty" (which renders as '')."""
    vals = [float(v) for v in values]
    if not vals:
        return ""
    if len(vals) > width:
        # bucket-mean downsample to exactly `width` cells
        edges = np.linspace(0, len(vals), width + 1)
        vals = [float(np.mean(vals[int(edges[i]):max(int(edges[i + 1]),
                                                     int(edges[i]) + 1)]))
                for i in range(width)]
    lo, hi = min(vals), max(vals)
    span = hi - lo
    if span <= 0 or not math.isfinite(span):
        # flat series: baseline if it sits at zero, mid-height otherwise
        return _SPARK_BLOCKS[0 if hi == 0 else 3] * len(vals)
    idx = [min(int((v - lo) / span * (len(_SPARK_BLOCKS) - 1e-9)),
               len(_SPARK_BLOCKS) - 1) for v in vals]
    return "".join(_SPARK_BLOCKS[i] for i in idx)


class CounterTimeline:
    """Append-only per-tenant counter time series with derived rates.

    Samples carry *cumulative* counters (the counter-block convention:
    every column except ``cq_depth`` is monotone non-decreasing); rates
    are derived between consecutive samples at report/save time, so
    snapshotting stays O(tenants × counters) per step with no math on
    the hot path."""

    def __init__(self, source: str = "run",
                 counter_names: tuple[str, ...] = tl.COUNTER_NAMES):
        self.source = source
        self.counter_names = tuple(counter_names)
        self.samples: list[dict] = []
        self._tenants: list[str] = []      # first-seen order
        self._gauge_names: list[str] = []

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------
    def snapshot(self, step: int, report: dict, *, gauges: dict | None = None,
                 t: float | None = None) -> None:
        """Append one sample.

        ``report`` is ``{tenant: {counter: cumulative_value}}`` — exactly
        what ``dp.runtime_report(state)`` returns; missing counters read
        as 0.  ``gauges`` are run-wide instantaneous levels (e.g. active
        decode slots).  ``t`` defaults to ``time.perf_counter()``; pass
        explicit stamps for deterministic artifacts/tests."""
        tenants = {}
        for name, ctrs in report.items():
            if name not in self._tenants:
                self._tenants.append(name)
            tenants[name] = {k: float(ctrs.get(k, 0.0))
                             for k in self.counter_names}
        g = {k: float(v) for k, v in (gauges or {}).items()}
        for k in g:
            if k not in self._gauge_names:
                self._gauge_names.append(k)
        self.samples.append({
            "step": int(step),
            "t": float(t if t is not None else time.perf_counter()),
            "tenants": tenants,
            "gauges": g,
        })

    def snapshot_block(self, step: int, ctrs, tenants: tuple[str, ...], *,
                       gauges: dict | None = None, t: float | None = None
                       ) -> None:
        """Counter-block form: a ``(len(tenants), NUM_COUNTERS)`` array in
        telemetry column order (``tenant_counters_init`` layout)."""
        self.snapshot(step, tl.tenant_counters_report(ctrs, tenants),
                      gauges=gauges, t=t)

    # ------------------------------------------------------------------
    # derived series
    # ------------------------------------------------------------------
    @property
    def tenants(self) -> tuple[str, ...]:
        return tuple(self._tenants)

    def _value(self, sample: dict, tenant: str, counter: str) -> float:
        return float(sample["tenants"].get(tenant, {}).get(counter, 0.0))

    def rate_axis(self) -> dict[str, list]:
        """Window-end coordinates for every rates() series: the step and
        wall-time stamp of each window's closing sample."""
        return {"step": [s["step"] for s in self.samples[1:]],
                "t": [s["t"] for s in self.samples[1:]]}

    def rates(self) -> dict[str, dict[str, list[float]]]:
        """Per-tenant derived series, one value per window between
        consecutive samples: ``{tenant: {field: [v, ...]}}``.

        Deltas divide by the window's wall time; a non-positive wall
        delta (explicit equal stamps, clock weirdness) falls back to the
        step delta so the series stays finite and deterministic."""
        out: dict[str, dict[str, list[float]]] = {
            tn: {f: [] for f in RATE_FIELDS} for tn in self._tenants}
        for prev, cur in zip(self.samples, self.samples[1:]):
            dt = cur["t"] - prev["t"]
            if dt <= 0:
                dt = float(max(cur["step"] - prev["step"], 1))
            for tn in self._tenants:
                d = {c: max(self._value(cur, tn, c)
                            - self._value(prev, tn, c), 0.0)
                     for c in self.counter_names}
                ops = d.get("ops", 0.0)
                pct = (lambda n: 100.0 * n / ops if ops > 0 else 0.0)
                r = out[tn]
                r["ops_s"].append(ops / dt)
                r["bytes_s"].append(d.get("bytes", 0.0) / dt)
                r["chunks_s"].append(d.get("chunks", 0.0) / dt)
                r["throttled_pct"].append(pct(d.get("throttled", 0.0)))
                r["stalls_pct"].append(pct(d.get("stalls", 0.0)))
                r["denied_pct"].append(pct(d.get("denied", 0.0)))
                # cq_depth is a high-water mark, not additive: report the
                # level at the window's close.
                r["cq_depth"].append(self._value(cur, tn, "cq_depth"))
        return out

    def gauge_series(self) -> dict[str, list[float]]:
        """Run-wide gauges aligned to the sample axis (not windows)."""
        return {g: [float(s["gauges"].get(g, 0.0)) for s in self.samples]
                for g in self._gauge_names}

    # ------------------------------------------------------------------
    # artifact
    # ------------------------------------------------------------------
    def to_doc(self) -> dict:
        return {
            "schema": TIMELINE_SCHEMA,
            "source": self.source,
            "counters": list(self.counter_names),
            "rate_fields": list(RATE_FIELDS),
            "tenants": list(self._tenants),
            "samples": self.samples,
            "axis": self.rate_axis(),
            "rates": self.rates(),
            "gauges": self.gauge_series(),
        }

    def save(self, path: str) -> str:
        """Write the schema-versioned JSON artifact; returns ``path``."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_doc(), f, indent=1)
        return path

    @staticmethod
    def load(path: str) -> dict:
        """Load and validate an artifact; returns the document dict."""
        with open(path) as f:
            doc = json.load(f)
        validate_timeline(doc)
        return doc

    # ------------------------------------------------------------------
    # console panels
    # ------------------------------------------------------------------
    def panel(self, width: int = 48,
              fields: tuple[str, ...] = RATE_FIELDS) -> str:
        """Per-tenant ASCII sparkline panels (plus run-wide gauges).

        All-zero series other than ``ops_s``/``bytes_s`` are elided so a
        quiet tenant stays one glanceable block."""
        lines: list[str] = []
        rates = self.rates()
        for tn in self._tenants:
            lines.append(f"-- tenant {tn} ({self.source}, "
                         f"{len(self.samples)} samples) ".ljust(width + 18, "-"))
            for f in fields:
                series = rates[tn][f]
                if not series:
                    continue
                if f not in ("ops_s", "bytes_s") and not any(series):
                    continue
                lines.append(f"  {f:14s} {sparkline(series, width):{width}s}"
                             f" last {series[-1]:.1f}")
        gauges = self.gauge_series()
        if gauges:
            lines.append(f"-- run gauges ".ljust(width + 18, "-"))
            for g, series in gauges.items():
                lines.append(f"  {g:14s} {sparkline(series, width):{width}s}"
                             f" last {series[-1]:.1f}")
        return "\n".join(lines)


def validate_timeline(doc: dict) -> dict:
    """Structural check of a timeline artifact; raises ValueError on a
    malformed document, returns it unchanged otherwise (so call sites can
    chain).  This is the CI smoke's assertion and the forward-compat
    gate: unknown schema versions are refused, not misread."""
    if not isinstance(doc, dict):
        raise ValueError(f"timeline artifact must be a dict, got {type(doc)}")
    if doc.get("schema") != TIMELINE_SCHEMA:
        raise ValueError(f"unknown timeline schema {doc.get('schema')!r} "
                         f"(expected {TIMELINE_SCHEMA!r})")
    for key in ("source", "counters", "rate_fields", "tenants", "samples",
                "axis", "rates", "gauges"):
        if key not in doc:
            raise ValueError(f"timeline artifact missing key {key!r}")
    n_windows = max(len(doc["samples"]) - 1, 0)
    if len(doc["axis"].get("step", ())) != n_windows:
        raise ValueError("timeline axis length != sample windows")
    for s in doc["samples"]:
        for key in ("step", "t", "tenants", "gauges"):
            if key not in s:
                raise ValueError(f"timeline sample missing key {key!r}")
    for tn in doc["tenants"]:
        series = doc["rates"].get(tn)
        if series is None:
            raise ValueError(f"timeline rates missing tenant {tn!r}")
        for f in doc["rate_fields"]:
            if len(series.get(f, ())) != n_windows:
                raise ValueError(
                    f"rate series {tn}/{f} length != window count")
    return doc


__all__ = ["CounterTimeline", "sparkline", "validate_timeline",
           "TIMELINE_SCHEMA", "RATE_FIELDS"]
