"""Per-tenant observability timelines — the dataplane's state made
first-class and inspectable over *time*, not just per step.

The mediation pipeline (core/mediation.py), the verbs CQ runtime
(core/verbs.py) and the serving engine (serve/engine.py) all account
traffic into per-tenant counter blocks, but ``dp.runtime_report`` /
``Engine.tenant_report`` are one flat view per step.  A
:class:`CounterTimeline` turns those flat views into an append-only
host-side time series:

* :meth:`CounterTimeline.snapshot` appends one sample — a per-tenant
  counter dict (``dp.runtime_report(state)``, ``Engine`` counters, or any
  ``{tenant: {counter: cumulative_value}}``) plus optional run-wide
  *gauges* (active slots, queue depth).  Snapshots only **read** host /
  device arrays between steps — never inside traced code — so with the
  toggle off (or on) traced results are bit-identical
  (tests/test_obs.py asserts this against a traced train step).
* :meth:`CounterTimeline.rates` derives per-window series from
  consecutive samples: ``ops_s`` / ``bytes_s`` / ``chunks_s`` (deltas
  over wall time), ``throttled_pct`` / ``stalls_pct`` / ``denied_pct``
  (share of the window's ops), and the ``cq_depth`` high-water level.
* :meth:`CounterTimeline.save` writes a schema-versioned JSON run
  artifact (``runs/<name>_timeline.json``, see docs/observability.md for
  the schema) and :meth:`CounterTimeline.panel` renders per-tenant ASCII
  sparkline panels for the console.
* :meth:`CounterTimeline.record_event` appends control-plane *events*
  (watcher triggers, elastic remeshes) to the artifact's ``events`` list
  (schema v2; v1 artifacts without events still load), and the optional
  ``sink=`` path streams every snapshot/event to a JSONL file as the run
  progresses, so long runs are not in-memory-only.
* :class:`ThresholdWatcher` is the trigger half of the elastic control
  loop (docs/elasticity.md): it watches the per-window rate series
  against thresholds with hysteresis (sustained-for-N-windows, cooldown)
  and emits trigger events that ``runtime/elastic.py`` turns into a
  remesh.

Everything here is host-side Python + numpy: no jax tracing, no device
allocation.  Counter *names* come from core/telemetry.py so the timeline
columns can never drift from the counter-block layout.
"""

from __future__ import annotations

import json
import math
import os
import time
from typing import Sequence

import numpy as np

from repro.core import telemetry as tl

# Artifact schema identifier.  Bump the version when the document layout
# changes; validate_timeline() refuses unknown schemas but accepts every
# version listed in TIMELINE_SCHEMAS (v1 = v2 without the events list).
TIMELINE_SCHEMA_V1 = "cord-timeline/v1"
TIMELINE_SCHEMA = "cord-timeline/v2"
TIMELINE_SCHEMAS = (TIMELINE_SCHEMA_V1, TIMELINE_SCHEMA)

# Derived per-window rate series (docs/observability.md for semantics).
# retrans_s/timeouts_s/srq_grants_s are the transport's fault-visibility
# series (docs/transport.md); cqe_err_pct is error CQEs as a share of the
# window's completions.  Older artifacts list fewer fields —
# validate_timeline checks a document against its OWN rate_fields list.
RATE_FIELDS = ("ops_s", "bytes_s", "chunks_s", "throttled_pct",
               "stalls_pct", "denied_pct", "cq_depth",
               "retrans_s", "timeouts_s", "srq_grants_s", "cqe_err_pct",
               "preempt_s", "restore_s")

_SPARK_BLOCKS = "▁▂▃▄▅▆▇█"


def sparkline(values, width: int = 48) -> str:
    """Render a numeric series as a unicode block sparkline.

    Series longer than ``width`` are bucket-averaged down; flat series
    render as a mid-height line so "constant" is distinguishable from
    "empty" (which renders as '')."""
    vals = [float(v) for v in values]
    if not vals:
        return ""
    if len(vals) > width:
        # bucket-mean downsample to exactly `width` cells
        edges = np.linspace(0, len(vals), width + 1)
        vals = [float(np.mean(vals[int(edges[i]):max(int(edges[i + 1]),
                                                     int(edges[i]) + 1)]))
                for i in range(width)]
    lo, hi = min(vals), max(vals)
    span = hi - lo
    if span <= 0 or not math.isfinite(span):
        # flat series: baseline if it sits at zero, mid-height otherwise
        return _SPARK_BLOCKS[0 if hi == 0 else 3] * len(vals)
    idx = [min(int((v - lo) / span * (len(_SPARK_BLOCKS) - 1e-9)),
               len(_SPARK_BLOCKS) - 1) for v in vals]
    return "".join(_SPARK_BLOCKS[i] for i in idx)


class CounterTimeline:
    """Append-only per-tenant counter time series with derived rates.

    Samples carry *cumulative* counters (the counter-block convention:
    every column except ``cq_depth`` is monotone non-decreasing); rates
    are derived between consecutive samples at report/save time, so
    snapshotting stays O(tenants × counters) per step with no math on
    the hot path."""

    def __init__(self, source: str = "run",
                 counter_names: tuple[str, ...] = tl.COUNTER_NAMES,
                 sink: str | None = None):
        self.source = source
        self.counter_names = tuple(counter_names)
        self.samples: list[dict] = []
        self.events: list[dict] = []
        self._tenants: list[str] = []      # first-seen order
        self._gauge_names: list[str] = []
        self._sink_path = sink
        self._sink = None

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------
    def snapshot(self, step: int, report: dict, *, gauges: dict | None = None,
                 t: float | None = None) -> None:
        """Append one sample.

        ``report`` is ``{tenant: {counter: cumulative_value}}`` — exactly
        what ``dp.runtime_report(state)`` returns; missing counters read
        as 0.  ``gauges`` are run-wide instantaneous levels (e.g. active
        decode slots).  ``t`` defaults to ``time.perf_counter()``; pass
        explicit stamps for deterministic artifacts/tests."""
        tenants = {}
        for name, ctrs in report.items():
            if name not in self._tenants:
                self._tenants.append(name)
            tenants[name] = {k: float(ctrs.get(k, 0.0))
                             for k in self.counter_names}
        g = {k: float(v) for k, v in (gauges or {}).items()}
        for k in g:
            if k not in self._gauge_names:
                self._gauge_names.append(k)
        sample = {
            "step": int(step),
            "t": float(t if t is not None else time.perf_counter()),
            "tenants": tenants,
            "gauges": g,
        }
        self.samples.append(sample)
        self._sink_write({"sample": sample})

    def snapshot_block(self, step: int, ctrs, tenants: tuple[str, ...], *,
                       gauges: dict | None = None, t: float | None = None
                       ) -> None:
        """Counter-block form: a ``(len(tenants), NUM_COUNTERS)`` array in
        telemetry column order (``tenant_counters_init`` layout)."""
        self.snapshot(step, tl.tenant_counters_report(ctrs, tenants),
                      gauges=gauges, t=t)

    def record_event(self, kind: str, step: int, *, tenant: str | None = None,
                     t: float | None = None, detail: dict | None = None
                     ) -> dict:
        """Append a control-plane event (watcher ``trigger``, elastic
        ``remesh``, ...) to the artifact's ``events`` list (schema v2) and
        the JSONL sink.  Events carry their own step/time stamps — they
        happen *between* snapshots, not on the sample axis."""
        ev = {"kind": str(kind), "step": int(step),
              "t": float(t if t is not None else time.perf_counter()),
              "tenant": tenant, "detail": dict(detail or {})}
        self.events.append(ev)
        self._sink_write({"event": ev})
        return ev

    # ------------------------------------------------------------------
    # streaming JSONL sink
    # ------------------------------------------------------------------
    def _sink_write(self, obj: dict) -> None:
        if self._sink_path is None:
            return
        if self._sink is None:
            d = os.path.dirname(self._sink_path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._sink = open(self._sink_path, "a")
            # one header line per run's stream: re-running with the same
            # sink path appends a NEW stream after the old one, and
            # read_jsonl treats each header as a stream restart — two
            # runs never merge into one timeline with bogus cross-run
            # windows (docs/observability.md)
            self._sink.write(json.dumps(
                {"schema": TIMELINE_SCHEMA, "source": self.source,
                 "counters": list(self.counter_names)}) + "\n")
        self._sink.write(json.dumps(obj) + "\n")
        self._sink.flush()

    def close(self) -> None:
        """Flush and close the JSONL sink (no-op without one)."""
        if self._sink is not None:
            self._sink.close()
            self._sink = None

    @classmethod
    def read_jsonl(cls, path: str) -> "CounterTimeline":
        """Rebuild a timeline from a streamed JSONL sink file.  The line
        format is: a header line ``{"schema", "source", "counters"}``,
        then one ``{"sample": {...}}`` or ``{"event": {...}}`` object per
        line.  A file holding several appended streams (the same sink
        path reused across runs) yields the LATEST stream — each header
        line is a stream restart, never a merge."""
        tl_ = None
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                obj = json.loads(line)
                if "schema" in obj:
                    if obj["schema"] not in TIMELINE_SCHEMAS:
                        raise ValueError(
                            f"unknown timeline sink schema {obj['schema']!r}")
                    tl_ = cls(source=obj.get("source", "run"),
                              counter_names=tuple(obj["counters"]))
                    continue
                if tl_ is None:
                    tl_ = cls()          # headerless stream
                if "sample" in obj:
                    s = obj["sample"]
                    tl_.snapshot(s["step"], s["tenants"],
                                 gauges=s.get("gauges"), t=s["t"])
                elif "event" in obj:
                    tl_.events.append(obj["event"])
        return tl_ if tl_ is not None else cls()

    # ------------------------------------------------------------------
    # derived series
    # ------------------------------------------------------------------
    @property
    def tenants(self) -> tuple[str, ...]:
        return tuple(self._tenants)

    def _value(self, sample: dict, tenant: str, counter: str) -> float:
        return float(sample["tenants"].get(tenant, {}).get(counter, 0.0))

    def rate_axis(self) -> dict[str, list]:
        """Window-end coordinates for every rates() series: the step and
        wall-time stamp of each window's closing sample."""
        return {"step": [s["step"] for s in self.samples[1:]],
                "t": [s["t"] for s in self.samples[1:]]}

    def _window(self, prev: dict, cur: dict) -> dict[str, dict[str, float]]:
        """Derived rates for ONE window between two samples, for every
        tenant seen so far: ``{tenant: {field: value}}``."""
        dt = cur["t"] - prev["t"]
        if dt <= 0:
            dt = float(max(cur["step"] - prev["step"], 1))
        out: dict[str, dict[str, float]] = {}
        for tn in self._tenants:
            d = {c: max(self._value(cur, tn, c)
                        - self._value(prev, tn, c), 0.0)
                 for c in self.counter_names}
            ops = d.get("ops", 0.0)
            pct = (lambda n: 100.0 * n / ops if ops > 0 else 0.0)
            comp = d.get("completions", 0.0)
            out[tn] = {
                "ops_s": ops / dt,
                "bytes_s": d.get("bytes", 0.0) / dt,
                "chunks_s": d.get("chunks", 0.0) / dt,
                "throttled_pct": pct(d.get("throttled", 0.0)),
                "stalls_pct": pct(d.get("stalls", 0.0)),
                "denied_pct": pct(d.get("denied", 0.0)),
                # cq_depth is a high-water mark, not additive: report the
                # level at the window's close.
                "cq_depth": self._value(cur, tn, "cq_depth"),
                "retrans_s": d.get("retransmits", 0.0) / dt,
                "timeouts_s": d.get("timeouts", 0.0) / dt,
                "srq_grants_s": d.get("srq_grants", 0.0) / dt,
                "cqe_err_pct": (100.0 * d.get("cqe_errors", 0.0) / comp
                                if comp > 0 else 0.0),
                "preempt_s": d.get("preemptions", 0.0) / dt,
                "restore_s": d.get("restores", 0.0) / dt,
            }
        return out

    def window_rates(self, i: int = -1) -> dict[str, dict[str, float]]:
        """Rates for the single window closing at ``samples[i]``
        (``i >= 1`` or negative; the newest window by default) — what a
        :class:`ThresholdWatcher` consumes incrementally.  Returns ``{}``
        while fewer than two samples exist."""
        n = len(self.samples)
        if n < 2:
            return {}
        if i < 0:
            i += n
        if not 1 <= i < n:
            raise IndexError(f"window index {i} outside [1, {n - 1}]")
        return self._window(self.samples[i - 1], self.samples[i])

    def rates(self) -> dict[str, dict[str, list[float]]]:
        """Per-tenant derived series, one value per window between
        consecutive samples: ``{tenant: {field: [v, ...]}}``.

        Deltas divide by the window's wall time; a non-positive wall
        delta (explicit equal stamps, clock weirdness) falls back to the
        step delta so the series stays finite and deterministic."""
        out: dict[str, dict[str, list[float]]] = {
            tn: {f: [] for f in RATE_FIELDS} for tn in self._tenants}
        for prev, cur in zip(self.samples, self.samples[1:]):
            w = self._window(prev, cur)
            for tn in self._tenants:
                for f in RATE_FIELDS:
                    out[tn][f].append(w[tn][f])
        return out

    def gauge_series(self) -> dict[str, list[float]]:
        """Run-wide gauges aligned to the sample axis (not windows)."""
        return {g: [float(s["gauges"].get(g, 0.0)) for s in self.samples]
                for g in self._gauge_names}

    # ------------------------------------------------------------------
    # artifact
    # ------------------------------------------------------------------
    def to_doc(self) -> dict:
        return {
            "schema": TIMELINE_SCHEMA,
            "source": self.source,
            "counters": list(self.counter_names),
            "rate_fields": list(RATE_FIELDS),
            "tenants": list(self._tenants),
            "samples": self.samples,
            "events": list(self.events),
            "axis": self.rate_axis(),
            "rates": self.rates(),
            "gauges": self.gauge_series(),
        }

    def save(self, path: str) -> str:
        """Write the schema-versioned JSON artifact; returns ``path``."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_doc(), f, indent=1)
        return path

    @staticmethod
    def load(path: str) -> dict:
        """Load and validate an artifact; returns the document dict."""
        with open(path) as f:
            doc = json.load(f)
        validate_timeline(doc)
        return doc

    # ------------------------------------------------------------------
    # console panels
    # ------------------------------------------------------------------
    def panel(self, width: int = 48,
              fields: tuple[str, ...] = RATE_FIELDS) -> str:
        """Per-tenant ASCII sparkline panels (plus run-wide gauges).

        All-zero series other than ``ops_s``/``bytes_s`` are elided so a
        quiet tenant stays one glanceable block."""
        lines: list[str] = []
        rates = self.rates()
        for tn in self._tenants:
            lines.append(f"-- tenant {tn} ({self.source}, "
                         f"{len(self.samples)} samples) ".ljust(width + 18, "-"))
            for f in fields:
                series = rates[tn][f]
                if not series:
                    continue
                if f not in ("ops_s", "bytes_s") and not any(series):
                    continue
                lines.append(f"  {f:14s} {sparkline(series, width):{width}s}"
                             f" last {series[-1]:.1f}")
        gauges = self.gauge_series()
        if gauges:
            lines.append(f"-- run gauges ".ljust(width + 18, "-"))
            for g, series in gauges.items():
                lines.append(f"  {g:14s} {sparkline(series, width):{width}s}"
                             f" last {series[-1]:.1f}")
        return "\n".join(lines)


def validate_timeline(doc: dict) -> dict:
    """Structural check of a timeline artifact; raises ValueError on a
    malformed document, returns it unchanged otherwise (so call sites can
    chain).  This is the CI smoke's assertion and the forward-compat
    gate: every known schema version is checked against its own layout
    (v1 = v2 without the ``events`` list), unknown versions are refused,
    not misread, and every series is length-checked against the sample
    axis — a truncated ``rates``/``gauges``/``axis`` series is rejected
    even on a v1 document."""
    if not isinstance(doc, dict):
        raise ValueError(f"timeline artifact must be a dict, got {type(doc)}")
    schema = doc.get("schema")
    if schema not in TIMELINE_SCHEMAS:
        raise ValueError(f"unknown timeline schema {schema!r} "
                         f"(expected one of {TIMELINE_SCHEMAS})")
    required = ["source", "counters", "rate_fields", "tenants", "samples",
                "axis", "rates", "gauges"]
    if schema == TIMELINE_SCHEMA:
        required.append("events")
    for key in required:
        if key not in doc:
            raise ValueError(f"timeline artifact missing key {key!r}")
    n_samples = len(doc["samples"])
    n_windows = max(n_samples - 1, 0)
    for ax in ("step", "t"):
        if len(doc["axis"].get(ax, ())) != n_windows:
            raise ValueError(f"timeline axis {ax!r} length != sample windows")
    for s in doc["samples"]:
        for key in ("step", "t", "tenants", "gauges"):
            if key not in s:
                raise ValueError(f"timeline sample missing key {key!r}")
    for tn in doc["tenants"]:
        series = doc["rates"].get(tn)
        if series is None:
            raise ValueError(f"timeline rates missing tenant {tn!r}")
        for f in doc["rate_fields"]:
            if len(series.get(f, ())) != n_windows:
                raise ValueError(
                    f"rate series {tn}/{f} length != window count")
    for g, series in doc["gauges"].items():
        if len(series) != n_samples:
            raise ValueError(f"gauge series {g!r} length != sample count")
    for ev in doc.get("events", ()):
        for key in ("kind", "step"):
            if key not in ev:
                raise ValueError(f"timeline event missing key {key!r}")
    return doc


class ThresholdWatcher:
    """Hysteresis threshold watcher over a timeline's rate series — the
    trigger half of the elastic control loop (docs/elasticity.md).

    ``thresholds`` maps :data:`RATE_FIELDS` names to trigger levels.  A
    tenant *trips* when any watched field sits at/over its level for
    ``sustain`` consecutive windows; tripping emits one trigger event,
    resets the tenant's streak and starts a ``cooldown`` of that many
    windows during which the tenant cannot accumulate a new streak.  One
    transient over-threshold window (or one quiet window inside a streak)
    therefore never triggers, and a persistently bad tenant triggers once
    per cooldown period, not once per window.

    :meth:`observe` is incremental — each call consumes only the windows
    appended since the last call, so it can run after every snapshot at
    O(new windows) cost.  The watcher is pure host-side bookkeeping: it
    never touches traced code."""

    def __init__(self, thresholds: dict[str, float], *, sustain: int = 3,
                 cooldown: int = 8, tenants: Sequence[str] | None = None):
        unknown = set(thresholds) - set(RATE_FIELDS)
        if unknown:
            raise ValueError(f"unknown rate fields {sorted(unknown)} "
                             f"(known: {RATE_FIELDS})")
        if not thresholds:
            raise ValueError("ThresholdWatcher needs at least one threshold")
        if sustain < 1 or cooldown < 0:
            raise ValueError(f"need sustain >= 1 and cooldown >= 0, got "
                             f"{sustain}/{cooldown}")
        self.thresholds = {k: float(v) for k, v in thresholds.items()}
        self.sustain = int(sustain)
        self.cooldown = int(cooldown)
        self.tenants = tuple(tenants) if tenants else None
        self.triggers: list[dict] = []     # every trigger ever emitted
        self._streak: dict[str, int] = {}
        self._cool: dict[str, int] = {}
        self._seen = 0                     # windows consumed so far

    @classmethod
    def from_config(cls, cfg) -> "ThresholdWatcher":
        """Build from an :class:`~repro.configs.base.ElasticConfig`,
        whose ``thresholds`` are CLI-friendly ``"rate_field=level"``
        strings."""
        th: dict[str, float] = {}
        for spec in cfg.thresholds:
            name, sep, level = spec.partition("=")
            if not sep:
                raise ValueError(
                    f"threshold spec must be 'rate_field=level', got {spec!r}")
            th[name.strip()] = float(level)
        return cls(th, sustain=cfg.sustain, cooldown=cfg.cooldown,
                   tenants=cfg.tenants or None)

    def observe(self, timeline: CounterTimeline) -> list[dict]:
        """Consume every not-yet-seen window of ``timeline``; returns the
        trigger events fired by those windows (often empty).  Event dicts
        match :meth:`CounterTimeline.record_event`'s shape so callers can
        log them straight into the artifact."""
        fired: list[dict] = []
        n_windows = max(len(timeline.samples) - 1, 0)
        while self._seen < n_windows:
            i = self._seen + 1            # sample index closing this window
            window = timeline.window_rates(i)
            close = timeline.samples[i]
            for tn, fields in window.items():
                if self.tenants is not None and tn not in self.tenants:
                    continue
                if self._cool.get(tn, 0) > 0:
                    self._cool[tn] -= 1
                    self._streak[tn] = 0
                    continue
                over = {f: fields.get(f, 0.0)
                        for f, lim in self.thresholds.items()
                        if fields.get(f, 0.0) >= lim}
                self._streak[tn] = self._streak.get(tn, 0) + 1 if over else 0
                if over and self._streak[tn] >= self.sustain:
                    ev = {"kind": "trigger", "step": int(close["step"]),
                          "t": float(close["t"]), "tenant": tn,
                          "detail": {"over": over,
                                     "sustained": self._streak[tn]}}
                    fired.append(ev)
                    self.triggers.append(ev)
                    self._streak[tn] = 0
                    self._cool[tn] = self.cooldown
            self._seen += 1
        return fired

    def gauges(self) -> dict[str, float]:
        """Run-wide watcher gauges to ride along in snapshots
        (docs/observability.md): the largest over-threshold streak and
        the largest remaining cooldown across watched tenants, as of the
        windows observed so far."""
        return {"watch_streak": float(max(self._streak.values(), default=0)),
                "watch_cooldown": float(max(self._cool.values(), default=0))}


__all__ = ["CounterTimeline", "ThresholdWatcher", "sparkline",
           "validate_timeline", "TIMELINE_SCHEMA", "TIMELINE_SCHEMA_V1",
           "TIMELINE_SCHEMAS", "RATE_FIELDS"]
