"""Per-tenant observability timelines — the dataplane's state made
first-class and inspectable over *time*, not just per step.

The mediation pipeline (core/mediation.py), the verbs CQ runtime
(core/verbs.py) and the serving engine (serve/engine.py) all account
traffic into per-tenant counter blocks, but ``dp.runtime_report`` /
``Engine.tenant_report`` are one flat view per step.  A
:class:`CounterTimeline` turns those flat views into an append-only
host-side time series:

* :meth:`CounterTimeline.snapshot` appends one sample — a per-tenant
  counter dict (``dp.runtime_report(state)``, ``Engine`` counters, or any
  ``{tenant: {counter: cumulative_value}}``) plus optional run-wide
  *gauges* (active slots, queue depth).  Snapshots only **read** host /
  device arrays between steps — never inside traced code — so with the
  toggle off (or on) traced results are bit-identical
  (tests/test_obs.py asserts this against a traced train step).
* :meth:`CounterTimeline.rates` derives per-window series from
  consecutive samples: ``ops_s`` / ``bytes_s`` / ``chunks_s`` (deltas
  over wall time), ``throttled_pct`` / ``stalls_pct`` / ``denied_pct``
  (share of the window's ops), and the ``cq_depth`` high-water level.
* :meth:`CounterTimeline.save` writes a schema-versioned JSON run
  artifact (``runs/<name>_timeline.json``, see docs/observability.md for
  the schema) and :meth:`CounterTimeline.panel` renders per-tenant ASCII
  sparkline panels for the console.
* :meth:`CounterTimeline.record_event` appends control-plane *events*
  (watcher triggers, elastic remeshes) to the artifact's ``events`` list
  (schema v2; v1 artifacts without events still load), and the optional
  ``sink=`` path streams every snapshot/event to a JSONL file as the run
  progresses, so long runs are not in-memory-only.
* :class:`ThresholdWatcher` is the trigger half of the elastic control
  loop (docs/elasticity.md): it watches the per-window rate series
  against thresholds with hysteresis (sustained-for-N-windows, cooldown)
  and emits trigger events that ``runtime/elastic.py`` turns into a
  remesh.

Everything here is host-side Python + numpy: no jax tracing, no device
allocation.  Counter *names* come from core/telemetry.py so the timeline
columns can never drift from the counter-block layout.
"""

from __future__ import annotations

import json
import math
import os
import time
from typing import Sequence

import numpy as np

from repro.core import telemetry as tl

# Artifact schema identifier.  Bump the version when the document layout
# changes; validate_timeline() refuses unknown schemas but accepts every
# version listed in TIMELINE_SCHEMAS (v1 = v2 without the events list).
TIMELINE_SCHEMA_V1 = "cord-timeline/v1"
TIMELINE_SCHEMA = "cord-timeline/v2"
TIMELINE_SCHEMAS = (TIMELINE_SCHEMA_V1, TIMELINE_SCHEMA)

# Derived per-window rate series (docs/observability.md for semantics).
# retrans_s/timeouts_s/srq_grants_s are the transport's fault-visibility
# series (docs/transport.md); cqe_err_pct is error CQEs as a share of the
# window's completions.  Older artifacts list fewer fields —
# validate_timeline checks a document against its OWN rate_fields list.
RATE_FIELDS = ("ops_s", "bytes_s", "chunks_s", "throttled_pct",
               "stalls_pct", "denied_pct", "cq_depth",
               "retrans_s", "timeouts_s", "srq_grants_s", "cqe_err_pct",
               "preempt_s", "restore_s")

_SPARK_BLOCKS = "▁▂▃▄▅▆▇█"


def sparkline(values, width: int = 48) -> str:
    """Render a numeric series as a unicode block sparkline.

    Series longer than ``width`` are bucket-averaged down; flat series
    render as a mid-height line so "constant" is distinguishable from
    "empty" (which renders as '')."""
    vals = [float(v) for v in values]
    if not vals:
        return ""
    if len(vals) > width:
        # bucket-mean downsample to exactly `width` cells
        edges = np.linspace(0, len(vals), width + 1)
        vals = [float(np.mean(vals[int(edges[i]):max(int(edges[i + 1]),
                                                     int(edges[i]) + 1)]))
                for i in range(width)]
    lo, hi = min(vals), max(vals)
    span = hi - lo
    if span <= 0 or not math.isfinite(span):
        # flat series: baseline if it sits at zero, mid-height otherwise
        return _SPARK_BLOCKS[0 if hi == 0 else 3] * len(vals)
    idx = [min(int((v - lo) / span * (len(_SPARK_BLOCKS) - 1e-9)),
               len(_SPARK_BLOCKS) - 1) for v in vals]
    return "".join(_SPARK_BLOCKS[i] for i in idx)


class CounterTimeline:
    """Append-only per-tenant counter time series with derived rates.

    Samples carry *cumulative* counters (the counter-block convention:
    every column except ``cq_depth`` is monotone non-decreasing); rates
    are derived between consecutive samples at report/save time, so
    snapshotting stays O(tenants × counters) per step with no math on
    the hot path."""

    def __init__(self, source: str = "run",
                 counter_names: tuple[str, ...] = tl.COUNTER_NAMES,
                 sink: str | None = None, rotate_bytes: int = 0):
        if rotate_bytes and sink is None:
            raise ValueError("rotate_bytes needs a sink path to rotate")
        if rotate_bytes < 0:
            raise ValueError(f"rotate_bytes must be >= 0, got {rotate_bytes}")
        self.source = source
        self.counter_names = tuple(counter_names)
        self.samples: list[dict] = []
        self.events: list[dict] = []
        self._tenants: list[str] = []      # first-seen order
        self._gauge_names: list[str] = []
        self._sink_path = sink
        self._sink = None
        self._sink_header = False          # header written for this segment
        self.rotate_bytes = int(rotate_bytes)
        self.rotations = 0                 # completed segments (path.1..N)

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------
    def snapshot(self, step: int, report: dict, *, gauges: dict | None = None,
                 t: float | None = None) -> None:
        """Append one sample.

        ``report`` is ``{tenant: {counter: cumulative_value}}`` — exactly
        what ``dp.runtime_report(state)`` returns; missing counters read
        as 0.  ``gauges`` are run-wide instantaneous levels (e.g. active
        decode slots).  ``t`` defaults to ``time.perf_counter()``; pass
        explicit stamps for deterministic artifacts/tests."""
        tenants = {}
        for name, ctrs in report.items():
            if name not in self._tenants:
                self._tenants.append(name)
            tenants[name] = {k: float(ctrs.get(k, 0.0))
                             for k in self.counter_names}
        g = {k: float(v) for k, v in (gauges or {}).items()}
        for k in g:
            if k not in self._gauge_names:
                self._gauge_names.append(k)
        sample = {
            "step": int(step),
            "t": float(t if t is not None else time.perf_counter()),
            "tenants": tenants,
            "gauges": g,
        }
        self.samples.append(sample)
        self._sink_write({"sample": sample})

    def snapshot_block(self, step: int, ctrs, tenants: tuple[str, ...], *,
                       gauges: dict | None = None, t: float | None = None
                       ) -> None:
        """Counter-block form: a ``(len(tenants), NUM_COUNTERS)`` array in
        telemetry column order (``tenant_counters_init`` layout)."""
        self.snapshot(step, tl.tenant_counters_report(ctrs, tenants),
                      gauges=gauges, t=t)

    def record_event(self, kind: str, step: int, *, tenant: str | None = None,
                     t: float | None = None, detail: dict | None = None
                     ) -> dict:
        """Append a control-plane event (watcher ``trigger``, elastic
        ``remesh``, ...) to the artifact's ``events`` list (schema v2) and
        the JSONL sink.  Events carry their own step/time stamps — they
        happen *between* snapshots, not on the sample axis."""
        ev = {"kind": str(kind), "step": int(step),
              "t": float(t if t is not None else time.perf_counter()),
              "tenant": tenant, "detail": dict(detail or {})}
        self.events.append(ev)
        self._sink_write({"event": ev})
        return ev

    # ------------------------------------------------------------------
    # streaming JSONL sink
    # ------------------------------------------------------------------
    def _sink_write(self, obj: dict) -> None:
        if self._sink_path is None:
            return
        if self._sink is None:
            d = os.path.dirname(self._sink_path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._sink = open(self._sink_path, "a")
            if not self._sink_header:
                # one header line per run's stream: re-running with the
                # same sink path appends a NEW stream after the old one,
                # and read_jsonl treats each header as a stream restart —
                # two runs never merge into one timeline with bogus
                # cross-run windows (docs/observability.md).  The flag
                # makes reopening after close() header-free: a late event
                # (recorded during engine shutdown, after the final
                # flush) continues the SAME stream instead of starting a
                # one-event "run" that orphans every earlier sample.
                self._sink.write(json.dumps(
                    {"schema": TIMELINE_SCHEMA, "source": self.source,
                     "counters": list(self.counter_names)}) + "\n")
                self._sink_header = True
        self._sink.write(json.dumps(obj) + "\n")
        self._sink.flush()
        if self.rotate_bytes and self._sink.tell() >= self.rotate_bytes:
            self._rotate()

    def _rotate(self) -> None:
        """Seal the current sink segment as ``<path>.<k>`` (k counting up
        from 1, oldest first) and arm a fresh segment — the next write
        opens ``<path>`` anew with its own header line, so every sealed
        segment is independently readable by :meth:`read_jsonl` while
        :meth:`read_rotated` stitches the whole run back together."""
        self._sink.close()
        self._sink = None
        self.rotations += 1
        os.replace(self._sink_path, f"{self._sink_path}.{self.rotations}")
        self._sink_header = False

    def close(self) -> None:
        """Flush and close the JSONL sink (no-op without one).

        Closing is not the end of the stream: events recorded *after*
        close — an engine-shutdown remesh, an end-of-run trigger — reopen
        the file and append to the same stream without a new header, so
        nothing written late is dropped from :meth:`read_jsonl`'s
        rebuild."""
        if self._sink is not None:
            self._sink.flush()
            self._sink.close()
            self._sink = None

    @classmethod
    def read_jsonl(cls, path: str) -> "CounterTimeline":
        """Rebuild a timeline from a streamed JSONL sink file.  The line
        format is: a header line ``{"schema", "source", "counters"}``,
        then one ``{"sample": {...}}`` or ``{"event": {...}}`` object per
        line.  A file holding several appended streams (the same sink
        path reused across runs) yields the LATEST stream — each header
        line is a stream restart, never a merge."""
        tl_ = None
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                obj = json.loads(line)
                if "schema" in obj:
                    if obj["schema"] not in TIMELINE_SCHEMAS:
                        raise ValueError(
                            f"unknown timeline sink schema {obj['schema']!r}")
                    tl_ = cls(source=obj.get("source", "run"),
                              counter_names=tuple(obj["counters"]))
                    continue
                if tl_ is None:
                    tl_ = cls()          # headerless stream
                if "sample" in obj:
                    s = obj["sample"]
                    tl_.snapshot(s["step"], s["tenants"],
                                 gauges=s.get("gauges"), t=s["t"])
                elif "event" in obj:
                    tl_.events.append(obj["event"])
        return tl_ if tl_ is not None else cls()

    @classmethod
    def read_rotated(cls, path: str) -> "CounterTimeline":
        """Rebuild ONE logical run from a rotated sink: sealed segments
        ``path.1 .. path.N`` (oldest first) then the live ``path`` are
        concatenated.  Each segment opens with its own header (so any
        single segment also reads standalone via :meth:`read_jsonl`), but
        here a header marks a *rotation boundary* of one stream, not a
        run restart — samples and events accumulate across segments."""
        paths, k = [], 1
        while os.path.exists(f"{path}.{k}"):
            paths.append(f"{path}.{k}")
            k += 1
        if os.path.exists(path):
            paths.append(path)
        if not paths:
            raise FileNotFoundError(f"no sink segments at {path!r}")
        tl_ = None
        for p in paths:
            with open(p) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    obj = json.loads(line)
                    if "schema" in obj:
                        if obj["schema"] not in TIMELINE_SCHEMAS:
                            raise ValueError(f"unknown timeline sink "
                                             f"schema {obj['schema']!r}")
                        if tl_ is None:
                            tl_ = cls(source=obj.get("source", "run"),
                                      counter_names=tuple(obj["counters"]))
                        continue
                    if tl_ is None:
                        tl_ = cls()        # headerless stream
                    if "sample" in obj:
                        s = obj["sample"]
                        tl_.snapshot(s["step"], s["tenants"],
                                     gauges=s.get("gauges"), t=s["t"])
                    elif "event" in obj:
                        tl_.events.append(obj["event"])
        return tl_ if tl_ is not None else cls()

    # ------------------------------------------------------------------
    # derived series
    # ------------------------------------------------------------------
    @property
    def tenants(self) -> tuple[str, ...]:
        return tuple(self._tenants)

    def _value(self, sample: dict, tenant: str, counter: str) -> float:
        return float(sample["tenants"].get(tenant, {}).get(counter, 0.0))

    def rate_axis(self) -> dict[str, list]:
        """Window-end coordinates for every rates() series: the step and
        wall-time stamp of each window's closing sample."""
        return {"step": [s["step"] for s in self.samples[1:]],
                "t": [s["t"] for s in self.samples[1:]]}

    def _window(self, prev: dict, cur: dict,
                tenants: Sequence[str] | None = None
                ) -> dict[str, dict[str, float]]:
        """Derived rates for ONE window between two samples:
        ``{tenant: {field: value}}`` — every tenant seen so far, or just
        ``tenants`` (intersected with the seen set) when a caller like a
        scoped :class:`ThresholdWatcher` only needs a few."""
        if tenants is None:
            tenants = self._tenants
        else:
            tenants = [tn for tn in tenants if tn in self._tenants]
        dt = cur["t"] - prev["t"]
        if dt <= 0:
            dt = float(max(cur["step"] - prev["step"], 1))
        out: dict[str, dict[str, float]] = {}
        for tn in tenants:
            d = {c: max(self._value(cur, tn, c)
                        - self._value(prev, tn, c), 0.0)
                 for c in self.counter_names}
            ops = d.get("ops", 0.0)
            pct = (lambda n: 100.0 * n / ops if ops > 0 else 0.0)
            comp = d.get("completions", 0.0)
            out[tn] = {
                "ops_s": ops / dt,
                "bytes_s": d.get("bytes", 0.0) / dt,
                "chunks_s": d.get("chunks", 0.0) / dt,
                "throttled_pct": pct(d.get("throttled", 0.0)),
                "stalls_pct": pct(d.get("stalls", 0.0)),
                "denied_pct": pct(d.get("denied", 0.0)),
                # cq_depth is a high-water mark, not additive: report the
                # level at the window's close.
                "cq_depth": self._value(cur, tn, "cq_depth"),
                "retrans_s": d.get("retransmits", 0.0) / dt,
                "timeouts_s": d.get("timeouts", 0.0) / dt,
                "srq_grants_s": d.get("srq_grants", 0.0) / dt,
                "cqe_err_pct": (100.0 * d.get("cqe_errors", 0.0) / comp
                                if comp > 0 else 0.0),
                "preempt_s": d.get("preemptions", 0.0) / dt,
                "restore_s": d.get("restores", 0.0) / dt,
            }
        return out

    def window_rates(self, i: int = -1,
                     tenants: Sequence[str] | None = None
                     ) -> dict[str, dict[str, float]]:
        """Rates for the single window closing at ``samples[i]``
        (``i >= 1`` or negative; the newest window by default) — what a
        :class:`ThresholdWatcher` consumes incrementally, optionally
        restricted to ``tenants`` so a scoped watcher pays O(watched
        tenants), not O(all tenants).  Returns ``{}`` while fewer than
        two samples exist."""
        n = len(self.samples)
        if n < 2:
            return {}
        if i < 0:
            i += n
        if not 1 <= i < n:
            raise IndexError(f"window index {i} outside [1, {n - 1}]")
        return self._window(self.samples[i - 1], self.samples[i],
                            tenants=tenants)

    def rates(self) -> dict[str, dict[str, list[float]]]:
        """Per-tenant derived series, one value per window between
        consecutive samples: ``{tenant: {field: [v, ...]}}``.

        Deltas divide by the window's wall time; a non-positive wall
        delta (explicit equal stamps, clock weirdness) falls back to the
        step delta so the series stays finite and deterministic."""
        out: dict[str, dict[str, list[float]]] = {
            tn: {f: [] for f in RATE_FIELDS} for tn in self._tenants}
        for prev, cur in zip(self.samples, self.samples[1:]):
            w = self._window(prev, cur)
            for tn in self._tenants:
                for f in RATE_FIELDS:
                    out[tn][f].append(w[tn][f])
        return out

    def gauge_series(self) -> dict[str, list[float]]:
        """Run-wide gauges aligned to the sample axis (not windows)."""
        return {g: [float(s["gauges"].get(g, 0.0)) for s in self.samples]
                for g in self._gauge_names}

    # ------------------------------------------------------------------
    # artifact
    # ------------------------------------------------------------------
    def to_doc(self) -> dict:
        return {
            "schema": TIMELINE_SCHEMA,
            "source": self.source,
            "counters": list(self.counter_names),
            "rate_fields": list(RATE_FIELDS),
            "tenants": list(self._tenants),
            "samples": self.samples,
            "events": list(self.events),
            "axis": self.rate_axis(),
            "rates": self.rates(),
            "gauges": self.gauge_series(),
        }

    def save(self, path: str) -> str:
        """Write the schema-versioned JSON artifact; returns ``path``."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_doc(), f, indent=1)
        return path

    @staticmethod
    def load(path: str) -> dict:
        """Load and validate an artifact; returns the document dict."""
        with open(path) as f:
            doc = json.load(f)
        validate_timeline(doc)
        return doc

    # ------------------------------------------------------------------
    # console panels
    # ------------------------------------------------------------------
    def panel(self, width: int = 48,
              fields: tuple[str, ...] = RATE_FIELDS) -> str:
        """Per-tenant ASCII sparkline panels (plus run-wide gauges).

        All-zero series other than ``ops_s``/``bytes_s`` are elided so a
        quiet tenant stays one glanceable block."""
        lines: list[str] = []
        rates = self.rates()
        for tn in self._tenants:
            lines.append(f"-- tenant {tn} ({self.source}, "
                         f"{len(self.samples)} samples) ".ljust(width + 18, "-"))
            for f in fields:
                series = rates[tn][f]
                if not series:
                    continue
                if f not in ("ops_s", "bytes_s") and not any(series):
                    continue
                lines.append(f"  {f:14s} {sparkline(series, width):{width}s}"
                             f" last {series[-1]:.1f}")
        gauges = self.gauge_series()
        if gauges:
            lines.append(f"-- run gauges ".ljust(width + 18, "-"))
            for g, series in gauges.items():
                lines.append(f"  {g:14s} {sparkline(series, width):{width}s}"
                             f" last {series[-1]:.1f}")
        return "\n".join(lines)


def validate_timeline(doc: dict) -> dict:
    """Structural check of a timeline artifact; raises ValueError on a
    malformed document, returns it unchanged otherwise (so call sites can
    chain).  This is the CI smoke's assertion and the forward-compat
    gate: every known schema version is checked against its own layout
    (v1 = v2 without the ``events`` list), unknown versions are refused,
    not misread, and every series is length-checked against the sample
    axis — a truncated ``rates``/``gauges``/``axis`` series is rejected
    even on a v1 document."""
    if not isinstance(doc, dict):
        raise ValueError(f"timeline artifact must be a dict, got {type(doc)}")
    schema = doc.get("schema")
    if schema not in TIMELINE_SCHEMAS:
        raise ValueError(f"unknown timeline schema {schema!r} "
                         f"(expected one of {TIMELINE_SCHEMAS})")
    required = ["source", "counters", "rate_fields", "tenants", "samples",
                "axis", "rates", "gauges"]
    if schema == TIMELINE_SCHEMA:
        required.append("events")
    for key in required:
        if key not in doc:
            raise ValueError(f"timeline artifact missing key {key!r}")
    n_samples = len(doc["samples"])
    n_windows = max(n_samples - 1, 0)
    for ax in ("step", "t"):
        if len(doc["axis"].get(ax, ())) != n_windows:
            raise ValueError(f"timeline axis {ax!r} length != sample windows")
    for s in doc["samples"]:
        for key in ("step", "t", "tenants", "gauges"):
            if key not in s:
                raise ValueError(f"timeline sample missing key {key!r}")
    for tn in doc["tenants"]:
        series = doc["rates"].get(tn)
        if series is None:
            raise ValueError(f"timeline rates missing tenant {tn!r}")
        for f in doc["rate_fields"]:
            if len(series.get(f, ())) != n_windows:
                raise ValueError(
                    f"rate series {tn}/{f} length != window count")
    for g, series in doc["gauges"].items():
        if len(series) != n_samples:
            raise ValueError(f"gauge series {g!r} length != sample count")
    for ev in doc.get("events", ()):
        for key in ("kind", "step"):
            if key not in ev:
                raise ValueError(f"timeline event missing key {key!r}")
    return doc


def merge_timelines(parts: Sequence[CounterTimeline], *,
                    source: str = "pod") -> CounterTimeline:
    """Merge per-process timelines into one pod-level timeline
    (docs/observability.md) — the cross-host half of the control plane:
    every process snapshots its own counters locally, the controller host
    merges them step-aligned and runs the watcher hierarchy over the
    merged rate series.

    Semantics:

    * **step-aligned, never truncated**: all parts must carry the same
      number of samples and sample ``i`` of every part must stamp the
      same step — a lagging or over-eager host raises ``ValueError``
      rather than silently dropping the tail (a misaligned pod merge is
      an upstream bug, and a merged artifact built from it would lie).
    * counter layouts must match; additive counters **sum** across parts
      per tenant, while ``cq_depth`` (a high-water level) takes the
      **max** — the same convention as the benchmark's
      ``accumulate_report``.
    * the merged sample's wall stamp is the **latest** part stamp (the
      pod window closes when the last process reports) and gauges sum.
    * events from every part interleave sorted by ``(step, t)``, each
      tagged with its origin timeline's ``source`` in
      ``detail["origin"]``.

    The result is an ordinary :class:`CounterTimeline` (schema
    ``cord-timeline/v2``): it saves, validates, renders panels and feeds
    watchers exactly like a single-process one."""
    parts = list(parts)
    if not parts:
        raise ValueError("merge_timelines needs at least one timeline")
    names = parts[0].counter_names
    for p in parts[1:]:
        if p.counter_names != names:
            raise ValueError(
                f"cannot merge timelines with different counter layouts: "
                f"{parts[0].source!r} has {names}, {p.source!r} has "
                f"{p.counter_names}")
    n = len(parts[0].samples)
    for p in parts[1:]:
        if len(p.samples) != n:
            raise ValueError(
                f"step-misaligned merge: {parts[0].source!r} has {n} "
                f"samples but {p.source!r} has {len(p.samples)} — refusing "
                f"to truncate; snapshot every process at every step")
    merged = CounterTimeline(source=source, counter_names=names)
    for i in range(n):
        steps = sorted({int(p.samples[i]["step"]) for p in parts})
        if len(steps) > 1:
            raise ValueError(f"step-misaligned merge: sample {i} stamps "
                             f"steps {steps} across parts")
        report: dict[str, dict[str, float]] = {}
        gauges: dict[str, float] = {}
        for p in parts:
            s = p.samples[i]
            for tn, ctrs in s["tenants"].items():
                acc = report.setdefault(tn, dict.fromkeys(names, 0.0))
                for c in names:
                    v = float(ctrs.get(c, 0.0))
                    acc[c] = max(acc[c], v) if c == "cq_depth" else acc[c] + v
            for g, v in s["gauges"].items():
                gauges[g] = gauges.get(g, 0.0) + float(v)
        merged.snapshot(steps[0], report, gauges=gauges,
                        t=max(float(p.samples[i]["t"]) for p in parts))
    tagged = [dict(ev, detail=dict(ev.get("detail") or {}, origin=p.source))
              for p in parts for ev in p.events]
    merged.events.extend(sorted(tagged,
                                key=lambda e: (e["step"], e.get("t", 0.0))))
    return merged


class ThresholdWatcher:
    """Hysteresis threshold watcher over a timeline's rate series — the
    trigger half of the elastic control loop (docs/elasticity.md).

    ``thresholds`` maps :data:`RATE_FIELDS` names to trigger levels.  A
    tenant *trips* when any watched field sits at/over its level for
    ``sustain`` consecutive windows; tripping emits one trigger event,
    resets the tenant's streak and starts a ``cooldown`` of that many
    windows during which the tenant cannot accumulate a new streak.  One
    transient over-threshold window (or one quiet window inside a streak)
    therefore never triggers, and a persistently bad tenant triggers once
    per cooldown period, not once per window.

    The optional **release arm** closes the shrink→grow cycle
    (docs/elasticity.md): after a trigger *arms* a tenant, sustained
    quiet — every ``release`` field strictly *below* its level for
    ``release_sustain`` consecutive windows — emits one ``recover`` event
    and starts a separate ``release_cooldown``.  Release levels must sit
    strictly below their trigger thresholds: the gap is the hysteresis
    band, so a rate parked *on* a level oscillates neither arm.  A tenant
    never recovers while still inside the trigger cooldown, and a window
    that trips (or merely sits over a trigger threshold) resets any
    recovery streak.

    :meth:`observe` is incremental — each call consumes only the windows
    appended since the last call, and each window derives rates only for
    the watched tenants, so it can run after every snapshot at
    O(new windows × watched tenants) cost.  The watcher is pure host-side
    bookkeeping: it never touches traced code."""

    def __init__(self, thresholds: dict[str, float], *, sustain: int = 3,
                 cooldown: int = 8, tenants: Sequence[str] | None = None,
                 release: dict[str, float] | None = None,
                 release_sustain: int | None = None,
                 release_cooldown: int | None = None):
        unknown = set(thresholds) - set(RATE_FIELDS)
        if unknown:
            raise ValueError(f"unknown rate fields {sorted(unknown)} "
                             f"(known: {RATE_FIELDS})")
        if not thresholds:
            raise ValueError("ThresholdWatcher needs at least one threshold")
        if sustain < 1 or cooldown < 0:
            raise ValueError(f"need sustain >= 1 and cooldown >= 0, got "
                             f"{sustain}/{cooldown}")
        self.thresholds = {k: float(v) for k, v in thresholds.items()}
        self.sustain = int(sustain)
        self.cooldown = int(cooldown)
        self.tenants = tuple(tenants) if tenants else None
        self.release = ({k: float(v) for k, v in release.items()}
                        if release else None)
        if self.release:
            unknown = set(self.release) - set(RATE_FIELDS)
            if unknown:
                raise ValueError(f"unknown release rate fields "
                                 f"{sorted(unknown)} (known: {RATE_FIELDS})")
            for f, lv in self.release.items():
                if f in self.thresholds and lv >= self.thresholds[f]:
                    raise ValueError(
                        f"release level {f}={lv} must sit below its trigger "
                        f"threshold {self.thresholds[f]} — the gap is the "
                        f"hysteresis band that damps oscillation")
        self.release_sustain = int(sustain if release_sustain is None
                                   else release_sustain)
        self.release_cooldown = int(cooldown if release_cooldown is None
                                    else release_cooldown)
        if self.release_sustain < 1 or self.release_cooldown < 0:
            raise ValueError(
                f"need release_sustain >= 1 and release_cooldown >= 0, got "
                f"{self.release_sustain}/{self.release_cooldown}")
        self.triggers: list[dict] = []     # every trigger ever emitted
        self.releases: list[dict] = []     # every recover ever emitted
        self._streak: dict[str, int] = {}
        self._cool: dict[str, int] = {}
        self._armed: dict[str, bool] = {}  # tripped, not yet recovered
        self._rstreak: dict[str, int] = {}
        self._rcool: dict[str, int] = {}
        self._seen = 0                     # windows consumed so far

    @classmethod
    def from_config(cls, cfg) -> "ThresholdWatcher":
        """Build from an :class:`~repro.configs.base.ElasticConfig`,
        whose ``thresholds`` (and optional ``release_thresholds``, the
        grow-back arm) are CLI-friendly ``"rate_field=level"`` strings."""
        def parse(specs):
            out: dict[str, float] = {}
            for spec in specs:
                name, sep, level = spec.partition("=")
                if not sep:
                    raise ValueError(f"threshold spec must be "
                                     f"'rate_field=level', got {spec!r}")
                out[name.strip()] = float(level)
            return out

        rel = parse(getattr(cfg, "release_thresholds", ()) or ())
        return cls(parse(cfg.thresholds), sustain=cfg.sustain,
                   cooldown=cfg.cooldown, tenants=cfg.tenants or None,
                   release=rel or None,
                   release_sustain=getattr(cfg, "release_sustain", None),
                   release_cooldown=getattr(cfg, "release_cooldown", None))

    def observe(self, timeline: CounterTimeline) -> list[dict]:
        """Consume every not-yet-seen window of ``timeline``; returns the
        ``trigger`` (and, with a release arm, ``recover``) events fired
        by those windows, often empty.  Event dicts match
        :meth:`CounterTimeline.record_event`'s shape so callers can log
        them straight into the artifact."""
        fired: list[dict] = []
        n_windows = max(len(timeline.samples) - 1, 0)
        while self._seen < n_windows:
            i = self._seen + 1            # sample index closing this window
            window = timeline.window_rates(i, tenants=self.tenants)
            close = timeline.samples[i]
            for tn, fields in window.items():
                if self._cool.get(tn, 0) > 0:
                    # trigger cooldown freezes BOTH arms: no re-trip, and
                    # no grow-back progress while the shrink settles
                    self._cool[tn] -= 1
                    self._streak[tn] = 0
                    self._rstreak[tn] = 0
                    continue
                over = {f: fields.get(f, 0.0)
                        for f, lim in self.thresholds.items()
                        if fields.get(f, 0.0) >= lim}
                self._streak[tn] = self._streak.get(tn, 0) + 1 if over else 0
                if over and self._streak[tn] >= self.sustain:
                    ev = {"kind": "trigger", "step": int(close["step"]),
                          "t": float(close["t"]), "tenant": tn,
                          "detail": {"over": over,
                                     "sustained": self._streak[tn]}}
                    fired.append(ev)
                    self.triggers.append(ev)
                    self._streak[tn] = 0
                    self._cool[tn] = self.cooldown
                    if self.release:
                        self._armed[tn] = True
                        self._rstreak[tn] = 0
                    continue
                # ---- release (grow-back) arm ------------------------------
                if not self.release or not self._armed.get(tn):
                    continue
                if self._rcool.get(tn, 0) > 0:
                    self._rcool[tn] -= 1
                    self._rstreak[tn] = 0
                    continue
                under = {f: fields.get(f, 0.0)
                         for f, lim in self.release.items()
                         if fields.get(f, 0.0) < lim}
                if over or len(under) < len(self.release):
                    # any release field at/over its level — or a fresh
                    # over-threshold window — cancels recovery progress
                    self._rstreak[tn] = 0
                    continue
                self._rstreak[tn] = self._rstreak.get(tn, 0) + 1
                if self._rstreak[tn] >= self.release_sustain:
                    ev = {"kind": "recover", "step": int(close["step"]),
                          "t": float(close["t"]), "tenant": tn,
                          "detail": {"under": under,
                                     "sustained": self._rstreak[tn]}}
                    fired.append(ev)
                    self.releases.append(ev)
                    self._armed[tn] = False
                    self._rstreak[tn] = 0
                    self._rcool[tn] = self.release_cooldown
            self._seen += 1
        return fired

    def gauges(self) -> dict[str, float]:
        """Run-wide watcher gauges to ride along in snapshots
        (docs/observability.md): the largest over-threshold streak and
        the largest remaining cooldown across watched tenants, as of the
        windows observed so far.  With a release arm configured, the
        grow-back side's streak/cooldown ride along too."""
        g = {"watch_streak": float(max(self._streak.values(), default=0)),
             "watch_cooldown": float(max(self._cool.values(), default=0))}
        if self.release:
            g["watch_release_streak"] = float(
                max(self._rstreak.values(), default=0))
            g["watch_release_cooldown"] = float(
                max(self._rcool.values(), default=0))
        return g


class WatcherGroup:
    """A named hierarchy of watchers driven off ONE timeline — typically
    the merged pod timeline from :func:`merge_timelines`, so a
    train-remesh watcher and a serve-budget watcher read the same
    cluster-wide rate series (docs/elasticity.md).

    :meth:`observe` consumes the new windows through every member
    incrementally, tags each fired event's detail with the member's name
    (``detail["watcher"]``), records the events into the timeline's
    artifact (unless ``record=False``) and returns them per member, so a
    controller picks up exactly its own watcher's events:
    ``evs = group.observe(pod); train_ctl.respond(state, step,
    evs["train"]); serve_ctl.respond(evs["serve"])``."""

    def __init__(self, watchers: dict[str, ThresholdWatcher]):
        if not watchers:
            raise ValueError("WatcherGroup needs at least one watcher")
        for name, w in watchers.items():
            if not isinstance(w, ThresholdWatcher):
                raise ValueError(f"watcher {name!r} is not a "
                                 f"ThresholdWatcher: {type(w)}")
        self.watchers = dict(watchers)

    def observe(self, timeline: CounterTimeline, *,
                record: bool = True) -> dict[str, list[dict]]:
        out: dict[str, list[dict]] = {}
        for name, w in self.watchers.items():
            events = w.observe(timeline)
            for ev in events:
                ev["detail"]["watcher"] = name
                if record:
                    timeline.record_event(ev["kind"], ev["step"],
                                          tenant=ev["tenant"], t=ev["t"],
                                          detail=ev["detail"])
            out[name] = events
        return out

    def gauges(self) -> dict[str, float]:
        """Every member's gauges, namespaced ``<name>_<gauge>``."""
        return {f"{name}_{k}": v for name, w in self.watchers.items()
                for k, v in w.gauges().items()}


__all__ = ["CounterTimeline", "ThresholdWatcher", "WatcherGroup",
           "merge_timelines", "sparkline",
           "validate_timeline", "TIMELINE_SCHEMA", "TIMELINE_SCHEMA_V1",
           "TIMELINE_SCHEMAS", "RATE_FIELDS"]
