"""CoRD core — the paper's primary contribution in JAX.

The Converged Dataplane (`Dataplane`) is the narrow waist through which
every communication operation in the framework flows, with three modes
(bypass / cord / socket), CoRD policies (telemetry, security/MR, quota,
QoS), technique toggles for the paper's Fig.-1 ablations, chunked
collective scheduling, and an ibverbs-style point-to-point layer for the
perftest reproduction.  Mediation itself is one composable artifact — the
`MediationPipeline` (core/mediation.py) — that the collectives, the GSPMD
constraint path and the verbs layer all compile their paths from, with
per-tenant runtime accounting threaded through shard_map bodies via the
uniform ``(x, state)`` convention.  `CounterTimeline` (core/obs.py)
streams those per-tenant counter blocks into schema-versioned timeline
artifacts and console sparkline panels (docs/observability.md).
"""

from repro.core.dataplane import Dataplane, make_dataplane
from repro.core.mediation import (
    HostTokenBucket,
    MediationPipeline,
    MediationStage,
    build_pipeline,
)
from repro.core.mr import MemoryRegion, MRError, MRRegistry
from repro.core.obs import (
    CounterTimeline,
    ThresholdWatcher,
    WatcherGroup,
    merge_timelines,
    sparkline,
    TIMELINE_SCHEMA,
    validate_timeline,
)
from repro.core.policies import (
    Policy,
    PolicyContext,
    PolicyViolation,
    QoSPolicy,
    QuotaPolicy,
    SecurityPolicy,
    TelemetryPolicy,
)
from repro.core.telemetry import OpRecord, Telemetry

__all__ = [
    "Dataplane", "make_dataplane",
    "MediationPipeline", "MediationStage", "build_pipeline",
    "HostTokenBucket",
    "MemoryRegion", "MRError", "MRRegistry",
    "CounterTimeline", "ThresholdWatcher", "WatcherGroup",
    "merge_timelines", "sparkline", "TIMELINE_SCHEMA",
    "validate_timeline",
    "Policy", "PolicyContext", "PolicyViolation",
    "QoSPolicy", "QuotaPolicy", "SecurityPolicy", "TelemetryPolicy",
    "OpRecord", "Telemetry",
]
