"""Chunked collective scheduling — the QoS control CoRD gives the OS,
used here both as a *policy* mechanism (issue order by priority class) and
as a *performance* mechanism (compute/communication overlap).

A large collective is split into chunks along a leading axis; each chunk is
issued through the dataplane separately.  Because the chunks are
independent ops in the graph, the scheduler can:

  * reorder them by QoS class (``schedule_batch``),
  * interleave them with compute (``chunked_psum`` with ``interleave``),
    giving XLA/TPU latency hiding over the ICI,
  * rate-limit a tenant by simply issuing fewer chunks per step.

This is the TPU-native expression of "the kernel is on the data path":
communication becomes schedulable at a granularity the framework controls.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import telemetry as tl
from repro.core.policies import QoSPolicy


def split_chunks(x: jax.Array, num_chunks: int, axis: int = 0) -> list[jax.Array]:
    """Split ``x`` into ``num_chunks`` equal chunks along ``axis``.

    Uneven extents are padded with zeros on the tail chunk rather than
    collapsing to one chunk, so chunk-granular scheduling (QoS
    preemption, rate limiting) still applies to odd-sized collectives.
    Callers slice the concatenated result back to the original extent
    (``chunked_psum`` does)."""
    n = x.shape[axis]
    num_chunks = max(1, min(num_chunks, n))
    rem = n % num_chunks
    if rem:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, num_chunks - rem)
        x = jnp.pad(x, widths)
    return list(jnp.split(x, num_chunks, axis=axis))


def _preempt_bucket(dp, state, tenant: str | None):
    """The QoS bucket governing ``tenant`` on ``dp``, if chunk-granular
    preemption can run: policies enforced, runtime state threaded with
    the bucket's slice present, and the tenant actually rate-limited."""
    if state is None or not getattr(dp, "enforce", False):
        return None
    name = tenant or dp.tenant
    for p in dp.policies:
        if isinstance(p, QoSPolicy) and p.governs(name) and p.name in state:
            return p
    return None


def chunked_psum(
    dp,
    x: jax.Array,
    axis: str,
    *,
    num_chunks: int,
    tag: str = "chunked_psum",
    qos: str = "default",
    state=None,
    tenant: str | None = None,
    interleave: Callable[[int], None] | None = None,
    preempt: bool = True,
):
    """psum ``x`` in ``num_chunks`` sequentially-issued chunks.

    Chunks are fenced with optimization barriers so the compiler cannot
    re-merge them into one collective — preserving both the scheduling
    semantics and the overlap opportunity.  Returns ``(out, state)`` —
    the uniform dataplane state convention; with runtime state threaded,
    the issuing tenant's ``chunks`` counter accounts every chunk.

    **Wire preemption** (``preempt=True``): when the issuing tenant is
    governed by an enforced QoS token bucket, every chunk consults the
    bucket *before it is issued* (``QoSPolicy.on_chunk_runtime``).  A
    chunk arriving on a dry bucket is deferred — it stalls on the
    token deficit, yielding the ICI to other tenants' traffic mid-op,
    and the deferral lands in the tenant's ``throttled`` counter.  The
    chunk ops are issued ``precharged`` so the pipeline's token-bucket
    stage does not debit them a second time; totals match the
    stage-charged path exactly, and values are bit-identical to the
    unconstrained collective."""
    n = x.shape[0]
    chunks = split_chunks(x, num_chunks, axis=0)
    bucket = _preempt_bucket(dp, state, tenant) if preempt else None
    tname = tenant or dp.tenant
    ti = dp.tenant_index(tenant)
    outs = []
    for i, c in enumerate(chunks):
        if interleave is not None:
            interleave(i)
        if len(chunks) > 1:
            (c,) = jax.lax.optimization_barrier((c,))
        if bucket is not None:
            rec = tl.OpRecord(kind="all_reduce", tag=f"{tag}/chunk{i}",
                              bytes=tl.nbytes(c),
                              axes=tl.normalize_axes(axis),
                              mode=dp.cfg.mode, qos=qos, precharged=True)
            c, state = bucket.on_chunk_runtime(c, state, rec, tname, ti)
        r, state = dp.psum(c, axis, tag=f"{tag}/chunk{i}", qos=qos,
                           state=state, tenant=tenant,
                           precharged=bucket is not None)
        outs.append(r)
    out = jnp.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]
    if out.shape[0] != n:     # drop the tail chunk's padding rows
        out = jax.lax.slice_in_dim(out, 0, n, axis=0)
    if state is not None and "counters" in state and len(chunks) > 1:
        ctrs = tl.tenant_counters_bump(state["counters"], ti,
                                       chunks=len(chunks))
        state = {**state, "counters": ctrs}
    return out, state


def bucket_pytree(tree, bucket_bytes: int) -> list[list[tuple]]:
    """Group pytree leaves into communication buckets of ~bucket_bytes.

    Returns a list of buckets; each bucket is a list of
    ``(path, leaf)`` tuples.  Used by the gradient synchronizer to issue
    bucketed, reverse-layer-order all-reduces (overlap with backward)."""
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    buckets: list[list[tuple]] = []
    cur: list[tuple] = []
    cur_bytes = 0
    for path, leaf in leaves:
        sz = leaf.size * jnp.dtype(leaf.dtype).itemsize
        if cur and cur_bytes + sz > bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append((path, leaf))
        cur_bytes += sz
    if cur:
        buckets.append(cur)
    return buckets


def schedule_batch(qos: QoSPolicy | None,
                   ops: Sequence[tuple[str, Callable[[], jax.Array]]]):
    """Issue a batch of dataplane ops in QoS-priority order.

    ``ops`` is a sequence of ``(qos_class, thunk)``; returns results in the
    *original* order, but issues (traces) them in priority order, which
    fixes their program order for the compiler's scheduler."""
    indexed = list(enumerate(ops))
    if qos is not None:
        indexed.sort(key=lambda kv: qos.priority(kv[1][0]))
    results: dict[int, jax.Array] = {}
    prev = None
    for idx, (_cls, thunk) in indexed:
        out = thunk()
        if prev is not None:
            # chain a barrier so issue order (= priority order) is fixed
            # in the program for the compiler's scheduler
            _, out = jax.lax.optimization_barrier((prev, out))
        results[idx] = out
        prev = out
    return [results[i] for i in range(len(ops))]


__all__ = ["split_chunks", "chunked_psum", "bucket_pytree", "schedule_batch"]
