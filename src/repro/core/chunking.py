"""Chunked collective scheduling — the QoS control CoRD gives the OS,
used here both as a *policy* mechanism (issue order by priority class) and
as a *performance* mechanism (compute/communication overlap).

A large collective is split into chunks along a leading axis; each chunk is
issued through the dataplane separately.  Because the chunks are
independent ops in the graph, the scheduler can:

  * reorder them by QoS class (``schedule_batch``),
  * interleave them with compute (``chunked_psum`` with ``interleave``),
    giving XLA/TPU latency hiding over the ICI,
  * rate-limit a tenant by simply issuing fewer chunks per step.

This is the TPU-native expression of "the kernel is on the data path":
communication becomes schedulable at a granularity the framework controls.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import telemetry as tl
from repro.core.policies import QoSPolicy


def split_chunks(x: jax.Array, num_chunks: int, axis: int = 0) -> list[jax.Array]:
    n = x.shape[axis]
    num_chunks = max(1, min(num_chunks, n))
    if n % num_chunks:
        num_chunks = 1  # fall back: uneven splits are not worth padding here
    return list(jnp.split(x, num_chunks, axis=axis))


def chunked_psum(
    dp,
    x: jax.Array,
    axis: str,
    *,
    num_chunks: int,
    tag: str = "chunked_psum",
    qos: str = "default",
    state=None,
    tenant: str | None = None,
    interleave: Callable[[int], None] | None = None,
):
    """psum ``x`` in ``num_chunks`` sequentially-issued chunks.

    Chunks are fenced with optimization barriers so the compiler cannot
    re-merge them into one collective — preserving both the scheduling
    semantics and the overlap opportunity.  Returns ``(out, state)`` —
    the uniform dataplane state convention; with runtime state threaded,
    the issuing tenant's ``chunks`` counter accounts every chunk."""
    chunks = split_chunks(x, num_chunks, axis=0)
    outs = []
    for i, c in enumerate(chunks):
        if interleave is not None:
            interleave(i)
        if len(chunks) > 1:
            (c,) = jax.lax.optimization_barrier((c,))
        r, state = dp.psum(c, axis, tag=f"{tag}/chunk{i}", qos=qos,
                           state=state, tenant=tenant)
        outs.append(r)
    out = jnp.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]
    if state is not None and "counters" in state and len(chunks) > 1:
        ctrs = tl.tenant_counters_bump(state["counters"],
                                       dp.tenant_index(tenant),
                                       chunks=len(chunks))
        state = {**state, "counters": ctrs}
    return out, state


def bucket_pytree(tree, bucket_bytes: int) -> list[list[tuple]]:
    """Group pytree leaves into communication buckets of ~bucket_bytes.

    Returns a list of buckets; each bucket is a list of
    ``(path, leaf)`` tuples.  Used by the gradient synchronizer to issue
    bucketed, reverse-layer-order all-reduces (overlap with backward)."""
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    buckets: list[list[tuple]] = []
    cur: list[tuple] = []
    cur_bytes = 0
    for path, leaf in leaves:
        sz = leaf.size * jnp.dtype(leaf.dtype).itemsize
        if cur and cur_bytes + sz > bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append((path, leaf))
        cur_bytes += sz
    if cur:
        buckets.append(cur)
    return buckets


def schedule_batch(qos: QoSPolicy | None,
                   ops: Sequence[tuple[str, Callable[[], jax.Array]]]):
    """Issue a batch of dataplane ops in QoS-priority order.

    ``ops`` is a sequence of ``(qos_class, thunk)``; returns results in the
    *original* order, but issues (traces) them in priority order, which
    fixes their program order for the compiler's scheduler."""
    indexed = list(enumerate(ops))
    if qos is not None:
        indexed.sort(key=lambda kv: qos.priority(kv[1][0]))
    results: dict[int, jax.Array] = {}
    prev = None
    for idx, (_cls, thunk) in indexed:
        out = thunk()
        if prev is not None:
            # chain a barrier so issue order (= priority order) is fixed
            # in the program for the compiler's scheduler
            _, out = jax.lax.optimization_barrier((prev, out))
        results[idx] = out
        prev = out
    return [results[i] for i in range(len(ops))]


__all__ = ["split_chunks", "chunked_psum", "bucket_pytree", "schedule_batch"]
