"""CoRD policies (paper §3): "lightweight, non-blocking policies ...
powerful enough to implement QoS, security, and isolation".

A policy sees every dataplane op at issue time and may
  * account it        (TelemetryPolicy — observability)
  * validate it       (SecurityPolicy — registered memory regions only)
  * meter it          (QuotaPolicy — per-tenant byte budgets)
  * throttle it       (QoSPolicy — priority classes + token-bucket limiter)

Policies must be *non-blocking* and constant-cost per op — the paper's
requirement that keeps CoRD fast.  Each policy has two planes:

* **trace-time hook** ``on_op`` — the kernel inspecting the WQE while the
  program is being built.  Free at run time; may refuse the op by raising
  :class:`PolicyViolation`.
* **runtime hooks** ``init_state`` / ``on_op_runtime`` — contribute a
  pytree slice to the dataplane's per-tenant runtime state and transform
  ``(x, state)`` inside traced code.  This is how QoS becomes a *real*
  rate limiter and quota becomes *real* per-tenant accounting: the work
  happens on the measured path, not just when the graph is traced.

Runtime hooks are invoked by the mediation pipeline stages
(core/mediation.py), never directly by user code.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core import techniques as tech
from repro.core import telemetry as tl
from repro.core.mr import MRError, MRRegistry


class PolicyViolation(Exception):
    pass


@dataclass
class PolicyContext:
    """Everything a policy may consult when an op is issued."""
    rec: tl.OpRecord
    tenant: str = "default"
    mr_name: str | None = None
    operand: object | None = None  # abstract value (shape/dtype), not data


class Policy:
    """Base policy: no-op on both planes."""

    name = "policy"

    # ---- trace-time plane ------------------------------------------------
    def on_op(self, ctx: PolicyContext) -> None:
        """Trace-time hook. Raise PolicyViolation to reject the op."""

    def reset(self) -> None:
        pass

    # ---- runtime plane ---------------------------------------------------
    def init_state(self, num_tenants: int):
        """Host-side: this policy's slice of the runtime state pytree, or
        None if the policy keeps no traced state."""
        return None

    def on_op_runtime(self, x, state, rec: tl.OpRecord, tenant: str,
                      tenant_idx: int):
        """Traced hook: transform ``(x, state)`` for one issued op.

        ``state`` is the dataplane's full runtime-state dict (the policy's
        own slice lives under ``state[self.name]``); ``tenant``/
        ``tenant_idx`` are static.  Must keep ``x`` value-identical."""
        return x, state


@dataclass
class TelemetryPolicy(Policy):
    """Record every op into the host-side telemetry registry."""

    telemetry: tl.Telemetry = field(default_factory=tl.Telemetry)
    name: str = "telemetry"

    def on_op(self, ctx: PolicyContext) -> None:
        self.telemetry.record(ctx.rec)

    def reset(self) -> None:
        self.telemetry.reset()


@dataclass
class SecurityPolicy(Policy):
    """Only registered memory regions may cross the dataplane
    (paper §4: NIC refuses unregistered addresses)."""

    registry: MRRegistry = field(default_factory=MRRegistry)
    strict: bool = False   # strict: unnamed operands are rejected too
    name: str = "security"

    def on_op(self, ctx: PolicyContext) -> None:
        if ctx.mr_name is None:
            if self.strict:
                raise PolicyViolation(
                    f"op {ctx.rec.tag!r}: anonymous operand under strict security")
            return
        try:
            self.registry.check(ctx.mr_name, ctx.operand)
        except MRError as e:
            raise PolicyViolation(str(e)) from e


@dataclass
class QuotaPolicy(Policy):
    """Per-tenant communication byte budgets (isolation / multi-tenancy —
    what Justitia/FreeFlow do with extra middleboxes, done at the
    mediation point instead).

    Two enforcement planes:
      * ``hard=True`` (default): exceeding the budget at trace time raises
        PolicyViolation — the op is refused before it exists.
      * runtime: the counter-bump mediation stage calls
        :meth:`on_op_runtime` after bumping the tenant's byte counter, so
        over-budget traffic is marked in the per-tenant ``denied`` counter
        on the measured path (useful with ``hard=False`` for observe-only
        metering)."""

    limits: dict[str, int] = field(default_factory=dict)   # tenant -> bytes
    used: dict[str, int] = field(default_factory=dict)
    hard: bool = True
    name: str = "quota"

    def on_op(self, ctx: PolicyContext) -> None:
        lim = self.limits.get(ctx.tenant)
        if lim is None:
            return
        used = self.used.get(ctx.tenant, 0) + ctx.rec.bytes * ctx.rec.count
        if used > lim and self.hard:
            raise PolicyViolation(
                f"tenant {ctx.tenant!r} exceeded dataplane quota "
                f"({used} > {lim} bytes)")
        self.used[ctx.tenant] = used

    def on_op_runtime(self, x, state, rec, tenant, tenant_idx):
        lim = self.limits.get(tenant)
        if state is None or lim is None or "counters" not in state:
            return x, state
        # counter-bump has already added this op's bytes: flag the tenant's
        # row as denied when its cumulative runtime bytes exceed the budget.
        used = state["counters"][tenant_idx, tl.CTR_BYTES]
        over = (used > lim).astype(jnp.float32)
        ctrs = state["counters"].at[tenant_idx, tl.CTR_DENIED].add(over)
        return x, {**state, "counters": ctrs}

    def reset(self) -> None:
        self.used.clear()


@dataclass
class QoSPolicy(Policy):
    """Priority classes + per-tenant token-bucket rate limiting.

    Two mechanisms, matching the two kinds of control the kernel regains
    in CoRD:

    * **scheduling** — ops tagged with a higher-priority class get their
      chunks issued first when the dataplane splits large collectives
      (core/chunking.py).  Zero data-path cost, pure issue-order control.
    * **throttling** — tenants listed in ``rates`` are limited by a token
      bucket evaluated *inside traced code*: each op consumes one token,
      each op refills ``rates[tenant]`` tokens (capacity ``burst``).  An
      op issued on an empty bucket is stalled by a serial delay
      proportional to the deficit (``stall_ns`` per missing token) and
      accounted in the tenant's ``throttled`` runtime counter.  Values are
      never altered — only op *rate* is."""

    # class name -> priority (lower = sooner). "default" = 100.
    classes: dict[str, int] = field(default_factory=lambda: {"default": 100})
    rates: dict[str, float] = field(default_factory=dict)  # tenant -> tokens/op
    burst: float = 4.0
    stall_ns: float = 0.0   # emulated stall per missing token; 0 = account only
    name: str = "qos"

    def __post_init__(self):
        self._stall_iters = 0

    def priority(self, qos_class: str) -> int:
        return self.classes.get(qos_class, 100)

    def on_op(self, ctx: PolicyContext) -> None:
        # Record the class; scheduling happens in the chunker.
        ctx.rec.qos = ctx.rec.qos or "default"

    def init_state(self, num_tenants: int):
        if not self.rates:
            return None
        # convert the stall cost to delay iterations now, host-side —
        # calibrate() must never run under a trace.
        self._stall_iters = tech.iters_for_ns(self.stall_ns) \
            if self.stall_ns > 0 else 0
        return {"tokens": jnp.full((num_tenants,), float(self.burst),
                                   jnp.float32)}

    def on_op_runtime(self, x, state, rec, tenant, tenant_idx):
        rate = self.rates.get(tenant)
        if state is None or rate is None or self.name not in state:
            return x, state
        tokens = state[self.name]["tokens"]
        tk = jnp.minimum(tokens[tenant_idx] + rate, float(self.burst))
        ok = tk >= 1.0
        new_tk = jnp.where(ok, tk - 1.0, 0.0)
        deficit = jnp.where(ok, 0.0, 1.0 - tk)
        if self._stall_iters:
            x = tech.delay_chain_dyn(
                x, (deficit * self._stall_iters).astype(jnp.int32))
        state = {**state,
                 self.name: {"tokens": tokens.at[tenant_idx].set(new_tk)}}
        if "counters" in state:
            ctrs = tl.tenant_counters_bump(
                state["counters"], tenant_idx,
                throttled=(~ok).astype(jnp.float32))
            state = {**state, "counters": ctrs}
        return x, state

    def on_chunk_runtime(self, x, state, rec, tenant, tenant_idx):
        """Chunk-granular bucket consultation — the wire-preemption hook
        (core/chunking.py).

        A large collective split into chunks consults the bucket once
        per *chunk* instead of once per op: each chunk costs one token,
        and a chunk arriving on a dry bucket is a **deferral** — it
        stalls on the deficit (yielding the ICI to other tenants for
        the stall window) and lands in the tenant's ``throttled``
        counter before the chunk is issued.  Token semantics are
        identical to :meth:`on_op_runtime`, so an N-chunk collective is
        charged exactly what N pipeline-charged ops would be; the
        issuing chunks are marked ``precharged`` so the token-bucket
        stage does not double-bill them."""
        return self.on_op_runtime(x, state, rec, tenant, tenant_idx)

    def governs(self, tenant: str) -> bool:
        """True if this policy rate-limits ``tenant``."""
        return bool(self.rates.get(tenant))

    # ---- connection-table plane (core/verbs.py conn_send) ---------------
    # The multi-QP transport arbitrates post order across tenants' QPs
    # with this same bucket, but the winning QP is picked *inside traced
    # code*, so the tenant index is a traced scalar — the static
    # on_op_runtime hook cannot serve it.

    def rates_for(self, tenants: tuple[str, ...]) -> tuple[float, ...]:
        """Static per-QP refill rates (0.0 = ungoverned) in QP order —
        the host-side half of the connection table's arbitration."""
        return tuple(float(self.rates.get(t) or 0.0) for t in tenants)

    def arb_scores(self, state, tenant_idx_arr, rates_arr):
        """Tokens-after-refill per QP, the arbitration score ``conn_send``
        ranks posts by.  Ungoverned QPs (rate 0) score above any governed
        bucket so QoS only ever *demotes* governed tenants.  Reads the
        same ``state["qos"]["tokens"]`` the token-bucket stage debits."""
        tokens = state[self.name]["tokens"]
        tk = jnp.minimum(tokens[tenant_idx_arr] + rates_arr,
                         float(self.burst))
        return jnp.where(rates_arr > 0, tk, float(self.burst) + 1.0)

    def charge_wr(self, state, tenant_idx, rate, mask, bump_mask=None):
        """Token-bucket refill + debit for one arbitrated WR at a *traced*
        tenant index.  ``mask`` gates the token update (applied on every
        rank — the bucket is connection state for the arbitration loop, so
        it must stay SPMD-uniform); ``bump_mask`` additionally gates the
        ``throttled`` counter bump (runtime state, active rank only).
        No stall is emulated: arbitration already prefers token-rich QPs,
        a dry winner is just accounted."""
        if state is None or self.name not in state:
            return state
        governed = jnp.asarray(rate) > 0
        m = jnp.asarray(mask) & governed
        tokens = state[self.name]["tokens"]
        tk = jnp.minimum(tokens[tenant_idx] + rate, float(self.burst))
        ok = tk >= 1.0
        new_tk = jnp.where(ok, tk - 1.0, 0.0)
        tokens = tokens.at[tenant_idx].set(
            jnp.where(m, new_tk, tokens[tenant_idx]))
        state = {**state, self.name: {"tokens": tokens}}
        bm = m if bump_mask is None else (m & jnp.asarray(bump_mask))
        if "counters" in state:
            ctrs = tl.tenant_counters_bump(
                state["counters"], tenant_idx,
                throttled=(bm & ~ok).astype(jnp.float32))
            state = {**state, "counters": ctrs}
        return state


def default_policies() -> list[Policy]:
    return [TelemetryPolicy()]


__all__ = [
    "Policy", "PolicyContext", "PolicyViolation",
    "TelemetryPolicy", "SecurityPolicy", "QuotaPolicy", "QoSPolicy",
    "default_policies",
]
