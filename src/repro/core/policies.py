"""CoRD policies (paper §3): "lightweight, non-blocking policies ...
powerful enough to implement QoS, security, and isolation".

A policy sees every dataplane op at issue time and may
  * account it        (TelemetryPolicy — observability)
  * validate it       (SecurityPolicy — registered memory regions only)
  * meter it          (QuotaPolicy — per-tenant byte budgets)
  * schedule it       (QoSPolicy — chunk issue order by priority class)

Policies must be *non-blocking* and constant-cost per op — the paper's
requirement that keeps CoRD fast.  Trace-time work (validation, accounting
into the host-side Telemetry) is free at run time; in-graph work (counter
bumps, the mediation delay) is the measured per-op crossing cost.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax

from repro.core import telemetry as tl
from repro.core.mr import MRError, MRRegistry


class PolicyViolation(Exception):
    pass


@dataclass
class PolicyContext:
    """Everything a policy may consult when an op is issued."""
    rec: tl.OpRecord
    tenant: str = "default"
    mr_name: str | None = None
    operand: object | None = None  # abstract value (shape/dtype), not data


class Policy:
    """Base policy: no-op."""

    name = "policy"

    def on_op(self, ctx: PolicyContext) -> None:
        """Trace-time hook. Raise PolicyViolation to reject the op."""

    def in_graph_cost(self, ctx: PolicyContext) -> int:
        """Extra mediation iterations this policy adds per op (run time)."""
        return 0

    def reset(self) -> None:
        pass


@dataclass
class TelemetryPolicy(Policy):
    """Record every op into the host-side telemetry registry."""

    telemetry: tl.Telemetry = field(default_factory=tl.Telemetry)
    name: str = "telemetry"

    def on_op(self, ctx: PolicyContext) -> None:
        self.telemetry.record(ctx.rec)

    def reset(self) -> None:
        self.telemetry.reset()


@dataclass
class SecurityPolicy(Policy):
    """Only registered memory regions may cross the dataplane
    (paper §4: NIC refuses unregistered addresses)."""

    registry: MRRegistry = field(default_factory=MRRegistry)
    strict: bool = False   # strict: unnamed operands are rejected too
    name: str = "security"

    def on_op(self, ctx: PolicyContext) -> None:
        if ctx.mr_name is None:
            if self.strict:
                raise PolicyViolation(
                    f"op {ctx.rec.tag!r}: anonymous operand under strict security")
            return
        try:
            self.registry.check(ctx.mr_name, ctx.operand)
        except MRError as e:
            raise PolicyViolation(str(e)) from e


@dataclass
class QuotaPolicy(Policy):
    """Per-tenant communication byte budgets (isolation / multi-tenancy —
    what Justitia/FreeFlow do with extra middleboxes, done at the
    mediation point instead)."""

    limits: dict[str, int] = field(default_factory=dict)   # tenant -> bytes
    used: dict[str, int] = field(default_factory=dict)
    name: str = "quota"

    def on_op(self, ctx: PolicyContext) -> None:
        lim = self.limits.get(ctx.tenant)
        if lim is None:
            return
        used = self.used.get(ctx.tenant, 0) + ctx.rec.bytes * ctx.rec.count
        if used > lim:
            raise PolicyViolation(
                f"tenant {ctx.tenant!r} exceeded dataplane quota "
                f"({used} > {lim} bytes)")
        self.used[ctx.tenant] = used

    def reset(self) -> None:
        self.used.clear()


@dataclass
class QoSPolicy(Policy):
    """Priority classes for chunk scheduling.

    Ops tagged with a higher-priority class get their chunks issued first
    when the dataplane splits large collectives (core/chunking.py). This is
    a *scheduling* policy: zero data-path cost, pure issue-order control —
    the kind of control the kernel regains in CoRD."""

    # class name -> priority (lower = sooner). "default" = 100.
    classes: dict[str, int] = field(default_factory=lambda: {"default": 100})
    name: str = "qos"

    def priority(self, qos_class: str) -> int:
        return self.classes.get(qos_class, 100)

    def on_op(self, ctx: PolicyContext) -> None:
        # Record the class; scheduling happens in the chunker.
        ctx.rec.qos = ctx.rec.qos or "default"


def default_policies() -> list[Policy]:
    return [TelemetryPolicy()]


__all__ = [
    "Policy", "PolicyContext", "PolicyViolation",
    "TelemetryPolicy", "SecurityPolicy", "QuotaPolicy", "QoSPolicy",
    "default_policies",
]
