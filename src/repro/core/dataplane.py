"""The Converged Dataplane — the paper's contribution, adapted to JAX/TPU.

Every communication edge in the framework is issued through a
:class:`Dataplane`:

* under **pjit/GSPMD** the model code calls :meth:`constrain` with logical
  axis names; the dataplane resolves them against its sharding rules and
  emits ``with_sharding_constraint`` — the compiler materializes the
  collectives.  The dataplane is the single control point that sees (and
  records, and may refuse) every one of these edges.
* inside **shard_map** (explicit paths: gradient sync, MoE dispatch option,
  perftest/NPB benchmarks, the verbs layer) the model code calls
  :meth:`psum` / :meth:`all_gather` / :meth:`reduce_scatter` /
  :meth:`all_to_all` / :meth:`ppermute`, which lower to ``jax.lax``
  collectives *after* passing the mediation layer.

Mediation is one composable artifact: ``self.pipeline`` — a
:class:`~repro.core.mediation.MediationPipeline` compiled by
:func:`~repro.core.mediation.build_pipeline` from the mode presets,
technique toggles and policy set.  The GSPMD constraint path, the five
explicit collectives and the verbs layer (core/verbs.py) all run it, so a
mode or policy ablation applies identically everywhere.

Runtime state follows one uniform convention: every explicit collective
takes an optional ``state`` pytree (from :meth:`runtime_init`) and
returns ``(out, state)`` — always a pair, state ``None`` when not
threaded.  The state carries per-tenant counter blocks and policy state
(QoS token buckets), so quota/QoS have *runtime* teeth inside traced
code, not just at trace time.

Three modes (paper Fig. 2):

====== ============= ========= ============ =========================
mode   kernel-bypass zero-copy polling      policies enforced
====== ============= ========= ============ =========================
bypass yes           yes       yes          none (OS has no control)
cord   **no**        yes       yes          all configured policies
socket **no**        **no**    **no**       all + heavy stack cost
====== ============= ========= ============ =========================

Technique toggles in :class:`DataplaneConfig` override the mode presets so
that the paper's Fig. 1 ablations ("remove one technique at a time") can be
reproduced exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import DataplaneConfig
from repro.core import techniques as tech
from repro.core import telemetry as tl
from repro.core.mediation import build_pipeline, runtime_state_init
from repro.core.mr import MRRegistry
from repro.core.policies import (
    Policy,
    PolicyContext,
    PolicyViolation,
    QoSPolicy,
    QuotaPolicy,
    SecurityPolicy,
    TelemetryPolicy,
)

# ---------------------------------------------------------------------------
# Mode presets: (kernel_bypass, zero_copy, polling, enforce_policies)
# ---------------------------------------------------------------------------

_MODE_PRESETS = {
    "bypass": dict(kernel_bypass=True, zero_copy=True, polling=True, enforce=False),
    "cord": dict(kernel_bypass=False, zero_copy=True, polling=True, enforce=True),
    "socket": dict(kernel_bypass=False, zero_copy=False, polling=False, enforce=True),
}

_POLICY_FACTORIES: dict[str, Callable[[], Policy]] = {
    "telemetry": TelemetryPolicy,
    "security": SecurityPolicy,
    "quota": QuotaPolicy,
    "qos": QoSPolicy,
}


class Dataplane:
    """The narrow waist: all framework communication flows through here."""

    def __init__(
        self,
        cfg: DataplaneConfig | None = None,
        mesh: Mesh | None = None,
        rules: dict[str, Any] | None = None,
        tenant: str = "default",
        tenants: Sequence[str] | None = None,
        policies: Sequence[Policy] | None = None,
    ) -> None:
        self.cfg = cfg or DataplaneConfig()
        self.mesh = mesh
        self.rules = dict(rules or {})
        self.tenant = tenant
        names = list(tenants if tenants is not None else self.cfg.tenants)
        if tenant not in names:
            names.insert(0, tenant)
        self.tenants: tuple[str, ...] = tuple(names)
        if self.cfg.mode not in _MODE_PRESETS:
            raise ValueError(f"unknown dataplane mode {self.cfg.mode!r}")
        preset = _MODE_PRESETS[self.cfg.mode]
        # Effective techniques: mode preset AND config toggle, so the fig-1
        # ablations can "remove" a technique from any mode.
        self.kernel_bypass = preset["kernel_bypass"] and self.cfg.kernel_bypass
        self.zero_copy = preset["zero_copy"] and self.cfg.zero_copy
        self.polling = preset["polling"] and self.cfg.polling
        self.enforce = preset["enforce"]
        if policies is not None:
            self.policies = list(policies)
        else:
            self.policies = [_POLICY_FACTORIES[p]() for p in self.cfg.policies]
        self._telemetry = next(
            (p.telemetry for p in self.policies if isinstance(p, TelemetryPolicy)),
            tl.Telemetry(enabled=False))
        self._security = next(
            (p for p in self.policies if isinstance(p, SecurityPolicy)), None)
        self.registry: MRRegistry = (self._security.registry
                                     if self._security else MRRegistry())
        if self.cfg.emulate_costs:
            # calibrate the delay primitive NOW (eagerly) — calling it for
            # the first time under a trace would stage the probe jit.
            tech.calibrate()
        # The single mediation artifact every path compiles against.
        self.pipeline = build_pipeline(self)

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    @property
    def telemetry(self) -> tl.Telemetry:
        return self._telemetry

    @property
    def mode(self) -> str:
        return self.cfg.mode

    def with_mode(self, mode: str) -> "Dataplane":
        return Dataplane(dataclasses.replace(self.cfg, mode=mode),
                         mesh=self.mesh, rules=self.rules, tenant=self.tenant,
                         tenants=self.tenants)

    def reset(self) -> None:
        for p in self.policies:
            p.reset()

    # ------------------------------------------------------------------
    # per-tenant runtime state
    # ------------------------------------------------------------------
    def tenant_index(self, tenant: str | None = None) -> int:
        """Static index of a tenant in this dataplane's tenant table."""
        name = tenant or self.tenant
        try:
            return self.tenants.index(name)
        except ValueError:
            raise KeyError(
                f"unknown tenant {name!r}; known tenants: {self.tenants}")

    def runtime_init(self) -> dict:
        """Per-tenant runtime-state pytree: thread it through shard_map
        bodies with the uniform ``(x, state)`` convention."""
        return runtime_state_init(self.tenants, self.policies)

    def runtime_report(self, state) -> dict:
        """Host-side per-tenant view of a runtime-state pytree."""
        return tl.tenant_counters_report(state["counters"], self.tenants)

    # ------------------------------------------------------------------
    # mediation core
    # ------------------------------------------------------------------
    def _policy_pass(self, rec: tl.OpRecord, operand, mr_name: str | None,
                     tenant: str) -> None:
        """Trace-time policy enforcement (the kernel looking at the WQE)."""
        if not self.enforce:
            return
        ctx = PolicyContext(rec=rec, tenant=tenant, mr_name=mr_name,
                            operand=operand)
        for p in self.policies:
            p.on_op(ctx)    # raises PolicyViolation to refuse the op

    def _record(self, kind: str, tag: str, x, axes, qos: str = "default",
                mr: str | None = None, count: int = 1,
                tenant: str | None = None,
                precharged: bool = False) -> tl.OpRecord:
        shape, dtype = tl.describe(x)
        rec = tl.OpRecord(kind=kind, tag=tag, bytes=tl.nbytes(x),
                          axes=tl.normalize_axes(axes),
                          shape=shape, dtype=dtype, mode=self.cfg.mode,
                          qos=qos, count=count, precharged=precharged)
        self._policy_pass(rec, x, mr, tenant or self.tenant)
        return rec

    def _mediate(self, collective, kind: str, x, axis, tag: str, *,
                 mr: str | None, state, qos: str, tenant: str | None,
                 precharged: bool = False):
        """One dataplane op: record → pipeline.send → collective →
        pipeline.complete.  All five explicit collectives are this."""
        rec = self._record(kind, tag, x, axis, qos, mr, tenant=tenant,
                           precharged=precharged)
        ti = self.tenant_index(tenant)
        x, state = self.pipeline.send(x, rec, state, ti)
        out = collective(x)
        out, state = self.pipeline.complete(out, rec, state, ti)
        return out, state

    # ------------------------------------------------------------------
    # GSPMD-mode mediation: logical sharding constraints
    # ------------------------------------------------------------------
    def spec(self, names: Sequence[str | None | tuple]) -> P:
        """Resolve logical axis names to a PartitionSpec via the rules.

        A mesh axis may appear at most once in a spec — later duplicates
        are dropped (first occurrence wins)."""
        out = []
        used: set[str] = set()

        def take(axes):
            kept = [a for a in axes if a not in used]
            used.update(kept)
            return kept

        for n in names:
            if n is None:
                out.append(None)
                continue
            subs = n if isinstance(n, (tuple, list)) else [n]
            merged: list[str] = []
            for sub in subs:
                r = self.rules.get(sub)
                if r is None:
                    continue
                merged.extend(take(list(r) if isinstance(r, (tuple, list))
                                  else [r]))
            out.append(tuple(merged) if len(merged) > 1
                       else (merged[0] if merged else None))
        return P(*out)

    def sharding(self, names: Sequence[str | None | tuple]) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(names))

    def constrain(self, x: jax.Array, names: Sequence[str | None | tuple],
                  tag: str = "constraint", qos: str = "default",
                  tenant: str | None = None) -> jax.Array:
        """Issue a sharding edge through the dataplane (GSPMD mode).

        Runs the same mediation pipeline as the explicit collectives
        (send side only — GSPMD materializes the completion); no runtime
        state can be threaded through a pjit constraint, so stateful
        stages are inert here."""
        if self.mesh is None:
            return x
        spec = self.spec(names)
        rec = self._record("constraint", tag, x, spec, qos, tenant=tenant)
        x, _ = self.pipeline.send(x, rec, None, self.tenant_index(tenant))
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    # ------------------------------------------------------------------
    # Explicit collectives (inside shard_map) — uniform (out, state)
    # ------------------------------------------------------------------
    def psum(self, x, axis, tag: str = "psum", mr: str | None = None,
             state=None, qos: str = "default", tenant: str | None = None,
             precharged: bool = False):
        """``precharged=True`` marks an op whose QoS tokens were already
        debited at chunk granularity by the issuer (chunked_psum's
        preemption path) — the token-bucket stage skips it."""
        return self._mediate(lambda v: jax.lax.psum(v, axis), "all_reduce",
                             x, axis, tag, mr=mr, state=state, qos=qos,
                             tenant=tenant, precharged=precharged)

    def all_gather(self, x, axis, tag: str = "all_gather", *, gather_axis: int = 0,
                   tiled: bool = False, mr: str | None = None,
                   state=None, qos: str = "default", tenant: str | None = None):
        return self._mediate(
            lambda v: jax.lax.all_gather(v, axis, axis=gather_axis, tiled=tiled),
            "all_gather", x, axis, tag, mr=mr, state=state, qos=qos,
            tenant=tenant)

    def reduce_scatter(self, x, axis, tag: str = "reduce_scatter", *,
                       scatter_axis: int = 0, mr: str | None = None,
                       state=None, qos: str = "default",
                       tenant: str | None = None):
        return self._mediate(
            lambda v: jax.lax.psum_scatter(v, axis,
                                           scatter_dimension=scatter_axis,
                                           tiled=True),
            "reduce_scatter", x, axis, tag, mr=mr, state=state, qos=qos,
            tenant=tenant)

    def all_to_all(self, x, axis, tag: str = "all_to_all", *, split_axis: int = 0,
                   concat_axis: int = 0, mr: str | None = None,
                   state=None, qos: str = "default", tenant: str | None = None):
        return self._mediate(
            lambda v: jax.lax.all_to_all(v, axis, split_axis=split_axis,
                                         concat_axis=concat_axis, tiled=True),
            "all_to_all", x, axis, tag, mr=mr, state=state, qos=qos,
            tenant=tenant)

    def ppermute(self, x, axis, perm, tag: str = "ppermute",
                 mr: str | None = None, state=None, qos: str = "default",
                 tenant: str | None = None):
        return self._mediate(
            lambda v: jax.lax.ppermute(v, axis, perm), "collective_permute",
            x, axis, tag, mr=mr, state=state, qos=qos, tenant=tenant)

    # ------------------------------------------------------------------
    # control plane
    # ------------------------------------------------------------------
    def reg_mr(self, name: str, x, tenant: str | None = None):
        """Control-plane memory registration (ioctl path in the paper)."""
        return self.registry.reg_mr(name, x, tenant or self.tenant)

    def reg_pytree(self, prefix: str, tree, tenant: str | None = None) -> int:
        return self.registry.reg_pytree(prefix, tree, tenant or self.tenant)


def make_dataplane(cfg: DataplaneConfig | None = None, mesh: Mesh | None = None,
                   rules: dict[str, Any] | None = None, **kw) -> Dataplane:
    return Dataplane(cfg, mesh=mesh, rules=rules, **kw)


__all__ = ["Dataplane", "make_dataplane", "PolicyViolation"]
