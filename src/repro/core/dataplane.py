"""The Converged Dataplane — the paper's contribution, adapted to JAX/TPU.

Every communication edge in the framework is issued through a
:class:`Dataplane`:

* under **pjit/GSPMD** the model code calls :meth:`constrain` with logical
  axis names; the dataplane resolves them against its sharding rules and
  emits ``with_sharding_constraint`` — the compiler materializes the
  collectives.  The dataplane is the single control point that sees (and
  records, and may refuse) every one of these edges.
* inside **shard_map** (explicit paths: gradient sync, MoE dispatch option,
  perftest/NPB benchmarks, the verbs layer) the model code calls
  :meth:`psum` / :meth:`all_gather` / :meth:`reduce_scatter` /
  :meth:`all_to_all` / :meth:`ppermute`, which lower to ``jax.lax``
  collectives *after* passing the mediation layer.

Three modes (paper Fig. 2):

====== ============= ========= ============ =========================
mode   kernel-bypass zero-copy polling      policies enforced
====== ============= ========= ============ =========================
bypass yes           yes       yes          none (OS has no control)
cord   **no**        yes       yes          all configured policies
socket **no**        **no**    **no**       all + heavy stack cost
====== ============= ========= ============ =========================

Technique toggles in :class:`DataplaneConfig` override the mode presets so
that the paper's Fig. 1 ablations ("remove one technique at a time") can be
reproduced exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import DataplaneConfig
from repro.core import techniques as tech
from repro.core import telemetry as tl
from repro.core.mr import MRRegistry
from repro.core.policies import (
    Policy,
    PolicyContext,
    PolicyViolation,
    QoSPolicy,
    QuotaPolicy,
    SecurityPolicy,
    TelemetryPolicy,
)

# ---------------------------------------------------------------------------
# Mode presets: (kernel_bypass, zero_copy, polling, enforce_policies)
# ---------------------------------------------------------------------------

_MODE_PRESETS = {
    "bypass": dict(kernel_bypass=True, zero_copy=True, polling=True, enforce=False),
    "cord": dict(kernel_bypass=False, zero_copy=True, polling=True, enforce=True),
    "socket": dict(kernel_bypass=False, zero_copy=False, polling=False, enforce=True),
}

_POLICY_FACTORIES: dict[str, Callable[[], Policy]] = {
    "telemetry": TelemetryPolicy,
    "security": SecurityPolicy,
    "quota": QuotaPolicy,
    "qos": QoSPolicy,
}


class Dataplane:
    """The narrow waist: all framework communication flows through here."""

    def __init__(
        self,
        cfg: DataplaneConfig | None = None,
        mesh: Mesh | None = None,
        rules: dict[str, Any] | None = None,
        tenant: str = "default",
        policies: Sequence[Policy] | None = None,
    ) -> None:
        self.cfg = cfg or DataplaneConfig()
        self.mesh = mesh
        self.rules = dict(rules or {})
        self.tenant = tenant
        if self.cfg.mode not in _MODE_PRESETS:
            raise ValueError(f"unknown dataplane mode {self.cfg.mode!r}")
        preset = _MODE_PRESETS[self.cfg.mode]
        # Effective techniques: mode preset AND config toggle, so the fig-1
        # ablations can "remove" a technique from any mode.
        self.kernel_bypass = preset["kernel_bypass"] and self.cfg.kernel_bypass
        self.zero_copy = preset["zero_copy"] and self.cfg.zero_copy
        self.polling = preset["polling"] and self.cfg.polling
        self.enforce = preset["enforce"]
        if policies is not None:
            self.policies = list(policies)
        else:
            self.policies = [_POLICY_FACTORIES[p]() for p in self.cfg.policies]
        self._telemetry = next(
            (p.telemetry for p in self.policies if isinstance(p, TelemetryPolicy)),
            tl.Telemetry(enabled=False))
        self._security = next(
            (p for p in self.policies if isinstance(p, SecurityPolicy)), None)
        self.registry: MRRegistry = (self._security.registry
                                     if self._security else MRRegistry())
        if self.cfg.emulate_costs:
            # calibrate the delay primitive NOW (eagerly) — calling it for
            # the first time under a trace would stage the probe jit.
            tech.calibrate()

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    @property
    def telemetry(self) -> tl.Telemetry:
        return self._telemetry

    @property
    def mode(self) -> str:
        return self.cfg.mode

    def with_mode(self, mode: str) -> "Dataplane":
        return Dataplane(dataclasses.replace(self.cfg, mode=mode),
                         mesh=self.mesh, rules=self.rules, tenant=self.tenant)

    def reset(self) -> None:
        for p in self.policies:
            p.reset()

    # ------------------------------------------------------------------
    # mediation core
    # ------------------------------------------------------------------
    def _policy_pass(self, rec: tl.OpRecord, operand, mr_name: str | None) -> None:
        """Trace-time policy enforcement (the kernel looking at the WQE)."""
        if not self.enforce:
            return
        ctx = PolicyContext(rec=rec, tenant=self.tenant, mr_name=mr_name,
                            operand=operand)
        for p in self.policies:
            p.on_op(ctx)    # raises PolicyViolation to refuse the op

    def _mediate_in(self, x: jax.Array, rec: tl.OpRecord,
                    state: jax.Array | None):
        """Run-time mediation on the send side."""
        if not self.kernel_bypass:
            if state is not None:
                state = tl.counters_bump(state, ops=1, bytes=rec.bytes)
            if self.cfg.emulate_costs:
                ns = self.cfg.syscall_cost_ns
                if self.cfg.mode == "socket":
                    ns += self.cfg.socket_stack_ns
                    ns += rec.bytes * self.cfg.socket_ns_per_byte
                x = tech.delay_chain(x, tech.iters_for_ns(ns))
        if not self.zero_copy:
            x = tech.staged_copy(x, copies=1)
        return x, state

    def _mediate_out(self, x: jax.Array, rec: tl.OpRecord,
                     state: jax.Array | None):
        """Run-time mediation on the completion side."""
        if not self.zero_copy:
            x = tech.staged_copy(x, copies=1)
        if not self.polling and self.cfg.emulate_costs:
            # wait-for-event: interrupt delivery + wakeup instead of polling
            x = tech.delay_chain(
                x, tech.iters_for_ns(self.cfg.interrupt_cost_us * 1e3))
        return x, state

    def _record(self, kind: str, tag: str, x, axes, qos: str = "default",
                mr: str | None = None, count: int = 1) -> tl.OpRecord:
        shape, dtype = tl.describe(x)
        rec = tl.OpRecord(kind=kind, tag=tag, bytes=tl.nbytes(x),
                          axes=tuple(axes) if isinstance(axes, (tuple, list)) else (axes,),
                          shape=shape, dtype=dtype, mode=self.cfg.mode,
                          qos=qos, count=count)
        self._policy_pass(rec, x, mr)
        if self.cfg.mode == "bypass":
            # The OS cannot see bypassed traffic — but we still let the
            # (trace-time-only) telemetry record it when explicitly enabled
            # for benchmarking, mirroring NIC counters.
            pass
        return rec

    # ------------------------------------------------------------------
    # GSPMD-mode mediation: logical sharding constraints
    # ------------------------------------------------------------------
    def spec(self, names: Sequence[str | None | tuple]) -> P:
        """Resolve logical axis names to a PartitionSpec via the rules.

        A mesh axis may appear at most once in a spec — later duplicates
        are dropped (first occurrence wins)."""
        out = []
        used: set[str] = set()

        def take(axes):
            kept = [a for a in axes if a not in used]
            used.update(kept)
            return kept

        for n in names:
            if n is None:
                out.append(None)
                continue
            subs = n if isinstance(n, (tuple, list)) else [n]
            merged: list[str] = []
            for sub in subs:
                r = self.rules.get(sub)
                if r is None:
                    continue
                merged.extend(take(list(r) if isinstance(r, (tuple, list))
                                  else [r]))
            out.append(tuple(merged) if len(merged) > 1
                       else (merged[0] if merged else None))
        return P(*out)

    def sharding(self, names: Sequence[str | None | tuple]) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(names))

    def constrain(self, x: jax.Array, names: Sequence[str | None | tuple],
                  tag: str = "constraint") -> jax.Array:
        """Issue a sharding edge through the dataplane (GSPMD mode)."""
        if self.mesh is None:
            return x
        spec = self.spec(names)
        self._record("constraint", tag, x, tuple(a for a in jax.tree.leaves(tuple(spec)) if a))
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    # ------------------------------------------------------------------
    # Explicit collectives (inside shard_map)
    # ------------------------------------------------------------------
    def psum(self, x, axis, tag: str = "psum", mr: str | None = None,
             state: jax.Array | None = None, qos: str = "default"):
        rec = self._record("all_reduce", tag, x, axis, qos, mr)
        x, state = self._mediate_in(x, rec, state)
        out = jax.lax.psum(x, axis)
        out, state = self._mediate_out(out, rec, state)
        return (out, state) if state is not None else out

    def all_gather(self, x, axis, tag: str = "all_gather", *, gather_axis: int = 0,
                   tiled: bool = False, mr: str | None = None,
                   state: jax.Array | None = None, qos: str = "default"):
        rec = self._record("all_gather", tag, x, axis, qos, mr)
        x, state = self._mediate_in(x, rec, state)
        out = jax.lax.all_gather(x, axis, axis=gather_axis, tiled=tiled)
        out, state = self._mediate_out(out, rec, state)
        return (out, state) if state is not None else out

    def reduce_scatter(self, x, axis, tag: str = "reduce_scatter", *,
                       scatter_axis: int = 0, mr: str | None = None,
                       state: jax.Array | None = None, qos: str = "default"):
        rec = self._record("reduce_scatter", tag, x, axis, qos, mr)
        x, state = self._mediate_in(x, rec, state)
        out = jax.lax.psum_scatter(x, axis, scatter_dimension=scatter_axis,
                                   tiled=True)
        out, state = self._mediate_out(out, rec, state)
        return (out, state) if state is not None else out

    def all_to_all(self, x, axis, tag: str = "all_to_all", *, split_axis: int = 0,
                   concat_axis: int = 0, mr: str | None = None,
                   state: jax.Array | None = None, qos: str = "default"):
        rec = self._record("all_to_all", tag, x, axis, qos, mr)
        x, state = self._mediate_in(x, rec, state)
        out = jax.lax.all_to_all(x, axis, split_axis=split_axis,
                                 concat_axis=concat_axis, tiled=True)
        out, state = self._mediate_out(out, rec, state)
        return (out, state) if state is not None else out

    def ppermute(self, x, axis, perm, tag: str = "ppermute",
                 mr: str | None = None, state: jax.Array | None = None,
                 qos: str = "default"):
        rec = self._record("collective_permute", tag, x, axis, qos, mr)
        x, state = self._mediate_in(x, rec, state)
        out = jax.lax.ppermute(x, axis, perm)
        out, state = self._mediate_out(out, rec, state)
        return (out, state) if state is not None else out

    # ------------------------------------------------------------------
    # control plane
    # ------------------------------------------------------------------
    def reg_mr(self, name: str, x, tenant: str | None = None):
        """Control-plane memory registration (ioctl path in the paper)."""
        return self.registry.reg_mr(name, x, tenant or self.tenant)

    def reg_pytree(self, prefix: str, tree, tenant: str | None = None) -> int:
        return self.registry.reg_pytree(prefix, tree, tenant or self.tenant)


def make_dataplane(cfg: DataplaneConfig | None = None, mesh: Mesh | None = None,
                   rules: dict[str, Any] | None = None, **kw) -> Dataplane:
    return Dataplane(cfg, mesh=mesh, rules=rules, **kw)


__all__ = ["Dataplane", "make_dataplane", "PolicyViolation"]
