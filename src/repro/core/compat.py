"""JAX version compatibility shims.

The framework targets the modern JAX API (``jax.shard_map`` with
``check_vma``, ``jax.sharding.AxisType``); CI images and some
accelerator containers still ship 0.4.x where those names live under
``jax.experimental`` or do not exist.  Every mesh/shard_map construction
in the repo goes through this module so the rest of the codebase can be
written once against the new surface.
"""

from __future__ import annotations

from typing import Sequence

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    _AxisType = None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None):
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if _AxisType is not None:
        kw["axis_types"] = (_AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


if hasattr(jax, "shard_map"):  # jax >= 0.6

    def shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)

else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def pallas_tpu_compiler_params(pltpu, **kwargs):
    """Build Pallas-TPU compiler params across the 0.4.x→0.5 rename
    (``TPUCompilerParams`` became ``CompilerParams``)."""
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


__all__ = ["make_mesh", "shard_map", "pallas_tpu_compiler_params"]
