"""Quickstart: build a model, train it through the CoRD dataplane for a few
steps on all local devices, and inspect what the dataplane saw.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.configs import get_model_config
from repro.configs.base import DataplaneConfig, RunConfig, TrainConfig
from repro.core import Dataplane
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.train import init_state, make_explicit_dp_step


def main():
    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    mesh = make_local_mesh()

    # The paper's knob: route every dataplane op through the mediation
    # layer ("cord"), raw kernel-bypass ("bypass"), or the socket path.
    dp = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh)

    run = RunConfig(train=TrainConfig(steps=20, learning_rate=5e-3,
                                      warmup_steps=5))
    step = make_explicit_dp_step(model, run, dp, axis="data")
    state = init_state(model, jax.random.PRNGKey(0))
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                global_batch=16))

    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state, metrics = step(state, batch)
        if i % 5 == 0:
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}")

    print("\nWhat the OS saw on the dataplane (telemetry policy):")
    print(dp.telemetry.report())


if __name__ == "__main__":
    main()
