"""Paper Fig. 6 in miniature: the NPB suite under bypass / cord / socket.

    PYTHONPATH=src:. python examples/npb_demo.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from benchmarks import npb


def main():
    rows = npb.run_all(benches=("EP", "CG", "FT"))
    print(f"{'bench':6s} {'mode':8s} {'ms':>9s} {'rel':>7s}")
    for r in rows:
        print(f"{r['bench']:6s} {r['mode']:8s} {r['ms']:9.2f} "
              f"{r['rel_runtime']:7.3f}")
    print("\npaper claim: cord ≈ bypass everywhere; socket (IPoIB) up to "
          "2× slower on comm-heavy kernels")


if __name__ == "__main__":
    main()
