"""CoRD policies in action, in four acts (docs/elasticity.md walks
through the third and fourth):

1. telemetry, quotas and memory-region security enforced on a live
   dataplane — the OS-level control the paper regains;
2. runtime QoS throttling of a noisy tenant, observed through a
   two-tenant timeline (docs/observability.md walks through the output);
3. the elastic response: a ThresholdWatcher trips on the noisy tenant's
   sustained throttle rate and the run remeshes it onto a shrunken
   2-device mesh slice, after which the victim's throughput recovers;
4. the pod-scale hierarchy: two "hosts" stream per-process timelines
   that merge step-aligned into ONE pod timeline, and a WatcherGroup
   runs a train-remesh watcher and a serve-budget watcher over the
   merged rates — shrink on sustained pressure, grow back on sustained
   quiet, the full closed cycle.

    PYTHONPATH=src python examples/policy_demo.py
"""

import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import DataplaneConfig, ElasticConfig
from repro.core import (
    CounterTimeline,
    Dataplane,
    PolicyViolation,
    ThresholdWatcher,
    WatcherGroup,
    compat,
    merge_timelines,
)
from repro.core.policies import (
    QoSPolicy,
    QuotaPolicy,
    SecurityPolicy,
    TelemetryPolicy,
)
from repro.runtime import ServeElasticController, shrink_mesh


def main():
    mesh = compat.make_mesh((8,), ("data",))
    dp = Dataplane(
        DataplaneConfig(mode="cord"), mesh=mesh, tenant="team-a",
        policies=[TelemetryPolicy(), SecurityPolicy(),
                  QuotaPolicy(limits={"team-a": 4096})])

    grads = jnp.ones((512,))
    dp.reg_mr("grads", jnp.ones(64))    # register the per-shard region

    @partial(compat.shard_map, mesh=mesh, in_specs=P("data"),
             out_specs=P("data"))
    def sync(g):
        out, _ = dp.psum(g, "data", tag="grads/allreduce",
                         mr="grads" if g.shape == (64,) else None)
        return out

    out = jax.jit(sync)(grads)
    print("allreduce under full policy stack ok:", float(out[0]))
    print(dp.telemetry.report())

    # quota exhaustion: enforcement is at op-issue (trace) time — issue
    # progressively larger programs until the tenant's byte budget runs out
    try:
        for i in range(1, 32):
            g = jnp.ones((512 * i,))
            dp.reg_mr("grads", jnp.ones(64 * i))
            jax.jit(sync)(g)
        print("quota never hit (unexpected)")
    except PolicyViolation as e:
        print(f"\nquota enforced: {e}")

    # security: unregistered traffic is refused
    dp2 = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh,
                    policies=[SecurityPolicy(strict=True)])

    @partial(compat.shard_map, mesh=mesh, in_specs=P("data"),
             out_specs=P("data"))
    def rogue(g):
        return dp2.psum(g, "data", tag="rogue")[0]

    try:
        jax.jit(rogue)(grads)
        print("rogue op allowed (unexpected)")
    except PolicyViolation as e:
        print(f"strict security refused anonymous op: {e}")

    # runtime QoS: the mediation pipeline's token bucket throttles the
    # "noisy" tenant's op rate inside traced code — per-tenant counters
    # come back in the runtime state.
    # stall_ns is the emulated cost a throttled op pays IN the traced
    # program — large enough here that noisy's stalls visibly tax any
    # tenant sharing a program with it (the act-3 remesh undoes that)
    dp3 = Dataplane(
        DataplaneConfig(mode="cord"), mesh=mesh,
        tenant="victim", tenants=("victim", "noisy"),
        policies=[TelemetryPolicy(),
                  QoSPolicy(rates={"noisy": 0.25}, burst=2.0, stall_ns=5e6)])

    @partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
             out_specs=(P("data"), P()))
    def burst(g, rt):
        def one(carry, _):
            g, rt = carry
            s, rt = dp3.psum(g.sum(), "data", tag="noisy/op", state=rt,
                             tenant="noisy")
            v, rt = dp3.psum(g.sum(), "data", tag="victim/op", state=rt,
                             tenant="victim")
            return (g + 0 * s + 0 * v, rt), None
        (g, rt), _ = jax.lax.scan(one, (g, rt), None, length=16)
        return g, rt

    # thread ONE runtime state through several bursts, snapshotting the
    # per-tenant counter block between jitted calls — the host-side
    # timeline never appears inside traced code
    burst_jit = jax.jit(burst)
    rt = dp3.runtime_init()
    timeline = CounterTimeline(source="policy-demo")
    for round_ in range(1, 7):
        _, rt = jax.block_until_ready(burst_jit(grads, rt))
        timeline.snapshot(round_, dp3.runtime_report(rt))
    print("\nper-tenant runtime accounting:")
    for tenant, ctrs in dp3.runtime_report(rt).items():
        print(f"  {tenant:8s} {ctrs}")
    print("\ntwo-tenant timeline (6 burst rounds, noisy throttled):")
    print(timeline.panel(width=24))

    # Act 3 — the elastic response (docs/elasticity.md): a watcher trips
    # on noisy's sustained throttle rate, and the remesh moves noisy onto
    # a shrunken 2-device slice while victim keeps the full mesh.  The
    # victim's throughput recovers because its burst program no longer
    # carries noisy's serial token-bucket stalls inline.
    watcher = ThresholdWatcher({"throttled_pct": 90.0}, sustain=3,
                               cooldown=8, tenants=("noisy",))
    for ev in watcher.observe(timeline):
        timeline.record_event(ev["kind"], ev["step"], tenant=ev["tenant"],
                              t=ev["t"], detail=ev["detail"])
    small = shrink_mesh(mesh, factor=4)          # 8 devices -> 2-device slice
    timeline.record_event("remesh", step=6, tenant="noisy",
                          detail={"devices_before": mesh.devices.size,
                                  "devices_after": small.devices.size})
    dp_victim = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh,
                          tenant="victim", policies=[TelemetryPolicy()])
    dp_noisy = Dataplane(
        DataplaneConfig(mode="cord"), mesh=small, tenant="noisy",
        policies=[TelemetryPolicy(),
                  QoSPolicy(rates={"noisy": 0.25}, burst=2.0, stall_ns=5e6)])

    def burst_on(dp, tenant, n_mesh):
        @partial(compat.shard_map, mesh=n_mesh, in_specs=(P("data"), P()),
                 out_specs=(P("data"), P()))
        def one_tenant(g, rt):
            def one(carry, _):
                g, rt = carry
                v, rt = dp.psum(g.sum(), "data", tag=f"{tenant}/op",
                                state=rt, tenant=tenant)
                return (g + 0 * v, rt), None
            (g, rt), _ = jax.lax.scan(one, (g, rt), None, length=16)
            return g, rt
        return jax.jit(one_tenant)

    vj = burst_on(dp_victim, "victim", mesh)
    nj = burst_on(dp_noisy, "noisy", small)
    rtv, rtn = dp_victim.runtime_init(), dp_noisy.runtime_init()
    base = dp3.runtime_report(rt)       # act-2 totals stay cumulative
    small_grads = jnp.ones((128,))
    v_wall = v_ops = 0
    for round_ in range(7, 11):
        t0 = time.perf_counter()
        _, rtv = jax.block_until_ready(vj(grads, rtv))
        if round_ > 7:                  # round 7 is the compile
            v_wall += time.perf_counter() - t0
            v_ops += 16
        _, rtn = jax.block_until_ready(nj(small_grads, rtn))
        rep_v = dp_victim.runtime_report(rtv)["victim"]
        rep_n = dp_noisy.runtime_report(rtn)["noisy"]
        timeline.snapshot(
            round_,
            {"victim": {k: base["victim"][k] + rep_v[k] for k in rep_v},
             "noisy": {k: base["noisy"][k] + rep_n[k] for k in rep_n}},
            gauges=watcher.gauges())
        # keep watching: post-remesh windows tick the cooldown down, and
        # a still-misbehaving tenant can re-trigger once it expires
        for ev in watcher.observe(timeline):
            timeline.record_event(ev["kind"], ev["step"],
                                  tenant=ev["tenant"], t=ev["t"],
                                  detail=ev["detail"])

    print("\ntimeline events (watcher trigger -> remesh):")
    for ev in timeline.events:
        print(f"  round {ev['step']} {ev['kind']:8s} "
              f"{ev['tenant']}: {ev['detail']}")
    print("\nthree-act timeline (rounds 7-10 after noisy's remesh):")
    print(timeline.panel(width=24))
    # pre-remesh the victim's ops are embedded in the shared program
    # (its wall clock includes noisy's stalls); post-remesh we time the
    # victim's burst alone — the wall it actually experiences
    pre = timeline.rates()["victim"]["ops_s"][1:5]       # windows 2-5
    print(f"victim ops_s: pre-remesh {sum(pre) / len(pre):.0f} "
          f"(sharing a program with throttled noisy) -> "
          f"post-remesh {v_ops / v_wall:.0f} (alone on the full mesh)")

    # Act 4 — the pod-scale hierarchy (docs/elasticity.md): every host
    # snapshots its OWN per-process timeline; the controller host merges
    # them step-aligned (merge_timelines) and one WatcherGroup reads the
    # merged pod rates — a train-remesh watcher and a serve-budget
    # watcher, each with a release arm, each driving its own response.
    mesh_h0 = compat.make_mesh((4,), ("data",), devices=jax.devices()[:4])
    mesh_h1 = compat.make_mesh((4,), ("data",), devices=jax.devices()[4:])
    dp_h0 = Dataplane(
        DataplaneConfig(mode="cord"), mesh=mesh_h0, tenant="noisy",
        policies=[TelemetryPolicy(),
                  QoSPolicy(rates={"noisy": 0.25}, burst=2.0, stall_ns=5e6)])
    dp_h1 = Dataplane(
        DataplaneConfig(mode="cord"), mesh=mesh_h1, tenant="api",
        policies=[TelemetryPolicy(),
                  QoSPolicy(rates={"api": 0.25}, burst=2.0, stall_ns=5e6)])
    h0_burst = burst_on(dp_h0, "noisy", mesh_h0)
    h1_burst = burst_on(dp_h1, "api", mesh_h1)
    rt0, rt1 = dp_h0.runtime_init(), dp_h1.runtime_init()
    tl_h0 = CounterTimeline(source="host0")  # controller host: events here
    tl_h1 = CounterTimeline(source="host1")

    class SlotKnob:
        """Stands in for a serving Engine's slot-budget interface — the
        real thing is Engine.slot_budget/set_slot_budget, driven the same
        way by launch/serve.py --elastic and the benchmarks/run.py
        control-plane smoke."""
        def __init__(self, cap=4):
            self._cap, self._default = 0, cap

        def slot_budget(self):
            return self._cap or self._default

        def set_slot_budget(self, n):
            prev, self._cap = self._cap, max(int(n), 0)
            return prev

    knob = SlotKnob()
    group = WatcherGroup({
        "train": ThresholdWatcher({"throttled_pct": 50.0}, sustain=2,
                                  cooldown=1, tenants=("noisy",),
                                  release={"throttled_pct": 5.0},
                                  release_sustain=2),
        "serve": ThresholdWatcher({"throttled_pct": 50.0}, sustain=2,
                                  cooldown=1, tenants=("api",),
                                  release={"throttled_pct": 5.0},
                                  release_sustain=2),
    })
    serve_ctl = ServeElasticController(
        ElasticConfig(enabled=True, shrink_factor=2), tl_h0, knob)
    mesh_stack = []                     # the train response's grow-back state

    print("\nact 4 — pod-scale watcher hierarchy over a merged timeline:")
    for i in range(1, 7):
        if i <= 3:                      # noisy phase: both hosts loaded
            _, rt0 = jax.block_until_ready(h0_burst(small_grads, rt0))
            _, rt1 = jax.block_until_ready(h1_burst(small_grads, rt1))
        tl_h0.snapshot(i, dp_h0.runtime_report(rt0),
                       gauges=group.gauges(), t=float(i))
        tl_h1.snapshot(i, dp_h1.runtime_report(rt1), t=float(i))
        pod = merge_timelines([tl_h0, tl_h1], source="pod")
        evs = group.observe(pod, record=False)
        for ev in evs["train"] + evs["serve"]:
            tl_h0.record_event(ev["kind"], ev["step"], tenant=ev["tenant"],
                               t=ev["t"], detail=ev["detail"])
        for ev in evs["train"]:
            if ev["kind"] == "trigger":
                small4 = shrink_mesh(mesh_h0, factor=2)
                mesh_stack.append(mesh_h0)
                print(f"  round {i}: train watcher tripped -> remesh "
                      f"noisy {mesh_h0.devices.size} -> "
                      f"{small4.devices.size} devices")
                tl_h0.record_event("remesh", i, tenant="noisy",
                                   t=float(i) + 0.5,
                                   detail={"watcher": "train",
                                           "direction": "shrink"})
            elif ev["kind"] == "recover" and mesh_stack:
                back = mesh_stack.pop()
                print(f"  round {i}: sustained quiet -> grow noisy back "
                      f"to {back.devices.size} devices")
                tl_h0.record_event("remesh", i, tenant="noisy",
                                   t=float(i) + 0.5,
                                   detail={"watcher": "train",
                                           "direction": "grow"})
        before = knob.slot_budget()
        serve_ctl.respond(evs["serve"])
        if knob.slot_budget() != before:
            print(f"  round {i}: serve watcher -> slot budget "
                  f"{before} -> {knob.slot_budget()}")

    pod = merge_timelines([tl_h0, tl_h1], source="pod")
    print("pod events (merged from both hosts, origin-tagged):")
    for ev in pod.events:
        print(f"  round {ev['step']} {ev['kind']:8s} {ev['tenant']}: "
              f"{ev['detail']}")
    print(f"slot budget closed the cycle: back at {knob.slot_budget()}")


if __name__ == "__main__":
    main()
