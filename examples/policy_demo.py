"""CoRD policies in action: telemetry, quotas, memory-region security and
runtime QoS throttling enforced on a live dataplane — the OS-level control
the paper regains — plus a two-tenant observability timeline of the
throttled run (docs/observability.md walks through this output).

    PYTHONPATH=src python examples/policy_demo.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import DataplaneConfig
from repro.core import CounterTimeline, Dataplane, PolicyViolation, compat
from repro.core.policies import (
    QoSPolicy,
    QuotaPolicy,
    SecurityPolicy,
    TelemetryPolicy,
)


def main():
    mesh = compat.make_mesh((8,), ("data",))
    dp = Dataplane(
        DataplaneConfig(mode="cord"), mesh=mesh, tenant="team-a",
        policies=[TelemetryPolicy(), SecurityPolicy(),
                  QuotaPolicy(limits={"team-a": 4096})])

    grads = jnp.ones((512,))
    dp.reg_mr("grads", jnp.ones(64))    # register the per-shard region

    @partial(compat.shard_map, mesh=mesh, in_specs=P("data"),
             out_specs=P("data"))
    def sync(g):
        out, _ = dp.psum(g, "data", tag="grads/allreduce",
                         mr="grads" if g.shape == (64,) else None)
        return out

    out = jax.jit(sync)(grads)
    print("allreduce under full policy stack ok:", float(out[0]))
    print(dp.telemetry.report())

    # quota exhaustion: enforcement is at op-issue (trace) time — issue
    # progressively larger programs until the tenant's byte budget runs out
    try:
        for i in range(1, 32):
            g = jnp.ones((512 * i,))
            dp.reg_mr("grads", jnp.ones(64 * i))
            jax.jit(sync)(g)
        print("quota never hit (unexpected)")
    except PolicyViolation as e:
        print(f"\nquota enforced: {e}")

    # security: unregistered traffic is refused
    dp2 = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh,
                    policies=[SecurityPolicy(strict=True)])

    @partial(compat.shard_map, mesh=mesh, in_specs=P("data"),
             out_specs=P("data"))
    def rogue(g):
        return dp2.psum(g, "data", tag="rogue")[0]

    try:
        jax.jit(rogue)(grads)
        print("rogue op allowed (unexpected)")
    except PolicyViolation as e:
        print(f"strict security refused anonymous op: {e}")

    # runtime QoS: the mediation pipeline's token bucket throttles the
    # "noisy" tenant's op rate inside traced code — per-tenant counters
    # come back in the runtime state.
    dp3 = Dataplane(
        DataplaneConfig(mode="cord"), mesh=mesh,
        tenant="victim", tenants=("victim", "noisy"),
        policies=[TelemetryPolicy(),
                  QoSPolicy(rates={"noisy": 0.25}, burst=2.0, stall_ns=5e4)])

    @partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
             out_specs=(P("data"), P()))
    def burst(g, rt):
        def one(carry, _):
            g, rt = carry
            s, rt = dp3.psum(g.sum(), "data", tag="noisy/op", state=rt,
                             tenant="noisy")
            v, rt = dp3.psum(g.sum(), "data", tag="victim/op", state=rt,
                             tenant="victim")
            return (g + 0 * s + 0 * v, rt), None
        (g, rt), _ = jax.lax.scan(one, (g, rt), None, length=16)
        return g, rt

    # thread ONE runtime state through several bursts, snapshotting the
    # per-tenant counter block between jitted calls — the host-side
    # timeline never appears inside traced code
    burst_jit = jax.jit(burst)
    rt = dp3.runtime_init()
    timeline = CounterTimeline(source="policy-demo")
    for round_ in range(1, 7):
        _, rt = jax.block_until_ready(burst_jit(grads, rt))
        timeline.snapshot(round_, dp3.runtime_report(rt))
    print("\nper-tenant runtime accounting:")
    for tenant, ctrs in dp3.runtime_report(rt).items():
        print(f"  {tenant:8s} {ctrs}")
    print("\ntwo-tenant timeline (6 burst rounds, noisy throttled):")
    print(timeline.panel(width=24))


if __name__ == "__main__":
    main()
