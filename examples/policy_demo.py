"""CoRD policies in action: telemetry, quotas and memory-region security
enforced on a live dataplane — the OS-level control the paper regains.

    PYTHONPATH=src python examples/policy_demo.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import DataplaneConfig
from repro.core import Dataplane, PolicyViolation
from repro.core.policies import QuotaPolicy, SecurityPolicy, TelemetryPolicy


def main():
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    dp = Dataplane(
        DataplaneConfig(mode="cord"), mesh=mesh, tenant="team-a",
        policies=[TelemetryPolicy(), SecurityPolicy(),
                  QuotaPolicy(limits={"team-a": 4096})])

    grads = jnp.ones((512,))
    dp.reg_mr("grads", jnp.ones(64))    # register the per-shard region

    @partial(jax.shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    def sync(g):
        return dp.psum(g, "data", tag="grads/allreduce",
                       mr="grads" if g.shape == (64,) else None)

    out = jax.jit(sync)(grads)
    print("allreduce under full policy stack ok:", float(out[0]))
    print(dp.telemetry.report())

    # quota exhaustion: enforcement is at op-issue (trace) time — issue
    # progressively larger programs until the tenant's byte budget runs out
    try:
        for i in range(1, 32):
            g = jnp.ones((512 * i,))
            dp.reg_mr("grads", jnp.ones(64 * i))
            jax.jit(sync)(g)
        print("quota never hit (unexpected)")
    except PolicyViolation as e:
        print(f"\nquota enforced: {e}")

    # security: unregistered traffic is refused
    dp2 = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh,
                    policies=[SecurityPolicy(strict=True)])

    @partial(jax.shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    def rogue(g):
        return dp2.psum(g, "data", tag="rogue")

    try:
        jax.jit(rogue)(grads)
        print("rogue op allowed (unexpected)")
    except PolicyViolation as e:
        print(f"strict security refused anonymous op: {e}")


if __name__ == "__main__":
    main()
