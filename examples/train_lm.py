"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
through the CoRD dataplane, with checkpointing, fault tolerance and int8
gradient compression.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import os
import shutil

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.configs.base import (
    AttentionConfig, DataplaneConfig, ModelConfig, RunConfig, TrainConfig,
)
from repro.core import Dataplane
from repro.data import DataConfig, ShardedLoader, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.runtime import FaultInjector, run_loop
from repro.train import init_state, make_explicit_dp_step

# ~100M params: 12L, d_model 512, vocab 50k (llama-style)
CFG_100M = ModelConfig(
    name="lm-100m", family="dense", num_layers=12, d_model=512, d_ff=2048,
    vocab_size=50_304,
    attention=AttentionConfig(num_heads=8, num_kv_heads=4),
    max_seq_len=1024, dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--mode", default="cord")
    ap.add_argument("--inject-failure", action="store_true")
    args = ap.parse_args()

    model = build_model(CFG_100M)
    n = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))))
    print(f"model: {n/1e6:.1f}M params")

    mesh = make_local_mesh()
    dp = Dataplane(DataplaneConfig(mode=args.mode), mesh=mesh)
    run = RunConfig(train=TrainConfig(
        steps=args.steps, learning_rate=3e-3, warmup_steps=30,
        grad_compression="int8", checkpoint_every=50,
        checkpoint_dir="/tmp/repro_train_lm"))
    shutil.rmtree("/tmp/repro_train_lm", ignore_errors=True)

    step = make_explicit_dp_step(model, run, dp, axis="data")
    state = init_state(model, jax.random.PRNGKey(0), compression="int8")
    ds = SyntheticLM(DataConfig(vocab_size=CFG_100M.vocab_size,
                                seq_len=args.seq_len,
                                global_batch=args.batch))
    loader = ShardedLoader(ds)

    def wrap(s, b):
        return step(s, {k: jnp.asarray(v) for k, v in b.items()})

    injector = FaultInjector(fail_steps=(args.steps // 2,)) \
        if args.inject_failure else None
    state, report = run_loop(
        wrap, state, loader, steps=args.steps,
        ckpt_dir="/tmp/repro_train_lm", checkpoint_every=50,
        injector=injector, log_every=20)

    first = report.metrics[0]["loss"]
    last = report.metrics[-1]["loss"]
    print(f"\nloss: {first:.3f} -> {last:.3f} over {report.steps_run} steps "
          f"({report.failures} failures, {report.restores} restores)")
    print(dp.telemetry.report())


if __name__ == "__main__":
    main()
