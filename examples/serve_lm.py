"""Serve a small LM with batched requests through the engine.

    PYTHONPATH=src python examples/serve_lm.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.configs import get_model_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve import Engine, Request


def main():
    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, cfg,
                 ServeConfig(max_batch=4, max_new_tokens=16), eos_id=-1)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 250, 5 + i % 7),
                    max_new_tokens=16) for i in range(10)]
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, continuous batching over 4 slots)")
    for r in done[:4]:
        print(f"  req {r.rid} ({len(r.prompt)} prompt toks): "
              f"{r.out_tokens}")


if __name__ == "__main__":
    main()
