"""Layer-level correctness: every optimized path against its naive oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, SSMConfig
from repro.layers.attention import attend
from repro.layers.common import rmsnorm, rmsnorm_init
from repro.layers.mamba import mamba, mamba_init, mamba_state_init
from repro.layers.moe import moe, moe_init
from repro.layers.rope import apply_rope
from repro.layers.xlstm import (
    mlstm, mlstm_init, slstm, slstm_init, slstm_state_init,
)

RNG = jax.random.PRNGKey(0)


def _qkv(S=64, B=2, H=4, KVH=2, D=16, dtype=jnp.float32):
    ks = jax.random.split(RNG, 3)
    return (jax.random.normal(ks[0], (B, S, H, D), dtype),
            jax.random.normal(ks[1], (B, S, KVH, D), dtype),
            jax.random.normal(ks[2], (B, S, KVH, D), dtype))


@pytest.mark.parametrize("kw", [
    dict(causal=True, window=None),
    dict(causal=True, window=8),
    dict(causal=False, window=None),
    dict(causal=True, window=None, logit_cap=12.0),
])
def test_flash_matches_naive_fwd_and_grad(kw):
    q, k, v = _qkv()
    pos = jnp.arange(q.shape[1])

    def loss(impl):
        def f(q, k, v):
            o = attend(q, k, v, q_pos=pos, k_pos=pos, impl=impl,
                       q_block=16, kv_block=16, **kw)
            return (o ** 2).sum()
        return f

    o_f = attend(q, k, v, q_pos=pos, k_pos=pos, impl="flash",
                 q_block=16, kv_block=16, **kw)
    o_n = attend(q, k, v, q_pos=pos, k_pos=pos, impl="naive", **kw)
    np.testing.assert_allclose(o_f, o_n, atol=2e-5)

    g_f = jax.grad(loss("flash"), (0, 1, 2))(q, k, v)
    g_n = jax.grad(loss("naive"), (0, 1, 2))(q, k, v)
    for a, b in zip(g_f, g_n):
        np.testing.assert_allclose(a, b, atol=5e-5)


def test_flash_decode_against_naive_with_cache_validity():
    q, k, v = _qkv(S=32)
    q1 = q[:, 10:11]
    pos1 = jnp.full((1,), 10, jnp.int32)
    k_pos = jnp.arange(32)
    valid = k_pos <= 10
    o_f = attend(q1, k, v, q_pos=pos1, k_pos=k_pos, causal=True,
                 window=None, k_valid=valid, impl="flash", q_block=1,
                 kv_block=8)
    o_n = attend(q1, k, v, q_pos=pos1, k_pos=k_pos, causal=True,
                 window=None, k_valid=valid, impl="naive")
    np.testing.assert_allclose(o_f, o_n, atol=2e-5)


def test_mamba_chunked_equals_streaming():
    cfg = SSMConfig(state_size=8, expand=2)
    p = mamba_init(RNG, 32, cfg)
    x = jax.random.normal(RNG, (2, 24, 32))
    full, st_full = mamba(p, x, cfg, chunk=8)
    st = mamba_state_init(2, 32, cfg, x.dtype)
    outs = []
    for t in range(24):
        o, st = mamba(p, x[:, t:t + 1], cfg, state=st)
        outs.append(o)
    np.testing.assert_allclose(full, jnp.concatenate(outs, 1), atol=1e-5)
    np.testing.assert_allclose(st_full["h"], st["h"], atol=1e-5)


def test_mlstm_chunked_equals_streaming():
    cfg = SSMConfig(state_size=8, expand=2, num_heads=2, conv_width=4)
    p = mlstm_init(RNG, 32, cfg)
    x = jax.random.normal(RNG, (2, 16, 32))
    full, _ = mlstm(p, x, cfg, chunk=4)
    st = None
    outs = []
    for t in range(16):
        o, st = mlstm(p, x[:, t:t + 1], cfg, state=st, chunk=1)
        outs.append(o)
    np.testing.assert_allclose(full, jnp.concatenate(outs, 1), atol=2e-5)


def test_slstm_streaming_consistency():
    cfg = SSMConfig(num_heads=2)
    p = slstm_init(RNG, 32, cfg)
    x = jax.random.normal(RNG, (2, 12, 32))
    full, _ = slstm(p, x, cfg)
    st = slstm_state_init(2, 32, cfg)
    outs = []
    for t in range(12):
        o, st = slstm(p, x[:, t:t + 1], cfg, state=st)
        outs.append(o)
    np.testing.assert_allclose(full, jnp.concatenate(outs, 1), atol=2e-5)


def test_moe_routes_and_balances():
    cfg = MoEConfig(num_experts=4, top_k=2, dense_residual=True,
                    dense_residual_ff=32)
    p = moe_init(RNG, 32, 64, cfg)
    x = jax.random.normal(RNG, (2, 32, 32))
    out, aux = moe(p, x, cfg, group_size=16, train=True, rng=RNG)
    assert out.shape == x.shape
    assert jnp.isfinite(out).all() and jnp.isfinite(aux)
    # determinism
    out2, _ = moe(p, x, cfg, group_size=16, train=True, rng=RNG)
    np.testing.assert_array_equal(out, out2)


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(RNG, (1, 8, 2, 16))
    pos = jnp.arange(8)
    r = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(jnp.linalg.norm(r, axis=-1),
                               jnp.linalg.norm(x, axis=-1), atol=1e-4)
    # dot(q_i, k_j) under rope depends only on i - j
    q = jnp.ones((1, 8, 1, 16))
    k = jnp.ones((1, 8, 1, 16))
    qr, kr = apply_rope(q, pos, 100.0), apply_rope(k, pos, 100.0)
    d01 = jnp.einsum("d,d->", qr[0, 0, 0], kr[0, 1, 0])
    d34 = jnp.einsum("d,d->", qr[0, 3, 0], kr[0, 4, 0])
    np.testing.assert_allclose(d01, d34, rtol=1e-5)


def test_rmsnorm_scale_invariance():
    p = rmsnorm_init(16)
    x = jax.random.normal(RNG, (4, 16))
    np.testing.assert_allclose(rmsnorm(p, x), rmsnorm(p, 3.7 * x), atol=1e-5)
