"""Family × stack serving conformance matrix.

Every config preset — including the downscaled big-model shims
(``arctic-480b``, ``llava-next-34b``) — is driven through the full
serving stack: continuous batching on persistent slots, gang decode,
timeline snapshots, the elastic slot-budget trigger, and preemption with
resume.  The matrix asserts the properties the converged-dataplane story
depends on:

* continuous ≡ gang at temperature 0 (per family, uniform prompts so the
  gang path adds no left padding),
* preempt → resume is EXACT at temperature 0 (the emitted tokens are the
  snapshot; recompute-based resume must replay them bit-identically,
  including through the mamba/xLSTM recurrences),
* timeline artifacts save/load/validate with per-tick gauges,
* a ThresholdWatcher over the serve timeline trips and its slot-budget
  response is enforced,
* paged KV raises the family-naming ServeError on non-pageable caches.

The whole module is marked ``family`` (and ``slow``): tier-1 skips it via
pytest.ini addopts; CI runs it as its own `pytest -m family` lane.
"""

import os

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, get_model_config
from repro.configs.base import ServeConfig
from repro.core import CounterTimeline, ThresholdWatcher, validate_timeline
from repro.models import build_model
from repro.serve import Engine, Request, ServeError

pytestmark = [pytest.mark.family, pytest.mark.slow]

# families whose decode cache is a pure {"k","v"} rank-5 stripe — the only
# layout the block pool can page
_PAGEABLE = ("dense", "moe", "vlm")

_CACHE: dict = {}


def family_model(arch):
    """(cfg, model, params) for one arch's smoke shim, built once per
    session — every preset in ARCHS goes through the same path."""
    if arch not in _CACHE:
        cfg = get_model_config(arch, smoke=True)
        model = build_model(cfg)
        _CACHE[arch] = (cfg, model, model.init(jax.random.PRNGKey(0)))
    return _CACHE[arch]


def _requests(lengths, max_new=5, tenants=None):
    return [Request(rid=i,
                    prompt=np.asarray((np.arange(n) + 3 * i) % 97, np.int32),
                    max_new_tokens=max_new,
                    tenant=tenants[i % len(tenants)] if tenants else "default")
            for i, n in enumerate(lengths)]


def _outs(done):
    return {r.rid: list(r.out_tokens) for r in done}


def _serve_cfg(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_new_tokens", 5)
    kw.setdefault("kv_cache_len", 64)
    return ServeConfig(**kw)


@pytest.mark.parametrize("arch", ARCHS)
def test_continuous_matches_gang_temp0(arch):
    """Greedy continuous batching ≡ gang decode, every family.

    Uniform prompt lengths ≥ 8: the gang path left-pads to the batch max
    (and attends the pads), so unequal lengths would compare different
    *models of the prompt*, not different schedulers."""
    cfg, model, params = family_model(arch)
    cont = Engine(model, params, cfg, _serve_cfg(), eos_id=-1)
    gang = Engine(model, params, cfg, _serve_cfg(), eos_id=-1)
    reqs = _requests([8] * 5)
    out_c = _outs(cont.run([Request(rid=r.rid, prompt=r.prompt.copy(),
                                    max_new_tokens=r.max_new_tokens)
                            for r in reqs]))
    out_g = _outs(gang.run(reqs, scheduler="gang"))
    assert out_c == out_g
    assert all(len(v) == 5 for v in out_c.values())
    # ONE decode compile regardless of family: the fixed-shape slot step
    assert cont.decode_compile_count() == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_mixed_lengths_and_tenants(arch):
    """Continuous serve dry-run: varied prompt lengths, two tenants, more
    requests than slots — everything completes with its full budget."""
    cfg, model, params = family_model(arch)
    eng = Engine(model, params, cfg, _serve_cfg(), eos_id=-1)
    done = eng.run(_requests([7, 9, 12, 8, 11], tenants=("a", "b")))
    assert len(done) == 5
    assert all(len(r.out_tokens) == 5 for r in done)
    rep = eng.tenant_report()
    assert rep["a"]["tokens"] + rep["b"]["tokens"] == 25


@pytest.mark.parametrize("arch", ARCHS)
def test_preempt_resume_exact(arch):
    """A mid-decode slot-budget preemption must resume exactly: the
    preempted run's outputs equal the undisturbed run's, per family —
    including the recurrent families, whose resume re-prefills the
    emitted prefix through the chunked scans rather than replaying
    sequential decode steps."""
    cfg, model, params = family_model(arch)
    lengths = [7, 9, 11]
    base = Engine(model, params, cfg, _serve_cfg(max_new_tokens=6), eos_id=-1)
    out_base = _outs(base.run(_requests(lengths, max_new=6)))

    eng = Engine(model, params, cfg, _serve_cfg(max_new_tokens=6), eos_id=-1)
    step, calls = eng._step_slots, {"n": 0}

    def spy(*a):
        calls["n"] += 1
        if calls["n"] == 3:          # two residents mid-decode by now
            eng.set_slot_budget(1)
        return step(*a)

    eng._step_slots = spy
    out_pre = _outs(eng.run(_requests(lengths, max_new=6)))
    rep = eng.tenant_report()["default"]
    assert rep["preemptions"] >= 1 and rep["restores"] >= 1
    assert out_pre == out_base


@pytest.mark.parametrize("arch", ARCHS)
def test_timeline_artifact(arch, tmp_path):
    """Per-tick serve snapshots produce a valid, loadable timeline
    artifact with slot gauges and nonzero served tokens, every family."""
    cfg, model, params = family_model(arch)
    tl = CounterTimeline(source=f"family/{arch}")
    eng = Engine(model, params, cfg, _serve_cfg(), eos_id=-1, obs=tl)
    eng.run(_requests([8, 9, 10]))
    assert len(tl.samples) >= 3
    assert any(s["gauges"]["active_slots"] > 0 for s in tl.samples)
    path = tl.save(os.path.join(tmp_path, f"{arch}_timeline.json"))
    doc = CounterTimeline.load(path)          # load() re-validates
    validate_timeline(doc)
    last = doc["samples"][-1]
    # served tokens ride the counter block's bytes column
    # (Engine.runtime_counters)
    assert last["tenants"].get("default", {}).get("bytes", 0) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_elastic_trigger_drives_slot_budget(arch):
    """The serve-side elastic loop, per family: a ThresholdWatcher over
    the engine's own timeline trips on sustained decode traffic, and the
    trigger's response (``set_slot_budget(1)``) is enforced on the next
    run — the active-slot gauge never exceeds the shrunken budget."""
    cfg, model, params = family_model(arch)
    tl = CounterTimeline(source=f"family/{arch}")
    eng = Engine(model, params, cfg, _serve_cfg(), eos_id=-1, obs=tl)
    eng.run(_requests([8, 9, 10, 8]))
    # chunks_s carries slot-occupancy steps/s (Engine.runtime_counters):
    # it moves on EVERY tick with an active slot — unlike tokens (bytes),
    # which land in a lump at completion — so a tiny threshold sees the
    # consecutive nonzero windows the sustain logic needs
    watcher = ThresholdWatcher({"chunks_s": 1e-9}, sustain=2, cooldown=64)
    fired = watcher.observe(tl)
    assert len(watcher.triggers) >= 1
    tl.record_event("slot_budget", step=int(fired[0]["step"]),
                    tenant=fired[0]["tenant"], detail={"budget": 1})
    assert tl.events and tl.events[-1]["kind"] == "slot_budget"

    tl2 = CounterTimeline(source=f"family/{arch}/shrunk")
    eng2 = Engine(model, params, cfg, _serve_cfg(), eos_id=-1, obs=tl2)
    eng2.set_slot_budget(1)
    done = eng2.run(_requests([8, 9, 10]))
    assert len(done) == 3 and all(len(r.out_tokens) == 5 for r in done)
    assert max(s["gauges"]["active_slots"] for s in tl2.samples) <= 1


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_support_matches_cache_layout(arch):
    """block_size > 0 either pages (pure rank-5 {k,v} stripe) or raises
    the family-naming ServeError — never a silent gang fallback."""
    cfg, model, params = family_model(arch)
    sc = _serve_cfg(kv_cache_len=64, block_size=8, n_blocks=24)
    if cfg.family in _PAGEABLE:
        eng = Engine(model, params, cfg, sc, eos_id=-1)
        assert eng.paged
        done = eng.run(_requests([8] * 3))
        assert all(len(r.out_tokens) == 5 for r in done)
    else:
        with pytest.raises(ServeError) as ei:
            Engine(model, params, cfg, sc, eos_id=-1)
        msg = str(ei.value)
        assert cfg.family in msg          # names the unsupported family
        assert "block_size=0" in msg      # names the flag to flip
