"""End-to-end behaviour of the paper's system: the CoRD dataplane carrying
a full training job with policies enabled, and the three dataplane modes
being behaviour-identical / cost-ordered."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_model_config
from repro.configs.base import DataplaneConfig, RunConfig, TrainConfig
from repro.core import Dataplane
from repro.core.policies import QuotaPolicy, SecurityPolicy, TelemetryPolicy
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.train import init_state, make_explicit_dp_step

RNG = jax.random.PRNGKey(0)


def test_training_through_cord_with_full_policy_stack(mesh8):
    """Train with telemetry + security + quota all enforced: the OS-level
    control the paper regains, at (near) zero cost."""
    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    run = RunConfig(train=TrainConfig(steps=6, learning_rate=5e-3,
                                      warmup_steps=2))
    dp = Dataplane(
        DataplaneConfig(mode="cord"), mesh=mesh8,
        policies=[TelemetryPolicy(), SecurityPolicy(strict=False),
                  QuotaPolicy(limits={"default": 1 << 30})])
    step = make_explicit_dp_step(model, run, dp, axis="data")
    state = init_state(model, RNG)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=16))
    losses = []
    for i in range(6):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], "training must converge through CoRD"
    tele = dp.telemetry.by_kind()
    assert tele["all_reduce"]["ops"] > 0, "policies saw the grad traffic"
    quota = next(p for p in dp.policies if isinstance(p, QuotaPolicy))
    assert quota.used["default"] > 0


def test_mode_equivalence_end_to_end(mesh8):
    """bypass / cord / socket must produce identical training trajectories
    (the dataplane mediates, never alters)."""
    cfg = get_model_config("granite-3-2b", smoke=True)
    model = build_model(cfg)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                global_batch=8))
    final = {}
    for mode in ("bypass", "cord", "socket"):
        run = RunConfig(train=TrainConfig(steps=3, learning_rate=1e-3))
        dp = Dataplane(DataplaneConfig(mode=mode, emulate_costs=True),
                       mesh=mesh8)
        step = make_explicit_dp_step(model, run, dp, axis="data")
        state = init_state(model, RNG)
        for i in range(3):
            b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
            state, m = step(state, b)
        final[mode] = float(m["loss"])
    assert final["bypass"] == final["cord"] == final["socket"], final


def test_serving_end_to_end_greedy_deterministic():
    from repro.configs.base import ServeConfig
    from repro.serve import Engine, Request
    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    eng = Engine(model, params, cfg, ServeConfig(max_batch=2,
                                                 max_new_tokens=6),
                 eos_id=-1)
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % 100) for i in range(3)]
    out1 = [r.out_tokens for r in eng.run(reqs)]
    reqs2 = [Request(rid=i, prompt=np.arange(4 + i) % 100) for i in range(3)]
    out2 = [r.out_tokens for r in eng.run(reqs2)]
    assert out1 == out2
    assert all(len(o) == 6 for o in out1)


def test_serving_tenant_admission_throttles_hog():
    """The engine runs the host-side mirror of the dataplane's QoS token
    bucket as admission control: a rate-limited tenant's requests are
    deferred across batching rounds, every request still completes."""
    from repro.configs.base import ServeConfig
    from repro.core.policies import QoSPolicy, TelemetryPolicy
    from repro.serve import Engine, Request
    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    dp = Dataplane(
        DataplaneConfig(mode="cord"),
        tenants=("default", "hog"),
        policies=[TelemetryPolicy(),
                  QoSPolicy(rates={"hog": 0.5}, burst=1.0)])
    eng = Engine(model, params, cfg,
                 ServeConfig(max_batch=4, max_new_tokens=4), dp=dp,
                 eos_id=-1)
    reqs = [Request(rid=i, prompt=np.arange(4) % 100,
                    tenant="hog" if i % 2 else "default")
            for i in range(6)]
    done = eng.run(reqs)
    assert len(done) == 6 and all(r.done for r in done)
    report = eng.tenant_report()
    assert report["hog"]["requests"] == 3
    assert report["hog"]["deferrals"] > 0       # the bucket pushed it back
    assert report["default"].get("deferrals", 0) == 0
