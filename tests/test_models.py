"""Per-architecture smoke tests (reduced same-family configs) + the
decode-consistency invariant: teacher-forced full forward and
prefill+decode must produce the same next-token predictions."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_model_config
from repro.configs.base import ShapeConfig
from repro.models import build_model, input_specs, make_batch

RNG = jax.random.PRNGKey(0)
TRAIN_SHAPE = ShapeConfig("t", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_model_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg, TRAIN_SHAPE, RNG)

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: model.loss(p, b), has_aux=True)
    )(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert loss.shape == ()
    gn = sum(jnp.sum(jnp.abs(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0, f"{arch}: degenerate grads"
    # output shapes via input specs
    specs = input_specs(cfg, TRAIN_SHAPE)
    assert specs["tokens"].shape[0] == 2


# MoE archs are excluded: capacity-based dropping makes routing depend on
# the token batch (full-seq groups vs single-token decode groups differ) —
# an inherent property of dropped-MoE serving, covered by the smoke test
# below instead.
@pytest.mark.parametrize("arch", ["gemma3-4b", "hymba-1.5b", "xlstm-350m",
                                  "granite-34b", "whisper-small"])
def test_decode_matches_teacher_forcing(arch):
    """Greedy decode over a prompt must predict the same tokens the full
    forward pass predicts at each position."""
    cfg = get_model_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(RNG)
    S = 12
    batch = make_batch(cfg, ShapeConfig("t", S, 2, "prefill"), RNG)
    tokens = batch["tokens"]
    prefix = cfg.num_patches if cfg.family == "vlm" else 0

    # full forward logits
    x, _, _, pre = model.apply(params, batch)
    from repro.layers.embedding import logits as logits_fn
    full_logits = logits_fn(params["embed"], x)

    # prefill on first S-3 tokens, then decode 3 steps teacher-forced
    cut = tokens.shape[1] - 3
    b1 = dict(batch)
    b1["tokens"] = tokens[:, :cut]
    cache = model.init_cache(2, prefix + tokens.shape[1] + 4)
    lg, cache = jax.jit(lambda p, b, c: model.prefill(p, b, c))(
        params, b1, cache)
    np.testing.assert_allclose(
        np.asarray(lg[:, -1]), np.asarray(full_logits[:, cut - 1 + pre]),
        atol=2e-3, rtol=1e-3)
    step = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos))
    for i in range(3):
        tok = tokens[:, cut + i][:, None]
        lg, cache = step(params, tok, cache,
                         jnp.asarray(prefix + cut + i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg[:, -1]),
            np.asarray(full_logits[:, prefix + cut + i]),
            atol=2e-3, rtol=1e-3)


def test_moe_decode_finite_and_batch_dependent():
    """MoE decode produces finite logits.  Eval-mode routing is dropless
    (layers/moe.py), so per-token outputs are batch-invariant — the serve
    conformance matrix (tests/test_family_matrix.py) asserts the exact
    continuous ≡ gang equality; here we keep the cheap shape/finiteness
    smoke on the raw prefill/decode hooks."""
    cfg = get_model_config("arctic-480b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(RNG)
    cache = model.init_cache(2, 16)
    b = make_batch(cfg, ShapeConfig("t", 8, 2, "prefill"), RNG)
    lg, cache = jax.jit(lambda p, b, c: model.prefill(p, b, c))(
        params, b, cache)
    assert jnp.isfinite(lg).all()
    lg2, cache = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos))(
        params, b["tokens"][:, -1:], cache, jnp.asarray(8, jnp.int32))
    assert jnp.isfinite(lg2).all() and lg2.shape == (2, 1, cfg.vocab_size)


def test_vlm_prefix_changes_text_logits():
    cfg = get_model_config("llava-next-34b", smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg, ShapeConfig("t", 24, 2, "train"), RNG)
    loss1, _ = model.loss(params, batch)
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"] + 1.0
    loss2, _ = model.loss(params, batch2)
    assert not np.allclose(float(loss1), float(loss2)), \
        "vision prefix should influence text loss"


def test_whisper_encoder_conditions_decoder():
    cfg = get_model_config("whisper-small", smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg, ShapeConfig("t", 16, 2, "train"), RNG)
    loss1, _ = model.loss(params, batch)
    batch2 = dict(batch)
    batch2["frames"] = batch["frames"] * 2.0 + 1.0
    loss2, _ = model.loss(params, batch2)
    assert not np.allclose(float(loss1), float(loss2))


def test_long_context_flags():
    from repro.configs import LONG_CONTEXT_ARCHS
    for arch in ARCHS:
        cfg = get_model_config(arch)
        assert cfg.is_subquadratic == (arch in LONG_CONTEXT_ARCHS), arch
