"""The composable mediation pipeline: stage composition, mode
value-equivalence across every collective, runtime QoS throttling,
per-tenant accounting, and verbs completion counting."""

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import DataplaneConfig
from repro.core import Dataplane, compat, verbs
from repro.core import telemetry as tl
from repro.core.chunking import chunked_psum
from repro.core.mediation import (
    HostTokenBucket,
    MediationPipeline,
    MediationStage,
)
from repro.core.policies import QoSPolicy, QuotaPolicy, TelemetryPolicy

RNG = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# composition
# ---------------------------------------------------------------------------

class _TracingStage(MediationStage):
    def __init__(self, name, log):
        self.name = name
        self.log = log

    def send(self, x, rec, state, tenant_idx):
        self.log.append(("send", self.name))
        return x, state

    def complete(self, x, rec, state, tenant_idx):
        self.log.append(("complete", self.name))
        return x, state


def test_pipeline_composes_in_declared_stage_order():
    log = []
    names = ["a", "b", "c", "d"]
    pipe = MediationPipeline([_TracingStage(n, log) for n in names])
    rec = tl.OpRecord(kind="test", tag="t", bytes=4, axes=("data",))
    x = jnp.ones(())
    pipe.send(x, rec)
    assert log == [("send", n) for n in names]
    log.clear()
    pipe.complete(x, rec)
    assert log == [("complete", n) for n in names]


def test_mode_presets_compile_expected_stages(mesh8):
    def stages(mode, **kw):
        dp = Dataplane(DataplaneConfig(mode=mode, emulate_costs=True, **kw),
                       mesh=mesh8)
        return dp.pipeline.stage_names

    assert stages("bypass") == ()
    assert stages("cord") == ("syscall-cost", "counter-bump")
    assert stages("socket") == ("syscall-cost", "socket-stack", "staged-copy",
                                "interrupt-wait", "counter-bump")
    # fig-1 ablation: remove zero-copy from bypass → only the copies
    assert stages("bypass", zero_copy=False) == ("staged-copy",)


# ---------------------------------------------------------------------------
# mode equivalence: every collective, bit-identical values across modes
# ---------------------------------------------------------------------------

def _all_collectives(mesh, dp, x):
    """Issue all five explicit collectives through the dataplane and
    return their raw outputs (no local arithmetic that XLA could
    reassociate between compilations)."""
    perm = [(i, (i + 1) % 8) for i in range(8)]

    @partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
             out_specs=((P(), P("data"), P("data"), P("data"), P("data")),
                        P()))
    def f(v, rt):
        s, rt = dp.psum(v.sum(), "data", tag="eq/psum", state=rt)
        g, rt = dp.all_gather(v, "data", tag="eq/ag", state=rt)
        r, rt = dp.reduce_scatter(g, "data", tag="eq/rs", state=rt)
        a, rt = dp.all_to_all(g, "data", tag="eq/a2a", state=rt)
        p, rt = dp.ppermute(v, "data", perm, tag="eq/perm", state=rt)
        return (s, g, r, a, p), rt

    return jax.jit(f)(x, dp.runtime_init())


def test_mediation_equivalence_values_identical_costs_differ(mesh8):
    """For each mode the collective *values* are bit-identical; only the
    pipeline (costs) and the telemetry/runtime accounting differ."""
    x = jax.random.normal(RNG, (64,))
    outs, reports, tele_bytes = {}, {}, {}
    for mode in ("bypass", "cord", "socket"):
        dp = Dataplane(DataplaneConfig(mode=mode, emulate_costs=True),
                       mesh=mesh8)
        out, rt = _all_collectives(mesh8, dp, x)
        outs[mode] = [np.asarray(o) for o in out]
        reports[mode] = dp.runtime_report(rt)["default"]
        tele_bytes[mode] = dp.telemetry.total_bytes()
    for ref, got in zip(outs["bypass"], outs["cord"]):
        np.testing.assert_array_equal(ref, got)
    for ref, got in zip(outs["bypass"], outs["socket"]):
        np.testing.assert_array_equal(ref, got)
    # bypass: the OS sees nothing — no telemetry, no runtime accounting
    assert tele_bytes["bypass"] == 0 and reports["bypass"]["ops"] == 0
    # cord/socket: both accountings see all five ops
    for mode in ("cord", "socket"):
        assert reports[mode]["ops"] == 5
        assert reports[mode]["bytes"] > 0
        assert tele_bytes[mode] > 0


def test_verbs_payload_identical_across_modes(mesh2):
    """The verbs layer built from the same pipeline: payload delivery is
    mode-invariant."""
    cfg = verbs.QPConfig(transport="RC", msg_bytes=64, depth=2)
    payload = jnp.arange(64, dtype=jnp.uint8)
    rings = {}
    for mode in ("bypass", "cord", "socket"):
        dp = Dataplane(DataplaneConfig(mode=mode, emulate_costs=True),
                       mesh=mesh2)

        @partial(compat.shard_map, mesh=mesh2, in_specs=P("rank", None),
                 out_specs=P("rank", None))
        def send(buf):
            rank = jax.lax.axis_index("rank")
            qp = verbs.qp_init(cfg)
            qp, _ = verbs.post_send(dp, cfg, qp, buf[0], rank, src=0)
            qp, _ = verbs.flush_send(dp, cfg, qp, rank, src=0, dst=1)
            return qp["recv_ring"][None, 0]

        rings[mode] = np.asarray(jax.jit(send)(
            jnp.stack([payload, jnp.zeros(64, jnp.uint8)])))
    np.testing.assert_array_equal(rings["bypass"], rings["cord"])
    np.testing.assert_array_equal(rings["bypass"], rings["socket"])
    np.testing.assert_array_equal(rings["cord"][1], np.asarray(payload))


# ---------------------------------------------------------------------------
# runtime QoS throttling (the acceptance-criterion test)
# ---------------------------------------------------------------------------

def _qos_dp(mesh, stall_ns):
    return Dataplane(
        DataplaneConfig(mode="cord"), mesh=mesh,
        tenant="free", tenants=("free", "limited"),
        policies=[TelemetryPolicy(),
                  QoSPolicy(rates={"limited": 0.25}, burst=1.0,
                            stall_ns=stall_ns)])


def _burst_ops(mesh, dp, tenant, n_ops=24):
    @partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
             out_specs=(P("data"), P()))
    def f(v, rt):
        def one(carry, _):
            v, rt = carry
            s, rt = dp.psum(v.sum(), "data", tag="qos/op", state=rt,
                            tenant=tenant)
            return (v + 0 * s, rt), None
        (v, rt), _ = jax.lax.scan(one, (v, rt), None, length=n_ops)
        return v, rt

    fn = jax.jit(f)
    x = jnp.ones(16)
    out, rt = jax.block_until_ready(fn(x, dp.runtime_init()))  # compile+run
    t0 = time.perf_counter()
    jax.block_until_ready(fn(x, dp.runtime_init()))
    return np.asarray(out), dp.runtime_report(rt), time.perf_counter() - t0


def test_qos_token_bucket_throttles_tenant_op_rate_at_runtime(mesh8):
    """cord mode + QoSPolicy: the rate-limited tenant's ops are throttled
    *at run time* — throttle counters bump on the measured path and the
    stall is real wall-clock work; an unlimited tenant is untouched."""
    dp = _qos_dp(mesh8, stall_ns=2e6)   # 2 ms per missing token
    out_free, rep_free, t_free = _burst_ops(mesh8, dp, "free")
    out_lim, rep_lim, t_lim = _burst_ops(mesh8, dp, "limited")

    # values are never altered by throttling
    np.testing.assert_array_equal(out_free, out_lim)

    # the limited tenant: bucket (burst 1, refill 0.25/op) admits the
    # first op untaxed and throttles the rest
    assert rep_lim["limited"]["ops"] == 24
    assert rep_lim["limited"]["throttled"] == 23
    assert rep_lim["free"]["ops"] == 0
    # the free tenant is never throttled
    assert rep_free["free"]["ops"] == 24
    assert rep_free["free"]["throttled"] == 0

    # and the throttle is real runtime work: ~23 × 0.75 × 2 ms of stall
    assert t_lim > t_free


def test_quota_runtime_accounting_marks_over_budget(mesh8):
    """QuotaPolicy with hard=False: traced per-tenant byte accounting marks
    over-budget ops in the denied counter instead of refusing at trace
    time."""
    dp = Dataplane(
        DataplaneConfig(mode="cord"), mesh=mesh8,
        tenant="t0", tenants=("t0",),
        policies=[TelemetryPolicy(),
                  QuotaPolicy(limits={"t0": 20}, hard=False)])

    @partial(compat.shard_map, mesh=mesh8, in_specs=(P("data"), P()),
             out_specs=(P("data"), P()))
    def f(v, rt):
        def one(carry, _):
            v, rt = carry
            s, rt = dp.psum(v.sum(), "data", tag="q/op", state=rt)  # 4 B/op
            return (v + 0 * s, rt), None
        (v, rt), _ = jax.lax.scan(one, (v, rt), None, length=10)
        return v, rt

    _, rt = jax.jit(f)(jnp.ones(16), dp.runtime_init())
    rep = dp.runtime_report(rt)["t0"]
    assert rep["bytes"] == 40                  # 10 ops × 4 bytes
    assert rep["denied"] == 5                  # ops 6..10 exceed the 20 B cap


def test_chunked_psum_accounts_chunks(mesh8):
    dp = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh8)
    x = jax.random.normal(RNG, (64, 4))

    @partial(compat.shard_map, mesh=mesh8, in_specs=(P("data"), P()),
             out_specs=(P("data"), P()))
    def f(v, rt):
        out, rt = chunked_psum(dp, v, "data", num_chunks=4, state=rt)
        return out, rt

    _, rt = jax.jit(f)(x, dp.runtime_init())
    rep = dp.runtime_report(rt)["default"]
    assert rep["ops"] == 4 and rep["chunks"] == 4


# ---------------------------------------------------------------------------
# verbs completion accounting
# ---------------------------------------------------------------------------

def test_poll_cq_returns_real_completion_counts(mesh2):
    dp = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh2)
    cfg = verbs.QPConfig(transport="RC", msg_bytes=16, depth=4)

    @partial(compat.shard_map, mesh=mesh2, in_specs=P("rank", None),
             out_specs=(P(), P(), P()))
    def roundtrip(buf):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        qp, _ = verbs.post_send(dp, cfg, qp, buf[0], rank, src=0)
        qp, _ = verbs.post_send(dp, cfg, qp, buf[0], rank, src=0)
        qp, _ = verbs.flush_send(dp, cfg, qp, rank, src=0, dst=1)
        n1, qp, _ = verbs.poll_cq(dp, cfg, qp, rank, poller=1)
        n2, qp, _ = verbs.poll_cq(dp, cfg, qp, rank, poller=1)
        return n1, n2, qp["cq_rcvd"]

    n1, n2, rcvd = jax.jit(roundtrip)(
        jnp.zeros((2, 16), jnp.uint8))
    assert int(n1) == 2      # both posted sends completed by the flush
    assert int(n2) == 0      # nothing new since the last poll
    assert int(rcvd) == 2    # drained exactly what was delivered


# ---------------------------------------------------------------------------
# host-side bucket (serving admission mirror)
# ---------------------------------------------------------------------------

def test_host_token_bucket_mirrors_traced_semantics():
    b = HostTokenBucket(rate=0.5, burst=2.0)
    takes = []
    for _ in range(8):
        b.refill()
        takes.append(b.take())
    # burst of 2 admits the first rounds; then one admit every other refill
    assert takes[0] and takes[1]
    assert sum(takes) < 8

    buckets = HostTokenBucket.from_policy(
        QoSPolicy(rates={"a": 1.0, "b": 0.0}))
    assert "a" in buckets and "b" not in buckets
