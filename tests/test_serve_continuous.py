"""Persistent-slot continuous batching: temperature-0 equivalence with
gang scheduling, mid-decode slot refill, WFQ slot shares, single-compile
decode, fused-mediation bit-equivalence, and admission accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_model_config
from repro.configs.base import DataplaneConfig, ModelConfig, ServeConfig
from repro.core import Dataplane
from repro.core import techniques as tech
from repro.core import telemetry as tl
from repro.core.mediation import HostTokenBucket
from repro.core.policies import QoSPolicy, TelemetryPolicy
from repro.layers.kvcache import (
    kv_cache_init,
    kv_slot_insert,
    kv_update_slots,
    slot_validity,
)
from repro.models import build_model
from repro.serve import Engine, Request, WFQScheduler, prompt_bucket

RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    return cfg, model, params


def _requests(lengths, tenants=None, max_new=16):
    tenants = tenants or ["default"] * len(lengths)
    return [Request(rid=i, prompt=np.asarray((np.arange(n) + 3 * i) % 100,
                                             np.int32),
                    tenant=t, max_new_tokens=max_new)
            for i, (n, t) in enumerate(zip(lengths, tenants))]


# ---------------------------------------------------------------------------
# scheduler equivalence + slot lifecycle
# ---------------------------------------------------------------------------

def test_continuous_matches_gang_temp0(smoke_model):
    """At temperature 0 continuous slots and gang scheduling emit the same
    tokens.  Prompt lengths sit on a bucket boundary so the gang path's
    left-padding (which perturbs logits for unaligned lengths — a legacy
    gang property) is empty on both sides."""
    cfg, model, params = smoke_model
    sc = ServeConfig(max_batch=2, max_new_tokens=5, kv_cache_len=64)
    cont = Engine(model, params, cfg, sc, eos_id=-1)
    gang = Engine(model, params, cfg, sc, eos_id=-1)
    out_c = {r.rid: r.out_tokens
             for r in cont.run(_requests([8] * 5), scheduler="continuous")}
    out_g = {r.rid: r.out_tokens
             for r in gang.run(_requests([8] * 5), scheduler="gang")}
    assert out_c == out_g
    assert all(len(o) == 5 for o in out_c.values())


def test_mid_decode_refill_tokens_independent_of_coresidents(smoke_model):
    """A request refilled into a freed slot mid-decode produces the same
    tokens as when served alone: co-residents (and the slot's previous
    occupant's stale cache) never leak into it."""
    cfg, model, params = smoke_model
    sc = ServeConfig(max_batch=2, max_new_tokens=8, kv_cache_len=64)
    eng = Engine(model, params, cfg, sc, eos_id=-1)
    # r0 ends after 3 tokens, freeing its slot while r1 still decodes;
    # r2 (varied length) is inserted mid-decode next to the running r1.
    crowd = _requests([8, 11, 5], max_new=8)
    crowd[0].max_new_tokens = 3
    late_in_crowd = next(r for r in eng.run(crowd) if r.rid == 2)
    alone = _requests([8, 11, 5], max_new=8)[2]
    (alone_done,) = eng.run([alone])
    assert late_in_crowd.out_tokens == alone_done.out_tokens
    assert len(late_in_crowd.out_tokens) == 8


def test_single_decode_compilation_across_varied_stream(smoke_model):
    """One engine, one decode-step compile, regardless of the request
    mix — while the gang baseline recompiles per distinct batch shape."""
    cfg, model, params = smoke_model
    lengths = [4, 9, 17, 6, 12, 20, 5, 10]      # buckets 8 / 16 / 32
    sc = ServeConfig(max_batch=2, max_new_tokens=4, kv_cache_len=64)
    cont = Engine(model, params, cfg, sc, eos_id=-1)
    cont.run(_requests(lengths), scheduler="continuous")
    assert cont._step_slots._cache_size() == 1
    assert cont.decode_compile_count() == 1
    gang = Engine(model, params, cfg, sc, eos_id=-1)
    gang.run(_requests(lengths), scheduler="gang")
    assert gang.decode_compile_count() >= 2


def test_wfq_slot_occupancy_proportional_to_weights(smoke_model):
    """Tenant weights 3:1 under a saturated queue: decode-slot occupancy
    splits 3:1 within ±10%."""
    cfg, model, params = smoke_model
    dp = Dataplane(
        DataplaneConfig(mode="cord"), tenants=("a", "b"),
        policies=[TelemetryPolicy(),
                  QoSPolicy(rates={"a": 3.0, "b": 1.0}, burst=1000.0)])
    sc = ServeConfig(max_batch=4, max_new_tokens=6, kv_cache_len=48)
    eng = Engine(model, params, cfg, sc, dp=dp, eos_id=-1)
    lengths, tenants = [], []
    for _ in range(8):                   # 24 a-requests : 8 b-requests
        lengths += [8, 8, 8, 8]
        tenants += ["a", "a", "a", "b"]
    done = eng.run(_requests(lengths, tenants, max_new=6))
    assert len(done) == 32
    rep = eng.tenant_report()
    ratio = rep["a"]["occupancy_steps"] / rep["b"]["occupancy_steps"]
    assert abs(ratio - 3.0) <= 0.3, rep
    ctrs, names = eng.runtime_counters()
    assert set(names) == {"a", "b"}
    occ = {t: ctrs[i, tl.CTR_CHUNKS] for i, t in enumerate(names)}
    assert occ["a"] == rep["a"]["occupancy_steps"]


def test_wfq_scheduler_grant_ratio_unit():
    wfq = WFQScheduler({"a": 3.0, "b": 1.0})
    grants = {"a": 0, "b": 0}
    for _ in range(400):
        wfq.note_backlog(["a", "b"])
        t = wfq.order(["a", "b"])[0]
        grants[t] += 1
        wfq.grant(t, cost=8)
    assert abs(grants["a"] / grants["b"] - 3.0) < 0.2


def test_wfq_idle_tenant_cannot_hoard_credit():
    """Regression: a tenant that idles while another is served must
    re-enter at the current virtual clock, not at its stale virtual time
    (which would let it monopolize slots until it 'caught up')."""
    wfq = WFQScheduler({"a": 1.0, "b": 1.0})
    wfq.note_backlog(["a", "b"])
    wfq.grant("b", cost=8)               # b served once, then goes idle
    for _ in range(100):                 # a alone backlogged
        wfq.note_backlog(["a"])
        wfq.grant("a", cost=8)
    grants = {"a": 0, "b": 0}
    for _ in range(20):                  # b returns
        wfq.note_backlog(["a", "b"])
        t = wfq.order(["a", "b"])[0]
        grants[t] += 1
        wfq.grant(t, cost=8)
    # equal weights → roughly alternating service, not a b-monopoly
    assert grants["b"] <= 11, grants


def test_max_slots_per_tenant_caps_occupancy(smoke_model):
    cfg, model, params = smoke_model
    sc = ServeConfig(max_batch=4, max_new_tokens=4, kv_cache_len=48,
                     max_slots_per_tenant=1)
    eng = Engine(model, params, cfg, sc, eos_id=-1)
    done = eng.run(_requests([8] * 6, ["hog"] * 5 + ["other"]))
    assert len(done) == 6
    rep = eng.tenant_report()
    # 5 hog requests × 3 decode steps each, never more than 1 slot at a
    # time: occupancy equals serial service, not parallel
    assert rep["hog"]["occupancy_steps"] == 15
    assert rep["hog"]["wfq_grants"] == 5


# ---------------------------------------------------------------------------
# admission accounting (satellite regressions)
# ---------------------------------------------------------------------------

def _bucket_engine(rates, burst=1.0, max_batch=1, scale=4.0):
    dp = Dataplane(DataplaneConfig(mode="cord"),
                   tenants=tuple(["default"] + list(rates)),
                   policies=[TelemetryPolicy(),
                             QoSPolicy(rates=rates, burst=burst)])
    model = object()                     # _admit_batch never runs the model
    return Engine(model, {}, ModelConfig(),
                  ServeConfig(max_batch=max_batch,
                              admission_token_scale=scale), dp=dp, eos_id=-1)


def test_admit_batch_counts_bucket_deferral_behind_full_batch():
    """Regression: a bucket-starved request sitting behind an already-full
    batch must still be counted as deferred (the old ``len(admitted) < B``
    guard masked it)."""
    eng = _bucket_engine({"slow": 0.1}, burst=1.0, max_batch=1, scale=1.0)
    eng._buckets["slow"].tokens = 0.0    # starved even after one refill
    fast = Request(rid=0, prompt=np.arange(4, dtype=np.int32))
    slow = Request(rid=1, prompt=np.arange(4, dtype=np.int32), tenant="slow")
    admitted, deferred = eng._admit_batch([fast, slow])
    assert admitted == [fast] and deferred == [slow]
    assert eng.tenant_stats["slow"]["deferrals"] == 1


def test_admission_charges_prompt_tokens():
    """The host bucket debits len(prompt) per admission (scaled bucket),
    matching the traced bucket's byte-proportional debits."""
    eng = _bucket_engine({"t": 1.0}, burst=4.0, max_batch=4, scale=4.0)
    bucket = eng._buckets["t"]
    assert bucket.burst == 16.0 and bucket.rate == 4.0   # scaled by 4
    r6 = Request(rid=0, prompt=np.arange(6, dtype=np.int32), tenant="t")
    admitted, _ = eng._admit_batch([r6])
    assert admitted == [r6]
    assert bucket.tokens == 16.0 - 6.0   # refill capped at burst, then -6
    assert bucket.can_take(10.0) and not bucket.can_take(10.1)


def test_admission_cost_clamped_to_burst():
    """A prompt longer than the bucket can ever hold drains a full bucket
    instead of being permanently inadmissible (no 10k-round starvation
    spin)."""
    eng = _bucket_engine({"t": 1.0}, burst=1.0, max_batch=2, scale=4.0)
    big = Request(rid=0, prompt=np.arange(20, dtype=np.int32), tenant="t")
    admitted, deferred = eng._admit_batch([big])
    assert admitted == [big] and not deferred
    assert eng._buckets["t"].tokens == 0.0       # burst 4 fully drained
    assert eng.tenant_stats["t"]["deferrals"] == 0


def test_continuous_counts_deferrals_behind_occupied_slots(smoke_model):
    """A bucket-starved tenant waiting while every slot is occupied still
    accrues deferrals (the continuous-path analogue of the _admit_batch
    full-batch masking fix)."""
    cfg, model, params = smoke_model
    dp = Dataplane(
        DataplaneConfig(mode="cord"), tenants=("default", "slow"),
        policies=[TelemetryPolicy(),
                  QoSPolicy(rates={"slow": 0.05}, burst=0.25)])
    sc = ServeConfig(max_batch=1, max_new_tokens=8, kv_cache_len=32,
                     admission_token_scale=4.0)   # slow: rate .2, burst 1
    eng = Engine(model, params, cfg, sc, dp=dp, eos_id=-1)
    reqs = _requests([8, 8, 8], ["slow", "default", "slow"], max_new=8)
    reqs[0].max_new_tokens = 2           # drains the slow bucket, exits fast
    done = eng.run(reqs)
    assert len(done) == 3 and all(r.done for r in done)
    # while "default" held the only slot, "slow" sat bucket-starved and
    # was deferred each scheduling round, not just when a slot was free
    assert eng.tenant_report()["slow"]["deferrals"] >= 2


def test_slot_report_live_view(smoke_model):
    """slot_report exposes the per-slot pos/active/tenant vectors while a
    run is in flight (the serve-side dashboard feed)."""
    cfg, model, params = smoke_model
    sc = ServeConfig(max_batch=2, max_new_tokens=4, kv_cache_len=32)
    eng = Engine(model, params, cfg, sc, eos_id=-1)
    seen = []
    orig = eng._step_slots
    def spy(*a):
        seen.append(eng.slot_report())
        return orig(*a)
    eng._step_slots = spy
    eng.run(_requests([8, 8, 8], ["a", "b", "a"], max_new=4))
    mid = seen[0]
    assert {s["tenant"] for s in mid if s["active"]} == {"a", "b"}
    assert all(s["pos"] == 8 for s in mid if s["active"])
    assert not any(s["active"] for s in eng.slot_report())   # drained


def test_duplicate_rids_and_prompts_are_servable(smoke_model):
    """Regression: requests are tracked by identity — duplicate rids (and
    equal-content prompts) must not confuse queue removal (ndarray ==
    inside dataclass equality used to raise mid-serve)."""
    cfg, model, params = smoke_model
    sc = ServeConfig(max_batch=2, max_new_tokens=3, kv_cache_len=32)
    eng = Engine(model, params, cfg, sc, eos_id=-1)
    dup = [Request(rid=0, prompt=np.arange(8, dtype=np.int32), tenant="b"),
           Request(rid=0, prompt=np.arange(5, dtype=np.int32), tenant="a"),
           Request(rid=0, prompt=np.arange(8, dtype=np.int32), tenant="a")]
    done = eng.run(dup)
    assert len(done) == 3 and all(r.done for r in done)
    assert all(len(r.out_tokens) == 3 for r in done)


def test_unknown_scheduler_raises(smoke_model):
    cfg, model, params = smoke_model
    eng = Engine(model, params, cfg, ServeConfig(max_batch=1), eos_id=-1)
    with pytest.raises(ValueError, match="unknown scheduler"):
        eng.run([], scheduler="continous")


def test_host_bucket_from_policy_scaling():
    buckets = HostTokenBucket.from_policy(
        QoSPolicy(rates={"a": 0.5}, burst=2.0), scale=8.0)
    assert buckets["a"].rate == 4.0 and buckets["a"].burst == 16.0


# ---------------------------------------------------------------------------
# slot-aware kvcache helpers
# ---------------------------------------------------------------------------

def test_kv_slot_insert_and_update_slots():
    cache = kv_cache_init(2, 3, 16, 1, 4, dtype=jnp.float32)
    pre = {k: v + 7.0 for k, v in
           kv_cache_init(2, 1, 8, 1, 4, dtype=jnp.float32).items()}
    cache = kv_slot_insert(cache, pre, jnp.int32(1))
    assert float(cache["k"][:, 1, :8].min()) == 7.0
    assert float(jnp.abs(cache["k"][:, 0]).max()) == 0.0   # other slots kept
    assert float(jnp.abs(cache["k"][:, 1, 8:]).max()) == 0.0

    ck, cv = cache["k"][0], cache["v"][0]                  # one layer (3,16,1,4)
    k_new = jnp.full((3, 1, 1, 4), 9.0)
    pos = jnp.asarray([0, 5, 15], jnp.int32)
    ck2, _ = kv_update_slots(ck, cv, k_new, k_new, pos)
    for row, p in enumerate([0, 5, 15]):
        assert float(ck2[row, p].min()) == 9.0
    np.testing.assert_array_equal(
        np.asarray(slot_validity(6, jnp.asarray([0, 3]))),
        [[1, 0, 0, 0, 0, 0], [1, 1, 1, 1, 0, 0]])


def test_prompt_bucket_powers_of_two():
    assert [prompt_bucket(n) for n in (1, 8, 9, 16, 17, 100)] == \
        [8, 8, 16, 16, 32, 128]


# ---------------------------------------------------------------------------
# fused mediation costs
# ---------------------------------------------------------------------------

def _pipeline_roundtrip(dp, x):
    rec = tl.OpRecord(kind="all_reduce", tag="fuse/test", bytes=tl.nbytes(x),
                      axes=("data",), mode=dp.mode)

    def f(v, rt):
        v, rt = dp.pipeline.send(v, rec, rt, 0)
        v, rt = dp.pipeline.complete(v, rec, rt, 0)
        return v, rt

    out, rt = jax.jit(f)(x, dp.runtime_init())
    return np.asarray(out), np.asarray(rt["counters"])


@pytest.mark.parametrize("mode", ["bypass", "cord", "socket"])
def test_fused_pipeline_bit_identical_per_stage(mode):
    """Fused cost emission (one delay chain + one copy pass per side) is
    bit-identical to the per-stage pipeline in every mode preset, runtime
    counters included."""
    x = jax.random.normal(RNG, (128,))
    outs, ctrs = {}, {}
    for fused in (True, False):
        dp = Dataplane(DataplaneConfig(mode=mode, emulate_costs=True,
                                       fuse_mediation=fused))
        assert dp.pipeline.fused is fused
        outs[fused], ctrs[fused] = _pipeline_roundtrip(dp, x)
    np.testing.assert_array_equal(outs[True], outs[False])
    np.testing.assert_array_equal(ctrs[True], ctrs[False])


def test_fused_pipeline_emits_single_delay_chain(monkeypatch):
    """socket mode per-stage pays one delay_chain per cost stage; the
    fused pipeline emits ≤ 1 per side."""
    calls = {"n": 0}
    orig = tech.delay_chain

    def counting(x, iters):
        calls["n"] += 1
        return orig(x, iters)

    monkeypatch.setattr(tech, "delay_chain", counting)
    x = jnp.ones(16)
    rec = tl.OpRecord(kind="all_reduce", tag="fuse/count", bytes=64,
                      axes=("data",))
    counts = {}
    for fused in (True, False):
        dp = Dataplane(DataplaneConfig(mode="socket", emulate_costs=True,
                                       fuse_mediation=fused))
        per_side = {}
        for side in ("send", "complete"):
            calls["n"] = 0
            getattr(dp.pipeline, side)(x, rec, dp.runtime_init(), 0)
            per_side[side] = calls["n"]
        counts[fused] = per_side
    assert counts[False]["send"] == 2          # syscall + socket-stack
    assert counts[True]["send"] == 1           # fused into one chain
    assert counts[True]["complete"] <= 1
    # total serial cost is preserved by fusion
    for side in ("send_delay_iters", "complete_delay_iters"):
        a = getattr(Dataplane(DataplaneConfig(mode="socket",
                                              emulate_costs=True)).pipeline,
                    side)(rec)
        b = getattr(Dataplane(DataplaneConfig(mode="socket",
                                              emulate_costs=True,
                                              fuse_mediation=False)).pipeline,
                    side)(rec)
        assert a == b
