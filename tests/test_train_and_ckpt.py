"""Training substrate: grad sync + compression, microbatching, fault
tolerance, checkpoint/restore, elastic remesh."""

import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get_model_config
from repro.configs.base import DataplaneConfig, RunConfig, TrainConfig
from repro.core import Dataplane
from repro.data import DataConfig, ShardedLoader, SyntheticLM
from repro.models import build_model
from repro.runtime import FaultInjector, remesh, run_loop
from repro.train import init_state, make_explicit_dp_step, make_train_step
from repro.train.gradsync import compress_error_feedback, quantize_int8

RNG = jax.random.PRNGKey(0)


def _setup(mesh, compression="none", steps=8, lr=5e-3):
    cfg = get_model_config("granite-3-2b", smoke=True)
    model = build_model(cfg)
    run = RunConfig(train=TrainConfig(steps=steps, learning_rate=lr,
                                      warmup_steps=2,
                                      grad_compression=compression))
    dp = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh)
    step = make_explicit_dp_step(model, run, dp, axis="data")
    state = init_state(model, RNG, compression=compression)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=16))
    return model, step, state, ds, dp


def test_explicit_dp_training_reduces_loss(mesh8):
    _, step, state, ds, dp = _setup(mesh8)
    losses = []
    for i in range(8):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert dp.telemetry.by_kind()["all_reduce"]["ops"] > 0


def test_int8_compression_trains_and_tracks_exact(mesh8):
    _, step_c, state_c, ds, _ = _setup(mesh8, compression="int8")
    _, step_e, state_e, _, _ = _setup(mesh8, compression="none")
    for i in range(6):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state_c, mc = step_c(state_c, b)
        state_e, me = step_e(state_e, b)
    # compressed training stays close to exact (error feedback)
    assert abs(float(mc["loss"]) - float(me["loss"])) < 0.3


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(RNG, (1000,)) * 5
    q, scale = quantize_int8(x)
    err = jnp.abs(q.astype(jnp.float32) * scale - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_accumulates_residual():
    g = jax.random.normal(RNG, (256,))
    err = jnp.zeros_like(g)
    q, s, err = compress_error_feedback(g, err)
    recon = q.astype(jnp.float32) * s
    np.testing.assert_allclose(recon + err, g, atol=1e-6)


def test_microbatch_equals_full_batch():
    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    dpn = Dataplane(DataplaneConfig(mode="cord"))
    state = init_state(model, RNG)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                global_batch=8))
    b = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    outs = {}
    for mb in (0, 4):
        run = RunConfig(train=TrainConfig(microbatch=mb, learning_rate=1e-3))
        step = make_train_step(model, run, dpn)  # no mesh -> plain jit
        # the step donates its input state: hand each variant its own copy
        s2, m = step(jax.tree.map(jnp.copy, state), b)
        outs[mb] = (float(m["loss"]), s2.params)
    assert abs(outs[0][0] - outs[4][0]) < 1e-3
    for a, b_ in zip(jax.tree.leaves(outs[0][1]), jax.tree.leaves(outs[4][1])):
        np.testing.assert_allclose(a, b_, atol=5e-5)


def test_fault_tolerant_loop_recovers(tmp_path, mesh8):
    _, step, state, ds, _ = _setup(mesh8)
    loader = ShardedLoader(ds)

    def wrap(s, b):
        return step(s, {k: jnp.asarray(v) for k, v in b.items()})

    inj = FaultInjector(fail_steps=(3, 5), max_failures_per_step=1)
    state, rep = run_loop(wrap, state, loader, steps=8,
                          ckpt_dir=str(tmp_path), checkpoint_every=2,
                          injector=inj, async_ckpt=False)
    assert rep.failures == 2
    assert rep.steps_run >= 8
    assert store.latest_step(str(tmp_path)) is not None


def test_hard_failure_restores_from_checkpoint(tmp_path, mesh8):
    _, step, state, ds, _ = _setup(mesh8)
    loader = ShardedLoader(ds)

    def wrap(s, b):
        return step(s, {k: jnp.asarray(v) for k, v in b.items()})

    inj = FaultInjector(fail_steps=(4,), max_failures_per_step=99)
    # unrecoverable by retry → must restore from the step-2 checkpoint;
    # the injector then allows... max_failures=99 would loop forever, so
    # bound retries: after restore the loop replays step 4 and hits the
    # injector again — use max_failures within budget instead.
    inj = FaultInjector(fail_steps=(4,), max_failures_per_step=4)
    state, rep = run_loop(wrap, state, loader, steps=6,
                          ckpt_dir=str(tmp_path), checkpoint_every=2,
                          injector=inj, max_retries=2, async_ckpt=False)
    assert rep.restores >= 1
    assert rep.steps_run >= 6


def test_checkpoint_roundtrip_and_prune(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "b": {"x": jnp.ones(3, jnp.int32)}}
    for s in (2, 4, 6, 8):
        store.save(str(tmp_path), s, tree, keep_last=2)
    assert store.all_steps(str(tmp_path)) == [6, 8]
    like = jax.tree.map(jnp.zeros_like, tree)
    back = store.restore(str(tmp_path), 8, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    store.save(str(tmp_path), 1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        store.restore(str(tmp_path), 1, {"w": jnp.ones((5,))})


def test_elastic_remesh_preserves_values(mesh42):
    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    state = init_state(model, RNG)
    state2 = remesh(state, mesh42)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(state2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and back onto a smaller mesh
    from repro.core import compat
    small = compat.make_mesh((2, 1), ("data", "model"),
                             devices=jax.devices()[:2])
    state3 = remesh(state2, small)
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(state3.params)[0]),
        np.asarray(jax.tree.leaves(state.params)[0]))


def test_loader_determinism_across_shards():
    ds = SyntheticLM(DataConfig(vocab_size=64, seq_len=16, global_batch=8))
    full = ds.batch_at(3)
    sh0 = ds.batch_at(3, shard=0, num_shards=2)
    sh1 = ds.batch_at(3, shard=1, num_shards=2)
    np.testing.assert_array_equal(
        np.concatenate([sh0["tokens"], sh1["tokens"]]), full["tokens"])
