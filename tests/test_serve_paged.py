"""Paged-KV serving: block-pool round-trips, gather-decode bit-identity
with the stripe layout, chunked-prefill equivalence, preemption with
exact temperature-0 resume, and submit-time admission under paging."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_model_config
from repro.configs.base import ServeConfig
from repro.core import telemetry as tl
from repro.layers.kvcache import (
    BlockAllocator,
    kv_cache_init,
    kv_pool_gather,
    kv_pool_init,
    kv_pool_insert,
    kv_pool_scatter_token,
)
from repro.models import build_model
from repro.serve import Engine, Request, ServeError

RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    return cfg, model, params


def _requests(lengths, tenants=None, max_new=8):
    tenants = tenants or ["default"] * len(lengths)
    return [Request(rid=i, prompt=np.asarray((np.arange(n) + 3 * i) % 100,
                                             np.int32),
                    tenant=t, max_new_tokens=max_new)
            for i, (n, t) in enumerate(zip(lengths, tenants))]


def _tokens(done):
    return {r.rid: r.out_tokens for r in done}


# ---------------------------------------------------------------------------
# block pool primitives
# ---------------------------------------------------------------------------

def test_block_allocator_round_trip():
    a = BlockAllocator(4)
    ids = a.alloc(3)
    assert ids == [1, 2, 3] and a.free_blocks == 1
    assert a.alloc(2) is None and a.free_blocks == 1   # all-or-nothing
    a.free([2])
    assert sorted(a.alloc(2)) == [2, 4]
    assert a.alloc(0) == [] and a.free_blocks == 0
    a.free([1, 2, 3, 4])
    assert a.free_blocks == 4


def test_block_allocator_double_free_raises():
    a = BlockAllocator(2)
    a.alloc(1)
    a.free([1])
    with pytest.raises(ValueError, match="double free"):
        a.free([1])
    with pytest.raises(ValueError, match="double free"):
        a.free([0])                      # the null block is never handed out


def test_kv_pool_insert_then_gather_bitwise():
    L, bs, KVH, hd = 2, 4, 1, 3
    pool = kv_pool_init(L, 6, bs, KVH, hd, dtype=jnp.float32)
    pre = {k: v + 7.0 for k, v in
           kv_cache_init(L, 1, 8, KVH, hd, dtype=jnp.float32).items()}
    pool = kv_pool_insert(pool, pre, jnp.asarray([2, 5], jnp.int32), bs)
    dense = kv_pool_gather(pool, jnp.asarray([[2, 5, 0]], jnp.int32), bs)
    assert dense["k"].shape == (L, 1, 12, KVH, hd)
    np.testing.assert_array_equal(np.asarray(dense["k"][:, 0, :8]), 7.0)
    # the unallocated table tail reads the null block: zeros
    assert float(jnp.abs(dense["k"][:, 0, 8:]).max()) == 0.0


def test_kv_pool_scatter_token_targets_and_drops():
    L, bs, KVH, hd = 1, 4, 1, 2
    pool = kv_pool_init(L, 4, bs, KVH, hd, dtype=jnp.float32)
    tables = jnp.asarray([[1, 2], [3, 0]], jnp.int32)
    pos = jnp.asarray([5, 1], jnp.int32)
    active = jnp.asarray([True, False])
    dense = kv_pool_gather(pool, tables, bs)
    dense = {k: v.at[:, 0, 5].set(9.0).at[:, 1, 1].set(4.0)
             for k, v in dense.items()}
    pool = kv_pool_scatter_token(pool, dense, tables, pos, active, bs)
    # slot 0, pos 5 → physical block tables[0, 1] = 2 at offset 1
    assert float(pool["k"][0, 2, 1].max()) == 9.0
    assert float(jnp.abs(pool["k"][0, 3]).max()) == 0.0   # inactive dropped
    assert float(jnp.abs(pool["k"][0, 0]).max()) == 0.0   # null block intact


# ---------------------------------------------------------------------------
# ServeConfig validation
# ---------------------------------------------------------------------------

def test_serve_config_paged_validation():
    with pytest.raises(ValueError, match="block_size"):
        ServeConfig(block_size=-1)
    with pytest.raises(ValueError, match="divide"):
        ServeConfig(block_size=6, kv_cache_len=64)
    with pytest.raises(ValueError, match="n_blocks"):
        ServeConfig(n_blocks=-1)
    with pytest.raises(ValueError, match="requires block_size"):
        ServeConfig(n_blocks=4)
    with pytest.raises(ValueError, match="power of two"):
        ServeConfig(prefill_chunk=12)
    with pytest.raises(ValueError, match="power of two"):
        ServeConfig(prefill_chunk=4)
    with pytest.raises(ValueError, match="multiple"):
        ServeConfig(prefill_chunk=16, block_size=32, kv_cache_len=64)
    sc = ServeConfig(block_size=8, n_blocks=4, prefill_chunk=16,
                     kv_cache_len=64)
    assert sc.block_size == 8 and sc.n_blocks == 4


# ---------------------------------------------------------------------------
# paged engine: bit-identity, admission, preemption, chunked prefill
# ---------------------------------------------------------------------------

def test_paged_decode_matches_stripe_bitwise(smoke_model):
    """Gather → fixed-shape decode → scatter over the block pool emits the
    exact tokens the contiguous stripe layout emits at temperature 0, on a
    mixed-length stream, with the decode step still compiled once."""
    cfg, model, params = smoke_model
    base = dict(max_batch=2, max_new_tokens=5, kv_cache_len=64)
    stripe = Engine(model, params, cfg, ServeConfig(**base), eos_id=-1)
    paged = Engine(model, params, cfg, ServeConfig(**base, block_size=8),
                   eos_id=-1)
    assert paged.paged and not stripe.paged
    lengths = [8, 13, 21, 8, 30]
    out_s = _tokens(stripe.run(_requests(lengths, max_new=5)))
    out_p = _tokens(paged.run(_requests(lengths, max_new=5)))
    assert out_p == out_s
    assert paged.decode_compile_count() == 1


def test_paged_admits_prompt_longer_than_stripe(smoke_model):
    """Slot count decouples from context length: a prompt no fixed stripe
    can hold is admissible while free blocks exist; the stripe and gang
    paths reject it with a clear submit-time ServeError."""
    cfg, model, params = smoke_model
    base = dict(max_batch=2, max_new_tokens=8, kv_cache_len=56)
    stripe = Engine(model, params, cfg, ServeConfig(**base), eos_id=-1)
    with pytest.raises(ServeError, match="cache positions"):
        stripe.run(_requests([80]))
    gang = Engine(model, params, cfg, ServeConfig(**base), eos_id=-1)
    with pytest.raises(ServeError, match="gang request"):
        gang.run(_requests([80]), scheduler="gang")
    paged = Engine(model, params, cfg,
                   ServeConfig(**base, block_size=8, n_blocks=24), eos_id=-1)
    (done,) = paged.run(_requests([80]))
    assert done.done and len(done.out_tokens) == 8
    # a prompt the POOL cannot ever hold still fails loudly at submit
    tiny = Engine(model, params, cfg,
                  ServeConfig(**base, block_size=8, n_blocks=4), eos_id=-1)
    with pytest.raises(ServeError, match="pool blocks"):
        tiny.run(_requests([80]))


def test_pool_pressure_preempts_and_resumes_exact(smoke_model):
    """Under a pool too small for both residents' growth, the engine
    preempts (tokens = snapshot, blocks freed, request re-queued) and the
    resumed request finishes with exactly the tokens of an unpressured
    run — recompute is exact at temperature 0."""
    cfg, model, params = smoke_model
    base = dict(max_batch=2, max_new_tokens=8, kv_cache_len=64,
                block_size=8)
    roomy = Engine(model, params, cfg, ServeConfig(**base), eos_id=-1)
    # each request needs 2 blocks (16 positions); 3 can't host both
    tight = Engine(model, params, cfg, ServeConfig(**base, n_blocks=3),
                   eos_id=-1)
    out_r = _tokens(roomy.run(_requests([8, 8])))
    out_t = _tokens(tight.run(_requests([8, 8])))
    assert out_t == out_r
    rep = tight.tenant_report()["default"]
    assert rep["preemptions"] >= 1 and rep["restores"] >= 1
    ctrs, names = tight.runtime_counters()
    i = list(names).index("default")
    assert ctrs[i, tl.CTR_PREEMPTIONS] == rep["preemptions"]
    assert ctrs[i, tl.CTR_RESTORES] == rep["restores"]
    assert tight._alloc.free_blocks == 3       # every block returned


def test_slot_budget_preempts_mid_run_exact(smoke_model):
    """set_slot_budget mid-decode evicts over-budget slots; the evicted
    requests resume (serially, under the tightened cap) with bit-identical
    tokens — WFQ budgets are enforceable, not advisory."""
    cfg, model, params = smoke_model
    sc = ServeConfig(max_batch=4, max_new_tokens=6, kv_cache_len=64,
                     block_size=8)
    ref = Engine(model, params, cfg, sc, eos_id=-1)
    out_ref = _tokens(ref.run(_requests([8] * 4, max_new=6)))
    eng = Engine(model, params, cfg, sc, eos_id=-1)
    calls = {"n": 0}
    orig = eng._step_pool

    def spy(*a):
        calls["n"] += 1
        if calls["n"] == 2:
            eng.set_slot_budget(1)       # tighten while 4 slots are held
        return orig(*a)

    eng._step_pool = spy
    out = _tokens(eng.run(_requests([8] * 4, max_new=6)))
    assert out == out_ref
    rep = eng.tenant_report()["default"]
    assert rep["preemptions"] >= 3 and rep["restores"] >= 3
    eng.set_slot_budget(0)               # relax back to the config cap
    assert eng._budget_cap == 0


def test_chunked_prefill_matches_whole_prefill(smoke_model):
    """Chunk-at-a-time prefill (interleaved with decode ticks) emits the
    same tokens as whole-prompt prefill, in both stripe and paged
    layouts."""
    cfg, model, params = smoke_model
    base = dict(max_batch=2, max_new_tokens=4, kv_cache_len=128)
    lengths = [40, 8, 23]
    whole = Engine(model, params, cfg, ServeConfig(**base), eos_id=-1)
    out_w = _tokens(whole.run(_requests(lengths, max_new=4)))
    chunked = Engine(model, params, cfg,
                     ServeConfig(**base, prefill_chunk=16), eos_id=-1)
    assert chunked.chunked
    assert _tokens(chunked.run(_requests(lengths, max_new=4))) == out_w
    both = Engine(model, params, cfg,
                  ServeConfig(**base, prefill_chunk=16, block_size=8),
                  eos_id=-1)
    assert both.paged and both.chunked
    assert _tokens(both.run(_requests(lengths, max_new=4))) == out_w


def _count_chunks(eng):
    """Count traced chunk-prefill steps on ``eng`` (replay detector)."""
    orig, c = eng._chunk, {"n": 0}

    def wrapped(*a, **kw):
        c["n"] += 1
        return orig(*a, **kw)

    eng._chunk = wrapped
    return c


def test_preempt_mid_chunked_prefill_replays_pending_chunks(smoke_model):
    """Regression: preempting a slot whose chunked prefill is still
    PENDING must drop the partial prefill state (``_prefills`` entry and
    queue position) and replay every chunk from offset 0 on resume — the
    emitted-tokens snapshot holds nothing for a request that never
    activated, so a stale entry or a skipped chunk would silently corrupt
    whatever lands in that slot next."""
    cfg, model, params = smoke_model
    base = dict(max_batch=2, max_new_tokens=6, kv_cache_len=128,
                prefill_chunk=16, block_size=8)
    lengths = [8, 40]                    # rid 1 prefills over >= 3 chunks

    ref = Engine(model, params, cfg, ServeConfig(**base), eos_id=-1)
    c_ref = _count_chunks(ref)
    out_ref = _tokens(ref.run(_requests(lengths, max_new=6)))

    eng = Engine(model, params, cfg, ServeConfig(**base), eos_id=-1)
    c_eng = _count_chunks(eng)
    orig_adv = eng._advance_chunk

    def adv(*a):
        out = orig_adv(*a)
        if c_eng["n"] == 1:              # first chunk landed; rest pending
            eng.set_slot_budget(1)       # next tick preempts the new slot
        return out

    eng._advance_chunk = adv
    out = _tokens(eng.run(_requests(lengths, max_new=6)))
    assert out == out_ref
    assert eng.tenant_report()["default"]["preemptions"] >= 1
    assert c_eng["n"] > c_ref["n"]       # the pending chunks were REPLAYED
    assert not eng._prefills             # no stale chunk state survives
    assert eng._alloc.free_blocks == eng._n_usable


def test_pool_pressure_while_chunked_prefill_pending(smoke_model):
    """Pool pressure striking while another slot's chunked prefill is in
    flight: the prefilling slot claimed its blocks up-front and is not a
    pressure victim, so the decoding slot preempts ITSELF, waits out the
    prefill, and resumes — both requests finish with the unpressured
    run's exact tokens and every block returns to the pool."""
    cfg, model, params = smoke_model
    base = dict(max_batch=2, max_new_tokens=6, kv_cache_len=128,
                prefill_chunk=16, block_size=8)
    lengths = [8, 40]
    roomy = Engine(model, params, cfg, ServeConfig(**base), eos_id=-1)
    out_r = _tokens(roomy.run(_requests(lengths, max_new=6)))
    # pool: rid 1's up-front prefill claim + one block — rid 0's first
    # decode growth past its initial block finds the free list empty
    need = -(-roomy._cover(lengths[1]) // base["block_size"])
    tight = Engine(model, params, cfg,
                   ServeConfig(**base, n_blocks=need + 1), eos_id=-1)
    out_t = _tokens(tight.run(_requests(lengths, max_new=6)))
    assert out_t == out_r
    assert tight.tenant_report()["default"]["preemptions"] >= 1
    assert tight._alloc.free_blocks == need + 1


def test_prefill_chunk_logits_and_cache_bitwise(smoke_model):
    """Model-level: scanning chunks at traced offsets reproduces the whole
    prefill's final-position logits and KV cache bit-for-bit."""
    cfg, model, params = smoke_model
    toks = jnp.asarray((np.arange(32) % 97)[None, :], jnp.int32)
    last = jnp.asarray([31], jnp.int32)
    logits_w, cache_w = model.prefill(params, {"tokens": toks},
                                      model.init_cache(1, 32), last_pos=last)
    cache_c = model.init_cache(1, 32)
    C = 8
    for off in range(0, 32, C):
        logits_c, cache_c = model.prefill_chunk(
            params, {"tokens": toks[:, off:off + C]}, cache_c,
            jnp.int32(off), last_pos=last)
    np.testing.assert_array_equal(np.asarray(logits_w), np.asarray(logits_c))
    for name in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(cache_w[name]),
                                      np.asarray(cache_c[name]))
