"""The CQ-driven async verbs runtime: sender-window bounds, credit
flow control (stall + resume), windowed/synchronous bit-equivalence,
per-tenant runtime accounting of verbs traffic — plus regression tests
for the READ phantom-completion, first-token-EOS and msg_bytes
truncation bugs."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import DataplaneConfig, ModelConfig, ServeConfig
from repro.core import Dataplane, compat, verbs


def _dp(mode, mesh, **kw):
    return Dataplane(DataplaneConfig(mode=mode, emulate_costs=True, **kw),
                     mesh=mesh)


def _run_windowed(mesh, dp, cfg, payload, *, credits, op="send",
                  with_state=True):
    """One windowed transfer src=0→dst=1; returns (out rows, qp scalars,
    per-tenant report or None)."""
    n = payload.shape[0]
    msgs = jnp.asarray(np.stack([payload, np.zeros_like(payload)]))

    @partial(compat.shard_map, mesh=mesh,
             in_specs=(P("rank", None, None), P()),
             out_specs=(P("rank", None, None), (P(), P(), P(), P()), P()))
    def f(m, rt):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        if op == "send":
            qp, rt = verbs.post_recv(dp, cfg, qp, rank, dst=1, n=credits,
                                     state=rt)
        out, qp, rt = verbs.windowed_send(dp, cfg, qp, m[0], rank, src=0,
                                          dst=1, op=op, state=rt)
        rt = verbs.allreduce_state(rt)
        return (out[None], (qp["win_hwm"], qp["cq_hwm"], qp["cq_sent"],
                            qp["credits"]), rt)

    rt0 = dp.runtime_init() if with_state else None
    out, scalars, rt = jax.jit(f)(msgs, rt0)
    report = dp.runtime_report(rt)[dp.tenant] if with_state else None
    return np.asarray(out), [int(s) for s in scalars], report


def _payload(n, msg_bytes, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, size=(n, msg_bytes), dtype=np.uint8)


# ---------------------------------------------------------------------------
# sender window
# ---------------------------------------------------------------------------

def test_window_never_exceeds_max_outstanding(mesh2):
    dp = _dp("cord", mesh2)
    for w in (1, 2, 4):
        cfg = verbs.QPConfig(transport="RC", msg_bytes=32, depth=8,
                             max_outstanding=w)
        out, (win_hwm, cq_hwm, cq_sent, _), _ = _run_windowed(
            mesh2, dp, cfg, _payload(10, 32), credits=10)
        assert win_hwm == w          # the window fills exactly to the cap
        assert cq_hwm <= cfg.effective_cq_depth
        assert cq_sent == 10         # every WR eventually completed


def test_windowed_report_counts_verbs_traffic(mesh2):
    """Verbs ops land in dp.runtime_report: ops/bytes from the pipeline's
    counter-bump, completions/credits/cq_depth from the CQ runtime."""
    dp = _dp("cord", mesh2)
    cfg = verbs.QPConfig(transport="RC", msg_bytes=64, depth=8,
                         max_outstanding=4)
    n = 8
    _, _, rep = _run_windowed(mesh2, dp, cfg, _payload(n, 64), credits=n)
    assert rep["ops"] == n + 1             # n posts + 1 post_recv
    assert rep["bytes"] == n * 64 + 4      # payloads + credit-grant token
    assert rep["completions"] == n
    assert rep["credits"] == n
    assert rep["stalls"] == 0
    assert rep["cq_depth"] == 4            # CQ high-water = the window


# ---------------------------------------------------------------------------
# credit flow control
# ---------------------------------------------------------------------------

def test_credit_exhaustion_stalls_then_resumes(mesh2):
    dp = _dp("cord", mesh2)
    cfg = verbs.QPConfig(transport="RC", msg_bytes=32, depth=8,
                         max_outstanding=8)
    n, credits = 12, 3
    payload = _payload(n, 32)
    out, (_, _, cq_sent, left), rep = _run_windowed(
        mesh2, dp, cfg, payload, credits=credits)
    # the sender ran dry every `credits` sends and resumed after each
    # receiver re-post: ceil(n/credits) - 1 stall episodes
    assert rep["stalls"] == (n + credits - 1) // credits - 1 == 3
    assert rep["credits"] == n             # every send consumed one credit
    assert cq_sent == n                    # ...and still completed them all
    np.testing.assert_array_equal(out[1], payload)   # delivery intact
    # ample credits: no stalls at all
    _, _, rep2 = _run_windowed(mesh2, dp, cfg, payload, credits=n)
    assert rep2["stalls"] == 0 and rep2["credits"] == n


def test_one_sided_ops_bypass_credits(mesh2):
    """WRITE consumes no receiver credits (no recv queue involvement)."""
    dp = _dp("cord", mesh2)
    cfg = verbs.QPConfig(transport="RC", msg_bytes=32, depth=8,
                         max_outstanding=2)
    payload = _payload(6, 32)
    out, (_, _, cq_sent, credits_left), rep = _run_windowed(
        mesh2, dp, cfg, payload, credits=0, op="write")
    assert cq_sent == 6 and credits_left == 0
    assert rep["credits"] == 0 and rep["stalls"] == 0
    np.testing.assert_array_equal(out[1], payload)


# ---------------------------------------------------------------------------
# windowed ≡ synchronous, per mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["bypass", "cord", "socket"])
def test_windowed_bit_identical_to_sync_path(mesh2, mode):
    dp = _dp(mode, mesh2)
    n, msg_bytes = 6, 64
    payload = _payload(n, msg_bytes, seed=3)
    cfg_w = verbs.QPConfig(transport="RC", msg_bytes=msg_bytes, depth=4,
                           max_outstanding=2)
    out, _, _ = _run_windowed(mesh2, dp, cfg_w, payload, credits=n,
                              with_state=False)

    cfg_s = verbs.QPConfig(transport="RC", msg_bytes=msg_bytes, depth=n)

    @partial(compat.shard_map, mesh=mesh2, in_specs=P("rank", None, None),
             out_specs=P("rank", None, None))
    def sync(m):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg_s)
        for i in range(n):
            qp, _ = verbs.post_send(dp, cfg_s, qp, m[0, i], rank, src=0)
        qp, _ = verbs.flush_send(dp, cfg_s, qp, rank, src=0, dst=1)
        return qp["recv_ring"][None]

    ring = jax.jit(sync)(
        jnp.asarray(np.stack([payload, np.zeros_like(payload)])))
    np.testing.assert_array_equal(out[1], np.asarray(ring)[1][:n])
    np.testing.assert_array_equal(out[1], payload)


def test_windowed_ud_delivery(mesh2):
    dp = _dp("cord", mesh2)
    cfg = verbs.QPConfig(transport="UD", msg_bytes=128, depth=4,
                         max_outstanding=4)
    payload = _payload(5, 128, seed=5)
    out, (_, _, cq_sent, _), _ = _run_windowed(mesh2, dp, cfg, payload,
                                               credits=5)
    assert cq_sent == 5
    np.testing.assert_array_equal(out[1], payload)


# ---------------------------------------------------------------------------
# CQ ring mechanics
# ---------------------------------------------------------------------------

def test_cq_ring_entries_pushed_and_consumed(mesh2):
    """flush_send pushes per-entry CQEs (status + wr_id); poll_cq consumes
    them back to CQE_EMPTY."""
    dp = _dp("cord", mesh2)
    cfg = verbs.QPConfig(transport="RC", msg_bytes=16, depth=4)

    @partial(compat.shard_map, mesh=mesh2, in_specs=P("rank", None),
             out_specs=(P(), P(), P(), P(), P()))
    def roundtrip(buf):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        qp, _ = verbs.post_send(dp, cfg, qp, buf[0], rank, src=0)
        qp, _ = verbs.post_send(dp, cfg, qp, buf[0], rank, src=0)
        qp, _ = verbs.flush_send(dp, cfg, qp, rank, src=0, dst=1)
        status_after_flush = qp["cq_status"]
        wrid_after_flush = qp["cq_wrid"]
        occ = verbs.cq_occupancy(qp)
        _, qp, _ = verbs.poll_cq(dp, cfg, qp, rank, poller=1)
        return (status_after_flush, wrid_after_flush, occ,
                qp["cq_status"], verbs.cq_occupancy(qp))

    st, wrid, occ, st2, occ2 = jax.jit(roundtrip)(
        jnp.zeros((2, 16), jnp.uint8))
    np.testing.assert_array_equal(
        np.asarray(st)[:2], [verbs.CQE_SEND, verbs.CQE_SEND])
    np.testing.assert_array_equal(np.asarray(wrid)[:2], [0, 1])
    assert int(occ) == 2
    assert int(occ2) == 0                       # poll drained the ring
    assert np.all(np.asarray(st2) == verbs.CQE_EMPTY)


def test_cq_ring_sheds_on_overflow(mesh2):
    """Unpolled CQEs are never overwritten: pushes past the ring's free
    space are shed and occupancy stays within the ring size."""
    dp = _dp("cord", mesh2)
    cfg = verbs.QPConfig(transport="RC", msg_bytes=16, depth=4, cq_depth=4)

    @partial(compat.shard_map, mesh=mesh2, in_specs=P("rank", None),
             out_specs=(P(), P(), P()))
    def overrun(buf):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        for _ in range(2):           # 2 × (4 posts + flush), never polled
            for _ in range(4):
                qp, _ = verbs.post_send(dp, cfg, qp, buf[0], rank, src=0)
            qp, _ = verbs.flush_send(dp, cfg, qp, rank, src=0, dst=1)
        return verbs.cq_occupancy(qp), qp["cq_hwm"], qp["cq_wrid"]

    occ, hwm, wrid = jax.jit(overrun)(jnp.zeros((2, 16), jnp.uint8))
    assert int(occ) == 4 and int(hwm) == 4      # ring never overfilled
    np.testing.assert_array_equal(np.asarray(wrid), [0, 1, 2, 3])


# ---------------------------------------------------------------------------
# regression: READ must not fabricate send completions
# ---------------------------------------------------------------------------

def test_read_completes_no_posted_sends(mesh2):
    dp = _dp("cord", mesh2)
    cfg = verbs.QPConfig(transport="RC", msg_bytes=16, depth=4)

    @partial(compat.shard_map, mesh=mesh2, in_specs=P("rank", None),
             out_specs=(P(), P(), P()))
    def readback(buf):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        qp, _ = verbs.post_send(dp, cfg, qp, buf[0], rank, src=0)
        qp, _ = verbs.post_send(dp, cfg, qp, buf[0], rank, src=0)
        # a one-sided READ moves remote memory — the two posted sends
        # stay pending (no flush has run for them)
        qp, _ = verbs.flush_send(dp, cfg, qp, rank, src=0, dst=1, op="read")
        phantom, qp, _ = verbs.poll_cq(dp, cfg, qp, rank, poller=0)
        # flushing the send queue then completes them for real
        qp, _ = verbs.flush_send(dp, cfg, qp, rank, src=0, dst=1, op="send")
        real, qp, _ = verbs.poll_cq(dp, cfg, qp, rank, poller=1)
        return phantom, real, qp["cq_sent"]

    phantom, real, cq_sent = jax.jit(readback)(jnp.zeros((2, 16), jnp.uint8))
    assert int(phantom) == 0     # was 2 before the fix
    assert int(real) == 2
    assert int(cq_sent) == 2


# ---------------------------------------------------------------------------
# regression: msg_bytes must divide by the slot dtype size
# ---------------------------------------------------------------------------

def test_msg_bytes_must_match_dtype_itemsize():
    with pytest.raises(verbs.TransportError):
        verbs.QPConfig(msg_bytes=6, dtype="float32")   # 6 // 4 truncates
    with pytest.raises(verbs.TransportError):
        verbs.QPConfig(msg_bytes=2, dtype="float32")   # 2 // 4 == 0 slots
    cfg = verbs.QPConfig(msg_bytes=8, dtype="float32")
    assert verbs.qp_init(cfg)["send_ring"].shape == (cfg.depth, 2)
    with pytest.raises(verbs.TransportError):
        verbs.qp_init(verbs.QPConfig(msg_bytes=6), dtype=jnp.float32)


# ---------------------------------------------------------------------------
# regression: a first sampled token == EOS must finish the request
# ---------------------------------------------------------------------------

class _EOSModel:
    """Stub model whose argmax token is always ``eos`` and which counts
    decode steps host-side."""

    def __init__(self, vocab=8, eos=1):
        self.vocab, self.eos = vocab, eos
        self.decode_calls = 0

    def init_cache(self, batch, cache_len):
        return {"len": jnp.zeros((batch,), jnp.int32)}

    def _logits(self, b, s):
        return jnp.zeros((b, s, self.vocab)) \
            .at[:, :, self.eos].set(10.0)

    def prefill(self, params, batch, cache, dp=None):
        toks = batch["tokens"]
        return self._logits(toks.shape[0], toks.shape[1]), cache

    def decode_step(self, params, tok, cache, pos, dp=None):
        self.decode_calls += 1
        return self._logits(tok.shape[0], 1), cache


def test_engine_stops_on_first_token_eos():
    from repro.serve.engine import Engine, Request

    model = _EOSModel()
    eng = Engine(model, params={}, cfg=ModelConfig(),
                 serve=ServeConfig(max_batch=2, max_new_tokens=16),
                 dp=None, eos_id=model.eos)
    reqs = [Request(rid=0, prompt=np.array([3, 4], np.int32)),
            Request(rid=1, prompt=np.array([5], np.int32))]
    done = eng.run(reqs)
    for r in done:
        assert r.done
        assert r.out_tokens == [model.eos]   # was 16 tokens before the fix
    # ...and no decode step ever ran for an all-EOS batch
    assert model.decode_calls == 0
