"""Per-tenant observability timelines (core/obs.py): snapshot append /
derived-rate correctness, JSON artifact round-trip, off-toggle
bit-identity against a traced train step, the counter column-order
regression, and the serve-engine per-tick feed."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_model_config
from repro.configs.base import (
    DataplaneConfig,
    ObsConfig,
    RunConfig,
    ServeConfig,
    TrainConfig,
)
from repro.core import Dataplane
from repro.core import telemetry as tl
from repro.core.obs import (
    RATE_FIELDS,
    TIMELINE_SCHEMA,
    CounterTimeline,
    sparkline,
    validate_timeline,
)
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.serve import Engine, Request
from repro.train import init_state, make_explicit_dp_step

RNG = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# snapshot append + derived rates
# ---------------------------------------------------------------------------

def test_snapshot_appends_and_unions_tenants():
    t = CounterTimeline(source="t")
    t.snapshot(0, {"a": {"ops": 1}}, t=0.0)
    t.snapshot(1, {"a": {"ops": 2}, "b": {"ops": 5}}, t=1.0)
    assert len(t.samples) == 2
    assert t.tenants == ("a", "b")           # first-seen order
    # a tenant absent from an earlier sample reads as zero there
    assert t.rates()["b"]["ops_s"] == [5.0]


def test_rates_hand_computed():
    t = CounterTimeline(source="t")
    t.snapshot(0, {"a": {"ops": 0, "bytes": 0}}, t=0.0)
    t.snapshot(1, {"a": {"ops": 4, "bytes": 4096, "throttled": 1,
                         "denied": 2, "stalls": 3, "chunks": 8}}, t=2.0)
    t.snapshot(2, {"a": {"ops": 8, "bytes": 8192, "throttled": 1,
                         "denied": 2, "stalls": 3, "chunks": 8}}, t=3.0)
    r = t.rates()["a"]
    assert r["ops_s"] == [2.0, 4.0]          # Δops / wall dt
    assert r["bytes_s"] == [2048.0, 4096.0]
    assert r["chunks_s"] == [4.0, 0.0]
    assert r["throttled_pct"] == [25.0, 0.0]  # Δthrottled / Δops
    assert r["denied_pct"] == [50.0, 0.0]
    assert r["stalls_pct"] == [75.0, 0.0]
    axis = t.rate_axis()
    assert axis["step"] == [1, 2] and axis["t"] == [2.0, 3.0]


def test_rates_fall_back_to_step_delta_on_equal_stamps():
    t = CounterTimeline(source="t")
    t.snapshot(0, {"a": {"ops": 0}}, t=1.0)
    t.snapshot(4, {"a": {"ops": 8}}, t=1.0)   # dt == 0 -> steps (4)
    assert t.rates()["a"]["ops_s"] == [2.0]


def test_cq_depth_is_a_level_not_a_rate():
    t = CounterTimeline(source="t")
    t.snapshot(0, {"a": {"ops": 0, "cq_depth": 3}}, t=0.0)
    t.snapshot(1, {"a": {"ops": 1, "cq_depth": 7}}, t=1.0)
    t.snapshot(2, {"a": {"ops": 2, "cq_depth": 7}}, t=2.0)
    # the high-water mark is reported at the window close, not differenced
    assert t.rates()["a"]["cq_depth"] == [7.0, 7.0]


def test_gauges_align_to_samples():
    t = CounterTimeline(source="t")
    t.snapshot(0, {"a": {"ops": 0}}, t=0.0, gauges={"active_slots": 2})
    t.snapshot(1, {"a": {"ops": 1}}, t=1.0)
    assert t.gauge_series() == {"active_slots": [2.0, 0.0]}


def test_sparkline_shapes():
    assert sparkline([], 8) == ""
    assert sparkline([0, 0, 0], 8) == "▁▁▁"          # flat zero: baseline
    assert sparkline([5, 5], 8) == "▄▄"              # flat nonzero: mid
    assert len(sparkline(list(range(100)), 16)) == 16  # downsampled
    s = sparkline([1, 9], 8)
    assert s[0] == "▁" and s[-1] == "█"


# ---------------------------------------------------------------------------
# artifact round-trip + validation
# ---------------------------------------------------------------------------

def test_artifact_roundtrip(tmp_path):
    t = CounterTimeline(source="rt")
    t.snapshot(0, {"a": {"ops": 0}}, t=0.0, gauges={"q": 1})
    t.snapshot(1, {"a": {"ops": 3}, "b": {"bytes": 64}}, t=1.0)
    path = t.save(str(tmp_path / "x_timeline.json"))
    doc = CounterTimeline.load(path)
    assert doc == t.to_doc()
    assert doc["schema"] == TIMELINE_SCHEMA
    assert doc["rate_fields"] == list(RATE_FIELDS)
    assert doc["counters"] == list(tl.COUNTER_NAMES)
    # the raw file is the same document (no lossy encode/decode)
    with open(path) as f:
        assert json.load(f) == doc


def test_validate_rejects_malformed():
    good = CounterTimeline(source="v")
    good.snapshot(0, {"a": {"ops": 0}}, t=0.0)
    good.snapshot(1, {"a": {"ops": 1}}, t=1.0)
    doc = good.to_doc()
    assert validate_timeline(doc) is doc
    with pytest.raises(ValueError, match="schema"):
        validate_timeline({**doc, "schema": "cord-timeline/v999"})
    with pytest.raises(ValueError, match="missing key"):
        validate_timeline({k: v for k, v in doc.items() if k != "rates"})
    bad = json.loads(json.dumps(doc))
    bad["rates"]["a"]["ops_s"] = []
    with pytest.raises(ValueError, match="length"):
        validate_timeline(bad)
    with pytest.raises(ValueError, match="missing tenant"):
        validate_timeline({**doc, "tenants": ["ghost"]})


# ---------------------------------------------------------------------------
# counter-block layout regressions
# ---------------------------------------------------------------------------

def test_snapshot_block_matches_dict_report():
    ctrs = tl.tenant_counters_init(2)
    ctrs = tl.tenant_counters_bump(ctrs, 0, ops=2, bytes=128)
    ctrs = tl.tenant_counters_bump(ctrs, 1, ops=1, throttled=1)
    a = CounterTimeline(source="blk")
    a.snapshot_block(0, ctrs, ("x", "y"), t=0.0)
    b = CounterTimeline(source="blk")
    b.snapshot(0, tl.tenant_counters_report(ctrs, ("x", "y")), t=0.0)
    assert a.samples == b.samples


def test_tenant_report_column_order_matches_counters_dict():
    """Regression: the per-tenant report and the flat counters_dict must
    agree column-for-column with COUNTER_NAMES — a reordered counter
    constant would silently scramble every timeline."""
    ctrs = np.arange(2 * tl.NUM_COUNTERS, dtype=np.float32).reshape(
        2, tl.NUM_COUNTERS)
    rep = tl.tenant_counters_report(ctrs, ("a", "b"))
    for i, tenant in enumerate(("a", "b")):
        assert list(rep[tenant]) == list(tl.COUNTER_NAMES)
        assert rep[tenant] == tl.counters_dict(ctrs[i])
    # and the bump row honours the same order
    row = np.asarray(tl.tenant_counters_bump(
        tl.tenant_counters_init(1), 0, ops=1, bytes=2, denied=3, chunks=4,
        throttled=5, stalls=6, credits=7, completions=8, retransmits=9,
        timeouts=10, srq_grants=11, cqe_errors=12, cq_shed=13,
        kernel_iters=14, kernel_copies=15, preemptions=16, restores=17))[0]
    assert tl.counters_dict(row) == {
        "ops": 1, "bytes": 2, "denied": 3, "chunks": 4, "throttled": 5,
        "stalls": 6, "credits": 7, "completions": 8, "cq_depth": 0,
        "retransmits": 9, "timeouts": 10, "srq_grants": 11,
        "cqe_errors": 12, "cq_shed": 13, "kernel_iters": 14,
        "kernel_copies": 15, "preemptions": 16, "restores": 17}


# ---------------------------------------------------------------------------
# off-toggle bit-identity on a traced train step
# ---------------------------------------------------------------------------

def _train(mesh8, *, accounting: bool, timeline: CounterTimeline | None,
           steps: int = 3):
    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    run = RunConfig(train=TrainConfig(steps=steps, learning_rate=1e-3),
                    obs=ObsConfig(timeline=timeline is not None))
    dp = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh8)
    step = make_explicit_dp_step(model, run, dp, axis="data",
                                 runtime_accounting=accounting)
    state = init_state(model, RNG)
    rt = dp.runtime_init() if accounting else None
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                global_batch=8))
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        if accounting:
            state, m, rt = step(state, b, rt)
            if timeline is not None:
                # host-side read strictly BETWEEN steps
                timeline.snapshot(i + 1, dp.runtime_report(rt))
        else:
            state, m = step(state, b)
        losses.append(float(m["loss"]))
    return state, losses, rt


def test_timeline_off_is_bit_identical_to_seed_step(mesh8):
    """The acceptance bar: with the obs toggle off the traced train step
    is the pre-obs program, and with it on, snapshots (device reads
    between steps) leave params/losses bit-identical — observability is
    provably free."""
    base_state, base_losses, _ = _train(mesh8, accounting=False,
                                        timeline=None)
    timeline = CounterTimeline(source="test")
    obs_state, obs_losses, rt = _train(mesh8, accounting=True,
                                       timeline=timeline)
    assert base_losses == obs_losses
    for a, b in zip(jax.tree.leaves(base_state.params),
                    jax.tree.leaves(obs_state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the timeline actually observed the gradient sync
    assert len(timeline.samples) == 3
    ops = timeline.rates()["default"]["ops_s"]
    assert len(ops) == 2 and all(v > 0 for v in ops)
    validate_timeline(timeline.to_doc())


# ---------------------------------------------------------------------------
# serve-engine per-tick feed
# ---------------------------------------------------------------------------

def test_engine_timeline_ticks_and_identity(tmp_path):
    """An attached timeline snapshots every decode tick (counter block +
    slot gauges) without perturbing outputs, and saves a valid artifact."""
    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    sc = ServeConfig(max_batch=2, max_new_tokens=5, kv_cache_len=64)

    def reqs():
        return [Request(rid=i, prompt=np.asarray((np.arange(8) + 3 * i) % 100,
                                                 np.int32),
                        max_new_tokens=5) for i in range(4)]

    timeline = CounterTimeline(source="test-serve")
    plain = Engine(model, params, cfg, sc, eos_id=-1)
    observed = Engine(model, params, cfg, sc, eos_id=-1, obs=timeline)
    out_p = {r.rid: r.out_tokens for r in plain.run(reqs())}
    out_o = {r.rid: r.out_tokens for r in observed.run(reqs())}
    assert out_p == out_o, "attaching obs must not change served tokens"
    assert timeline.samples, "no engine ticks captured"
    g = timeline.gauge_series()
    assert set(g) == {"active_slots", "queued"}
    assert max(g["active_slots"]) > 0
    doc = CounterTimeline.load(
        timeline.save(str(tmp_path / "serve_timeline.json")))
    # served tokens land in the bytes column of the final sample
    last = doc["samples"][-1]["tenants"]["default"]
    assert last["bytes"] == sum(len(o) for o in out_o.values())

    # ObsConfig.every strides the engine ticks: every=3 keeps every
    # third snapshot (same run → a third of the samples, same identity)
    strided = CounterTimeline(source="test-serve-every")
    eng3 = Engine(model, params, cfg, sc, eos_id=-1, obs=strided,
                  obs_every=3)
    out_3 = {r.rid: r.out_tokens for r in eng3.run(reqs())}
    assert out_3 == out_p
    assert len(strided.samples) == len(timeline.samples) // 3
