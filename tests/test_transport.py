"""The shared-CQ multi-QP transport (docs/transport.md): go-back-N
retransmission under injected wire loss/corruption (lossy transfers
complete bit-identically to lossless ones), retry exhaustion turning a
QP fatal, the connection table's shared CQ/SRQ with QoS-arbitrated post
order, per-QP and per-tenant fault counters, CQ-overrun shedding
visibility, and live migration of retransmission state through
quiesce → snapshot → restore."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import DataplaneConfig
from repro.core import Dataplane, compat, verbs
from repro.core.policies import QoSPolicy, TelemetryPolicy
from repro.runtime.fault import WireFault


def _dp(mesh, **kw):
    kw.setdefault("policies", [TelemetryPolicy()])
    return Dataplane(DataplaneConfig(mode="cord", emulate_costs=False),
                     mesh=mesh, **kw)


def _payload(n, msg_bytes, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, (n, msg_bytes), dtype=np.uint8)


def _stack(payload):
    """(2, ...) input: src rank holds the payload, dst rank zeros."""
    return jnp.asarray(np.stack([payload, np.zeros_like(payload)]))


# ---------------------------------------------------------------------------
# single-QP plane: windowed_send + WireFault
# ---------------------------------------------------------------------------

CFG = verbs.QPConfig(msg_bytes=64, depth=8, max_outstanding=4,
                     retry_limit=7, rto_ticks=4, backoff_ticks=1)


def _run_windowed(mesh, dp, cfg, msgs, *, fault=None, credits=None):
    n = int(msgs.shape[1])
    credits = n if credits is None else credits

    def body(m, rt):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        qp, rt = verbs.post_recv(dp, cfg, qp, rank, dst=1, n=credits,
                                 state=rt)
        out, qp, rt = verbs.windowed_send(dp, cfg, qp, m[0], rank, src=0,
                                          dst=1, state=rt, fault=fault)
        return out[None], qp, verbs.allreduce_state(rt)

    fn = jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(P("rank", None, None), P()),
        out_specs=(P("rank", None, None), verbs.qp_specs("rank"), P())))
    out, qp, rt = jax.block_until_ready(fn(msgs, dp.runtime_init()))
    return np.asarray(out)[1], qp, dp.runtime_report(rt)[dp.tenant]


def test_windowed_lossless_equals_rtx_machine(mesh2):
    """A fault whose schedule never fires still compiles the full
    retransmission loop — its output must match the plain path exactly."""
    dp = _dp(mesh2)
    payload = _payload(6, CFG.msg_bytes, seed=1)
    msgs = _stack(payload)
    plain, _, _ = _run_windowed(mesh2, dp, CFG, msgs)
    armed = WireFault(drops=((99, 99),))
    assert armed.active
    out, qp, rep = _run_windowed(mesh2, dp, CFG, msgs, fault=armed)
    np.testing.assert_array_equal(out, plain)
    np.testing.assert_array_equal(out, payload)
    assert rep["retransmits"] == 0 and rep["timeouts"] == 0
    assert int(qp["retry_cnt"]) == 0


@pytest.mark.parametrize("fault, kind", [
    (WireFault(drops=((2, 0),)), "drop_mid"),      # gap-detected rewind
    (WireFault(drops=((5, 0),)), "drop_last"),     # RTO-detected rewind
    (WireFault(corrupts=((1, 0),)), "corrupt"),    # NAK (CQE_ERR_RETRY)
    (WireFault(drop_rate=0.2, corrupt_rate=0.2, seed=3), "rates"),
])
def test_windowed_lossy_completes_bit_identical(mesh2, fault, kind):
    dp = _dp(mesh2)
    payload = _payload(6, CFG.msg_bytes, seed=2)
    out, qp, rep = _run_windowed(mesh2, dp, CFG, _stack(payload),
                                 fault=fault)
    np.testing.assert_array_equal(out, payload)
    # something was actually injected and recovered from
    assert rep["retransmits"] > 0, rep
    if kind == "drop_last":
        assert rep["timeouts"] > 0, rep       # no later CQE to show the gap
    if kind == "corrupt":
        assert rep["cqe_errors"] > 0, rep     # the NAK CQE was drained
    # recovery is complete: the in-order ack reset the retry counter
    assert int(qp["retry_cnt"]) == 0


def test_windowed_retry_exhaustion_turns_fatal(mesh2):
    """100% loss: the QP retries retry_limit times, turns fatal instead
    of hanging (fuel-bounded), and undelivered slots stay zero."""
    cfg = verbs.QPConfig(msg_bytes=64, depth=8, max_outstanding=4,
                         retry_limit=2, rto_ticks=3, backoff_ticks=1)
    dp = _dp(mesh2)
    payload = _payload(4, cfg.msg_bytes, seed=3)
    out, qp, rep = _run_windowed(mesh2, dp, cfg, _stack(payload),
                                 fault=WireFault(drop_rate=1.0))
    assert int(qp["retry_cnt"]) > cfg.retry_limit
    np.testing.assert_array_equal(out, np.zeros_like(payload))
    assert rep["timeouts"] >= cfg.retry_limit + 1, rep


def test_windowed_retransmits_pay_mediation_cost(mesh2):
    """Every retry is a real re-post: ops/bytes accounting grows by
    exactly the retransmitted work relative to a lossless run."""
    dp = _dp(mesh2)
    payload = _payload(6, CFG.msg_bytes, seed=4)
    _, _, rep0 = _run_windowed(mesh2, dp, CFG, _stack(payload))
    fault = WireFault(drops=((2, 0),))
    _, _, rep1 = _run_windowed(mesh2, dp, CFG, _stack(payload), fault=fault)
    extra = rep1["ops"] - rep0["ops"]
    assert extra == rep1["retransmits"] > 0
    assert rep1["bytes"] - rep0["bytes"] == extra * CFG.msg_bytes


def test_cq_shed_lands_in_telemetry(mesh2):
    """Satellite: CQEs shed on ring overrun are counted, not silently
    dropped — both on the QP and in the tenant counter block."""
    cfg = verbs.QPConfig(msg_bytes=16, depth=8, cq_depth=2)
    dp = _dp(mesh2)
    payload = _payload(6, cfg.msg_bytes, seed=5)
    msgs = _stack(payload)

    def body(m, rt):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        for i in range(6):
            qp, rt = verbs.post_send(dp, cfg, qp, m[0, i], rank, src=0,
                                     state=rt)
        qp, rt = verbs.flush_send(dp, cfg, qp, rank, src=0, dst=1, state=rt)
        return qp, verbs.allreduce_state(rt)

    fn = jax.jit(compat.shard_map(
        body, mesh=mesh2, in_specs=(P("rank", None, None), P()),
        out_specs=(verbs.qp_specs("rank"), P())))
    qp, rt = jax.block_until_ready(fn(msgs, dp.runtime_init()))
    rep = dp.runtime_report(rt)[dp.tenant]
    assert int(qp["cq_shed"]) == 4          # 6 CQEs into a 2-slot ring
    assert rep["cq_shed"] == 4.0, rep


# ---------------------------------------------------------------------------
# connection table: shared CQ + SRQ + QoS arbitration
# ---------------------------------------------------------------------------

CCFG = verbs.QPConfig(msg_bytes=32, depth=8, max_outstanding=3,
                      retry_limit=7, rto_ticks=4, backoff_ticks=1)


def _conn_payload(Q, n, msg_bytes, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, (Q, n, msg_bytes), dtype=np.uint8)


def _run_conn(mesh, dp, cfg, msgs, *, tenants=None, fault=None,
              credits=None):
    Q, n = int(msgs.shape[1]), int(msgs.shape[2])
    credits = Q * n if credits is None else credits

    def body(m, rt):
        rank = jax.lax.axis_index("rank")
        conn = verbs.conn_init(cfg, Q)
        conn, rt = verbs.srq_post(dp, cfg, conn, rank, dst=1, n=credits,
                                  state=rt)
        out, conn, rt = verbs.conn_send(dp, cfg, conn, m[0], rank, src=0,
                                        dst=1, state=rt, tenants=tenants,
                                        fault=fault)
        return out[None], conn, verbs.allreduce_state(rt)

    fn = jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(P("rank", None, None, None), P()),
        out_specs=(P("rank", None, None, None), verbs.conn_specs(), P())))
    out, conn, rt = jax.block_until_ready(fn(msgs, dp.runtime_init()))
    return np.asarray(out)[1], conn, dp.runtime_report(rt)


def test_conn_send_lossless_all_qps_deliver(mesh2):
    Q, n = 3, 4
    dp = _dp(mesh2)
    payload = _conn_payload(Q, n, CCFG.msg_bytes, seed=6)
    out, conn, rep = _run_conn(mesh2, dp, CCFG, _stack(payload))
    np.testing.assert_array_equal(out, payload)
    # every delivery was granted an SRQ buffer, attributed per QP
    np.testing.assert_array_equal(np.asarray(conn["srq_grants"]),
                                  np.full(Q, n))
    r = rep[dp.tenant]
    assert r["srq_grants"] == Q * n
    # Q*n posts + the single mediated srq_post syscall
    assert r["ops"] == Q * n + 1 and r["completions"] == Q * n
    assert int(conn["cq_hwm"]) > 0          # CQEs really share one ring


def test_conn_send_requires_rc_and_matching_shapes(mesh2):
    ud = verbs.QPConfig(transport="UD", msg_bytes=32)
    conn = verbs.conn_init(CCFG, 2)
    msgs = jnp.zeros((2, 1, 32), jnp.uint8)
    with pytest.raises(verbs.TransportError):
        verbs.conn_send(_dp(mesh2), ud, conn, msgs, jnp.int32(0), 0, 1)
    with pytest.raises(verbs.TransportError):
        verbs.conn_send(_dp(mesh2), CCFG, conn,
                        jnp.zeros((3, 1, 32), jnp.uint8), jnp.int32(0), 0, 1)
    with pytest.raises(verbs.TransportError):
        verbs.conn_init(CCFG, 0)


@pytest.mark.parametrize("fault", [
    # QP 1's second message dropped (wr identity = qp * n + msg)
    WireFault(drops=((1 * 4 + 1, 0),)),
    # QP 2's first message corrupted, twice in a row
    WireFault(corrupts=((2 * 4 + 0, 0), (2 * 4 + 0, 1))),
    # background loss across every QP
    WireFault(drop_rate=0.15, corrupt_rate=0.15, seed=7),
])
def test_conn_send_lossy_bit_identical(mesh2, fault):
    Q, n = 3, 4
    dp = _dp(mesh2)
    payload = _conn_payload(Q, n, CCFG.msg_bytes, seed=7)
    out, conn, rep = _run_conn(mesh2, dp, CCFG, _stack(payload),
                               fault=fault)
    np.testing.assert_array_equal(out, payload)
    retrans = np.asarray(conn["retransmits"])
    assert retrans.sum() > 0
    assert rep[dp.tenant]["retransmits"] == retrans.sum()
    # full recovery on every QP
    np.testing.assert_array_equal(np.asarray(conn["retry_cnt"]),
                                  np.zeros(Q, np.int32))


def test_conn_send_scheduled_fault_hits_only_its_qp(mesh2):
    """A rewind is per-QP: the shared CQ is epoch-filtered, never flushed
    under the other connections."""
    Q, n = 3, 4
    dp = _dp(mesh2)
    payload = _conn_payload(Q, n, CCFG.msg_bytes, seed=8)
    fault = WireFault(drops=((1 * 4 + 1, 0),))
    out, conn, _ = _run_conn(mesh2, dp, CCFG, _stack(payload), fault=fault)
    np.testing.assert_array_equal(out, payload)
    retrans = np.asarray(conn["retransmits"])
    assert retrans[1] > 0
    assert retrans[0] == 0 and retrans[2] == 0
    # only the rewound QP changed epoch
    epochs = np.asarray(conn["epoch"])
    assert epochs[1] > 0 and epochs[0] == 0 and epochs[2] == 0


def test_conn_send_fatal_qp_isolated(mesh2):
    """One QP losing every transmission exhausts its retries and turns
    fatal; the others complete bit-identically around it."""
    Q, n = 3, 2
    cfg = verbs.QPConfig(msg_bytes=32, depth=8, max_outstanding=3,
                         retry_limit=2, rto_ticks=3, backoff_ticks=1)
    dp = _dp(mesh2)
    payload = _conn_payload(Q, n, cfg.msg_bytes, seed=9)
    # drop every attempt of QP 1's messages
    drops = tuple((1 * n + m, a) for m in range(n)
                  for a in range(cfg.retry_limit + 2))
    out, conn, _ = _run_conn(mesh2, dp, cfg, _stack(payload),
                             fault=WireFault(drops=drops))
    retry = np.asarray(conn["retry_cnt"])
    assert retry[1] > cfg.retry_limit
    np.testing.assert_array_equal(out[1], np.zeros_like(payload[1]))
    np.testing.assert_array_equal(out[0], payload[0])
    np.testing.assert_array_equal(out[2], payload[2])
    assert retry[0] == 0 and retry[2] == 0


def test_conn_qos_arbitration_charges_and_throttles(mesh2):
    """The mediation token buckets arbitrate post order: a rate-limited
    tenant's QPs still deliver bit-identically, but its bucket records
    the deficit while the ungoverned tenant's does not."""
    Q, n = 4, 3
    payload = _conn_payload(Q, n, CCFG.msg_bytes, seed=10)
    tenants = ("a", "b", "a", "b")
    dp = Dataplane(
        DataplaneConfig(mode="cord", emulate_costs=False), mesh=mesh2,
        tenant="a", tenants=("a", "b"),
        policies=[TelemetryPolicy(),
                  QoSPolicy(rates={"b": 0.25}, burst=1.0)])
    out, conn, rep = _run_conn(mesh2, dp, CCFG, _stack(payload),
                               tenants=tenants)
    np.testing.assert_array_equal(out, payload)
    # the srq_post syscall is billed to the default tenant ("a")
    assert rep["a"]["ops"] == 2 * n + 1 and rep["b"]["ops"] == 2 * n
    assert rep["b"]["throttled"] > 0
    assert rep["a"]["throttled"] == 0
    assert rep["a"]["srq_grants"] == 2 * n
    assert rep["b"]["srq_grants"] == 2 * n


def test_srq_starvation_stalls_then_recovers(mesh2):
    """Under-granted SRQ: the table stalls, the receiver re-posts its
    consumed buffers, and delivery still completes bit-identically."""
    Q, n = 2, 4
    dp = _dp(mesh2)
    payload = _conn_payload(Q, n, CCFG.msg_bytes, seed=11)
    out, conn, rep = _run_conn(mesh2, dp, CCFG, _stack(payload), credits=2)
    np.testing.assert_array_equal(out, payload)
    assert rep[dp.tenant]["stalls"] > 0
    assert int(conn["srq_owed"]) + int(conn["srq_credits"]) >= 0


# ---------------------------------------------------------------------------
# migration: quiesce / snapshot / restore with retry state in flight
# ---------------------------------------------------------------------------

def _conn_parts(mesh, dp, cfg, Q, *, tenants=None, fault=None, credits=0):
    """Jitted init/grant/xfer/quiesce pieces of a migratable table."""
    cspec = verbs.conn_specs()

    def init_body(rt):
        rank = jax.lax.axis_index("rank")
        conn = verbs.conn_init(cfg, Q)
        if credits:
            conn, rt = verbs.srq_post(dp, cfg, conn, rank, dst=1,
                                      n=credits, state=rt)
        return conn, verbs.allreduce_state(rt)

    def xfer_body(m, conn, rt):
        rank = jax.lax.axis_index("rank")
        out, conn, rt = verbs.conn_send(dp, cfg, conn, m[0], rank, src=0,
                                        dst=1, state=rt, tenants=tenants,
                                        fault=fault)
        return out[None], conn, verbs.allreduce_state(rt)

    def quiesce_body(conn, rt):
        rank = jax.lax.axis_index("rank")
        conn, rt = verbs.conn_quiesce(dp, cfg, conn, rank, src=0, state=rt,
                                      tenants=tenants)
        return conn, verbs.allreduce_state(rt)

    return {
        "init": jax.jit(compat.shard_map(
            init_body, mesh=mesh, in_specs=(P(),),
            out_specs=(cspec, P()))),
        "xfer": jax.jit(compat.shard_map(
            xfer_body, mesh=mesh,
            in_specs=(P("rank", None, None, None), cspec, P()),
            out_specs=(P("rank", None, None, None), cspec, P()))),
        "quiesce": jax.jit(compat.shard_map(
            quiesce_body, mesh=mesh, in_specs=(cspec, P()),
            out_specs=(cspec, P()))),
    }


def test_conn_migration_under_loss_bit_identical(mesh2):
    """The acceptance flow: half the transfer under injected loss on mesh
    A, quiesce → stop-and-copy → restore onto a different mesh, the rest
    there — the combined delivery matches an uninterrupted lossless run
    and the table's fault counters ride along."""
    Q, n, k = 3, 4, 2
    mesh_b = compat.make_mesh((2,), ("rank",), devices=jax.devices()[2:4])
    fault = WireFault(drop_rate=0.2, corrupt_rate=0.1, seed=12)
    payload = _conn_payload(Q, n, CCFG.msg_bytes, seed=12)
    msgs = _stack(payload)

    dp_a, dp_b = _dp(mesh2), _dp(mesh_b)
    pa = _conn_parts(mesh2, dp_a, CCFG, Q, fault=fault, credits=Q * n * 2)
    pb = _conn_parts(mesh_b, dp_b, CCFG, Q, fault=fault)

    # lossless baseline, uninterrupted
    base, _, _ = _run_conn(mesh2, dp_a, CCFG, msgs)

    conn, _ = pa["init"](dp_a.runtime_init())
    out1, conn, _ = pa["xfer"](msgs[:, :, :k], conn, dp_a.runtime_init())
    conn, _ = pa["quiesce"](conn, dp_a.runtime_init())
    snap = verbs.conn_snapshot(conn)
    assert int(snap["cq_head"] - snap["cq_tail"]) == 0, "CQ not quiesced"
    # every QP's window is closed; nothing silently in flight
    np.testing.assert_array_equal(snap["sq_head"], snap["cq_sent"])
    retrans_a = snap["retransmits"].copy()

    conn_b = verbs.conn_restore(snap, mesh_b)
    out2, conn_b, _ = jax.block_until_ready(
        pb["xfer"](msgs[:, :, k:], conn_b, dp_b.runtime_init()))
    moved = np.concatenate([np.asarray(out1)[1], np.asarray(out2)[1]],
                           axis=1)
    np.testing.assert_array_equal(moved, np.asarray(base))
    # migrated counters only ever grow — the snapshot carried them
    snap_b = verbs.conn_snapshot(conn_b)
    assert (snap_b["retransmits"] >= retrans_a).all()
    assert (snap_b["srq_grants"] == 2 * k * np.ones(Q)).all() \
        or (snap_b["srq_grants"] >= k).all()


def test_conn_quiesce_routes_error_cqes_and_inflight(mesh2):
    """Satellite: quiesce with the shared CQ holding an error CQE, a
    stale-epoch CQE, and a QP with silently-dropped WRs in flight — each
    routes to the right QP's rtx_pending, stale entries are discarded,
    and retry/backoff state survives the snapshot bit-identically."""
    Q = 3
    dp = _dp(mesh2)
    parts = _conn_parts(mesh2, dp, CCFG, Q)
    conn, _ = parts["init"](dp.runtime_init())
    snap = {k: np.array(v) for k, v in verbs.conn_snapshot(conn).items()}

    # hand-build mid-retry state: QP1 took a NAK (error CQE in the ring,
    # retry counter live), QP0 rewound earlier (a stale-epoch CQE is
    # still queued), QP2 has two WRs in flight that never completed
    snap["epoch"][0] = 2
    snap["cq_status"][0] = verbs.CQE_ERR_RETRY
    snap["cq_wrid"][0] = snap["cq_sent"][1]
    snap["cq_qp"][0] = 1
    snap["cq_epoch"][0] = snap["epoch"][1]
    snap["cq_status"][1] = verbs.CQE_SEND
    snap["cq_wrid"][1] = 5
    snap["cq_qp"][1] = 0
    snap["cq_epoch"][1] = 1                      # != epoch[0] == 2: stale
    snap["cq_head"] = np.int32(2)
    snap["sq_head"][2] = snap["cq_sent"][2] + 2  # dropped in flight
    snap["retry_cnt"][1] = 3
    snap["backoff"][1] = 1

    conn = verbs.conn_restore(snap, mesh2)
    conn, rt = parts["quiesce"](conn, dp.runtime_init())
    q = {k: np.array(v) for k, v in verbs.conn_snapshot(conn).items()}

    assert int(q["cq_head"] - q["cq_tail"]) == 0
    # error CQE → QP1; stale CQE discarded (QP0 untouched); dropped → QP2
    np.testing.assert_array_equal(q["rtx_pending"], [0, 1, 2])
    np.testing.assert_array_equal(q["sq_head"], q["cq_sent"])
    # in-flight retry state is preserved for the resuming side
    assert q["retry_cnt"][1] == 3 and q["backoff"][1] == 1
    assert q["epoch"][0] == 2
    rep = dp.runtime_report(rt)[dp.tenant]
    assert rep["cqe_errors"] == 1.0
    assert rep["completions"] == 2.0             # both CQEs were drained


def test_windowed_migration_under_loss_bit_identical(mesh2):
    """Single-QP plane: a lossy windowed transfer split by quiesce →
    snapshot → restore onto another mesh completes bit-identically, with
    retransmission counters carried across the move."""
    from benchmarks import perftest

    n, k, msg_bytes, window = 8, 4, 64, 4
    mesh_b = compat.make_mesh((2,), ("rank",), devices=jax.devices()[4:6])
    payload = _payload(n, msg_bytes, seed=13)
    msgs = _stack(payload)
    fault = WireFault(drop_rate=0.2, seed=13)
    cfg = verbs.QPConfig(msg_bytes=msg_bytes, depth=max(window, 2),
                         max_outstanding=window)
    dp_a, dp_b = _dp(mesh2), _dp(mesh_b)
    qspec = verbs.qp_specs("rank")

    def mk(mesh, dp, credits):
        def init_body(rt):
            rank = jax.lax.axis_index("rank")
            qp = verbs.qp_init(cfg)
            if credits:
                qp, rt = verbs.post_recv(dp, cfg, qp, rank, dst=1,
                                         n=credits, state=rt)
            return qp, verbs.allreduce_state(rt)

        def xfer_body(m, qp, rt):
            rank = jax.lax.axis_index("rank")
            out, qp, rt = verbs.windowed_send(dp, cfg, qp, m[0], rank,
                                              src=0, dst=1, state=rt,
                                              fault=fault)
            return out[None], qp, verbs.allreduce_state(rt)

        def quiesce_body(qp, rt):
            rank = jax.lax.axis_index("rank")
            qp, rt = verbs.qp_quiesce(dp, cfg, qp, rank, src=0, state=rt)
            return qp, verbs.allreduce_state(rt)

        return {
            "init": jax.jit(compat.shard_map(
                init_body, mesh=mesh, in_specs=(P(),),
                out_specs=(qspec, P()))),
            "xfer": jax.jit(compat.shard_map(
                xfer_body, mesh=mesh,
                in_specs=(P("rank", None, None), qspec, P()),
                out_specs=(P("rank", None, None), qspec, P()))),
            "quiesce": jax.jit(compat.shard_map(
                quiesce_body, mesh=mesh, in_specs=(qspec, P()),
                out_specs=(qspec, P()))),
        }

    pa, pb = mk(mesh2, dp_a, n * 4), mk(mesh_b, dp_b, 0)
    qp, _ = pa["init"](dp_a.runtime_init())
    out1, qp, _ = pa["xfer"](msgs[:, :k], qp, dp_a.runtime_init())
    qp, _ = pa["quiesce"](qp, dp_a.runtime_init())
    snap = verbs.qp_snapshot(qp)
    assert int(snap["cq_head"] - snap["cq_tail"]) == 0
    assert int(snap["sq_head"]) == int(snap["cq_sent"])
    qp_b = verbs.qp_restore(snap, mesh_b)
    out2, qp_b, _ = jax.block_until_ready(
        pb["xfer"](msgs[:, k:], qp_b, dp_b.runtime_init()))
    moved = np.concatenate([np.asarray(out1)[1], np.asarray(out2)[1]])
    np.testing.assert_array_equal(moved, payload)
    assert int(verbs.qp_snapshot(qp_b)["retry_cnt"]) == 0


def test_conn_restore_rejects_non_table_snapshot(mesh2):
    conn = verbs.conn_init(CCFG, 2)
    snap = verbs.conn_snapshot(conn)
    del snap["cq_qp"]
    with pytest.raises(verbs.TransportError):
        verbs.conn_restore(snap, mesh2)


def test_conn_churn_round_under_loss(mesh2):
    """Mini churn (the full ≥100-QP sweep is benchmarks/perftest.py):
    tables created, driven under loss, quiesced and torn down in rounds
    stay bit-identical throughout and reuse the same compiled shapes."""
    Q, n = 4, 2
    dp = _dp(mesh2)
    fault = WireFault(drop_rate=0.2, seed=21)
    for rnd in range(3):
        payload = _conn_payload(Q, n, CCFG.msg_bytes, seed=30 + rnd)
        out, conn, _ = _run_conn(mesh2, dp, CCFG, _stack(payload),
                                 fault=fault)
        np.testing.assert_array_equal(out, payload)
        np.testing.assert_array_equal(np.asarray(conn["retry_cnt"]),
                                      np.zeros(Q, np.int32))


# ---------------------------------------------------------------------------
# adaptive RTO (EWMA drain latency, clamped to the static ceiling)
# ---------------------------------------------------------------------------

def test_adaptive_rto_static_fallback_and_clamp():
    cfg = verbs.QPConfig(rto_ticks=8)
    assert cfg.adaptive_rto                          # default on
    # no samples yet → static value unchanged
    assert int(verbs.adaptive_rto(jnp.float32(0.0), jnp.int32(0), cfg)) == 8
    # fast drains tighten the timer (2*ceil(srtt)+1), floored at 2 ticks
    assert int(verbs.adaptive_rto(jnp.float32(1.0), jnp.int32(2), cfg)) == 3
    assert int(verbs.adaptive_rto(jnp.float32(0.0), jnp.int32(1), cfg)) == 2
    # slow drains never exceed the static ceiling — retry fuel bounds hold
    assert int(verbs.adaptive_rto(jnp.float32(100.0), jnp.int32(5), cfg)) == 8
    # per-QP (Q,) estimates vectorise elementwise
    out = verbs.adaptive_rto(jnp.asarray([0.5, 50.0, 1.5]),
                             jnp.asarray([2, 3, 0]), cfg)
    assert out.tolist() == [3, 8, 8]


def test_adaptive_rto_off_matches_legacy_static_loop(mesh2):
    """adaptive_rto=False keeps the static re-arm; both settings complete
    a lossy transfer bit-identically (the timer only changes *when* a
    silent loss is declared, never the recovered payload)."""
    dp = _dp(mesh2)
    payload = _payload(6, CFG.msg_bytes, seed=11)
    fault = WireFault(drop_rate=0.3, seed=7)
    outs = {}
    for flag in (True, False):
        cfg = verbs.QPConfig(msg_bytes=64, depth=8, max_outstanding=4,
                             retry_limit=7, rto_ticks=4, backoff_ticks=1,
                             adaptive_rto=flag)
        out, _, _ = _run_windowed(mesh2, dp, cfg, _stack(payload),
                                  fault=fault)
        np.testing.assert_array_equal(out, payload)
        outs[flag] = out
    np.testing.assert_array_equal(outs[True], outs[False])
