"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import ModelConfig, TrainConfig, apply_overrides
from repro.core.chunking import bucket_pytree
from repro.core.mediation import MediationPipeline, MediationStage
from repro.core.telemetry import OpRecord, Telemetry, counters_bump, counters_init
from repro.layers.attention import make_mask
from repro.train.gradsync import dequantize_int8, quantize_int8

SETTINGS = settings(max_examples=25, deadline=None)


@SETTINGS
@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                min_size=1, max_size=200))
def test_int8_quantization_error_bounded(xs):
    x = jnp.asarray(xs, jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-5


@SETTINGS
@given(st.integers(1, 64), st.integers(1, 64),
       st.integers(0, 32), st.booleans())
def test_mask_invariants(sq, sk, window, causal):
    qp = jnp.arange(sq)
    kp = jnp.arange(sk)
    m = np.asarray(make_mask(qp, kp, causal=causal, window=window))
    assert m.shape == (sq, sk)
    if causal:
        for i in range(min(sq, sk)):
            assert not m[i, i + 1:].any(), "future leak"
    if window > 0 and causal:
        mw = np.asarray(make_mask(qp, kp, causal=True, window=0))
        assert (m <= mw).all(), "window mask must be subset of causal"
    # every causal row with a visible position attends somewhere
    if causal and window == 0 and sk >= 1:
        assert m[0, 0]


@SETTINGS
@given(st.lists(st.integers(1, 2000), min_size=1, max_size=12),
       st.integers(64, 4096))
def test_bucket_pytree_is_partition(sizes, bucket_bytes):
    tree = {f"l{i}": jnp.zeros((n,), jnp.float32)
            for i, n in enumerate(sizes)}
    buckets = bucket_pytree(tree, bucket_bytes)
    flat = [path for b in buckets for path, _ in b]
    assert len(flat) == len(sizes)          # every leaf exactly once
    assert len(set(str(p) for p in flat)) == len(sizes)
    for b in buckets[:-1]:
        if len(b) > 1:
            total = sum(leaf.size * 4 for _, leaf in b)
            assert total <= bucket_bytes * 2  # bounded (greedy fill)


@SETTINGS
@given(st.lists(st.sampled_from("abcdef"), max_size=8))
def test_mediation_pipeline_composes_in_declared_order(names):
    """The pipeline applies stages exactly in declared order, on both the
    send and the completion side, for any stage multiset."""
    log = []

    class Probe(MediationStage):
        def __init__(self, n):
            self.name = n

        def send(self, x, rec, state, tenant_idx):
            log.append(("send", self.name))
            return x, state

        def complete(self, x, rec, state, tenant_idx):
            log.append(("complete", self.name))
            return x, state

    pipe = MediationPipeline([Probe(n) for n in names])
    assert pipe.stage_names == tuple(names)
    rec = OpRecord(kind="p", tag="p", bytes=1, axes=("data",))
    x, state = pipe.send(jnp.ones(()), rec, None, 0)
    assert log == [("send", n) for n in names] and state is None
    log.clear()
    pipe.complete(x, rec, None, 0)
    assert log == [("complete", n) for n in names]


@SETTINGS
@given(st.integers(1, 100), st.integers(0, 10**6))
def test_telemetry_counters_additive(ops, nbytes):
    c = counters_init()
    for _ in range(3):
        c = counters_bump(c, ops=ops, bytes=nbytes)
    assert float(c[0]) == 3 * ops
    assert float(c[1]) == 3 * nbytes


@SETTINGS
@given(st.integers(1, 10**5))
def test_telemetry_bytes_accounting(n):
    t = Telemetry()
    t.record(OpRecord(kind="all_reduce", tag="x", bytes=n, axes=("data",)))
    t.record(OpRecord(kind="all_gather", tag="x", bytes=n, axes=("data",),
                      count=2))
    assert t.total_bytes() == n * 3
    assert t.by_kind()["all_gather"]["ops"] == 2


@SETTINGS
@given(st.integers(1, 512), st.integers(1, 64), st.floats(1e-5, 1.0))
def test_config_override_roundtrip(d_model, layers, lr):
    cfg = ModelConfig()
    cfg = apply_overrides(cfg, [f"d_model={d_model}",
                                f"num_layers={layers}"])
    assert cfg.d_model == d_model and cfg.num_layers == layers
    t = apply_overrides(TrainConfig(), [f"learning_rate={lr}"])
    assert abs(t.learning_rate - lr) < 1e-9


@SETTINGS
@given(st.integers(2, 8), st.integers(1, 8), st.integers(16, 128))
def test_param_spec_always_divides(model_ways, data_ways, dim):
    from repro.parallel.sharding import spec_for_param
    sizes = {"model": model_ways, "data": data_ways}
    spec = spec_for_param("layers/mlp/wi", 2, (dim, dim * 2),
                          fsdp=True, mesh_sizes=sizes)
    shape = (dim, dim * 2)
    for i, ax in enumerate(tuple(spec)):
        if ax is None:
            continue
        ways = sizes.get(ax, 1) if isinstance(ax, str) else \
            int(np.prod([sizes.get(a, 1) for a in ax]))
        assert shape[i] % ways == 0
