"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import ModelConfig, TrainConfig, apply_overrides
from repro.core.chunking import bucket_pytree, split_chunks
from repro.core.mediation import MediationPipeline, MediationStage
from repro.core.telemetry import OpRecord, Telemetry, counters_bump, counters_init
from repro.layers.attention import make_mask
from repro.layers.kvcache import BlockAllocator
from repro.train.gradsync import dequantize_int8, quantize_int8

SETTINGS = settings(max_examples=25, deadline=None)


@SETTINGS
@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                min_size=1, max_size=200))
def test_int8_quantization_error_bounded(xs):
    x = jnp.asarray(xs, jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-5


@SETTINGS
@given(st.integers(1, 64), st.integers(1, 64),
       st.integers(0, 32), st.booleans())
def test_mask_invariants(sq, sk, window, causal):
    qp = jnp.arange(sq)
    kp = jnp.arange(sk)
    m = np.asarray(make_mask(qp, kp, causal=causal, window=window))
    assert m.shape == (sq, sk)
    if causal:
        for i in range(min(sq, sk)):
            assert not m[i, i + 1:].any(), "future leak"
    if window > 0 and causal:
        mw = np.asarray(make_mask(qp, kp, causal=True, window=0))
        assert (m <= mw).all(), "window mask must be subset of causal"
    # every causal row with a visible position attends somewhere
    if causal and window == 0 and sk >= 1:
        assert m[0, 0]


@SETTINGS
@given(st.lists(st.integers(1, 2000), min_size=1, max_size=12),
       st.integers(64, 4096))
def test_bucket_pytree_is_partition(sizes, bucket_bytes):
    tree = {f"l{i}": jnp.zeros((n,), jnp.float32)
            for i, n in enumerate(sizes)}
    buckets = bucket_pytree(tree, bucket_bytes)
    flat = [path for b in buckets for path, _ in b]
    assert len(flat) == len(sizes)          # every leaf exactly once
    assert len(set(str(p) for p in flat)) == len(sizes)
    for b in buckets[:-1]:
        if len(b) > 1:
            total = sum(leaf.size * 4 for _, leaf in b)
            assert total <= bucket_bytes * 2  # bounded (greedy fill)


@SETTINGS
@given(st.lists(st.sampled_from("abcdef"), max_size=8))
def test_mediation_pipeline_composes_in_declared_order(names):
    """The pipeline applies stages exactly in declared order, on both the
    send and the completion side, for any stage multiset."""
    log = []

    class Probe(MediationStage):
        def __init__(self, n):
            self.name = n

        def send(self, x, rec, state, tenant_idx):
            log.append(("send", self.name))
            return x, state

        def complete(self, x, rec, state, tenant_idx):
            log.append(("complete", self.name))
            return x, state

    pipe = MediationPipeline([Probe(n) for n in names])
    assert pipe.stage_names == tuple(names)
    rec = OpRecord(kind="p", tag="p", bytes=1, axes=("data",))
    x, state = pipe.send(jnp.ones(()), rec, None, 0)
    assert log == [("send", n) for n in names] and state is None
    log.clear()
    pipe.complete(x, rec, None, 0)
    assert log == [("complete", n) for n in names]


@SETTINGS
@given(st.integers(1, 100), st.integers(0, 10**6))
def test_telemetry_counters_additive(ops, nbytes):
    c = counters_init()
    for _ in range(3):
        c = counters_bump(c, ops=ops, bytes=nbytes)
    assert float(c[0]) == 3 * ops
    assert float(c[1]) == 3 * nbytes


@SETTINGS
@given(st.integers(1, 10**5))
def test_telemetry_bytes_accounting(n):
    t = Telemetry()
    t.record(OpRecord(kind="all_reduce", tag="x", bytes=n, axes=("data",)))
    t.record(OpRecord(kind="all_gather", tag="x", bytes=n, axes=("data",),
                      count=2))
    assert t.total_bytes() == n * 3
    assert t.by_kind()["all_gather"]["ops"] == 2


@SETTINGS
@given(st.integers(1, 512), st.integers(1, 64), st.floats(1e-5, 1.0))
def test_config_override_roundtrip(d_model, layers, lr):
    cfg = ModelConfig()
    cfg = apply_overrides(cfg, [f"d_model={d_model}",
                                f"num_layers={layers}"])
    assert cfg.d_model == d_model and cfg.num_layers == layers
    t = apply_overrides(TrainConfig(), [f"learning_rate={lr}"])
    assert abs(t.learning_rate - lr) < 1e-9


@SETTINGS
@given(st.integers(1, 24),
       st.lists(st.tuples(st.sampled_from("af"), st.integers(0, 9)),
                max_size=40))
def test_block_allocator_claim_free_invariants(n_blocks, ops):
    """Any alloc/free interleaving preserves the pool invariants: alloc
    is all-or-nothing (None leaves the free list untouched), handed-out
    ids are unique, in 1..n_blocks and never 0 (the null block), ids are
    never handed out twice while held, and free + held == n_blocks at
    every step."""
    a = BlockAllocator(n_blocks)
    held: set[int] = set()
    for kind, k in ops:
        if kind == "a":
            before = a.free_blocks
            ids = a.alloc(k)
            if k > before:
                assert ids is None and a.free_blocks == before
            else:
                assert len(ids) == k == len(set(ids))
                assert all(1 <= i <= n_blocks for i in ids)
                assert not held & set(ids)        # never handed out twice
                held |= set(ids)
        else:
            take = sorted(held)[:min(k, len(held))]
            a.free(take)
            held -= set(take)
        assert a.free_blocks + len(held) == n_blocks
    if held:                                       # double free always raises
        with pytest.raises(ValueError, match="double free"):
            a.free([next(iter(held))] * 2)


@SETTINGS
@given(st.integers(1, 97), st.integers(1, 16), st.integers(0, 1),
       st.integers(1, 5))
def test_split_chunks_pad_restore_roundtrip(n, num_chunks, axis, other_dim):
    """split_chunks partitions any extent into equal chunks: the clamp
    keeps 1 <= k <= n, every chunk has the same extent, concatenating and
    slicing back restores the input bitwise, and the tail pad is exactly
    zeros (chunk-granular QoS scheduling relies on all three)."""
    shape = [n, other_dim] if axis == 0 else [other_dim, n]
    x = (jnp.arange(np.prod(shape), dtype=jnp.float32) + 1.0).reshape(shape)
    chunks = split_chunks(x, num_chunks, axis=axis)
    k = max(1, min(num_chunks, n))
    assert len(chunks) == k
    per = chunks[0].shape[axis]
    assert all(c.shape[axis] == per for c in chunks)
    assert per * k >= n                  # covers the extent
    assert per * k - n < k               # minimal padding
    cat = jnp.concatenate(chunks, axis=axis)
    restored = jax.lax.slice_in_dim(cat, 0, n, axis=axis)
    np.testing.assert_array_equal(np.asarray(restored), np.asarray(x))
    pad = np.asarray(jax.lax.slice_in_dim(cat, n, cat.shape[axis], axis=axis))
    assert pad.size == 0 or np.abs(pad).max() == 0.0  # zero tail pad


@SETTINGS
@given(st.integers(2, 8), st.integers(1, 8), st.integers(16, 128))
def test_param_spec_always_divides(model_ways, data_ways, dim):
    from repro.parallel.sharding import spec_for_param
    sizes = {"model": model_ways, "data": data_ways}
    spec = spec_for_param("layers/mlp/wi", 2, (dim, dim * 2),
                          fsdp=True, mesh_sizes=sizes)
    shape = (dim, dim * 2)
    for i, ax in enumerate(tuple(spec)):
        if ax is None:
            continue
        ways = sizes.get(ax, 1) if isinstance(ax, str) else \
            int(np.prod([sizes.get(a, 1) for a in ax]))
        assert shape[i] % ways == 0
