"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles (assert_allclose per the deliverable contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref

RNG = jax.random.PRNGKey(7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, H, KVH, S, D)
    (1, 2, 2, 128, 32),
    (2, 4, 2, 256, 64),
    (1, 8, 1, 128, 128),      # MQA
    (2, 3, 1, 192, 64),       # odd head count, ragged blocks
])
def test_flash_kernel_sweep(shape, dtype):
    b, h, kvh, s, d = shape
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kvh, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kvh, s, d), dtype)
    scal = jnp.array([0, s], jnp.int32)
    o = flash_attention_fwd(q, k, v, scal, causal=True, q_block=64,
                            kv_block=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("window,valid", [(16, None), (0, 100), (32, 150)])
def test_flash_kernel_window_and_validity(window, valid):
    b, h, kvh, s, d = 1, 2, 1, 192, 32
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    vl = valid if valid is not None else s
    scal = jnp.array([window, vl], jnp.int32)
    o = flash_attention_fwd(q, k, v, scal, causal=True, q_block=64,
                            kv_block=64, interpret=True)
    ref = flash_attention_ref(q, k, v, window=window, valid_len=vl,
                              causal=True)
    np.testing.assert_allclose(o, ref, atol=2e-5)


def test_flash_ops_layout_wrapper():
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))   # (B,S,H,D) layout
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    o = flash_attention(q, k, v, causal=True, interpret=True)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(o.transpose(0, 2, 1, 3), ref, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, S, DI, N)
    (1, 64, 32, 8),
    (2, 100, 96, 16),        # ragged S (padding path)
    (1, 128, 256, 4),
])
def test_ssm_kernel_sweep(shape, dtype):
    b, s, di, n = shape
    ks = jax.random.split(RNG, 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, di))).astype(dtype)
    x = jax.random.normal(ks[1], (b, s, di), dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, n), dtype)
    c = jax.random.normal(ks[4], (b, s, n), dtype)
    h0 = jax.random.normal(ks[5], (b, di, n), jnp.float32)
    y, hf = ssm_scan(dt, x, a, bb, c, h0, chunk=32, channel_block=32,
                     interpret=True)
    yr, hr = ssm_scan_ref(dt, x, a, bb, c, h0)
    tol = 1e-4 if dtype == jnp.float32 else 1.5e-1
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol)
    np.testing.assert_allclose(hf, hr, atol=tol)


def test_ssm_state_neutral_padding():
    """dt = 0 padding must leave the carried state untouched."""
    b, s, di, n = 1, 50, 32, 8   # 50 pads to 64 with chunk 32
    ks = jax.random.split(RNG, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, di)))
    x = jax.random.normal(ks[1], (b, s, di))
    a = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, n))
    c = jax.random.normal(ks[4], (b, s, n))
    _, hf = ssm_scan(dt, x, a, bb, c, chunk=32, channel_block=32,
                     interpret=True)
    _, hr = ssm_scan_ref(dt, x, a, bb, c, jnp.zeros((b, di, n)))
    np.testing.assert_allclose(hf, hr, atol=1e-4)
