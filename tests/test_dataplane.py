"""CoRD dataplane semantics: mode numerics, policies, verbs, chunking."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import DataplaneConfig
from repro.core import Dataplane, MRError, PolicyViolation, compat, verbs
from repro.core.chunking import bucket_pytree, chunked_psum, schedule_batch
from repro.core.policies import QoSPolicy, QuotaPolicy, SecurityPolicy, TelemetryPolicy

RNG = jax.random.PRNGKey(0)


def _psum_over(mesh, dp, x):
    @partial(compat.shard_map, mesh=mesh, in_specs=P("data"), out_specs=P())
    def f(v):
        out, _ = dp.psum(v.sum(), "data", tag="t/psum")
        return out
    return jax.jit(f)(x)


def test_modes_numerically_identical(mesh8):
    """The paper's architecture changes WHO controls the dataplane, never
    WHAT is computed: all three modes must be bit-identical."""
    x = jax.random.normal(RNG, (64,))
    outs = {}
    for mode in ("bypass", "cord", "socket"):
        dp = Dataplane(DataplaneConfig(mode=mode, emulate_costs=True),
                       mesh=mesh8)
        outs[mode] = _psum_over(mesh8, dp, x)
    np.testing.assert_array_equal(outs["bypass"], outs["cord"])
    np.testing.assert_array_equal(outs["bypass"], outs["socket"])


def test_bypass_is_invisible_to_the_os(mesh8):
    dp = Dataplane(DataplaneConfig(mode="bypass"), mesh=mesh8)
    _psum_over(mesh8, dp, jnp.ones(16))
    assert dp.telemetry.total_bytes() == 0  # no OS visibility — the problem
    assert dp.pipeline.stage_names == ()    # the OS is off the data path


def test_cord_telemetry_accounts_every_op(mesh8):
    dp = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh8)

    @partial(compat.shard_map, mesh=mesh8, in_specs=P("data"),
             out_specs=P("data"))
    def f(v):
        s, _ = dp.psum(v.sum(), "data", tag="a")
        g, _ = dp.all_gather(v, "data", tag="b")
        return v + s + g.sum()
    jax.jit(f)(jnp.ones(16))
    kinds = dp.telemetry.by_kind()
    assert kinds["all_reduce"]["ops"] == 1
    assert kinds["all_gather"]["ops"] == 1
    assert dp.telemetry.by_tag()["a"]["bytes"] == 4


def test_quota_policy_refuses_over_budget(mesh8):
    dp = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh8,
                   policies=[TelemetryPolicy(),
                             QuotaPolicy(limits={"default": 2})])
    with pytest.raises(PolicyViolation):
        _psum_over(mesh8, dp, jnp.ones(64))  # 4-byte op > 2-byte quota


def test_security_policy_mr_registration(mesh8):
    sec = SecurityPolicy(strict=False)
    dp = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh8,
                   policies=[sec])
    buf = jnp.ones(8)
    dp.reg_mr("grads", buf)

    @partial(compat.shard_map, mesh=mesh8, in_specs=P(), out_specs=P())
    def ok(v):
        return dp.psum(v, "data", mr="grads")[0]
    jax.jit(ok)(buf)  # registered → allowed

    @partial(compat.shard_map, mesh=mesh8, in_specs=P(), out_specs=P())
    def bad(v):
        return dp.psum(v, "data", mr="grads")[0]
    with pytest.raises(PolicyViolation):
        jax.jit(bad)(jnp.ones(16))  # signature mismatch → refused


def test_mr_registry_shape_check():
    from repro.core.mr import MRRegistry
    reg = MRRegistry()
    reg.reg_mr("a", jnp.ones((4, 4)))
    assert reg.check("a", jnp.ones((4, 4)))
    with pytest.raises(MRError):
        reg.check("a", jnp.ones((4, 5)))
    with pytest.raises(MRError):
        reg.check("missing", jnp.ones(1))


def test_chunked_psum_equals_psum(mesh8):
    dp = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh8)
    x = jax.random.normal(RNG, (64, 4))

    @partial(compat.shard_map, mesh=mesh8, in_specs=P("data"),
             out_specs=P("data"))
    def f(v):
        whole, _ = dp.psum(v, "data")
        chunked, _ = chunked_psum(dp, v, "data", num_chunks=4)
        return whole - chunked
    np.testing.assert_allclose(jax.jit(f)(x), 0.0, atol=1e-6)


def test_qos_schedule_returns_original_order():
    qos = QoSPolicy(classes={"hi": 0, "lo": 9})
    outs = schedule_batch(qos, [
        ("lo", lambda: jnp.asarray(1.0)),
        ("hi", lambda: jnp.asarray(2.0)),
        ("lo", lambda: jnp.asarray(3.0)),
    ])
    assert [float(o) for o in outs] == [1.0, 2.0, 3.0]


def test_bucket_pytree_partition():
    tree = {"a": jnp.ones((100,)), "b": jnp.ones((3,)),
            "c": jnp.ones((50, 2))}
    buckets = bucket_pytree(tree, bucket_bytes=256)
    leaves = [leaf for b in buckets for _, leaf in b]
    assert len(leaves) == 3
    assert sum(l.size for l in leaves) == 203


def test_verbs_send_read_write_payload(mesh2):
    dp = Dataplane(DataplaneConfig(mode="cord"), mesh=mesh2)
    cfg = verbs.QPConfig(transport="RC", msg_bytes=64, depth=2)
    payload = jnp.arange(64, dtype=jnp.uint8)

    @partial(compat.shard_map, mesh=mesh2, in_specs=P("rank", None),
             out_specs=P("rank", None))
    def send(buf):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        qp, _ = verbs.post_send(dp, cfg, qp, buf[0], rank, src=0)
        qp, _ = verbs.flush_send(dp, cfg, qp, rank, src=0, dst=1, op="send")
        return qp["recv_ring"][None, 0]

    out = jax.jit(send)(jnp.stack([payload, jnp.zeros(64, jnp.uint8)]))
    np.testing.assert_array_equal(np.asarray(out)[1], np.asarray(payload))

    with pytest.raises(verbs.TransportError):
        verbs.QPConfig(transport="UD", msg_bytes=8192)  # > MTU


def test_technique_toggles_preserve_values(mesh8):
    """'Removing' techniques changes timing, never results."""
    base = Dataplane(DataplaneConfig(mode="bypass"), mesh=mesh8)
    ablated = Dataplane(DataplaneConfig(
        mode="bypass", zero_copy=False, polling=False, kernel_bypass=False,
        emulate_costs=True), mesh=mesh8)
    x = jax.random.normal(RNG, (64,))
    np.testing.assert_array_equal(_psum_over(mesh8, base, x),
                                  _psum_over(mesh8, ablated, x))


def test_spec_dedupes_mesh_axes(mesh42):
    dp = Dataplane(DataplaneConfig(), mesh=mesh42,
                   rules={"heads": "model", "head_dim": "model",
                          "batch": ("data",)})
    spec = dp.spec(("batch", None, "heads", "head_dim"))
    flat = [a for s in spec if s for a in (s if isinstance(s, tuple) else (s,))]
    assert len(flat) == len(set(flat)), f"duplicate axes in {spec}"
