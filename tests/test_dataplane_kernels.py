"""Pallas dataplane kernels (kernels/dataplane, docs/kernels.md).

Everything here runs the kernels in interpret mode (CPU backend) — the
contract under test is the repo invariant: mediation changes cost and
state, never results.  Bit-identity is asserted with
``assert_array_equal``, never ``allclose``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from functools import partial
from jax.sharding import PartitionSpec as P

from repro.configs.base import DataplaneConfig
from repro.core import compat
from repro.core import techniques as tech
from repro.core.chunking import chunked_psum, split_chunks
from repro.core.dataplane import Dataplane
from repro.core.policies import QoSPolicy, TelemetryPolicy
from repro.kernels.dataplane import (
    COST_COPIES,
    COST_ITERS,
    bounce_copy,
    kernel_calibrate,
    kernel_iters_for_ns,
    mediated_cost,
    rescale_iters,
    use_pallas_dataplane,
)


# ---------------------------------------------------------------------------
# bounce_copy ≡ staged_copy
# ---------------------------------------------------------------------------

BOUNCE_CASES = [
    # (shape, dtype, copies, chunk_elems)
    ((37,), jnp.float32, 1, 16),          # ragged tail through slot 0
    ((64, 16), jnp.uint8, 3, 256),        # byte payload, multi-pass
    ((8193,), jnp.float32, 2, 8192),      # one full chunk + 1-elem tail
    ((3, 5, 7), jnp.bfloat16, 1, 32),     # nd payload, odd extents
    ((1,), jnp.float32, 2, 8192),         # single element
    ((4096,), jnp.int32, 1, 1024),        # exact multiple: no tail path
]


@pytest.mark.parametrize("shape,dtype,copies,chunk", BOUNCE_CASES)
def test_bounce_copy_matches_staged_copy(shape, dtype, copies, chunk):
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    got = bounce_copy(x, copies=copies, chunk_elems=chunk)
    want = tech.staged_copy(x, copies=copies)
    assert got.shape == x.shape and got.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bounce_copy_zero_copies_is_identity():
    x = jnp.arange(10.0)
    assert bounce_copy(x, copies=0) is x


def test_bounce_copy_nonfinite_payload_bit_identical():
    # the in-kernel tie must survive NaN / -0.0 (a select, not arithmetic)
    x = jnp.array([jnp.nan, -0.0, jnp.inf, -jnp.inf, 1.5], jnp.float32)
    got = np.asarray(bounce_copy(x, copies=2, chunk_elems=2))
    np.testing.assert_array_equal(
        got.view(np.int32), np.asarray(x).view(np.int32))


# ---------------------------------------------------------------------------
# mediated_cost: delay_chain tie semantics + per-chunk counters
# ---------------------------------------------------------------------------

def test_mediated_cost_value_identical():
    x = jnp.array([jnp.nan, -0.0, 2.0, -1.0], jnp.float32)
    out, _ = mediated_cost(x, delay_iters=100, copies=1, chunk_elems=2)
    np.testing.assert_array_equal(
        np.asarray(out).view(np.int32), np.asarray(x).view(np.int32))


def test_mediated_cost_counters():
    x = jnp.zeros((128,), jnp.float32)
    out, ctrs = mediated_cost(x, delay_iters=50, copies=2, chunk_elems=32)
    ctrs = np.asarray(ctrs)
    assert ctrs.shape == (4, 2)
    # even split rounded up: every chunk burns ceil(50/4) = 13
    np.testing.assert_array_equal(ctrs[:, COST_ITERS], 13)
    np.testing.assert_array_equal(ctrs[:, COST_COPIES], 2)
    assert ctrs[:, COST_ITERS].sum() >= 50
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_mediated_cost_no_work_shortcut():
    x = jnp.ones((8,))
    out, ctrs = mediated_cost(x, delay_iters=0, copies=0)
    assert out is x
    np.testing.assert_array_equal(np.asarray(ctrs), 0)


# ---------------------------------------------------------------------------
# backend selection + calibration plumbing
# ---------------------------------------------------------------------------

def test_use_pallas_dataplane_resolution():
    assert use_pallas_dataplane("on") is True
    assert use_pallas_dataplane("off") is False
    assert use_pallas_dataplane(True) is True
    # "auto" means TPU-only; these tests run on CPU
    assert use_pallas_dataplane("auto") is (jax.default_backend() == "tpu")
    with pytest.raises(ValueError):
        use_pallas_dataplane("maybe")


def test_calibrate_memoized_per_backend():
    tech._CALIBRATION.clear()
    a = tech.calibrate()
    assert tech._CALIBRATION  # cached
    b = tech.calibrate()
    assert a == b  # second call is a dict hit, not a re-probe
    assert tech.iters_for_ns(0) == 0
    assert tech.iters_for_ns(1e6) >= 1


def test_kernel_calibration_off_tpu_matches_xla_slope():
    # off-TPU the kernel path IS delay_chain, so the slopes coincide and
    # rescale_iters is the identity — interpret-mode tests see unchanged
    # iteration counts.
    assert kernel_calibrate() == tech.calibrate()
    assert rescale_iters(1234) == 1234
    assert rescale_iters(0) == 0
    assert kernel_iters_for_ns(0) == 0


# ---------------------------------------------------------------------------
# pipeline-level equivalence: pallas on ≡ off, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused", [True, False])
def test_pipeline_pallas_bit_identical(mesh8, fused):
    outs, reports = {}, {}
    for pallas in ("off", "on"):
        dp = Dataplane(
            DataplaneConfig(mode="socket", emulate_costs=True,
                            pallas_dataplane=pallas, fuse_mediation=fused),
            mesh=mesh8)
        assert dp.pipeline.pallas is (pallas == "on")

        @partial(compat.shard_map, mesh=mesh8, in_specs=(P("data"), P()),
                 out_specs=(P("data"), P()))
        def f(v, rt):
            g, rt = dp.all_gather(v, "data", state=rt)
            r, rt = dp.reduce_scatter(g, "data", state=rt)
            return r, rt

        out, rt = jax.jit(f)(
            jax.random.normal(jax.random.PRNGKey(3), (64,)),
            dp.runtime_init())
        outs[pallas] = np.asarray(out)
        reports[pallas] = dp.runtime_report(rt)["default"]
    np.testing.assert_array_equal(outs["off"], outs["on"])
    assert reports["off"] == reports["on"]


def test_stage_names_unchanged_by_pallas(mesh8):
    # the kernel path swaps the *implementation*, never the stage list
    for pallas in ("off", "on"):
        dp = Dataplane(DataplaneConfig(mode="socket", emulate_costs=True,
                                       pallas_dataplane=pallas), mesh=mesh8)
        assert dp.pipeline.stage_names == (
            "syscall-cost", "socket-stack", "staged-copy",
            "interrupt-wait", "counter-bump")


# ---------------------------------------------------------------------------
# split_chunks padding (satellite: no more collapse-to-1)
# ---------------------------------------------------------------------------

def test_split_chunks_pads_uneven():
    x = jnp.arange(10.0).reshape(10, 1)
    chunks = split_chunks(x, 4)
    assert len(chunks) == 4
    assert all(c.shape == (3, 1) for c in chunks)
    cat = np.asarray(jnp.concatenate(chunks, axis=0))
    np.testing.assert_array_equal(cat[:10], np.asarray(x))
    np.testing.assert_array_equal(cat[10:], 0)


def test_split_chunks_even_unpadded():
    x = jnp.arange(8.0)
    chunks = split_chunks(x, 4)
    assert len(chunks) == 4 and all(c.shape == (2,) for c in chunks)
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate(chunks)), np.asarray(x))


def test_split_chunks_more_chunks_than_rows():
    assert len(split_chunks(jnp.ones((3, 2)), 8)) == 3


# ---------------------------------------------------------------------------
# chunk-granular wire preemption
# ---------------------------------------------------------------------------

def _preempt_dp(mesh, rates):
    pols = [TelemetryPolicy(),
            QoSPolicy(rates=rates, burst=2.0, stall_ns=1e4)]
    return Dataplane(DataplaneConfig(mode="cord"), mesh=mesh,
                     tenant="t", tenants=("t",), policies=pols)


def _run_chunked(mesh, dp, n, num_chunks):
    @partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
             out_specs=(P("data"), P()))
    def f(v, rt):
        return chunked_psum(dp, v, "data", num_chunks=num_chunks, state=rt)

    out, rt = jax.jit(f)(
        jax.random.normal(jax.random.PRNGKey(4), (n, 4)),
        dp.runtime_init())
    return np.asarray(out), dp.runtime_report(rt)["t"]


def test_chunk_preemption_defers_and_stays_bit_identical(mesh8):
    # 64 rows over 8 shards = 8 rows/shard -> 8 chunks per shard
    free, rep_free = _run_chunked(mesh8, _preempt_dp(mesh8, {}), 64, 8)
    gated, rep = _run_chunked(mesh8, _preempt_dp(mesh8, {"t": 0.25}), 64, 8)
    np.testing.assert_array_equal(free, gated)
    # burst 2 + 8 * 0.25 refills = 4 issuable tokens; 8 chunks -> deferrals
    assert rep["chunks"] == 8 and rep["ops"] == 8
    assert rep["throttled"] > 0
    assert rep_free["throttled"] == 0


def test_chunk_preemption_no_double_charge(mesh8):
    # an N-chunk preempted collective must cost exactly what N
    # stage-charged plain psums cost: same throttled total, because the
    # chunk ops are issued precharged.
    _, rep_chunked = _run_chunked(
        mesh8, _preempt_dp(mesh8, {"t": 0.25}), 64, 8)

    dp = _preempt_dp(mesh8, {"t": 0.25})

    @partial(compat.shard_map, mesh=mesh8, in_specs=(P("data"), P()),
             out_specs=(P("data"), P()))
    def f(v, rt):
        outs = []
        for i in range(8):
            r, rt = dp.psum(v[i], "data", tag=f"plain{i}", state=rt)
            outs.append(r)
        return jnp.stack(outs), rt

    _, rt = jax.jit(f)(
        jax.random.normal(jax.random.PRNGKey(4), (64, 4)),
        dp.runtime_init())
    rep_plain = dp.runtime_report(rt)["t"]
    assert rep_chunked["throttled"] == rep_plain["throttled"]
    assert rep_chunked["ops"] == rep_plain["ops"]


def test_chunk_preemption_uneven_payload(mesh8):
    # 80 rows / 8 shards = 10 rows/shard, 4 chunks -> tail pad of 2 rows;
    # output must slice back to the original extent, values identical to
    # the unconstrained run
    free, _ = _run_chunked(mesh8, _preempt_dp(mesh8, {}), 80, 4)
    gated, rep = _run_chunked(mesh8, _preempt_dp(mesh8, {"t": 0.25}), 80, 4)
    assert gated.shape == (80, 4)
    np.testing.assert_array_equal(free, gated)
    assert rep["chunks"] == 4 and rep["throttled"] > 0


def test_preemption_off_when_unenforced(mesh8):
    # no rates -> no governing bucket -> ops are NOT precharged and the
    # pipeline's token-bucket stage (absent here) never runs; plain path
    dp = _preempt_dp(mesh8, {})
    out, rep = _run_chunked(mesh8, dp, 64, 8)
    assert rep["throttled"] == 0 and rep["chunks"] == 8
