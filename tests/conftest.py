# Multi-device tests need several host devices. 8 is the standard JAX test
# harness value — NOT the 512-device dry-run configuration, which is set
# exclusively inside launch/dryrun.py (see its header comment).
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pytest  # noqa: E402

from repro.core import compat  # noqa: E402


@pytest.fixture(scope="session")
def mesh8():
    return compat.make_mesh((8,), ("data",))


@pytest.fixture(scope="session")
def mesh42():
    return compat.make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="session")
def mesh2():
    return compat.make_mesh((2,), ("rank",))
