# Multi-device tests need several host devices. 8 is the standard JAX test
# harness value — NOT the 512-device dry-run configuration, which is set
# exclusively inside launch/dryrun.py (see its header comment).
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh8():
    return jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


@pytest.fixture(scope="session")
def mesh42():
    return jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def mesh2():
    return jax.make_mesh((2,), ("rank",),
                         axis_types=(jax.sharding.AxisType.Auto,))
