"""Pod-scale control plane conformance (docs/elasticity.md,
docs/observability.md): the ThresholdWatcher release arm's hysteresis
edge cases, cross-host ``merge_timelines`` round-trips and misalignment
refusal, JSONL sink close/rotation semantics, the WatcherGroup
hierarchy, the ElasticController shrink→grow mesh cycle, serve-side slot
budget elasticity with exact temp-0 resume, and live connection-table
migration back onto a *grown* mesh with retries in flight."""

import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_model_config
from repro.configs.base import ElasticConfig, ServeConfig
from repro.core import verbs
from repro.core.obs import (
    CounterTimeline,
    ThresholdWatcher,
    WatcherGroup,
    merge_timelines,
)
from repro.models import build_model
from repro.runtime import ElasticController, ServeElasticController
from repro.runtime.fault import WireFault
from repro.serve import Engine, Request
from repro.train import init_state
from test_transport import CCFG, _conn_parts, _conn_payload, _dp, \
    _run_conn, _stack

RNG = jax.random.PRNGKey(0)


def _ramp(pcts, tenant="noisy", source="ramp", ops_per_window=4.0):
    """Timeline whose windows show the given denied_pct series."""
    t = CounterTimeline(source=source)
    ops = den = 0.0
    t.snapshot(0, {tenant: {"ops": 0, "denied": 0}}, t=0.0)
    for i, pct in enumerate(pcts, start=1):
        ops += ops_per_window
        den += ops_per_window * pct / 100.0
        t.snapshot(i, {tenant: {"ops": ops, "denied": den}}, t=float(i))
    return t


def _rw(trigger=50.0, release=10.0, **kw):
    """Watcher with both arms configured; tight defaults so a short ramp
    exercises the whole trip→cool→recover cycle."""
    kw.setdefault("sustain", 2)
    kw.setdefault("cooldown", 1)
    kw.setdefault("release_sustain", 2)
    kw.setdefault("release_cooldown", 0)
    return ThresholdWatcher({"denied_pct": trigger},
                            release={"denied_pct": release}, **kw)


# ---------------------------------------------------------------------------
# release (grow-back) arm hysteresis
# ---------------------------------------------------------------------------

def test_release_levels_validated():
    # a release level at/over its trigger removes the hysteresis band
    with pytest.raises(ValueError, match="below its trigger"):
        ThresholdWatcher({"denied_pct": 50.0}, release={"denied_pct": 50.0})
    with pytest.raises(ValueError, match="unknown release rate fields"):
        ThresholdWatcher({"denied_pct": 50.0}, release={"bogus": 1.0})
    with pytest.raises(ValueError, match="release_sustain"):
        ThresholdWatcher({"denied_pct": 50.0}, release={"denied_pct": 10.0},
                         release_sustain=0)


def test_recover_after_sustained_quiet():
    # trigger at w2, cooldown eats w3, quiet w4+w5 sustain -> recover at 5
    w = _rw()
    evs = w.observe(_ramp([80, 80, 0, 0, 0, 0]))
    assert [(e["kind"], e["step"]) for e in evs] == [("trigger", 2),
                                                     ("recover", 5)]
    assert evs[1]["detail"]["under"] == {"denied_pct": 0.0}
    assert evs[1]["detail"]["sustained"] == 2
    # quiet without a preceding trigger never arms the release side
    w2 = _rw()
    assert w2.observe(_ramp([0] * 8)) == []
    assert w2.releases == []


def test_one_trigger_one_recover_per_excursion():
    # two full excursions; extended quiet after a recover adds nothing
    w = _rw()
    evs = w.observe(_ramp([80, 80, 0, 0, 0, 80, 80, 0, 0, 0, 0]))
    assert [(e["kind"], e["step"]) for e in evs] == [
        ("trigger", 2), ("recover", 5), ("trigger", 7), ("recover", 10)]
    assert len(w.triggers) == 2 and len(w.releases) == 2


def test_no_recover_inside_trigger_cooldown():
    # quiet windows inside the trigger cooldown never count toward the
    # release streak: recover lands at trip + cooldown + release_sustain
    w = _rw(cooldown=4)
    evs = w.observe(_ramp([80, 80] + [0] * 6))
    assert [(e["kind"], e["step"]) for e in evs] == [("trigger", 2),
                                                     ("recover", 8)]


def test_on_threshold_oscillation_damped():
    # a rate parked ON the trigger level trips (>=), but parked ON the
    # release level it never recovers (strict <) — the gap between the
    # two levels is the only place hysteresis lets state flip
    w = ThresholdWatcher({"denied_pct": 50.0}, sustain=2, cooldown=0,
                         release={"denied_pct": 10.0}, release_sustain=1)
    evs = w.observe(_ramp([50, 50, 10, 10, 30, 30, 9]))
    assert [(e["kind"], e["step"]) for e in evs] == [("trigger", 2),
                                                     ("recover", 7)]
    assert len(w.triggers) == 1    # in-band windows (30) rebuilt no streak


def test_release_cooldown_gates_next_recover():
    # sustain=1/cooldown=0 isolates the release cooldown: the first
    # recover at w2 starts a 2-window release cooldown that the second
    # excursion's quiet tail must sit through before recovering at w6
    w = _rw(sustain=1, cooldown=0, release_sustain=1, release_cooldown=2)
    evs = w.observe(_ramp([80, 0, 80, 0, 0, 0]))
    assert [(e["kind"], e["step"]) for e in evs] == [
        ("trigger", 1), ("recover", 2), ("trigger", 3), ("recover", 6)]


def test_observe_consumes_each_window_exactly_once(monkeypatch):
    # observe() is incremental: rate math runs once per NEW window, never
    # over the whole history again (the O(new windows) contract)
    calls = []
    orig = CounterTimeline._window

    def counting(self, prev, cur, tenants=None):
        calls.append(cur["step"])
        return orig(self, prev, cur, tenants=tenants)

    monkeypatch.setattr(CounterTimeline, "_window", counting)
    t = _ramp([80, 80, 0, 0, 0])
    w = _rw()
    w.observe(t)
    assert calls == [1, 2, 3, 4, 5]
    w.observe(t)
    assert calls == [1, 2, 3, 4, 5]       # nothing new -> no rate math
    t.snapshot(6, {"noisy": {"ops": 24.0, "denied": 6.4}}, t=6.0)
    t.snapshot(7, {"noisy": {"ops": 28.0, "denied": 6.4}}, t=7.0)
    w.observe(t)
    assert calls == [1, 2, 3, 4, 5, 6, 7]


def test_release_gauges_ride_along_only_when_configured():
    plain = ThresholdWatcher({"denied_pct": 50.0}, sustain=2, cooldown=1)
    assert set(plain.gauges()) == {"watch_streak", "watch_cooldown"}
    w = _rw(release_sustain=3)
    w.observe(_ramp([80, 80, 0, 0]))   # trip w2, cool w3, rstreak=1 at w4
    g = w.gauges()
    assert set(g) == {"watch_streak", "watch_cooldown",
                      "watch_release_streak", "watch_release_cooldown"}
    assert g["watch_release_streak"] == 1.0


# ---------------------------------------------------------------------------
# cross-host timeline merge
# ---------------------------------------------------------------------------

def test_merge_round_trip_artifact_and_rate_sums(tmp_path):
    a = CounterTimeline(source="host0")
    b = CounterTimeline(source="host1")
    for i in range(4):
        a.snapshot(i, {"x": {"ops": 2.0 * i, "bytes": 10.0 * i,
                             "denied": 1.0 * i, "cq_depth": i}},
                   gauges={"queue": 1.0}, t=float(i))
        b.snapshot(i, {"x": {"ops": 6.0 * i, "cq_depth": 5.0},
                       "y": {"ops": 1.0 * i}},
                   gauges={"queue": 2.0}, t=float(i) + 0.25)
    a.record_event("trigger", 2, tenant="x", t=2.0)
    pod = merge_timelines([a, b], source="pod")
    assert pod.source == "pod" and pod.tenants == ("x", "y")
    ra, rb, rp = a.rates(), b.rates(), pod.rates()
    for k in range(3):
        # additive rates sum across processes
        assert rp["x"]["ops_s"][k] == pytest.approx(
            ra["x"]["ops_s"][k] + rb["x"]["ops_s"][k])
        assert rp["x"]["bytes_s"][k] == pytest.approx(ra["x"]["bytes_s"][k])
        assert rp["y"]["ops_s"][k] == pytest.approx(rb["y"]["ops_s"][k])
    # shares pool over the pod's total ops, not a sum of per-host pcts
    assert rp["x"]["denied_pct"][0] == pytest.approx(100.0 * 1.0 / 8.0)
    # cq_depth is a high-water level: max across parts, never a sum
    assert rp["x"]["cq_depth"] == [5.0, 5.0, 5.0]
    # the pod window closes when the LAST process reports; gauges pool
    assert [s["t"] for s in pod.samples] == [i + 0.25 for i in range(4)]
    assert pod.gauge_series()["queue"] == [3.0] * 4
    # the merged timeline is an ordinary v2 artifact: save -> validate
    doc = CounterTimeline.load(pod.save(str(tmp_path / "pod.json")))
    assert doc["schema"] == "cord-timeline/v2"
    assert doc["events"][0]["detail"]["origin"] == "host0"


def test_merge_refuses_misaligned_parts():
    with pytest.raises(ValueError, match="at least one"):
        merge_timelines([])
    # a lagging host raises rather than silently truncating the pod tail
    with pytest.raises(ValueError, match="refusing to truncate"):
        merge_timelines([_ramp([80, 80]), _ramp([80])])
    # equal sample counts but skewed step stamps are just as misaligned
    c = CounterTimeline(source="skewed")
    c.snapshot(0, {"noisy": {"ops": 0}}, t=0.0)
    c.snapshot(1, {"noisy": {"ops": 4.0}}, t=1.0)
    c.snapshot(3, {"noisy": {"ops": 8.0}}, t=3.0)
    with pytest.raises(ValueError, match="step-misaligned"):
        merge_timelines([_ramp([80, 80]), c])
    thin = CounterTimeline(source="thin", counter_names=("ops", "bytes"))
    with pytest.raises(ValueError, match="counter layouts"):
        merge_timelines([_ramp([80]), thin])


def test_merge_interleaves_events_with_origin():
    a = _ramp([80], source="host0")
    b = _ramp([0], source="host1")
    a.record_event("trigger", 1, tenant="noisy", t=1.0)
    b.record_event("remesh", 1, tenant="noisy", t=0.5,
                   detail={"direction": "shrink"})
    a.record_event("recover", 1, tenant="noisy", t=1.5)
    pod = merge_timelines([a, b])
    assert [(e["kind"], e["detail"]["origin"]) for e in pod.events] == [
        ("remesh", "host1"), ("trigger", "host0"), ("recover", "host0")]
    assert pod.events[0]["detail"]["direction"] == "shrink"
    # merge copies event details; the source timelines stay untouched
    assert "origin" not in a.events[0]["detail"]


# ---------------------------------------------------------------------------
# JSONL sink: late events + rotation
# ---------------------------------------------------------------------------

def test_sink_event_after_close_joins_same_stream(tmp_path):
    p = str(tmp_path / "run.jsonl")
    t = CounterTimeline(source="late", sink=p)
    t.snapshot(0, {"x": {"ops": 0}}, t=0.0)
    t.snapshot(1, {"x": {"ops": 4.0}}, t=1.0)
    t.close()
    # an engine-shutdown event lands AFTER the final flush: it must
    # reopen the same stream, not start a one-event "run" of its own
    t.record_event("remesh", 1, tenant="x", t=1.5,
                   detail={"direction": "grow"})
    t.close()
    back = CounterTimeline.read_jsonl(p)
    assert [s["step"] for s in back.samples] == [0, 1]
    assert [e["kind"] for e in back.events] == ["remesh"]
    with open(p) as f:
        headers = [ln for ln in f if "schema" in json.loads(ln)]
    assert len(headers) == 1


def test_sink_rotation_stitches_and_segments_standalone(tmp_path):
    with pytest.raises(ValueError, match="needs a sink"):
        CounterTimeline(rotate_bytes=64)
    p = str(tmp_path / "rot.jsonl")
    t = CounterTimeline(source="rot", sink=p, rotate_bytes=900)
    for i in range(12):
        t.snapshot(i, {"x": {"ops": 4.0 * i}}, t=float(i))
    t.record_event("late", 11, tenant="x", t=11.5)
    t.close()
    assert t.rotations >= 2 and os.path.exists(p + ".1")
    # the whole run stitches back together, events included
    whole = CounterTimeline.read_rotated(p)
    assert [s["step"] for s in whole.samples] == list(range(12))
    assert [e["kind"] for e in whole.events] == ["late"]
    # every sealed segment carries its own header and reads standalone
    seg = CounterTimeline.read_jsonl(p + ".1")
    assert seg.source == "rot" and 0 < len(seg.samples) < 12
    # the live file alone is just the newest segment, not the run
    live = CounterTimeline.read_jsonl(p)
    assert len(live.samples) < 12
    with pytest.raises(FileNotFoundError):
        CounterTimeline.read_rotated(str(tmp_path / "missing.jsonl"))


# ---------------------------------------------------------------------------
# watcher hierarchy
# ---------------------------------------------------------------------------

def test_watcher_group_tags_records_and_namespaces():
    with pytest.raises(ValueError, match="at least one"):
        WatcherGroup({})
    with pytest.raises(ValueError, match="not a"):
        WatcherGroup({"x": object()})
    t = CounterTimeline(source="pod")
    t.snapshot(0, {"t0": {"ops": 0, "denied": 0},
                   "s0": {"ops": 0, "throttled": 0}}, t=0.0)
    for i in range(1, 3):
        t.snapshot(i, {"t0": {"ops": 4.0 * i, "denied": 4.0 * i},
                       "s0": {"ops": 4.0 * i, "throttled": 4.0 * i}},
                   t=float(i))
    group = WatcherGroup({
        "train": ThresholdWatcher({"denied_pct": 50.0}, sustain=2,
                                  cooldown=4, tenants=("t0",)),
        "serve": ThresholdWatcher({"throttled_pct": 50.0}, sustain=2,
                                  cooldown=4, tenants=("s0",)),
    })
    evs = group.observe(t)
    assert [e["tenant"] for e in evs["train"]] == ["t0"]
    assert [e["tenant"] for e in evs["serve"]] == ["s0"]
    assert all(e["detail"]["watcher"] == "serve" for e in evs["serve"])
    # both members' events land in the shared artifact, tagged by name
    assert sorted(e["detail"]["watcher"] for e in t.events) == \
        ["serve", "train"]
    g = group.gauges()
    assert "train_watch_streak" in g and "serve_watch_cooldown" in g
    # record=False observes without touching the artifact
    t2 = _ramp([80, 80])
    g2 = WatcherGroup({"train": ThresholdWatcher({"denied_pct": 50.0},
                                                 sustain=2, cooldown=4)})
    evs2 = g2.observe(t2, record=False)
    assert len(evs2["train"]) == 1 and t2.events == []


# ---------------------------------------------------------------------------
# train-side controller: shrink -> grow-back mesh cycle
# ---------------------------------------------------------------------------

def test_controller_shrink_grow_cycle_restores_mesh(mesh42):
    cfg = get_model_config("gemma3-1b", smoke=True)
    state = init_state(build_model(cfg), RNG)
    before = [np.asarray(x) for x in jax.tree.leaves(state.params)]
    timeline = CounterTimeline(source="cycle")
    ecfg = ElasticConfig(enabled=True, thresholds=("denied_pct=50",),
                         release_thresholds=("denied_pct=5",),
                         sustain=2, cooldown=1, release_sustain=2,
                         release_cooldown=0, shrink_factor=2,
                         min_devices=2, max_remesh=1)
    ctl = ElasticController(ecfg, timeline, mesh42)
    ops = den = 0.0
    timeline.snapshot(0, {"default": {"ops": 0, "denied": 0}}, t=0.0)
    for i, pct in enumerate([80, 80, 0, 0, 0], start=1):
        ops, den = ops + 4.0, den + 4.0 * pct / 100.0
        timeline.snapshot(i, {"default": {"ops": ops, "denied": den}},
                          t=float(i))
        state, moved = ctl.drive(state, i)
        if i == 2:
            assert moved and ctl.mesh.devices.shape == (2, 2)
    assert moved and ctl.mesh.devices.shape == (4, 2)      # grew back
    assert ctl.remeshes == 1 and ctl.grows == 1
    kinds = [(e["kind"], e["detail"].get("direction"))
             for e in timeline.events]
    assert kinds == [("trigger", None), ("remesh", "shrink"),
                     ("recover", None), ("remesh", "grow")]
    assert timeline.events[1]["detail"]["devices_after"] == 4
    assert timeline.events[3]["detail"]["devices_after"] == 8
    # both migrations preserved every parameter bit
    after = [np.asarray(x) for x in jax.tree.leaves(state.params)]
    for x, y in zip(before, after):
        np.testing.assert_array_equal(x, y)
    # grow-backs are free; the NEXT excursion hits the shrink budget
    for i, pct in enumerate([80, 80], start=6):
        ops, den = ops + 4.0, den + 4.0 * pct / 100.0
        timeline.snapshot(i, {"default": {"ops": ops, "denied": den}},
                          t=float(i))
    state, moved = ctl.drive(state, 7)
    assert not moved and ctl.remeshes == 1
    assert timeline.events[-1]["kind"] == "remesh-skipped"
    assert "max_remesh" in timeline.events[-1]["detail"]["reason"]


def test_grow_without_shrink_records_skip(mesh42):
    timeline = CounterTimeline(source="noshrink")
    ctl = ElasticController(ElasticConfig(enabled=True), timeline, mesh42)
    state = object()                  # never migrated on the skip path
    out, moved = ctl.grow_mesh(state, 5)
    assert out is state and not moved and ctl.grows == 0
    ev = timeline.events[-1]
    assert ev["kind"] == "remesh-skipped"
    assert "nothing to grow back to" in ev["detail"]["reason"]


# ---------------------------------------------------------------------------
# serve-side controller: slot budget down -> up
# ---------------------------------------------------------------------------

class _SlotKnob:
    """The engine's slot-budget surface (slot_budget / set_slot_budget)
    without the engine — isolates the controller's bookkeeping."""

    def __init__(self, default=8):
        self._default, self._cap = default, 0

    def slot_budget(self):
        return self._cap or self._default

    def set_slot_budget(self, n):
        prev, self._cap = self._cap, max(int(n), 0)
        return prev


def _ev(kind, step=1, tenant="burst"):
    return {"kind": kind, "step": step, "tenant": tenant, "detail": {}}


def test_serve_controller_budget_cycle_and_skip_reasons():
    tl_ = CounterTimeline(source="serve")
    knob = _SlotKnob(default=8)
    cfg = ElasticConfig(enabled=True, shrink_factor=2, max_remesh=1,
                        thresholds=("throttled_pct=50",))
    ctl = ServeElasticController(cfg, tl_, knob)
    ctl.respond([_ev("trigger")])
    assert knob.slot_budget() == 4 and ctl.shrinks == 1
    ctl.respond([_ev("trigger", step=2)])        # double-shrink refused
    assert knob.slot_budget() == 4
    ctl.respond([_ev("recover", step=3)])
    assert knob.slot_budget() == 8 and ctl.grows == 1
    ctl.respond([_ev("recover", step=4)])        # nothing left to grow
    ctl.respond([_ev("trigger", step=5)])        # shrink budget exhausted
    assert knob.slot_budget() == 8
    kinds = [(e["kind"], e["detail"].get("direction")
              or e["detail"].get("reason")) for e in tl_.events]
    assert kinds[0] == ("budget", "shrink")
    assert kinds[1][0] == "budget-skipped" and "awaiting recover" in kinds[1][1]
    assert kinds[2] == ("budget", "grow")
    assert "nothing to grow back to" in kinds[3][1]
    assert "max_remesh" in kinds[4][1]
    assert tl_.events[0]["detail"]["slots_after"] == 4
    assert tl_.events[2]["detail"]["slots_after"] == 8
    # a one-slot budget has no room below it: the floor is explanatory
    floor = ServeElasticController(cfg, CounterTimeline(source="floor"),
                                   _SlotKnob(default=1))
    floor.respond([_ev("trigger")])
    assert floor.shrinks == 0
    assert "floor" in floor.timeline.events[-1]["detail"]["reason"]


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    return cfg, model, params


def _requests(lengths, max_new=16):
    return [Request(rid=i,
                    prompt=np.asarray((np.arange(n) + 3 * i) % 100, np.int32),
                    max_new_tokens=max_new)
            for i, n in enumerate(lengths)]


def test_engine_slot_budget_returns_previous(smoke_model):
    cfg, model, params = smoke_model
    eng = Engine(model, params, cfg,
                 ServeConfig(max_batch=3, max_new_tokens=4, kv_cache_len=64),
                 eos_id=-1)
    assert eng.slot_budget() == 3            # falls back to max_batch
    assert eng.set_slot_budget(2) == 0       # previous raw override
    assert eng.slot_budget() == 2
    assert eng.set_slot_budget(0) == 2       # 0 clears back to the default
    assert eng.slot_budget() == 3


def test_serve_budget_shrink_grow_exact_resume(smoke_model):
    """The serve-side cycle on a live engine: a mid-run budget shrink
    preempts running slots, the grow-back restores the budget, and every
    request still emits exactly the tokens of an undisturbed run."""
    cfg, model, params = smoke_model
    sc = ServeConfig(max_batch=3, max_new_tokens=10, kv_cache_len=64)
    base_eng = Engine(model, params, cfg, sc, eos_id=-1)
    base = {r.rid: r.out_tokens
            for r in base_eng.run(_requests([8, 8, 8], max_new=10))}
    assert all(len(o) == 10 for o in base.values())

    tl_ = CounterTimeline(source="elastic-serve")
    eng = Engine(model, params, cfg, sc, eos_id=-1, obs=tl_)
    ctl = ServeElasticController(
        ElasticConfig(enabled=True, shrink_factor=2,
                      thresholds=("throttled_pct=50",),
                      release_thresholds=("throttled_pct=10",)), tl_, eng)
    ticks = {"n": 0}

    def hook(_eng):
        # deterministic stand-in for the watcher: shrink while all three
        # slots decode, grow back while the preempted ones still wait
        ticks["n"] += 1
        if ticks["n"] == 3:
            ctl.respond([_ev("trigger", step=ticks["n"], tenant="default")])
        elif ticks["n"] == 14:
            ctl.respond([_ev("recover", step=ticks["n"], tenant="default")])

    eng.on_tick = hook
    done = {r.rid: r.out_tokens
            for r in eng.run(_requests([8, 8, 8], max_new=10))}
    assert done == base                      # exact temp-0 resume
    assert ctl.shrinks == 1 and ctl.grows == 1
    assert eng.slot_budget() == 3            # budget closed the cycle
    last = tl_.samples[-1]["tenants"]["default"]
    assert last["preemptions"] >= 1 and last["restores"] >= 1
    dirs = [e["detail"]["direction"] for e in tl_.events
            if e["kind"] == "budget"]
    assert dirs == ["shrink", "grow"]


# ---------------------------------------------------------------------------
# transport: connection-table migration back onto a grown mesh
# ---------------------------------------------------------------------------

def test_conn_restore_onto_grown_mesh_bit_identical(mesh2):
    """Shrink→grow for in-flight connections: a lossy transfer migrates
    A→B (the shrink) and then B→A (the grow-back onto the original
    mesh), with retry state live across both moves — the three-leg
    delivery matches an uninterrupted lossless run and the fault
    counters only ever grow."""
    from repro.core import compat
    Q, n, k1, k2 = 3, 6, 2, 4
    mesh_b = compat.make_mesh((2,), ("rank",), devices=jax.devices()[2:4])
    fault = WireFault(drop_rate=0.2, corrupt_rate=0.1, seed=7)
    payload = _conn_payload(Q, n, CCFG.msg_bytes, seed=7)
    msgs = _stack(payload)

    dp_a, dp_b = _dp(mesh2), _dp(mesh_b)
    pa = _conn_parts(mesh2, dp_a, CCFG, Q, fault=fault, credits=Q * n * 2)
    pb = _conn_parts(mesh_b, dp_b, CCFG, Q, fault=fault)

    # lossless baseline, uninterrupted
    base, _, _ = _run_conn(mesh2, dp_a, CCFG, msgs)

    # leg 1 on mesh A, then quiesce + snapshot (the shrink-side move)
    conn, _ = pa["init"](dp_a.runtime_init())
    out1, conn, _ = pa["xfer"](msgs[:, :, :k1], conn, dp_a.runtime_init())
    conn, _ = pa["quiesce"](conn, dp_a.runtime_init())
    snap1 = verbs.conn_snapshot(conn)
    assert int(snap1["cq_head"] - snap1["cq_tail"]) == 0, "CQ not quiesced"
    np.testing.assert_array_equal(snap1["sq_head"], snap1["cq_sent"])
    retrans_1 = np.array(snap1["retransmits"]).copy()

    # leg 2 on the smaller mesh B, still under loss
    conn_b = verbs.conn_restore(snap1, mesh_b)
    out2, conn_b, _ = pb["xfer"](msgs[:, :, k1:k2], conn_b,
                                 dp_b.runtime_init())
    conn_b, _ = pb["quiesce"](conn_b, dp_b.runtime_init())
    snap2 = verbs.conn_snapshot(conn_b)
    np.testing.assert_array_equal(snap2["sq_head"], snap2["cq_sent"])
    retrans_2 = np.array(snap2["retransmits"]).copy()

    # grow-back: restore onto the ORIGINAL mesh A and finish there
    conn_c = verbs.conn_restore(snap2, mesh2)
    out3, conn_c, _ = jax.block_until_ready(
        pa["xfer"](msgs[:, :, k2:], conn_c, dp_a.runtime_init()))

    moved = np.concatenate([np.asarray(out1)[1], np.asarray(out2)[1],
                            np.asarray(out3)[1]], axis=1)
    np.testing.assert_array_equal(moved, np.asarray(base))
    # counters rode along both migrations and only ever grew
    snap3 = verbs.conn_snapshot(conn_c)
    assert (retrans_2 >= retrans_1).all()
    assert (np.array(snap3["retransmits"]) >= retrans_2).all()
    assert (np.array(snap3["srq_grants"]) >= n).all()
