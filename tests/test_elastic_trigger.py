"""The elastic control loop (docs/elasticity.md): ThresholdWatcher
hysteresis/cooldown math, live QP migration (quiesce drains to a clean
CQ, the QP pytree round-trips through a remesh with counters preserved,
surviving transfers are bit-identical), v1/v2 timeline artifact
compatibility, the streaming JSONL sink, and the end-to-end
ElasticController remesh of a live TrainState."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_model_config
from repro.configs.base import (
    DataplaneConfig,
    ElasticConfig,
    RunConfig,
    apply_overrides,
)
from repro.core import Dataplane, compat, verbs
from repro.core.obs import (
    RATE_FIELDS,
    TIMELINE_SCHEMA,
    TIMELINE_SCHEMA_V1,
    CounterTimeline,
    ThresholdWatcher,
    validate_timeline,
)
from repro.models import build_model
from repro.runtime import ElasticController, shrink_mesh
from repro.train import init_state

RNG = jax.random.PRNGKey(0)


def _ramp(denied_pct_per_window, ops_per_window=4.0):
    """Timeline whose windows show the given denied_pct series."""
    t = CounterTimeline(source="ramp")
    ops = den = 0.0
    t.snapshot(0, {"noisy": {"ops": 0, "denied": 0}}, t=0.0)
    for i, pct in enumerate(denied_pct_per_window, start=1):
        ops += ops_per_window
        den += ops_per_window * pct / 100.0
        t.snapshot(i, {"noisy": {"ops": ops, "denied": den}}, t=float(i))
    return t


# ---------------------------------------------------------------------------
# watcher hysteresis / cooldown
# ---------------------------------------------------------------------------

def test_watcher_requires_sustained_windows():
    # alternating spikes never build a streak of 2
    t = _ramp([80, 0, 80, 0, 80, 0, 80])
    w = ThresholdWatcher({"denied_pct": 50.0}, sustain=2, cooldown=4)
    assert w.observe(t) == []
    # and a single transient spike never fires even with sustain=1 streaks
    # elsewhere in the series
    t2 = _ramp([0, 0, 80, 0, 0])
    w2 = ThresholdWatcher({"denied_pct": 50.0}, sustain=2, cooldown=0)
    assert w2.observe(t2) == []


def test_watcher_fires_once_then_cooldown_holds():
    t = _ramp([80] * 6)
    w = ThresholdWatcher({"denied_pct": 50.0}, sustain=3, cooldown=10)
    evs = w.observe(t)
    assert len(evs) == 1
    ev = evs[0]
    # trips at the window that completes the streak (window 3, step 3)
    assert ev["step"] == 3 and ev["tenant"] == "noisy"
    assert ev["kind"] == "trigger"
    assert ev["detail"]["over"] == {"denied_pct": pytest.approx(80.0)}
    assert ev["detail"]["sustained"] == 3
    # later windows extend the run but fall inside the cooldown
    t.snapshot(7, {"noisy": {"ops": 28, "denied": 28 * 0.8}}, t=7.0)
    assert w.observe(t) == []
    assert len(w.triggers) == 1


def test_watcher_rearms_after_cooldown():
    # sustain=2, cooldown=1: trigger at w2; w3 cools; w4-5 rebuild the
    # streak -> trigger at w5; w6 cools; w7-8 -> trigger at w8
    t = _ramp([80] * 8)
    w = ThresholdWatcher({"denied_pct": 50.0}, sustain=2, cooldown=1)
    assert [e["step"] for e in w.observe(t)] == [2, 5, 8]


def test_watcher_incremental_equals_batch():
    pcts = [80, 80, 0, 80, 80, 80, 80]
    batch = ThresholdWatcher({"denied_pct": 50.0}, sustain=2, cooldown=2)
    batch_evs = batch.observe(_ramp(pcts))

    inc = ThresholdWatcher({"denied_pct": 50.0}, sustain=2, cooldown=2)
    t = CounterTimeline(source="ramp")
    t.snapshot(0, {"noisy": {"ops": 0, "denied": 0}}, t=0.0)
    inc_evs, ops, den = [], 0.0, 0.0
    for i, pct in enumerate(pcts, start=1):
        ops, den = ops + 4, den + 4 * pct / 100.0
        t.snapshot(i, {"noisy": {"ops": ops, "denied": den}}, t=float(i))
        inc_evs += inc.observe(t)
    assert [e["step"] for e in inc_evs] == [e["step"] for e in batch_evs]


def test_watcher_tenant_filter_and_multi_field():
    t = CounterTimeline(source="two")
    t.snapshot(0, {"a": {"ops": 0, "denied": 0},
                   "b": {"ops": 0, "throttled": 0}}, t=0.0)
    for i in range(1, 4):
        t.snapshot(i, {"a": {"ops": 4.0 * i, "denied": 4.0 * i},
                       "b": {"ops": 4.0 * i, "throttled": 4.0 * i}},
                   t=float(i))
    # both fields watched, but only tenant b is in scope
    w = ThresholdWatcher({"denied_pct": 50.0, "throttled_pct": 50.0},
                         sustain=2, cooldown=4, tenants=("b",))
    evs = w.observe(t)
    assert [(e["tenant"], e["step"]) for e in evs] == [("b", 2)]
    assert evs[0]["detail"]["over"] == {"throttled_pct": 100.0}


def test_watcher_gauges_track_streak_and_cooldown():
    w = ThresholdWatcher({"denied_pct": 50.0}, sustain=3, cooldown=5)
    assert w.gauges() == {"watch_streak": 0.0, "watch_cooldown": 0.0}
    w.observe(_ramp([80, 80]))
    assert w.gauges() == {"watch_streak": 2.0, "watch_cooldown": 0.0}
    w.observe(_ramp([80, 80, 80]))          # completes the streak: trigger
    assert w.gauges() == {"watch_streak": 0.0, "watch_cooldown": 5.0}


def test_watcher_validation_and_from_config():
    with pytest.raises(ValueError, match="unknown rate fields"):
        ThresholdWatcher({"nope": 1.0})
    with pytest.raises(ValueError, match="at least one"):
        ThresholdWatcher({})
    with pytest.raises(ValueError, match="sustain"):
        ThresholdWatcher({"denied_pct": 1.0}, sustain=0)
    cfg = ElasticConfig(thresholds=("denied_pct=50", "stalls_pct=75.5"),
                        sustain=4, cooldown=9, tenants=("x",))
    w = ThresholdWatcher.from_config(cfg)
    assert w.thresholds == {"denied_pct": 50.0, "stalls_pct": 75.5}
    assert (w.sustain, w.cooldown, w.tenants) == (4, 9, ("x",))
    with pytest.raises(ValueError, match="rate_field=level"):
        ThresholdWatcher.from_config(ElasticConfig(thresholds=("denied",)))
    # and the config is reachable through RunConfig CLI overrides
    run = apply_overrides(RunConfig(), ["elastic.sustain=7"])
    assert run.elastic.sustain == 7


def test_window_rates_single_window():
    t = _ramp([80, 40])
    assert t.window_rates(1)["noisy"]["denied_pct"] == pytest.approx(80.0)
    assert t.window_rates(-1)["noisy"]["denied_pct"] == pytest.approx(40.0)
    assert t.window_rates() == t.window_rates(2)
    with pytest.raises(IndexError):
        t.window_rates(0)
    assert CounterTimeline(source="e").window_rates() == {}


# ---------------------------------------------------------------------------
# v1/v2 artifact compatibility + validation regressions
# ---------------------------------------------------------------------------

def test_v2_events_roundtrip(tmp_path):
    t = _ramp([80, 80])
    t.record_event("trigger", 2, tenant="noisy", t=2.0,
                   detail={"over": {"denied_pct": 80.0}})
    t.record_event("remesh", 2, tenant="noisy", t=2.1,
                   detail={"devices_after": 4})
    path = t.save(str(tmp_path / "v2_timeline.json"))
    doc = CounterTimeline.load(path)
    assert doc["schema"] == TIMELINE_SCHEMA == "cord-timeline/v2"
    assert [e["kind"] for e in doc["events"]] == ["trigger", "remesh"]
    assert doc["events"][1]["detail"] == {"devices_after": 4}


def test_v1_artifact_still_loads(tmp_path):
    """The compatibility rule: v1 (no events list) is accepted and
    checked against the v1 layout; a v2 doc *missing* events is not."""
    doc = _ramp([80, 80]).to_doc()
    v1 = {k: v for k, v in doc.items() if k != "events"}
    v1["schema"] = TIMELINE_SCHEMA_V1
    path = tmp_path / "old_timeline.json"
    path.write_text(json.dumps(v1))
    loaded = CounterTimeline.load(str(path))
    assert loaded["schema"] == "cord-timeline/v1"
    # v2 without events is malformed
    with pytest.raises(ValueError, match="events"):
        validate_timeline({k: v for k, v in doc.items() if k != "events"})
    # unknown versions stay refused
    with pytest.raises(ValueError, match="schema"):
        validate_timeline({**doc, "schema": "cord-timeline/v3"})
    # events must carry kind + step
    with pytest.raises(ValueError, match="event missing key"):
        validate_timeline({**doc, "events": [{"kind": "remesh"}]})


def test_validate_rejects_series_length_mismatch_on_v1():
    """Regression (PR 5 bugfix): a v1 artifact whose series lengths
    disagree with the sample axis used to pass validation as long as the
    schema string matched; now every series is length-checked."""
    doc = _ramp([80, 80]).to_doc()
    doc["gauges"] = {"active_slots": [1.0]}      # 3 samples -> needs 3
    v1 = {k: v for k, v in doc.items() if k != "events"}
    v1["schema"] = TIMELINE_SCHEMA_V1
    with pytest.raises(ValueError, match="gauge series"):
        validate_timeline(v1)
    # the wall-time axis is checked too (only `step` was before)
    doc2 = _ramp([80, 80]).to_doc()
    doc2["axis"]["t"] = doc2["axis"]["t"][:-1]
    v1b = {k: v for k, v in doc2.items() if k != "events"}
    v1b["schema"] = TIMELINE_SCHEMA_V1
    with pytest.raises(ValueError, match="axis 't'"):
        validate_timeline(v1b)


# ---------------------------------------------------------------------------
# streaming JSONL sink
# ---------------------------------------------------------------------------

def test_jsonl_sink_streams_and_rebuilds(tmp_path):
    path = str(tmp_path / "run.jsonl")
    t = CounterTimeline(source="sink-test", sink=path)
    t.snapshot(1, {"a": {"ops": 4, "bytes": 64}}, t=1.0,
               gauges={"watch_streak": 1})
    t.record_event("trigger", 1, tenant="a", t=1.5, detail={"x": 1})
    t.snapshot(2, {"a": {"ops": 8, "bytes": 128}}, t=2.0,
               gauges={"watch_streak": 2})
    t.close()

    lines = [json.loads(line) for line in open(path)]
    assert lines[0]["schema"] == TIMELINE_SCHEMA       # header
    assert [next(iter(o)) for o in lines[1:]] == \
        ["sample", "event", "sample"]                  # arrival order

    back = CounterTimeline.read_jsonl(path)
    assert back.source == "sink-test"
    assert back.samples == t.samples
    assert back.events == t.events
    assert back.to_doc() == t.to_doc()

    # a rerun over the same path appends a NEW stream (its own header);
    # read_jsonl yields the latest stream, never a cross-run merge whose
    # boundary window would corrupt the rate series
    t2 = CounterTimeline(source="sink-rerun", sink=path)
    t2.snapshot(1, {"a": {"ops": 2}}, t=0.5)
    t2.close()
    lines = [json.loads(line) for line in open(path)]
    assert sum("schema" in o for o in lines) == 2
    latest = CounterTimeline.read_jsonl(path)
    assert latest.source == "sink-rerun"
    assert [s["step"] for s in latest.samples] == [1]
    assert latest.events == []


# ---------------------------------------------------------------------------
# live QP migration: quiesce → snapshot → restore
# ---------------------------------------------------------------------------

N_MSGS, MSG_BYTES, WINDOW = 6, 128, 2


def _dp(mesh):
    return Dataplane(DataplaneConfig(mode="cord", emulate_costs=True),
                     mesh=mesh)


def _conn(mesh, dp, *, credits=0):
    """init/xfer/quiesce jits threading the QP pytree through qp_specs —
    the migratable-connection shape benchmarks/perftest.py also builds."""
    cfg = verbs.QPConfig(msg_bytes=MSG_BYTES, depth=max(WINDOW, 2),
                         max_outstanding=WINDOW)
    qspec = verbs.qp_specs("rank")

    def init_body(rt):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        if credits:
            qp, rt = verbs.post_recv(dp, cfg, qp, rank, dst=1, n=credits,
                                     state=rt)
        return qp, verbs.allreduce_state(rt)

    def xfer_body(msgs, qp, rt):
        rank = jax.lax.axis_index("rank")
        out, qp, rt = verbs.windowed_send(dp, cfg, qp, msgs[0], rank,
                                          src=0, dst=1, state=rt)
        return out[None], qp, verbs.allreduce_state(rt)

    def quiesce_body(qp, rt):
        rank = jax.lax.axis_index("rank")
        qp, rt = verbs.qp_quiesce(dp, cfg, qp, rank, src=0, state=rt)
        return qp, verbs.allreduce_state(rt)

    sm = compat.shard_map
    return {
        "cfg": cfg,
        "init": jax.jit(sm(init_body, mesh=mesh, in_specs=(P(),),
                           out_specs=(qspec, P()))),
        "xfer": jax.jit(sm(xfer_body, mesh=mesh,
                           in_specs=(P("rank", None, None), qspec, P()),
                           out_specs=(P("rank", None, None), qspec, P()))),
        "quiesce": jax.jit(sm(quiesce_body, mesh=mesh, in_specs=(qspec, P()),
                              out_specs=(qspec, P()))),
    }


def _msgs():
    payload = np.arange(N_MSGS * MSG_BYTES, dtype=np.uint8) \
        .reshape(N_MSGS, MSG_BYTES)
    return jnp.asarray(np.stack([payload, np.zeros_like(payload)])), payload


@pytest.fixture(scope="module")
def mesh_pair():
    devs = jax.devices()
    return (compat.make_mesh((2,), ("rank",), devices=devs[:2]),
            compat.make_mesh((2,), ("rank",), devices=devs[2:4]))


def test_qp_specs_cover_qp_init_layout():
    cfg = verbs.QPConfig(msg_bytes=MSG_BYTES)
    assert set(verbs.qp_specs()) == set(verbs.qp_init(cfg))


def test_quiesce_drains_to_empty_cq(mesh_pair):
    """Sync posts + flush (no poll) leave CQEs outstanding; quiesce must
    consume them all, close the window, and account the drains in the
    poller's completions counter."""
    mesh, _ = mesh_pair
    dp = _dp(mesh)
    cfg = verbs.QPConfig(msg_bytes=MSG_BYTES, depth=N_MSGS)
    qspec = verbs.qp_specs("rank")

    def fill_body(msgs, rt):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        for i in range(N_MSGS):
            qp, rt = verbs.post_send(dp, cfg, qp, msgs[0, i], rank, src=0,
                                     state=rt)
        qp, rt = verbs.flush_send(dp, cfg, qp, rank, src=0, dst=1, state=rt)
        return qp, verbs.allreduce_state(rt)

    def quiesce_body(qp, rt):
        rank = jax.lax.axis_index("rank")
        qp, rt = verbs.qp_quiesce(dp, cfg, qp, rank, src=0, state=rt)
        return qp, verbs.allreduce_state(rt)

    sm = compat.shard_map
    fill = jax.jit(sm(fill_body, mesh=mesh,
                      in_specs=(P("rank", None, None), P()),
                      out_specs=(qspec, P())))
    quiesce = jax.jit(sm(quiesce_body, mesh=mesh, in_specs=(qspec, P()),
                         out_specs=(qspec, P())))

    msgs, _ = _msgs()
    qp, _ = fill(msgs, dp.runtime_init())
    assert int(qp["cq_head"] - qp["cq_tail"]) == N_MSGS  # outstanding CQEs
    qp, rt = quiesce(qp, dp.runtime_init())
    snap = verbs.qp_snapshot(qp)
    assert int(snap["cq_head"] - snap["cq_tail"]) == 0
    assert int(snap["cq_sent"]) == int(snap["sq_head"]) == N_MSGS
    assert int(snap["cq_rcvd"]) == N_MSGS
    rep = dp.runtime_report(rt)[dp.tenant]
    assert rep["completions"] == N_MSGS
    # quiescing a clean QP is a no-op with no further completions
    qp2, rt2 = quiesce(qp, dp.runtime_init())
    assert dp.runtime_report(rt2)[dp.tenant]["completions"] == 0
    assert int(qp2["cq_head"] - qp2["cq_tail"]) == 0


def test_migrated_transfer_is_bit_identical(mesh_pair):
    """The acceptance invariant: a windowed transfer split around a
    quiesce → snapshot → restore onto a DIFFERENT mesh delivers the same
    bytes and ends with the same QP counters as an uninterrupted one,
    and credits granted before the move are spent after it."""
    mesh_a, mesh_b = mesh_pair
    conn_a = _conn(mesh_a, _dp(mesh_a), credits=N_MSGS)
    conn_b = _conn(mesh_b, _dp(mesh_b))
    msgs, payload = _msgs()
    dp_a, dp_b = _dp(mesh_a), _dp(mesh_b)

    qp, _ = conn_a["init"](dp_a.runtime_init())
    full_out, qp_full, _ = conn_a["xfer"](msgs, qp, dp_a.runtime_init())

    k = N_MSGS // 2
    qp, _ = conn_a["init"](dp_a.runtime_init())
    out1, qp, _ = conn_a["xfer"](msgs[:, :k], qp, dp_a.runtime_init())
    qp, _ = conn_a["quiesce"](qp, dp_a.runtime_init())
    snap = verbs.qp_snapshot(qp)
    assert int(snap["cq_head"] - snap["cq_tail"]) == 0
    assert int(snap["credits"]) == N_MSGS - k    # unspent credits survive
    assert int(snap["sq_head"]) == k
    qp_b = verbs.qp_restore(snap, mesh_b)
    out2, qp_b, _ = conn_b["xfer"](msgs[:, k:], qp_b, dp_b.runtime_init())

    moved = np.concatenate([np.asarray(out1)[1], np.asarray(out2)[1]])
    np.testing.assert_array_equal(moved, np.asarray(full_out)[1])
    np.testing.assert_array_equal(moved, payload)
    snap_b, snap_f = verbs.qp_snapshot(qp_b), verbs.qp_snapshot(qp_full)
    for key in ("sq_head", "cq_sent", "credits", "rx_owed"):
        assert int(snap_b[key]) == int(snap_f[key]), key


def test_qp_snapshot_restore_preserves_every_leaf(mesh_pair):
    mesh_a, mesh_b = mesh_pair
    conn = _conn(mesh_a, _dp(mesh_a), credits=N_MSGS)
    msgs, _ = _msgs()
    dp = _dp(mesh_a)
    qp, _ = conn["init"](dp.runtime_init())
    _, qp, _ = conn["xfer"](msgs[:, :3], qp, dp.runtime_init())
    qp, _ = conn["quiesce"](qp, dp.runtime_init())
    snap = verbs.qp_snapshot(qp)
    restored = verbs.qp_restore(snap, mesh_b)
    for key, val in snap.items():
        np.testing.assert_array_equal(np.asarray(restored[key]), val,
                                      err_msg=key)
    with pytest.raises(verbs.TransportError, match="missing keys"):
        verbs.qp_restore({"send_ring": snap["send_ring"]}, mesh_b)


# ---------------------------------------------------------------------------
# shrink_mesh + end-to-end controller remesh
# ---------------------------------------------------------------------------

def test_shrink_mesh_shapes(mesh8, mesh42):
    small = shrink_mesh(mesh8, 2)
    assert small.devices.shape == (4,) and small.axis_names == ("data",)
    assert list(small.devices.reshape(-1)) == \
        list(mesh8.devices.reshape(-1)[:4])
    # largest axis absorbs the shrink
    assert shrink_mesh(mesh42, 2).devices.shape == (2, 2)
    # refuses to go below min_devices / below the factor
    assert shrink_mesh(mesh8, 2, min_devices=8) is None
    two = shrink_mesh(mesh8, 4)
    assert two.devices.shape == (2,)
    assert shrink_mesh(two, 4) is None
    assert shrink_mesh(mesh8, 1) is None


def test_controller_remeshes_live_train_state(mesh42):
    """Sustained over-threshold windows drive exactly one remesh of a
    live TrainState onto the shrunken slice (max_remesh budget), with
    trigger+remesh events recorded and parameter values preserved."""
    cfg = get_model_config("gemma3-1b", smoke=True)
    state = init_state(build_model(cfg), RNG)
    before = [np.asarray(x) for x in jax.tree.leaves(state.params)]

    timeline = CounterTimeline(source="ctl")
    ecfg = ElasticConfig(enabled=True, thresholds=("denied_pct=50",),
                         sustain=2, cooldown=4, shrink_factor=2,
                         min_devices=2, max_remesh=1)
    ctl = ElasticController(ecfg, timeline, mesh42)

    timeline.snapshot(0, {"default": {"ops": 0, "denied": 0}}, t=0.0)
    state, moved = ctl.drive(state, 0)
    assert not moved                        # no windows yet
    for i in range(1, 4):
        timeline.snapshot(i, {"default": {"ops": 4.0 * i, "denied": 4.0 * i}},
                          t=float(i))
    state, moved = ctl.drive(state, 3)
    assert moved and ctl.remeshes == 1
    assert ctl.mesh.devices.shape == (2, 2)
    kinds = [e["kind"] for e in timeline.events]
    assert kinds == ["trigger", "remesh"]
    assert timeline.events[1]["detail"]["devices_after"] == 4
    # migration preserved every parameter bit
    after = [np.asarray(x) for x in jax.tree.leaves(state.params)]
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)
    # the remesh budget caps further moves even under sustained pressure,
    # and the unanswerable trigger is recorded, not swallowed
    for i in range(4, 12):
        timeline.snapshot(i, {"default": {"ops": 4.0 * i, "denied": 4.0 * i}},
                          t=float(i))
    state, moved = ctl.drive(state, 11)
    assert not moved and ctl.remeshes == 1
    assert timeline.events[-1]["kind"] == "remesh-skipped"
    assert "max_remesh" in timeline.events[-1]["detail"]["reason"]


def test_controller_records_skip_when_mesh_cannot_shrink():
    """A trigger on a mesh with nowhere to shrink to (e.g. the default
    single-device local run) must leave an explanatory event."""
    devs = jax.devices()
    tiny = compat.make_mesh((1,), ("data",), devices=devs[:1])
    timeline = CounterTimeline(source="tiny")
    ecfg = ElasticConfig(enabled=True, thresholds=("denied_pct=50",),
                         sustain=1, cooldown=0, min_devices=1)
    ctl = ElasticController(ecfg, timeline, tiny)
    timeline.snapshot(0, {"default": {"ops": 0, "denied": 0}}, t=0.0)
    timeline.snapshot(1, {"default": {"ops": 4, "denied": 4}}, t=1.0)
    state, moved = ctl.drive({"x": 1}, 1)
    assert not moved and ctl.remeshes == 0
    assert [e["kind"] for e in timeline.events] == \
        ["trigger", "remesh-skipped"]
    assert "no smaller mesh" in timeline.events[-1]["detail"]["reason"]
