"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Reads runs/dryrun/*.json (+ saved compiled HLO) and derives, per
(arch × shape × mesh):

    compute term    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips × 819 GB/s)
    collective term = collective_bytes / (chips × 50 GB/s/link ICI)

HLO_FLOPs/bytes come from the loop-aware analyzer (repro.analysis.hlo):
XLA's cost_analysis counts while bodies once, undercounting scans by ~L×
(calibrated in EXPERIMENTS.md).  Both raw and corrected values are
reported.  MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode),
with N = active params; the ratio MODEL/HLO flags remat & redundancy.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_SUGGEST = {
    "compute": "increase arithmetic efficiency (larger per-chip batch, "
               "fuse elementwise into matmuls) or accept — compute-bound is "
               "the roofline target",
    "memory": "cut HBM traffic: fuse/remat less, larger blocks (Pallas "
              "kernels), bf16 residents, avoid padded/replicated buffers",
    "collective": "reshard to shrink the dominant collective (different "
                  "TP/EP split), chunk + overlap collectives with compute, "
                  "or compress the payload",
}


def model_flops(meta: dict) -> float:
    n = meta.get("active_params") or meta.get("params", 0)
    kind = meta["kind"]
    shape_tokens = {"train": 4096 * 256, "prefill": 32768 * 32}
    if meta["shape"] == "long_500k":
        tokens = 1
    elif kind == "decode":
        tokens = 128
    else:
        tokens = shape_tokens.get(kind, 0)
        if meta["shape"] == "train_4k":
            tokens = 4096 * 256
        elif meta["shape"] == "prefill_32k":
            tokens = 32768 * 32
    mult = 6 if kind == "train" else 2
    return mult * n * tokens


def analyze_cell(path: str, *, use_hlo: bool = True) -> dict | None:
    with open(path) as f:
        rec = json.load(f)
    if not rec.get("ok"):
        return None
    chips = 512 if rec["multi_pod"] else 256

    flops_dev = rec["cost"]["flops_per_device"] or 0
    bytes_dev = rec["cost"]["bytes_per_device"] or 0
    coll_dev = rec.get("collective_bytes_total", 0)
    corrected = None
    hlo_path = path.replace(".json", ".hlo.gz")
    if use_hlo and os.path.exists(hlo_path):
        from repro.analysis.hlo import analyze_file
        corrected = analyze_file(hlo_path)
        flops_dev = max(flops_dev, corrected["flops"])
        bytes_dev = max(bytes_dev, corrected["bytes"])
        coll_dev = max(coll_dev, corrected["collective_bytes"])

    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec)
    mf_dev = mf / chips
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "chips": chips,
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom,
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": (mf_dev / flops_dev) if flops_dev else 0.0,
        "roofline_fraction": (mf_dev / PEAK_FLOPS) / max(terms[dom], 1e-30),
        "xla_flops_per_device": rec["cost"]["flops_per_device"],
        "corrected_flops_per_device": flops_dev,
        "suggestion": _SUGGEST[dom],
        "lower_s": rec.get("lower_s"), "compile_s": rec.get("compile_s"),
        "memory_temp_gib": (rec["memory"]["temp_bytes"] or 0) / 2**30,
        "memory_args_gib": (rec["memory"]["argument_bytes"] or 0) / 2**30,
        "params_gib_dev": rec.get("params_bytes_per_device", 0) / 2**30,
        "cache_gib_dev": rec.get("cache_bytes_per_device", 0) / 2**30,
    }


def run_all(dryrun_dir: str = "runs/dryrun", use_hlo: bool = True):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        try:
            row = analyze_cell(path, use_hlo=use_hlo)
        except Exception as e:  # noqa: BLE001
            row = {"arch": os.path.basename(path), "error": str(e)[:200]}
        if row:
            rows.append(row)
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful/HLO | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if "error" in r:
            lines.append(f"| {r['arch']} | ERROR {r['error'][:60]} |" + " |" * 7)
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    rows = run_all(use_hlo="--no-hlo" not in sys.argv)
    print(markdown_table(rows))
    with open("runs/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
