"""NPB-style MPI benchmark suite over the CoRD dataplane (paper Fig. 6).

Five kernels with the paper's communication profiles, running on an
8-rank shard_map mesh with every collective issued through the dataplane
(bypass / cord / socket modes — socket ≈ IPoIB):

  EP — embarrassingly parallel (one tiny all-reduce at the end)
  IS — integer bucket sort (histogram psum + all-to-all key exchange;
       message- AND data-intensive — the paper's worst case for IPoIB)
  CG — conjugate-gradient iterations on a banded operator (halo
       ppermute + dot-product psums; few large messages)
  FT — 2-D pencil FFT (large all-to-all transposes; data-intensive)
  MG — multigrid V-cycle (halo exchanges at every level; many small
       messages)

Reported: wall time per mode and runtime relative to bypass.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import DataplaneConfig
from repro.core.dataplane import Dataplane

RANKS = 8


def make_mesh():
    return jax.make_mesh((RANKS,), ("rank",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def make_dp(mode: str, mesh, *, syscall_ns=1500.0, interrupt_us=45.0,
            socket_ns=4000.0, socket_ns_per_byte=1.1) -> Dataplane:
    return Dataplane(DataplaneConfig(
        mode=mode, emulate_costs=True, syscall_cost_ns=syscall_ns,
        interrupt_cost_us=interrupt_us, socket_stack_ns=socket_ns,
        socket_ns_per_byte=socket_ns_per_byte),
        mesh=mesh)


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def build_ep(mesh, dp: Dataplane, n_per_rank: int = 1 << 18, steps: int = 4):
    def body(seed):
        rank = jax.lax.axis_index("rank")

        def one(carry, i):
            s = carry
            key = jax.random.fold_in(jax.random.PRNGKey(0), rank * 1000 + i)
            xy = jax.random.uniform(key, (n_per_rank, 2)) * 2 - 1
            r2 = (xy ** 2).sum(-1)
            acc = jnp.where(r2 <= 1.0, 1.0, 0.0).sum()
            return s + acc, None

        s, _ = jax.lax.scan(one, jnp.zeros(()), jnp.arange(steps))
        return dp.psum(s, "rank", tag="ep/final")

    return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P(),
                                 out_specs=P(), check_vma=False))


def build_is(mesh, dp: Dataplane, n_per_rank: int = 1 << 14, steps: int = 8):
    nbuckets = RANKS

    def body(keys):  # (RANKS, n) int32, rank-sharded
        rank = jax.lax.axis_index("rank")
        k = keys[0]

        def one(carry, i):
            k = carry
            # bucket by top bits → destination rank
            dest = k // (2**20 // nbuckets)
            hist = jnp.zeros((nbuckets,), jnp.int32).at[dest].add(1)
            hist = dp.psum(hist, "rank", tag="is/histogram")
            # sort locally by destination, then all-to-all exchange
            order = jnp.argsort(dest)
            ks = k[order].reshape(nbuckets, -1)
            recv = dp.all_to_all(ks, "rank", tag="is/exchange",
                                 split_axis=0, concat_axis=0)
            k2 = jnp.sort(recv.reshape(-1))
            # re-randomize for the next iteration (keeps sizes static)
            key = jax.random.fold_in(jax.random.PRNGKey(1), rank * 77 + i)
            return jax.random.randint(key, k.shape, 0, 2**20,
                                      jnp.int32) + (k2[:1] & 0), hist.sum()

        k, _ = jax.lax.scan(one, k, jnp.arange(steps))
        return k[None]

    return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P("rank"),
                                 out_specs=P("rank"), check_vma=False))


def build_cg(mesh, dp: Dataplane, n_per_rank: int = 1 << 15,
             iters: int = 12):
    def halo_matvec(x, rank):
        # banded operator: 3-point stencil across the rank boundary
        left = dp.ppermute(x[-1:], "rank",
                           [(i, (i + 1) % RANKS) for i in range(RANKS)],
                           tag="cg/halo_r")
        right = dp.ppermute(x[:1], "rank",
                            [(i, (i - 1) % RANKS) for i in range(RANKS)],
                            tag="cg/halo_l")
        xm = jnp.concatenate([left, x, right])
        return 2.0 * x - 0.5 * xm[:-2] - 0.5 * xm[2:] + 0.01 * x

    def body(b):  # (RANKS, n) rank-sharded rhs
        rank = jax.lax.axis_index("rank")
        b = b[0]
        x = jnp.zeros_like(b)
        r = b
        p = r
        rs = dp.psum(jnp.dot(r, r), "rank", tag="cg/dot")

        def one(carry, _):
            x, r, p, rs = carry
            ap = halo_matvec(p, rank)
            pap = dp.psum(jnp.dot(p, ap), "rank", tag="cg/dot")
            alpha = rs / jnp.maximum(pap, 1e-30)
            x = x + alpha * p
            r = r - alpha * ap
            rs_new = dp.psum(jnp.dot(r, r), "rank", tag="cg/dot")
            p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
            return (x, r, p, rs_new), None

        (x, r, p, rs), _ = jax.lax.scan(one, (x, r, p, rs), None,
                                        length=iters)
        return x[None]

    return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P("rank"),
                                 out_specs=P("rank"), check_vma=False))


def build_ft(mesh, dp: Dataplane, n: int = 512, steps: int = 3):
    # (n, n) grid, rows rank-sharded: FFT rows → transpose (all-to-all)
    # → FFT rows (= columns of the original) → inverse path.
    rows = n // RANKS

    def body(grid):  # (RANKS*rows, n) sharded on dim 0
        g = grid  # local (rows, n)

        def transpose(a):
            blocks = a.reshape(rows, RANKS, n // RANKS).swapaxes(0, 1)
            recv = dp.all_to_all(blocks, "rank", tag="ft/transpose",
                                 split_axis=0, concat_axis=0)
            return recv.reshape(RANKS, rows, n // RANKS) \
                .transpose(2, 0, 1).reshape(n // RANKS * RANKS, rows) \
                .astype(a.dtype)[: rows * RANKS].reshape(rows, -1) \
                if False else recv.reshape(n, n // RANKS).T

        def one(carry, _):
            g = carry
            g = jnp.fft.fft(g, axis=1)
            gt = transpose(g)
            gt = jnp.fft.fft(gt, axis=1)
            g = transpose(gt)
            g = jnp.fft.ifft(g, axis=1)
            return (g * (1.0 + 1e-6)).astype(g.dtype), None

        g, _ = jax.lax.scan(one, g.astype(jnp.complex64), None,
                            length=steps)
        return jnp.real(g)

    return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P("rank"),
                                 out_specs=P("rank"), check_vma=False))


def build_mg(mesh, dp: Dataplane, n_per_rank: int = 1 << 14,
             cycles: int = 3, levels: int = 5):
    def smooth(x, tag):
        left = dp.ppermute(x[-1:], "rank",
                           [(i, (i + 1) % RANKS) for i in range(RANKS)],
                           tag=f"mg/halo_r/{tag}")
        right = dp.ppermute(x[:1], "rank",
                            [(i, (i - 1) % RANKS) for i in range(RANKS)],
                            tag=f"mg/halo_l/{tag}")
        xm = jnp.concatenate([left, x, right])
        return 0.25 * xm[:-2] + 0.5 * x + 0.25 * xm[2:]

    def body(x0):
        x = x0[0]

        def vcycle(carry, _):
            x = carry
            grids = []
            g = x
            for lev in range(levels):          # restrict
                g = smooth(g, f"d{lev}")
                grids.append(g)
                g = g.reshape(-1, 2).mean(-1)
            for lev in reversed(range(levels)):  # prolong
                g = jnp.repeat(g, 2)
                g = smooth(g + grids[lev], f"u{lev}")
            return g, None

        x, _ = jax.lax.scan(vcycle, x, None, length=cycles)
        return x[None]

    return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P("rank"),
                                 out_specs=P("rank"), check_vma=False))


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

BENCHES = {
    "EP": (build_ep, lambda: jnp.zeros(())),
    "IS": (build_is, lambda: jax.random.randint(
        jax.random.PRNGKey(3), (RANKS, 1 << 14), 0, 2**20, jnp.int32)),
    "CG": (build_cg, lambda: jax.random.normal(
        jax.random.PRNGKey(4), (RANKS, 1 << 15))),
    "FT": (build_ft, lambda: jax.random.normal(
        jax.random.PRNGKey(5), (512, 512))),
    "MG": (build_mg, lambda: jax.random.normal(
        jax.random.PRNGKey(6), (RANKS, 1 << 14))),
}


def _measure(fn, arg, reps=3):
    jax.block_until_ready(fn(arg))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(arg))
        best = min(best, time.perf_counter() - t0)
    return best


def run_all(benches=None, modes=("bypass", "cord", "socket")):
    mesh = make_mesh()
    rows = []
    for name, (builder, arg_fn) in BENCHES.items():
        if benches and name not in benches:
            continue
        arg = arg_fn()
        base = None
        for mode in modes:
            dp = make_dp(mode, mesh)
            fn = builder(mesh, dp)
            t = _measure(fn, arg)
            if mode == "bypass":
                base = t
            comm = dp.telemetry.by_kind()
            rows.append({
                "table": "fig6", "bench": name, "mode": mode,
                "ms": round(t * 1e3, 2),
                "rel_runtime": round(t / base, 3),
                "comm_ops": int(sum(v["ops"] for v in comm.values())),
                "comm_mib": round(sum(v["bytes"] for v in comm.values())
                                  / 2**20, 2),
            })
    return rows


if __name__ == "__main__":
    import json
    for row in run_all():
        print(json.dumps(row))
