"""NPB-style MPI benchmark suite over the CoRD dataplane (paper Fig. 6).

Five kernels with the paper's communication profiles, running on an
8-rank shard_map mesh with every collective issued through the dataplane
(bypass / cord / socket modes — socket ≈ IPoIB):

  EP — embarrassingly parallel (one tiny all-reduce at the end)
  IS — integer bucket sort (histogram psum + all-to-all key exchange;
       message- AND data-intensive — the paper's worst case for IPoIB)
  CG — conjugate-gradient iterations on a banded operator (halo
       ppermute + dot-product psums; few large messages)
  FT — 2-D pencil FFT (large all-to-all transposes; data-intensive)
  MG — multigrid V-cycle (halo exchanges at every level; many small
       messages)

Every kernel threads the dataplane's per-tenant runtime state through its
shard_map body with the uniform ``(x, state)`` convention, so in ``cord``/
``socket`` mode the runtime op/byte counters are bumped on the measured
path (the per-op mediation work) and reported alongside the trace-time
telemetry.

Reported: wall time per mode, runtime relative to bypass, and both
accountings (trace-time comm_* and runtime rt_*).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import DataplaneConfig
from repro.core import compat
from repro.core.dataplane import Dataplane

RANKS = 8


def make_mesh():
    return compat.make_mesh((RANKS,), ("rank",))


def make_dp(mode: str, mesh, *, syscall_ns=1500.0, interrupt_us=45.0,
            socket_ns=4000.0, socket_ns_per_byte=1.1) -> Dataplane:
    return Dataplane(DataplaneConfig(
        mode=mode, emulate_costs=True, syscall_cost_ns=syscall_ns,
        interrupt_cost_us=interrupt_us, socket_stack_ns=socket_ns,
        socket_ns_per_byte=socket_ns_per_byte),
        mesh=mesh)


def _shard(body, mesh, in_spec):
    """shard_map a ``(arg, state) -> (out, state)`` kernel body."""
    return jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(in_spec, P()), out_specs=(in_spec, P())))


# ---------------------------------------------------------------------------
# kernels — every body is (arg, state) -> (out, state)
# ---------------------------------------------------------------------------

def build_ep(mesh, dp: Dataplane, n_per_rank: int = 1 << 18, steps: int = 4):
    def body(seed, rt):
        rank = jax.lax.axis_index("rank")

        def one(carry, i):
            s = carry
            key = jax.random.fold_in(jax.random.PRNGKey(0), rank * 1000 + i)
            xy = jax.random.uniform(key, (n_per_rank, 2)) * 2 - 1
            r2 = (xy ** 2).sum(-1)
            acc = jnp.where(r2 <= 1.0, 1.0, 0.0).sum()
            return s + acc, None

        s, _ = jax.lax.scan(one, jnp.zeros(()), jnp.arange(steps))
        out, rt = dp.psum(s, "rank", tag="ep/final", state=rt)
        return out + 0.0 * seed, rt

    return _shard(body, mesh, P())


def build_is(mesh, dp: Dataplane, n_per_rank: int = 1 << 14, steps: int = 8):
    nbuckets = RANKS

    def body(keys, rt):  # (RANKS, n) int32, rank-sharded
        rank = jax.lax.axis_index("rank")
        k = keys[0]

        def one(carry, i):
            k, rt = carry
            # bucket by top bits → destination rank
            dest = k // (2**20 // nbuckets)
            hist = jnp.zeros((nbuckets,), jnp.int32).at[dest].add(1)
            hist, rt = dp.psum(hist, "rank", tag="is/histogram", state=rt)
            # sort locally by destination, then all-to-all exchange
            order = jnp.argsort(dest)
            ks = k[order].reshape(nbuckets, -1)
            recv, rt = dp.all_to_all(ks, "rank", tag="is/exchange",
                                     split_axis=0, concat_axis=0, state=rt)
            k2 = jnp.sort(recv.reshape(-1))
            # re-randomize for the next iteration (keeps sizes static)
            key = jax.random.fold_in(jax.random.PRNGKey(1), rank * 77 + i)
            k = jax.random.randint(key, k.shape, 0, 2**20,
                                   jnp.int32) + (k2[:1] & 0)
            return (k, rt), hist.sum()

        (k, rt), _ = jax.lax.scan(one, (k, rt), jnp.arange(steps))
        return k[None], rt

    return _shard(body, mesh, P("rank"))


def build_cg(mesh, dp: Dataplane, n_per_rank: int = 1 << 15,
             iters: int = 12):
    def halo_matvec(x, rt):
        # banded operator: 3-point stencil across the rank boundary
        left, rt = dp.ppermute(x[-1:], "rank",
                               [(i, (i + 1) % RANKS) for i in range(RANKS)],
                               tag="cg/halo_r", state=rt)
        right, rt = dp.ppermute(x[:1], "rank",
                                [(i, (i - 1) % RANKS) for i in range(RANKS)],
                                tag="cg/halo_l", state=rt)
        xm = jnp.concatenate([left, x, right])
        return 2.0 * x - 0.5 * xm[:-2] - 0.5 * xm[2:] + 0.01 * x, rt

    def body(b, rt):  # (RANKS, n) rank-sharded rhs
        b = b[0]
        x = jnp.zeros_like(b)
        r = b
        p = r
        rs, rt = dp.psum(jnp.dot(r, r), "rank", tag="cg/dot", state=rt)

        def one(carry, _):
            x, r, p, rs, rt = carry
            ap, rt = halo_matvec(p, rt)
            pap, rt = dp.psum(jnp.dot(p, ap), "rank", tag="cg/dot", state=rt)
            alpha = rs / jnp.maximum(pap, 1e-30)
            x = x + alpha * p
            r = r - alpha * ap
            rs_new, rt = dp.psum(jnp.dot(r, r), "rank", tag="cg/dot",
                                 state=rt)
            p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
            return (x, r, p, rs_new, rt), None

        (x, r, p, rs, rt), _ = jax.lax.scan(one, (x, r, p, rs, rt), None,
                                            length=iters)
        return x[None], rt

    return _shard(body, mesh, P("rank"))


def build_ft(mesh, dp: Dataplane, n: int = 512, steps: int = 3):
    # (n, n) grid, rows rank-sharded: FFT rows → transpose (all-to-all)
    # → FFT rows (= columns of the original) → inverse path.
    rows = n // RANKS

    def body(grid, rt):  # (RANKS*rows, n) sharded on dim 0
        g = grid  # local (rows, n)

        def transpose(a, rt):
            blocks = a.reshape(rows, RANKS, n // RANKS).swapaxes(0, 1)
            recv, rt = dp.all_to_all(blocks, "rank", tag="ft/transpose",
                                     split_axis=0, concat_axis=0, state=rt)
            return recv.reshape(n, n // RANKS).T, rt

        def one(carry, _):
            g, rt = carry
            g = jnp.fft.fft(g, axis=1)
            gt, rt = transpose(g, rt)
            gt = jnp.fft.fft(gt, axis=1)
            g, rt = transpose(gt, rt)
            g = jnp.fft.ifft(g, axis=1)
            return ((g * (1.0 + 1e-6)).astype(g.dtype), rt), None

        (g, rt), _ = jax.lax.scan(one, (g.astype(jnp.complex64), rt), None,
                                  length=steps)
        return jnp.real(g), rt

    return _shard(body, mesh, P("rank"))


def build_mg(mesh, dp: Dataplane, n_per_rank: int = 1 << 14,
             cycles: int = 3, levels: int = 5):
    def smooth(x, rt, tag):
        left, rt = dp.ppermute(x[-1:], "rank",
                               [(i, (i + 1) % RANKS) for i in range(RANKS)],
                               tag=f"mg/halo_r/{tag}", state=rt)
        right, rt = dp.ppermute(x[:1], "rank",
                                [(i, (i - 1) % RANKS) for i in range(RANKS)],
                                tag=f"mg/halo_l/{tag}", state=rt)
        xm = jnp.concatenate([left, x, right])
        return 0.25 * xm[:-2] + 0.5 * x + 0.25 * xm[2:], rt

    def body(x0, rt):
        x = x0[0]

        def vcycle(carry, _):
            x, rt = carry
            grids = []
            g = x
            for lev in range(levels):          # restrict
                g, rt = smooth(g, rt, f"d{lev}")
                grids.append(g)
                g = g.reshape(-1, 2).mean(-1)
            for lev in reversed(range(levels)):  # prolong
                g = jnp.repeat(g, 2)
                g, rt = smooth(g + grids[lev], rt, f"u{lev}")
            return (g, rt), None

        (x, rt), _ = jax.lax.scan(vcycle, (x, rt), None, length=cycles)
        return x[None], rt

    return _shard(body, mesh, P("rank"))


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

BENCHES = {
    "EP": (build_ep, lambda: jnp.zeros(())),
    "IS": (build_is, lambda: jax.random.randint(
        jax.random.PRNGKey(3), (RANKS, 1 << 14), 0, 2**20, jnp.int32)),
    "CG": (build_cg, lambda: jax.random.normal(
        jax.random.PRNGKey(4), (RANKS, 1 << 15))),
    "FT": (build_ft, lambda: jax.random.normal(
        jax.random.PRNGKey(5), (512, 512))),
    "MG": (build_mg, lambda: jax.random.normal(
        jax.random.PRNGKey(6), (RANKS, 1 << 14))),
}


def _measure(fn, arg, rt, reps=3):
    """Best wall time over ``reps`` plus the (out, state) of the warmup."""
    result = jax.block_until_ready(fn(arg, rt))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(arg, rt))
        best = min(best, time.perf_counter() - t0)
    return best, result


def run_all(benches=None, modes=("bypass", "cord", "socket")):
    mesh = make_mesh()
    rows = []
    for name, (builder, arg_fn) in BENCHES.items():
        if benches and name not in benches:
            continue
        arg = arg_fn()
        base = None
        for mode in modes:
            dp = make_dp(mode, mesh)
            fn = builder(mesh, dp)
            t, (_, rt) = _measure(fn, arg, dp.runtime_init())
            if base is None:
                base = t
            comm = dp.telemetry.by_kind()
            runtime = dp.runtime_report(rt)[dp.tenant]
            rows.append({
                "table": "fig6", "bench": name, "mode": mode,
                "ms": round(t * 1e3, 2),
                "rel_runtime": round(t / base, 3),
                "comm_ops": int(sum(v["ops"] for v in comm.values())),
                "comm_mib": round(sum(v["bytes"] for v in comm.values())
                                  / 2**20, 2),
                "rt_ops": int(runtime["ops"]),
                "rt_mib": round(runtime["bytes"] / 2**20, 2),
            })
    return rows


if __name__ == "__main__":
    import json
    for row in run_all():
        print(json.dumps(row))
