"""perftest reproduction (paper §2 Fig. 1, §5 Figs. 3/4/5).

Measures point-to-point latency and throughput over the verbs layer on a
2-rank (CPU-device) mesh, with the paper's technique ablations and
mode matrix:

  fig1  — "remove" one technique at a time: baseline / no zero-copy /
          no kernel-bypass / no polling; latency + throughput vs msg size.
  fig3  — latency overhead matrix: {RC,UD} × {Send,Read,Write} ×
          {BP,CD}→{BP,CD}, relative to BP→BP.
  fig4  — CoRD/bypass throughput ratio + message rate vs msg size.
  fig5  — same harness under the "system A" cost preset (higher, noisier
          mediation costs — the cloud VM of the paper).
  window — bandwidth vs. sender-window depth (RC + UD) through the real
          CQ-driven async runtime (verbs.windowed_send), with the
          runtime's stall/credit/completion/CQ-depth counters per row.
  credits — flow-control ablation: credit-starved senders stall and
          resume; delivery stays complete and bit-identical.
  churn  — connection churn: rounds of shared-CQ/SRQ connection tables
          (verbs.conn_send) created, driven under injected wire loss,
          live-migrated mid-transfer onto a second mesh and torn down —
          ≥100 QPs total, every transfer bit-identical to lossless
          (docs/transport.md).

Cost scaling (EXPERIMENTS.md §Perftest): the CPU collective baseline is
~50× slower than real RDMA, so emulated mediation costs are calibrated as
*ratios to the measured bypass baseline* matching the paper's ratios
(syscall ≈ 0.15×L0, interrupt ≈ 4×L0); memory-copy costs are real copies
(no scaling).  The reproduced claims are therefore the relative-overhead
structure, which is what the paper argues from.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import DataplaneConfig
from repro.core import compat, verbs
from repro.core import telemetry as tl
from repro.core.dataplane import Dataplane

MSG_SIZES = [64, 1024, 4096, 32_768, 262_144, 1_048_576]


def make_mesh2():
    return compat.make_mesh((2,), ("rank",))


def _dp(mode: str, *, emulate=True, syscall_ns=400.0, interrupt_us=8.0,
        socket_ns=3000.0, zero_copy=True, polling=True, kernel_bypass=True,
        mesh=None) -> Dataplane:
    return Dataplane(DataplaneConfig(
        mode=mode, emulate_costs=emulate, syscall_cost_ns=syscall_ns,
        interrupt_cost_us=interrupt_us, socket_stack_ns=socket_ns,
        zero_copy=zero_copy, polling=polling, kernel_bypass=kernel_bypass),
        mesh=mesh)


# ---------------------------------------------------------------------------
# ping-pong latency
# ---------------------------------------------------------------------------

def build_pingpong(mesh, dp_client: Dataplane, dp_server: Dataplane,
                   msg_bytes: int, iters: int, transport="RC", op="send"):
    cfg = verbs.QPConfig(transport=transport, msg_bytes=msg_bytes, depth=1)

    def body(buf):
        rank = jax.lax.axis_index("rank")

        def one(carry, _):
            x = carry
            if op == "send":
                # client post (syscall side) → NIC → server completion
                x, _ = verbs.rank_mediate(x, rank, 0, dp_client)
                x = jax.lax.ppermute(x, "rank", [(0, 1)])
                x, _ = verbs.rank_complete(x, rank, 1, dp_server)
                # reply
                x, _ = verbs.rank_mediate(x, rank, 1, dp_server)
                x = jax.lax.ppermute(x, "rank", [(1, 0)])
                x, _ = verbs.rank_complete(x, rank, 0, dp_client)
            elif op == "write":
                # one-sided write: only the active (client) side mediates
                x, _ = verbs.rank_mediate(x, rank, 0, dp_client)
                x = jax.lax.ppermute(x, "rank", [(0, 1)])
                # perftest write latency: server writes back (its own post)
                x, _ = verbs.rank_mediate(x, rank, 1, dp_server)
                x = jax.lax.ppermute(x, "rank", [(1, 0)])
                x, _ = verbs.rank_complete(x, rank, 0, dp_client)
            else:  # read: client pulls; server CPU never involved
                x, _ = verbs.rank_mediate(x, rank, 0, dp_client)
                x = jax.lax.ppermute(x, "rank", [(1, 0)])   # data server→client
                x, _ = verbs.rank_complete(x, rank, 0, dp_client)
                x = jax.lax.ppermute(x, "rank", [(0, 1)])   # sync back
            return x, None

        x, _ = jax.lax.scan(one, buf, None, length=iters)
        return x

    shard = compat.shard_map(body, mesh=mesh, in_specs=P("rank"),
                             out_specs=P("rank"))
    return jax.jit(shard), cfg


def measure(fn, *args, warmup=2, reps=3) -> float:
    """Best wall time of fn(*args) in seconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def pingpong_latency_us(mesh, dp_c, dp_s, msg_bytes, *, iters=30,
                        transport="RC", op="send") -> float:
    fn, _ = build_pingpong(mesh, dp_c, dp_s, msg_bytes, iters,
                           transport, op)
    buf = jnp.zeros((2, msg_bytes), jnp.uint8)
    t = measure(fn, buf)
    # one-way latency = RTT/2 (paper convention); read = full op time
    div = iters * (2 if op != "read" else 1)
    return t / div * 1e6


# ---------------------------------------------------------------------------
# windowed throughput (message rate)
# ---------------------------------------------------------------------------

def build_throughput(mesh, dp_client: Dataplane, dp_server: Dataplane,
                     msg_bytes: int, window: int, iters: int,
                     transport="RC", op="send"):
    cfg = verbs.QPConfig(transport=transport, msg_bytes=msg_bytes,
                         depth=window)

    from repro.core import techniques as tech

    # Per-message mediation work comes straight from each endpoint's
    # compiled pipeline — the same cost model every other path runs.
    rec = tl.OpRecord(kind="verbs", tag=f"tput/{op}", bytes=msg_bytes,
                      axes=("rank",))
    post_it = dp_client.pipeline.send_delay_iters(rec)
    poll_side = 1 if op == "send" else 0
    dp_poll = dp_server if op == "send" else dp_client
    poll_it = dp_poll.pipeline.complete_delay_iters(rec)

    def body(ring):
        rank = jax.lax.axis_index("rank")

        def one(carry, _):
            ring = carry
            # `window` posts: serial per-message syscalls on the client —
            # one W×iters scalar chain, barrier-tied to the ring (the
            # payload is NOT rewritten per post: zero-copy means the NIC
            # reads the registered ring directly).
            if post_it:
                tok = jax.lax.cond(
                    rank == 0,
                    lambda: tech.delay_scalar(window * post_it),
                    lambda: jnp.float32(1.0))
                ring = tech.tie(ring, tok)
            if not dp_client.zero_copy:
                # per-message bounce copy = one staged copy of the ring
                ring = jax.lax.cond(rank == 0, tech.staged_copy,
                                    lambda r: r, ring)
            perm = [(0, 1)] if op != "read" else [(1, 0)]
            ring = jax.lax.ppermute(ring, "rank", perm)
            # completions: per-message interrupt/poll on the polling side
            if poll_it:
                tok = jax.lax.cond(
                    rank == poll_side,
                    lambda: tech.delay_scalar(window * poll_it),
                    lambda: jnp.float32(1.0))
                ring = tech.tie(ring, tok)
            if not dp_poll.zero_copy:
                ring = jax.lax.cond(rank == poll_side, tech.staged_copy,
                                    lambda r: r, ring)
            return ring, None

        ring, _ = jax.lax.scan(one, ring, None, length=iters)
        return ring

    shard = compat.shard_map(body, mesh=mesh, in_specs=P("rank"),
                             out_specs=P("rank"))
    return jax.jit(shard), cfg


def throughput(mesh, dp_c, dp_s, msg_bytes, *, window=64, iters=5,
               transport="RC", op="send"):
    """Returns (GBit/s, msgs/s)."""
    fn, _ = build_throughput(mesh, dp_c, dp_s, msg_bytes, window, iters,
                             transport, op)
    ring = jnp.zeros((2, window, msg_bytes), jnp.uint8)
    t = measure(fn, ring)
    msgs = window * iters
    return msgs * msg_bytes * 8 / t / 1e9, msgs / t


# ---------------------------------------------------------------------------
# CQ-driven windowed throughput (the async verbs runtime)
# ---------------------------------------------------------------------------

def build_windowed(mesh, dp_client: Dataplane, dp_server: Dataplane,
                   msg_bytes: int, n_msgs: int, window: int,
                   transport="RC", op="send", credits: int | None = None,
                   fault=None):
    """Compile one windowed transfer through ``verbs.windowed_send``: the
    real CQ runtime (sender window, credit flow control, per-CQE drains),
    with runtime counters threaded and psum-aggregated per connection.
    ``fault`` (a :class:`~repro.runtime.fault.WireFault`) injects wire
    loss and arms the go-back-N retransmission machine."""
    cfg = verbs.QPConfig(transport=transport, msg_bytes=msg_bytes,
                         depth=max(window, 2), max_outstanding=window)
    credits = n_msgs if credits is None else credits

    def body(msgs, rt):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        if op == "send":
            qp, rt = verbs.post_recv(dp_server, cfg, qp, rank, dst=1,
                                     n=credits, state=rt)
        out, qp, rt = verbs.windowed_send(dp_client, cfg, qp, msgs[0], rank,
                                          src=0, dst=1, op=op, state=rt,
                                          dp_peer=dp_server, fault=fault)
        rt = verbs.allreduce_state(rt)
        return (out[None], (qp["win_hwm"], qp["cq_hwm"], qp["cq_sent"]), rt)

    shard = compat.shard_map(body, mesh=mesh,
                             in_specs=(P("rank", None, None), P()),
                             out_specs=(P("rank", None, None),
                                        (P(), P(), P()), P()))
    return jax.jit(shard), cfg


def build_migratable(mesh, dp: Dataplane, msg_bytes: int, window: int,
                     transport="RC", credits: int = 0):
    """Jitted pieces of a *migratable* windowed connection on ``mesh``:
    ``init(rt)`` creates the QP (granting ``credits`` receiver credits),
    ``xfer(msgs, qp, rt)`` moves one batch through ``windowed_send``, and
    ``quiesce(qp, rt)`` drains it to a migratable snapshot.  The QP
    pytree is threaded through every shard_map boundary with
    ``verbs.qp_specs``, so between calls it can be stop-and-copied
    (``verbs.qp_snapshot``) and restored onto another mesh
    (``verbs.qp_restore``) — the live-migration flow the elastic smoke
    and tests/test_elastic_trigger.py drive (docs/elasticity.md)."""
    cfg = verbs.QPConfig(transport=transport, msg_bytes=msg_bytes,
                         depth=max(window, 2), max_outstanding=window)
    qspec = verbs.qp_specs("rank")

    def init_body(rt):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        if credits:
            qp, rt = verbs.post_recv(dp, cfg, qp, rank, dst=1, n=credits,
                                     state=rt)
        return qp, verbs.allreduce_state(rt)

    def xfer_body(msgs, qp, rt):
        rank = jax.lax.axis_index("rank")
        out, qp, rt = verbs.windowed_send(dp, cfg, qp, msgs[0], rank,
                                          src=0, dst=1, state=rt)
        return out[None], qp, verbs.allreduce_state(rt)

    def quiesce_body(qp, rt):
        rank = jax.lax.axis_index("rank")
        qp, rt = verbs.qp_quiesce(dp, cfg, qp, rank, src=0, state=rt)
        return qp, verbs.allreduce_state(rt)

    init = jax.jit(compat.shard_map(init_body, mesh=mesh, in_specs=(P(),),
                                    out_specs=(qspec, P())))
    xfer = jax.jit(compat.shard_map(
        xfer_body, mesh=mesh,
        in_specs=(P("rank", None, None), qspec, P()),
        out_specs=(P("rank", None, None), qspec, P())))
    quiesce = jax.jit(compat.shard_map(quiesce_body, mesh=mesh,
                                       in_specs=(qspec, P()),
                                       out_specs=(qspec, P())))
    return {"init": init, "xfer": xfer, "quiesce": quiesce, "cfg": cfg}


def build_conn_parts(mesh, dp: Dataplane, cfg, num_qps: int, *,
                     tenants=None, fault=None, credits: int = 0):
    """Jitted pieces of a migratable *connection table* (the
    :func:`build_migratable` analogue for the shared-CQ/SRQ transport):
    ``init(rt)`` builds the table (granting ``credits`` SRQ buffers),
    ``xfer(msgs, conn, rt)`` drives one ``verbs.conn_send`` batch —
    optionally through an injected :class:`WireFault` — and
    ``quiesce(conn, rt)`` drains the shared CQ to a migratable snapshot
    with per-QP retransmission state preserved (docs/transport.md)."""
    cspec = verbs.conn_specs()

    def init_body(rt):
        rank = jax.lax.axis_index("rank")
        conn = verbs.conn_init(cfg, num_qps)
        if credits:
            conn, rt = verbs.srq_post(dp, cfg, conn, rank, dst=1,
                                      n=credits, state=rt)
        return conn, verbs.allreduce_state(rt)

    def xfer_body(msgs, conn, rt):
        rank = jax.lax.axis_index("rank")
        out, conn, rt = verbs.conn_send(dp, cfg, conn, msgs[0], rank,
                                        src=0, dst=1, state=rt,
                                        tenants=tenants, fault=fault)
        return out[None], conn, verbs.allreduce_state(rt)

    def quiesce_body(conn, rt):
        rank = jax.lax.axis_index("rank")
        conn, rt = verbs.conn_quiesce(dp, cfg, conn, rank, src=0,
                                      state=rt, tenants=tenants)
        return conn, verbs.allreduce_state(rt)

    init = jax.jit(compat.shard_map(init_body, mesh=mesh, in_specs=(P(),),
                                    out_specs=(cspec, P())))
    xfer = jax.jit(compat.shard_map(
        xfer_body, mesh=mesh,
        in_specs=(P("rank", None, None, None), cspec, P()),
        out_specs=(P("rank", None, None, None), cspec, P())))
    quiesce = jax.jit(compat.shard_map(quiesce_body, mesh=mesh,
                                       in_specs=(cspec, P()),
                                       out_specs=(cspec, P())))
    return {"init": init, "xfer": xfer, "quiesce": quiesce}


def connection_churn(mesh_a, mesh_b=None, preset: "CostPreset | None" = None,
                     *, rounds=13, qps=8, n_msgs=4, msg_bytes=256, window=4,
                     drop_rate=0.1, corrupt_rate=0.05, emulate=True,
                     table="churn"):
    """Connection-churn sweep: ``rounds`` × ``qps`` connection tables
    (≥100 QPs at the defaults) are created, driven under injected wire
    loss, live-migrated *mid-transfer* onto a second mesh (quiesce →
    stop-and-copy → restore), completed there and torn down.  Every
    round asserts the combined delivery is bit-identical to the lossless
    payload — injected loss is non-terminal — and reports the table's
    retransmit/timeout/SRQ-grant counters.  Shapes are constant across
    rounds, so the compiled init/xfer/quiesce executables are reused."""
    from repro.runtime.fault import WireFault

    if mesh_b is None:
        devs = jax.devices()
        mesh_b = compat.make_mesh((2,), ("rank",), devices=devs[2:4]) \
            if len(devs) >= 4 else mesh_a
    kw = {} if preset is None else dict(syscall_ns=preset.syscall_ns,
                                        interrupt_us=preset.interrupt_us)
    dp_a = _dp("cord", emulate=emulate, mesh=mesh_a, **kw)
    dp_b = _dp("cord", emulate=emulate, mesh=mesh_b, **kw)
    cfg = verbs.QPConfig(msg_bytes=msg_bytes, depth=max(window, 2),
                         max_outstanding=window)
    fault = WireFault(drop_rate=drop_rate, corrupt_rate=corrupt_rate, seed=9)
    pa = build_conn_parts(mesh_a, dp_a, cfg, qps, fault=fault,
                          credits=qps * n_msgs * 2)
    pb = build_conn_parts(mesh_b, dp_b, cfg, qps, fault=fault)
    k = n_msgs // 2
    rows, churned = [], 0
    retrans = timeouts = grants = 0
    t0 = time.perf_counter()
    for rnd in range(rounds):
        rng = np.random.default_rng(1000 + rnd)
        payload = rng.integers(0, 256, (qps, n_msgs, msg_bytes),
                               dtype=np.uint8)
        msgs = jnp.asarray(np.stack([payload, np.zeros_like(payload)]))
        conn, _ = pa["init"](dp_a.runtime_init())
        out1, conn, _ = pa["xfer"](msgs[:, :, :k], conn,
                                   dp_a.runtime_init())
        conn, _ = pa["quiesce"](conn, dp_a.runtime_init())
        snap = verbs.conn_snapshot(conn)
        assert int(snap["cq_head"] - snap["cq_tail"]) == 0, \
            "shared CQ not quiesced"
        conn_b = verbs.conn_restore(snap, mesh_b)
        out2, conn_b, rt = jax.block_until_ready(
            pb["xfer"](msgs[:, :, k:], conn_b, dp_b.runtime_init()))
        moved = np.concatenate([np.asarray(out1)[1], np.asarray(out2)[1]],
                               axis=1)
        np.testing.assert_array_equal(
            moved, payload,
            err_msg=f"churn round {rnd}: lossy transfer not bit-identical")
        final = verbs.conn_snapshot(conn_b)
        retrans += int(final["retransmits"].sum())
        timeouts += int(final["timeouts"].sum())
        grants += int(final["srq_grants"].sum())
        churned += qps
        del conn, conn_b, snap, final                 # teardown
    dt = time.perf_counter() - t0
    rows.append({"table": table, "rounds": rounds, "qps_per_round": qps,
                 "qps_churned": churned, "bytes": msg_bytes,
                 "msgs_per_qp": n_msgs, "drop_rate": drop_rate,
                 "corrupt_rate": corrupt_rate, "bit_identical": True,
                 "retransmits": retrans, "timeouts": timeouts,
                 "srq_grants": grants,
                 "rounds_per_s": round(rounds / dt, 2)})
    return rows


def windowed_throughput(mesh, dp_c, dp_s, msg_bytes, *, window, n_msgs=32,
                        transport="RC", op="send", credits=None):
    """Returns (GBit/s, msgs/s, stats) for one CQ-runtime transfer."""
    fn, _ = build_windowed(mesh, dp_c, dp_s, msg_bytes, n_msgs, window,
                           transport, op, credits)
    msgs = jnp.zeros((2, n_msgs, msg_bytes), jnp.uint8)
    rt0 = dp_c.runtime_init()
    t = measure(fn, msgs, rt0)
    _, (win_hwm, cq_hwm, _), rt = jax.block_until_ready(fn(msgs, rt0))
    rep = dp_c.runtime_report(rt)[dp_c.tenant]
    stats = {"win_hwm": int(win_hwm), "cq_hwm": int(cq_hwm),
             "stalls": int(rep["stalls"]), "credits": int(rep["credits"]),
             "completions": int(rep["completions"]),
             "cq_depth": int(rep["cq_depth"])}
    return n_msgs * msg_bytes * 8 / t / 1e9, n_msgs / t, stats


def window_sweep(mesh, preset: "CostPreset | None" = None, *, sizes=(4096,),
                 windows=(1, 2, 4, 8, 16), n_msgs=32, table="window"):
    """Bandwidth vs. window depth through the CQ-driven path (paper §5
    deep-queue behaviour), RC and UD, with the runtime's stall/credit/
    completion/CQ-depth counters attached to every row."""
    kw = {} if preset is None else dict(syscall_ns=preset.syscall_ns,
                                        interrupt_us=preset.interrupt_us)
    rows = []
    for transport in ("RC", "UD"):
        ops = ("send", "write") if transport == "RC" else ("send",)
        for op in ops:
            for size in sizes:
                if transport == "UD" and size > verbs.UD_MTU:
                    continue
                for w in windows:
                    dp = _dp("cord", emulate=True, mesh=mesh, **kw)
                    gbps, rate, stats = windowed_throughput(
                        mesh, dp, dp, size, window=w, n_msgs=n_msgs,
                        transport=transport, op=op)
                    rows.append({"table": table, "transport": transport,
                                 "op": op, "bytes": size, "window": w,
                                 "gbps": round(gbps, 3),
                                 "msgs_per_s": round(rate), **stats})
    return rows


def credit_ablation(mesh, preset: "CostPreset | None" = None, *,
                    msg_bytes=4096, window=8, n_msgs=32,
                    credit_levels=(2, 8, 32), table="credits"):
    """Flow-control ablation: starve the sender of receiver credits and
    show the stall counter climbing while delivery stays complete."""
    kw = {} if preset is None else dict(syscall_ns=preset.syscall_ns,
                                        interrupt_us=preset.interrupt_us)
    rows = []
    for credits in credit_levels:
        dp = _dp("cord", emulate=True, mesh=mesh, **kw)
        gbps, rate, stats = windowed_throughput(
            mesh, dp, dp, msg_bytes, window=window, n_msgs=n_msgs,
            credits=credits)
        rows.append({"table": table, "bytes": msg_bytes, "window": window,
                     "rx_credits": credits, "gbps": round(gbps, 3),
                     "msgs_per_s": round(rate), **stats})
    return rows


def verify_windowed_matches_sync(mesh, mode="cord", msg_bytes=256,
                                 n_msgs=6, window=2,
                                 transport="RC") -> None:
    """Assert the CQ runtime delivers payloads bit-identical to the
    synchronous post/flush path (the acceptance invariant; also covered
    in tests/test_verbs_async.py)."""
    dp = _dp(mode, emulate=True, mesh=mesh)
    payload = np.arange(n_msgs * msg_bytes, dtype=np.uint8) \
        .reshape(n_msgs, msg_bytes)
    msgs = jnp.asarray(np.stack([payload, np.zeros_like(payload)]))

    fn, _ = build_windowed(mesh, dp, dp, msg_bytes, n_msgs, window,
                           transport)
    out, _, _ = fn(msgs, dp.runtime_init())
    windowed = np.asarray(out)[1]

    cfg = verbs.QPConfig(transport=transport, msg_bytes=msg_bytes,
                         depth=n_msgs)

    def sync(m):
        rank = jax.lax.axis_index("rank")
        qp = verbs.qp_init(cfg)
        for i in range(n_msgs):
            qp, _ = verbs.post_send(dp, cfg, qp, m[0, i], rank, src=0)
        qp, _ = verbs.flush_send(dp, cfg, qp, rank, src=0, dst=1)
        return qp["recv_ring"][None]

    ring = jax.jit(compat.shard_map(sync, mesh=mesh,
                                    in_specs=P("rank", None, None),
                                    out_specs=P("rank", None, None)))(msgs)
    np.testing.assert_array_equal(windowed, np.asarray(ring)[1][:n_msgs])


# ---------------------------------------------------------------------------
# calibrated cost presets
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CostPreset:
    name: str
    syscall_ns: float
    interrupt_us: float
    socket_ns: float


def calibrate_presets(mesh) -> dict[str, CostPreset]:
    """Scale emulated costs to the measured bypass baseline so the
    overhead *ratios* match the paper's systems (see module docstring)."""
    dp0 = _dp("bypass", emulate=False, mesh=mesh)
    l0_us = pingpong_latency_us(mesh, dp0, dp0, 4096, iters=30)
    return {
        # system L: syscall ≈ 0.15·L0, interrupt ≈ 4·L0
        "L": CostPreset("L", syscall_ns=0.15 * l0_us * 1e3,
                        interrupt_us=4.0 * l0_us,
                        socket_ns=1.2 * l0_us * 1e3),
        # system A (cloud VM): ~2× higher mediation costs
        "A": CostPreset("A", syscall_ns=0.3 * l0_us * 1e3,
                        interrupt_us=6.0 * l0_us,
                        socket_ns=2.0 * l0_us * 1e3),
    }, l0_us


# ---------------------------------------------------------------------------
# paper tables
# ---------------------------------------------------------------------------

def fig1(mesh, preset: CostPreset, sizes=None):
    """Technique ablation: latency + throughput per message size."""
    sizes = sizes or MSG_SIZES
    variants = {
        "baseline": dict(),
        "no_zero_copy": dict(zero_copy=False),
        "no_kernel_bypass": dict(kernel_bypass=False),
        "no_polling": dict(polling=False),
    }
    rows = []
    for name, kw in variants.items():
        dp = _dp("bypass", emulate=True, syscall_ns=preset.syscall_ns,
                 interrupt_us=preset.interrupt_us, mesh=mesh, **kw)
        for size in sizes:
            lat = pingpong_latency_us(mesh, dp, dp, size, iters=20)
            gbps, rate = throughput(mesh, dp, dp, size, window=32, iters=4)
            rows.append({"table": "fig1", "variant": name, "bytes": size,
                         "latency_us": round(lat, 2),
                         "gbps": round(gbps, 3),
                         "msgs_per_s": round(rate)})
    return rows


def fig3(mesh, preset: CostPreset, msg_bytes=4096, table="fig3"):
    """Latency overhead matrix vs BP→BP."""
    rows = []
    combos = [("BP", "BP"), ("CD", "BP"), ("BP", "CD"), ("CD", "CD")]
    for transport in ("RC", "UD"):
        ops = ("send", "read", "write") if transport == "RC" else ("send",)
        for op in ops:
            base = None
            for cm, sm in combos:
                mk = lambda m: _dp(
                    "cord" if m == "CD" else "bypass", emulate=True,
                    syscall_ns=preset.syscall_ns,
                    interrupt_us=preset.interrupt_us, mesh=mesh)
                lat = pingpong_latency_us(mesh, mk(cm), mk(sm), msg_bytes,
                                          iters=20, transport=transport,
                                          op=op)
                if (cm, sm) == ("BP", "BP"):
                    base = lat
                rows.append({"table": table, "transport": transport,
                             "op": op, "client": cm, "server": sm,
                             "latency_us": round(lat, 2),
                             "overhead_us": round(lat - base, 2)})
    return rows


def fig4(mesh, preset: CostPreset, sizes=None, table="fig4"):
    """CoRD relative throughput + bypass message rate."""
    sizes = sizes or MSG_SIZES
    rows = []
    for transport in ("RC", "UD"):
        ops = ("send", "read", "write") if transport == "RC" else ("send",)
        for op in ops:
            for size in sizes:
                if transport == "UD" and size > verbs.UD_MTU:
                    continue
                dp_b = _dp("bypass", emulate=True, mesh=mesh)
                dp_c = _dp("cord", emulate=True,
                           syscall_ns=preset.syscall_ns,
                           interrupt_us=preset.interrupt_us, mesh=mesh)
                g_b, r_b = throughput(mesh, dp_b, dp_b, size, window=32,
                                      iters=4, transport=transport, op=op)
                g_c, r_c = throughput(mesh, dp_c, dp_c, size, window=32,
                                      iters=4, transport=transport, op=op)
                rows.append({"table": table, "transport": transport,
                             "op": op, "bytes": size,
                             "rel_throughput": round(g_c / g_b, 4),
                             "bypass_msgs_per_s": round(r_b),
                             "cord_msgs_per_s": round(r_c)})
    return rows


def run_all(fast: bool = False):
    mesh = make_mesh2()
    presets, l0 = calibrate_presets(mesh)
    sizes = [64, 4096, 262_144] if fast else MSG_SIZES
    rows = [{"table": "calibration", "baseline_latency_us": round(l0, 2),
             "syscall_ns": round(presets['L'].syscall_ns),
             "interrupt_us": round(presets['L'].interrupt_us, 1)}]
    rows += fig1(mesh, presets["L"], sizes)
    rows += fig3(mesh, presets["L"])
    rows += fig4(mesh, presets["L"], sizes)
    # CQ-runtime window-depth sweep + credit flow-control ablation
    wsizes = (4096,) if fast else (4096, 65_536)
    windows = (1, 4, 16) if fast else (1, 2, 4, 8, 16)
    rows += window_sweep(mesh, presets["L"], sizes=wsizes, windows=windows)
    rows += credit_ablation(mesh, presets["L"])
    # connection churn: ≥100 QPs through create/migrate/teardown under
    # injected wire loss, every transfer bit-identical to lossless
    rows += connection_churn(mesh, preset=presets["L"])
    # fig5 = system A preset
    rows += fig3(mesh, presets["A"], table="fig5_lat")
    rows += fig4(mesh, presets["A"], sizes, table="fig5_bw")
    return rows


def dry_run() -> None:
    """CI smoke for the CQ-driven path: verify windowed delivery is
    bit-identical to the synchronous flush, then run a minimal RC+UD
    window sweep and one credit-starved transfer."""
    import json
    mesh = make_mesh2()
    verify_windowed_matches_sync(mesh)
    print(json.dumps({"table": "dryrun", "windowed_vs_sync": "bit-identical"}))
    for row in window_sweep(mesh, sizes=(1024,), windows=(1, 4), n_msgs=8,
                            table="window_dryrun"):
        print(json.dumps(row))
    for row in credit_ablation(mesh, msg_bytes=1024, window=4, n_msgs=8,
                               credit_levels=(2, 8), table="credits_dryrun"):
        print(json.dumps(row))
        if row["rx_credits"] < 8:
            assert row["stalls"] > 0, "credit starvation produced no stalls"
        assert row["completions"] == 8, "not every message completed"
    # connection churn under wire loss: the full ≥100-QP sweep runs with
    # costs off, so it stays CI-fast; connection_churn asserts every
    # migrated lossy transfer is bit-identical internally
    for row in connection_churn(mesh, emulate=False, msg_bytes=64,
                                table="churn_dryrun"):
        print(json.dumps(row))
        assert row["qps_churned"] >= 100, row
        assert row["retransmits"] > 0, "wire loss injected nothing"
    print("perftest dry-run ok")


if __name__ == "__main__":
    import json
    import sys

    from benchmarks._bootstrap import ensure_host_devices

    # 4 host devices: the churn sweep migrates tables onto a second mesh
    ensure_host_devices(4, module="benchmarks.perftest")
    if "--dry-run" in sys.argv:
        dry_run()
    else:
        for row in run_all(fast="--fast" in sys.argv):
            print(json.dumps(row))
